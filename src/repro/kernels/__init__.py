# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.


def pad_to(x: int, m: int) -> int:
    """Round ``x`` up to the next multiple of ``m`` — the one padding
    rule shared by the kernel entry points (auto-padding N/C to block
    multiples) and the ops wrappers (lane-padding D/K to 128)."""
    return ((x + m - 1) // m) * m
