"""Multi-tenant request stream primitives for the continuous mining
service — admission control and fair scheduling over per-tenant queues.

The paper's workflow engine runs ONE application's DAG; real grid load
("Mining the Workload of Real Grid Computing Systems", arXiv:1412.2673)
is a bursty stream of arrivals from many users.  This module is the
request-side half of that gap, deliberately kept in ``workflow`` next to
the scheduler whose per-site slot/queue machinery the service leans on:

  * :class:`MiningRequest` — one tenant's mining query (app + dataset +
    params), with the lifecycle timestamps the service's ledger reports;
  * :class:`TenantQueues` — bounded per-tenant FIFO queues (admission
    control: a full queue REJECTS instead of growing without bound) with
    a deterministic fair picker: round-robin across tenants with pending
    work, or weighted round-robin when tenants carry weights — a tenant
    is never starved while it has queued work, and with equal weights
    and saturated queues the per-pick counts across tenants differ by
    at most one per cycle (the fairness bound the CI smoke gates).

Execution — coalescing identical requests, batching onto the mesh, the
result cache — is the service's job (``launch.serve``); nothing here
touches jax.
"""

from __future__ import annotations

import itertools
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Any

REQUEST_STATES = ("queued", "running", "done", "failed", "rejected")

# Hard per-cycle burst ceiling for weighted round-robin.  Normalizing a
# fractional weight map by its smallest entry preserves ratios exactly,
# but an extreme map like ``{a: 1.0, b: 1e-6}`` would then grant tenant
# ``a`` a ~1e6-pick burst before the cursor ever reaches ``b`` — a
# starvation window no ratio is worth.  Grants are therefore clamped to
# this bound: ratios are honored exactly up to MAX_BURST:1 and saturate
# beyond it, so within any cycle every backlogged tenant is picked at
# least once per MAX_BURST picks of any other tenant.
MAX_BURST = 16


class QueueFullError(RuntimeError):
    """Admission control: the tenant's bounded queue is at capacity."""


@dataclass
class MiningRequest:
    """One mining query from one tenant, as the service tracks it.

    ``params`` are app-specific (e.g. ``{"k": 3, "minsup": 0.1}``); the
    service canonicalizes them (``runtime.cache.params_key``) for both
    coalescing and cache keying.  Timestamps are service-clock seconds
    (``submitted_at`` set at admission, ``started_at`` when the request
    leaves its queue for execution, ``finished_at`` at completion);
    ``queue_wait_s``/``service_s`` are derived for the ledger.
    """

    request_id: int
    tenant: str
    app: str
    dataset: str
    params: dict = field(default_factory=dict)
    status: str = "queued"
    submitted_at: float = 0.0
    started_at: float | None = None
    finished_at: float | None = None
    # filled at completion by the service's ledger:
    dataset_version: int | None = None
    cache_hit: bool = False
    coalesced_into: int | None = None  # request_id whose execution served this
    backend: str | None = None
    compute_s: float = 0.0  # this request's share of measured device compute
    fused: bool = False  # served by a cross-request fused dispatch
    error: str | None = None

    @property
    def queue_wait_s(self) -> float:
        if self.started_at is None:
            return 0.0
        return max(self.started_at - self.submitted_at, 0.0)

    @property
    def service_s(self) -> float:
        """Admission to completion — the tenant-visible latency."""
        if self.finished_at is None:
            return 0.0
        return max(self.finished_at - self.submitted_at, 0.0)


class TenantQueues:
    """Bounded per-tenant FIFO queues with deterministic weighted
    round-robin picking.

    ``max_depth`` bounds EACH tenant's queue (admission control);
    ``weights`` maps tenant -> positive share (unknown tenants get 1.0).
    The picker walks tenants in first-seen order from a persistent
    cursor; a tenant with weight w may be picked up to ``ceil(w)`` times
    per full cycle before the cursor moves on, so over any window in
    which every tenant stays backlogged, tenant i's share of picks
    converges to w_i / sum(w) — and with uniform weights the picks per
    cycle differ by at most one across tenants (the bound
    ``tests/test_service.py`` and the CI smoke assert).

    Burst grants are integer pick counts, so the ratio contract only
    holds when every weight is >= 1 (a weight of 0.5 would otherwise
    round up to the same one-pick-per-cycle as weight 1).  Fractional
    weight maps are therefore NORMALIZED at construction: when the
    smallest weight is below 1, every weight is divided by it, which
    preserves the ratios exactly — ``{a: 1, b: 0.5}`` grants the same
    2:1 shares as ``{a: 2, b: 1}``.  Tenants absent from the map keep
    weight 1.0, i.e. they share like the smallest-weighted tenant.

    Per-cycle grants are BOUNDED: the integer grant table derived from
    the (normalized) weights clamps every entry to ``[1, MAX_BURST]``,
    so an extreme map like ``{a: 1.0, b: 1e-6}`` grants ``a`` at most
    ``MAX_BURST`` consecutive picks instead of a ~1e6-pick starvation
    burst — ratios are preserved exactly up to ``MAX_BURST:1`` and
    saturate beyond it (``grant_table`` exposes the realized grants).
    """

    def __init__(self, max_depth: int = 64, weights: dict[str, float] | None = None):
        if max_depth < 1:
            raise ValueError(f"max_depth must be >= 1, got {max_depth}")
        self.max_depth = max_depth
        self.weights = dict(weights or {})
        for t, w in self.weights.items():
            if w <= 0:
                raise ValueError(f"tenant {t!r} weight must be > 0, got {w}")
        if self.weights:
            smallest = min(self.weights.values())
            if smallest < 1.0:
                self.weights = {t: w / smallest for t, w in self.weights.items()}
        self._queues: OrderedDict[str, deque[MiningRequest]] = OrderedDict()
        self._cursor = 0  # index into first-seen tenant order
        self._burst = 0  # picks granted to the cursor tenant this cycle
        self.rejected = 0

    # -- admission -----------------------------------------------------------

    def push(self, req: MiningRequest) -> None:
        """Admit one request, or reject it (``QueueFullError``, the
        request marked ``rejected``) when the tenant's queue is full."""
        q = self._queues.setdefault(req.tenant, deque())
        if len(q) >= self.max_depth:
            req.status = "rejected"
            self.rejected += 1
            raise QueueFullError(
                f"tenant {req.tenant!r} queue is full "
                f"({self.max_depth} pending requests); retry after a drain"
            )
        q.append(req)

    # -- introspection -------------------------------------------------------

    def depth(self, tenant: str) -> int:
        return len(self._queues.get(tenant, ()))

    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def tenants(self) -> list[str]:
        return list(self._queues)

    # -- fair picking --------------------------------------------------------

    def _weight(self, tenant: str) -> float:
        return float(self.weights.get(tenant, 1.0))

    def _grant(self, tenant: str) -> int:
        """Integer picks-per-cycle grant: the tenant's (normalized)
        weight rounded to an integer and clamped to ``[1, MAX_BURST]`` —
        the bounded grant table that caps burst starvation under extreme
        fractional weights while preserving moderate ratios exactly."""
        return max(1, min(MAX_BURST, round(self._weight(tenant))))

    def grant_table(self) -> dict[str, int]:
        """The realized per-cycle grants for every weighted tenant
        (unlisted tenants get 1) — what the fairness property tests and
        the service ledger audit."""
        return {t: self._grant(t) for t in self.weights}

    def pick(self) -> MiningRequest | None:
        """Pop the next request under weighted round-robin, or None when
        every queue is empty.  Deterministic: depends only on push/pick
        history and the weights."""
        order = list(self._queues)
        if not order:
            return None
        for _ in range(2 * len(order) + 1):
            self._cursor %= len(order)
            tenant = order[self._cursor]
            q = self._queues[tenant]
            if q and self._burst < self._grant(tenant):
                self._burst += 1
                return q.popleft()
            self._cursor += 1
            self._burst = 0
        return None

    def pick_batch(self, max_requests: int) -> list[MiningRequest]:
        """Up to ``max_requests`` fair picks — one service dispatch wave."""
        out: list[MiningRequest] = []
        for _ in range(max_requests):
            req = self.pick()
            if req is None:
                break
            out.append(req)
        return out


def request_ids() -> itertools.count:
    """Monotonic request-id source (one per service instance)."""
    return itertools.count(1)


def coalesce(batch: list[MiningRequest], keyfn) -> "OrderedDict[Any, list[MiningRequest]]":
    """Group a picked batch by execution key (first-pick order): requests
    sharing ``keyfn(req)`` — same dataset version, app and canonical
    params — are one execution, with the first request as the
    representative and the rest marked ``coalesced_into`` it by the
    service after the run."""
    groups: OrderedDict[Any, list[MiningRequest]] = OrderedDict()
    for req in batch:
        groups.setdefault(keyfn(req), []).append(req)
    return groups
