"""Execution-backend layer: resolution, batched grouping semantics, and
inline-vs-batched equivalence of the mining applications.

The contract under test: backends change HOW job callables execute
(dispatch fusion), never WHAT the scheduler decides — results, ledgers
and fixed-placement scheduling fingerprints must be identical across
backends.
"""

import jax
import numpy as np
import pytest

from repro.core.apriori import TransactionDB
from repro.core.vclustering import VClusterConfig
from repro.data.synthetic import (
    gaussian_mixture,
    ibm_transactions,
    split_sites,
    split_transactions,
)
from repro.runtime import GridRuntime
from repro.workflow.dag import DAG, TimedResult
from repro.workflow.engine import Engine
from repro.workflow.executor import (
    BACKENDS,
    BatchedBackend,
    ExecutionBackend,
    InlineBackend,
    Partition,
    resolve_backend,
)
from repro.workflow.faults import FaultInjector
from repro.workflow.overhead import GridModel
from repro.workflow.sitejob import (
    MissingJobTimeWarning,
    SiteJob,
    job_specs,
    merge_owner_times,
    timed_batch,
)


class TestResolveBackend:
    def test_names(self):
        assert isinstance(resolve_backend("inline"), InlineBackend)
        assert isinstance(resolve_backend("batched"), BatchedBackend)
        assert resolve_backend("multihost").name == "multihost"
        assert resolve_backend(None).name == "inline"

    def test_instance_passthrough(self):
        b = BatchedBackend()
        assert resolve_backend(b) is b

    def test_unknown_raises(self):
        with pytest.raises(ValueError, match="unknown backend"):
            resolve_backend("gpu-cluster")
        with pytest.raises(ValueError, match="unknown backend"):
            Engine(backend="gpu-cluster")

    def test_registry_names(self):
        assert BACKENDS == ("inline", "batched", "multihost")

    def test_engine_default_is_inline(self):
        eng = Engine(model=GridModel(prep_latency_s=0.0))
        dag = DAG("d")
        dag.job("a", lambda: 1)
        rep = eng.run(dag)
        assert rep.backend == "inline"

    def test_min_batch_validation(self):
        with pytest.raises(ValueError, match="min_batch"):
            BatchedBackend(min_batch=0)

    def test_runtime_honors_configured_backend_instance(self):
        """A configured ExecutionBackend instance must survive the
        GridRuntime engine rebuild even when its NAME matches the
        engine's current backend."""
        mine = BatchedBackend(min_batch=4)
        rt = GridRuntime(
            engine=Engine(model=GridModel(), backend="batched"),
            sync="pooled", backend=mine,
        )
        assert rt.engine.backend is mine
        # and a matching name as a string keeps the engine untouched
        eng = Engine(model=GridModel(), backend="batched")
        assert GridRuntime(engine=eng, sync="pooled", backend="batched").engine is eng


def _fanout_dag(n=4, calls=None, record=None):
    """n same-key leaf jobs + a collector; the fused fn counts its
    invocations and which members each call covered."""
    calls = calls if calls is not None else []

    def fused(bargs, argss):
        calls.append(tuple(bargs))
        return [10 * i for i in bargs]

    bf = timed_batch(fused, record)
    dag = DAG("fanout")
    for i in range(n):
        dag.job(
            f"leaf_{i}",
            lambda i=i: TimedResult(10 * i, 0.0),
            batch_key="leaf",
            batched_fn=bf,
            batch_arg=i,
        )
    dag.job("sum", lambda *xs: sum(xs), deps=[f"leaf_{i}" for i in range(n)])
    return dag, calls


class TestBatchedBackend:
    @pytest.mark.parametrize("schedule", ["staged", "async"])
    def test_one_fused_call_covers_fanout(self, schedule):
        record = {}
        dag, calls = _fanout_dag(4, record=record)
        results = {}
        eng = Engine(model=GridModel(prep_latency_s=0.0), schedule=schedule, backend="batched")
        rep = eng.run(dag, results=results)
        assert calls == [(0, 1, 2, 3)]  # ONE fused dispatch for the whole group
        assert results["sum"] == 60
        assert rep.backend == "batched"
        # apportioning: every member gets the same share, ledgered in both
        # job_times and the record dict
        shares = {rep.job_times[f"leaf_{i}"] for i in range(4)}
        assert len(shares) == 1
        assert record == {f"leaf_{i}": rep.job_times["leaf_0"] for i in range(4)}

    def test_singleton_falls_back_to_fn(self):
        dag, calls = _fanout_dag(1)
        eng = Engine(model=GridModel(prep_latency_s=0.0), backend="batched")
        results = {}
        eng.run(dag, results=results)
        assert calls == []  # no vmap-of-one: plain fn path
        assert results["leaf_0"] == 0

    def test_min_batch_one_forces_fused_singleton(self):
        """min_batch=1 pushes even a singleton group through batched_fn
        (profiling the fused path) — the configured value is honored."""
        dag, calls = _fanout_dag(1)
        eng = Engine(
            model=GridModel(prep_latency_s=0.0), backend=BatchedBackend(min_batch=1)
        )
        results = {}
        eng.run(dag, results=results)
        assert calls == [(0,)]
        assert results["leaf_0"] == 0

    def test_min_batch_threshold(self):
        dag, calls = _fanout_dag(3)
        eng = Engine(
            model=GridModel(prep_latency_s=0.0), backend=BatchedBackend(min_batch=4)
        )
        results = {}
        eng.run(dag, results=results)
        assert calls == []  # group smaller than min_batch: inline path
        assert results["sum"] == 30

    def test_unready_peers_excluded(self):
        """Same batch_key but one member's dependency has not produced a
        result at fuse time: the fused call covers only the ready
        members; the straggler later falls back to its own fn (a
        singleton is never vmapped)."""
        calls = []

        def fused(bargs, argss):
            calls.append(tuple(bargs))
            return [100 + i for i in bargs]

        bf = timed_batch(fused)
        dag = DAG("staggered")
        dag.job("a", lambda: TimedResult(101, 0.0), batch_key="g", batched_fn=bf, batch_arg=1)
        dag.job("b", lambda: TimedResult(102, 0.0), batch_key="g", batched_fn=bf, batch_arg=2)
        # "late" is inserted AFTER a/b, so when a executes (first in the
        # stage loop) late has no result yet and c must be excluded
        dag.job("late", lambda: TimedResult(0, 0.0))
        dag.job(
            "c", lambda r: TimedResult(103, 0.0), deps=["late"],
            batch_key="g", batched_fn=bf, batch_arg=3,
        )
        results = {}
        Engine(model=GridModel(prep_latency_s=0.0), backend="batched").run(dag, results=results)
        assert calls == [(1, 2)]  # c excluded from the fuse, then singleton
        assert results["a"] == 101 and results["b"] == 102 and results["c"] == 103

    def test_mismatched_batch_output_raises(self):
        def bad_fused(names, bargs, argss):
            return [TimedResult(0, 0.0)]  # wrong arity

        dag = DAG("bad")
        dag.job("a", lambda: 0, batch_key="g", batched_fn=bad_fused, batch_arg=0)
        dag.job("b", lambda: 0, batch_key="g", batched_fn=bad_fused, batch_arg=1)
        with pytest.raises(RuntimeError, match="returned 1 results for 2"):
            Engine(model=GridModel(prep_latency_s=0.0), backend="batched").run(dag)


class TestBatchedWithFaults:
    def test_retry_consumes_cached_result(self):
        """An injected failure retries the job; the retry must consume
        the batch-cached result, not re-execute the fused call."""
        dag, calls = _fanout_dag(3)
        eng = Engine(
            model=GridModel(prep_latency_s=0.0),
            faults=FaultInjector(fail={"leaf_1": 1}),
            backend="batched",
        )
        results = {}
        rep = eng.run(dag, results=results)
        assert calls == [(0, 1, 2)]  # still exactly one fused dispatch
        assert rep.retries == 1
        assert results["sum"] == 30


def _mining_inputs():
    pts, _ = gaussian_mixture(0, 600, 2, 3, spread=10.0, sigma=0.7)
    xs = split_sites(pts, 3, seed=1)
    dense = ibm_transactions(seed=2, n_tx=300, n_items=18, avg_tx_len=6, n_patterns=6)
    sites = [TransactionDB.from_dense(s) for s in split_transactions(dense, 3, seed=0)]
    return xs, sites


def scheduler_fingerprint(rep):
    """What the scheduler decided, independent of measured compute: the
    backend must not change any of it under fixed placement."""
    return (
        rep.schedule,
        rep.placement,
        tuple(sorted(rep.placements.items())),
        rep.prep_s,
        rep.submit_s,
        rep.transfer_s,
        rep.retries,
        rep.speculative,
        tuple(sorted(rep.job_times)),
    )


class TestBackendEquivalence:
    """inline and batched must produce identical mining results and
    identical fixed-placement scheduler fingerprints on both engine
    schedulers — batching fuses dispatches, nothing else."""

    @pytest.fixture(scope="class")
    def runs(self):
        xs, sites = _mining_inputs()
        cfg = VClusterConfig(k_local=4, kmeans_iters=6, use_kernel=False)
        out = {}
        for schedule in ("staged", "async"):
            for backend in ("inline", "batched"):
                rt = GridRuntime(
                    sync="pooled", use_kernel=False, count_backend="jnp",
                    schedule=schedule, backend=backend,
                )
                out[(schedule, backend)] = (
                    rt.run_vclustering(jax.random.PRNGKey(0), xs, cfg),
                    rt.run_gfm(sites, 3, 0.1),
                    rt.run_fdm(sites, 3, 0.1),
                )
        return out

    @pytest.mark.parametrize("schedule", ["staged", "async"])
    def test_identical_mining_results(self, runs, schedule):
        vi, gi, fi = runs[(schedule, "inline")]
        vb, gb, fb = runs[(schedule, "batched")]
        assert np.array_equal(np.asarray(vi.result.labels), np.asarray(vb.result.labels))
        assert int(vi.result.merged.n_global) == int(vb.result.merged.n_global)
        assert gi.result.frequent == gb.result.frequent
        assert gi.result.comm.rounds == gb.result.comm.rounds
        assert gi.result.comm.bytes_sent == gb.result.comm.bytes_sent
        assert gi.result.comm.count_calls == gb.result.comm.count_calls
        assert fi.result.frequent == fb.result.frequent
        assert fi.result.comm.rounds == fb.result.comm.rounds

    @pytest.mark.parametrize("schedule", ["staged", "async"])
    def test_identical_scheduler_fingerprints(self, runs, schedule):
        for ri, rb in zip(runs[(schedule, "inline")], runs[(schedule, "batched")]):
            assert scheduler_fingerprint(ri.report) == scheduler_fingerprint(rb.report)
            assert ri.report.backend == "inline" and rb.report.backend == "batched"

    def test_batched_measured_matches_ledger(self, runs):
        """Apportioned batch shares must land in BOTH the runtime's
        measured dict and the engine's job_times, equally."""
        vb, gb, fb = runs[("staged", "batched")]
        for run in (vb, gb, fb):
            for name, dt in run.measured.items():
                assert run.report.job_times[name] == pytest.approx(dt, rel=1e-9)


class _FakeShippingBackend(ExecutionBackend):
    """Simulates a 2-process partitioned run in ONE process: even sites
    are "owned", odd sites execute locally anyway (the redundant-execution
    hazard) but return a fake owner-measured shipped TimedResult — so the
    owner-only-timing normalization path is exercised without a real
    ``jax.distributed`` runtime."""

    name = "fakeship"
    SHIPPED_S = 0.125

    def __init__(self):
        self._part = None

    def partition(self, dag, model=None):
        owner_of = {n: j.site % 2 for n, j in dag.jobs.items()}
        self._part = Partition(
            owned=frozenset(n for n, p in owner_of.items() if p == 0),
            owner_of=owner_of,
            n_processes=2,
            process_index=0,
            owned_sites=tuple(sorted({j.site for j in dag.jobs.values()} - {1})),
        )
        return self._part

    def call(self, job, args):
        raw = job.fn(*args)
        if job.name in self._part.owned:
            return raw
        value = raw.value if isinstance(raw, TimedResult) else raw
        return TimedResult(value, self.SHIPPED_S)


class TestOwnerOnlyTiming:
    """Satellite of the multihost ownership work: redundantly-executed
    (or shipped) jobs must never leave process-local times in the
    measured record — ``job_specs(strict=True)`` has to hold on every
    process of a partitioned run."""

    def test_merge_owner_times_completes_partial_record(self):
        measured = {"a": 1.0}
        job_times = {"a": 1.0, "b": 2.0, "c": 3.0}
        out = merge_owner_times(measured, job_times, owned=("a",))
        assert out == job_times
        jobs = [SiteJob(name=n, fn=lambda: 0) for n in ("a", "b", "c")]
        # regression: the owner-only partial record used to raise here
        specs = job_specs(jobs, out, strict=True)
        assert [sp.compute_s for sp in specs] == [1.0, 2.0, 3.0]

    def test_merge_owner_times_overwrites_stale_non_owned_entries(self):
        # the redundant-execution hazard: a local recording for a job
        # owned elsewhere must yield to the shipped authority
        out = merge_owner_times({"a": 1.0, "b": 99.0}, {"a": 1.0, "b": 2.0}, owned=("a",))
        assert out == {"a": 1.0, "b": 2.0}

    def test_merge_owner_times_unpartitioned_keeps_local(self):
        out = merge_owner_times({"a": 1.0}, {"a": 5.0, "b": 2.0}, owned=None)
        assert out == {"a": 1.0, "b": 2.0}

    def test_merge_owner_times_rejects_stray_owned_names(self):
        # an owned name the ledger has never heard of is a caller bug (a
        # stale partition, a typo) — it must raise, naming the strays
        with pytest.raises(ValueError, match="ghost"):
            merge_owner_times({"a": 1.0}, {"a": 1.0, "b": 2.0}, owned=("a", "ghost"))
        with pytest.raises(ValueError, match="2 owned job name"):
            merge_owner_times({}, {"a": 1.0}, owned=("x", "y"))

    def test_timed_batch_owned_filter_records_owner_only(self):
        record = {}
        bf = timed_batch(
            lambda bargs, argss: [0 for _ in bargs], record, owned=lambda n: n == "x"
        )
        outs = bf(["x", "y"], [0, 1], [[], []])
        assert list(record) == ["x"]
        assert len(outs) == 2 and all(isinstance(o, TimedResult) for o in outs)

    def test_partitioned_run_measured_is_owner_consistent(self):
        """End-to-end through GridRuntime: a partitioned run's measured
        record is completed/normalized from the engine's globally
        consistent ledger — strict job_specs holds, and non-owned entries
        carry the shipped owner measurement, not the local recording."""
        xs, _ = _mining_inputs()
        cfg = VClusterConfig(k_local=3, kmeans_iters=4, use_kernel=False)
        rt = GridRuntime(
            sync="pooled", use_kernel=False, count_backend="jnp",
            backend=_FakeShippingBackend(),
        )
        run = rt.run_vclustering(jax.random.PRNGKey(0), xs, cfg)
        assert run.n_processes == 2
        assert run.owned_sites == (0, 2)
        assert set(run.measured) >= set(run.report.job_times)
        owned = set(run.report.owned_jobs)
        for name, dt in run.report.job_times.items():
            if name not in owned:
                assert run.measured[name] == pytest.approx(_FakeShippingBackend.SHIPPED_S)
                assert dt == pytest.approx(_FakeShippingBackend.SHIPPED_S)


class TestJobSpecsMissingTimes:
    def _jobs(self):
        return [
            SiteJob(name="a", fn=lambda: 0),
            SiteJob(name="b", fn=lambda: 0, deps=["a"]),
        ]

    def test_complete_times_no_warning(self, recwarn):
        job_specs(self._jobs(), {"a": 1.0, "b": 2.0})
        assert not [w for w in recwarn.list if issubclass(w.category, MissingJobTimeWarning)]

    def test_missing_entry_warns(self):
        with pytest.warns(MissingJobTimeWarning, match="b"):
            specs = job_specs(self._jobs(), {"a": 1.0})
        assert specs[1].compute_s == 0.0

    def test_missing_entry_strict_raises(self):
        with pytest.raises(KeyError, match="no measured time"):
            job_specs(self._jobs(), {"a": 1.0}, strict=True)

    def test_none_times_stays_silent(self, recwarn):
        specs = job_specs(self._jobs(), None)
        assert [sp.compute_s for sp in specs] == [0.0, 0.0]
        assert not [w for w in recwarn.list if issubclass(w.category, MissingJobTimeWarning)]

    def test_none_times_strict_raises(self):
        with pytest.raises(KeyError, match="strict"):
            job_specs(self._jobs(), None, strict=True)
