"""Jitted public wrappers around the Pallas kernels.

Handle padding/layout so callers pass natural shapes; select interpret
mode automatically off-TPU (this container is CPU-only — Mosaic kernels
are VALIDATED via the interpreter and TARGET TPU).

Block-size seam: every mining-kernel wrapper takes ``block=`` —

  * ``None`` (default) — the module's default mode: the shipped
    hard-coded blocks, unless the mode was flipped to ``"auto"`` via
    :func:`set_default_block` or ``REPRO_KERNEL_BLOCKS=auto``;
  * ``"auto"`` — consult :mod:`repro.kernels.autotune`: the memoized
    winner for this padded shape, searching (and memoizing) on first
    sight.  Under a jit trace timing is impossible, so traced calls use
    the memoized winner when one exists and the defaults otherwise;
  * an explicit config — ``(block_n, block_c)`` for support counting,
    ``block_n`` for k-means assignment — used as-is (the legacy
    ``block_n=``/``block_c=`` kwargs still work and win over ``block=``).

Block size never changes results (each kernel's padding contract), so
the seam changes speed and nothing else — ``core.apriori`` and the
batched/multihost backends pick up tuned blocks with zero call-site
churn.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import autotune, pad_to, ref
from repro.kernels.kmeans_assign import BIG, kmeans_assign_pallas
from repro.kernels.support_count import (
    support_count_pallas,
    support_count_prune_pallas,
)

_BLOCK_MODE = (
    "auto" if os.environ.get("REPRO_KERNEL_BLOCKS", "default") == "auto" else "default"
)


def set_default_block(mode: str) -> str:
    """Flip the module-wide block mode (``"default"`` | ``"auto"``);
    returns the previous mode.  ``"auto"`` makes every wrapper call with
    ``block=None`` consult the autotuner — activate it process-wide to
    run tuned blocks with zero call-site churn."""
    global _BLOCK_MODE
    if mode not in ("default", "auto"):
        raise ValueError(f"unknown block mode {mode!r} (want 'default' or 'auto')")
    prev = _BLOCK_MODE
    _BLOCK_MODE = mode
    return prev


def default_block() -> str:
    """The current module-wide block mode."""
    return _BLOCK_MODE


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _is_tracer(x) -> bool:
    return isinstance(x, jax.core.Tracer)


def _resolve_support_blocks(
    tx_t, masks_t, block, block_n, block_c, interpret: bool
) -> tuple[int, int]:
    """The (block_n, block_c) one support-count dispatch will use.
    Explicit kwargs win; then an explicit ``block`` tuple; then the
    autotuner when auto is requested (lookup-only under a trace); else
    the shipped defaults."""
    dn, dc = autotune.DEFAULT_SUPPORT_BLOCKS
    if block_n is not None or block_c is not None:
        return (block_n or dn, block_c or dc)
    if isinstance(block, tuple):
        return block
    auto = block == "auto" or (block is None and _BLOCK_MODE == "auto")
    if not auto:
        return (dn, dc)
    w, n = tx_t.shape
    _, c = masks_t.shape
    if _is_tracer(tx_t) or _is_tracer(masks_t):
        cfg = autotune.lookup(autotune.support_count_key(w, n, c, tx_t.dtype, interpret))
        return cfg if cfg is not None else (dn, dc)
    return tuple(autotune.tune_support_count(tx_t, masks_t, interpret=interpret)["config"])


def _resolve_kmeans_block(xp, cp, block, block_n, interpret: bool) -> int:
    """The block_n one kmeans-assign dispatch will use (same resolution
    order as :func:`_resolve_support_blocks`)."""
    if block_n is not None:
        return block_n
    if isinstance(block, int):
        return block
    auto = block == "auto" or (block is None and _BLOCK_MODE == "auto")
    if not auto:
        return autotune.DEFAULT_KMEANS_BLOCK
    n, d = xp.shape
    k, _ = cp.shape
    if _is_tracer(xp) or _is_tracer(cp):
        cfg = autotune.lookup(autotune.kmeans_assign_key(n, d, k, xp.dtype, interpret))
        return cfg if cfg is not None else autotune.DEFAULT_KMEANS_BLOCK
    return autotune.tune_kmeans_assign(xp, cp, interpret=interpret)["config"]


def kmeans_assign(
    x: jax.Array,
    centers: jax.Array,
    block: int | str | None = None,
    block_n: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Nearest-center assignment.  x (N, D), centers (K, D) ->
    (assign (N,) int32, min_d2 (N,) f32).  Pads D and K to the 128-lane
    boundary per the kernel contract (the kernel auto-pads N itself)."""
    n, d = x.shape
    k, _ = centers.shape
    dp = pad_to(max(d, 128), 128)
    kp = pad_to(max(k, 128), 128)
    xp = jnp.zeros((n, dp), jnp.float32).at[:, :d].set(x.astype(jnp.float32))
    # padded center rows sit at +BIG so they never win the argmin;
    # padded D columns are zero in both operands (distance unchanged)
    cp = jnp.full((kp, dp), 0.0, jnp.float32)
    cp = cp.at[:, :d].set(jnp.full((kp, d), BIG, jnp.float32))
    cp = cp.at[:k, :d].set(centers.astype(jnp.float32))
    interp = not _on_tpu()
    bn = _resolve_kmeans_block(xp, cp, block, block_n, interp)
    return kmeans_assign_pallas(xp, cp, block_n=bn, interpret=interp)


def _to_kernel_layout(tx_packed: jax.Array, masks: jax.Array):
    """(N, W)/(C, W) uint32 -> the kernel's transposed (W, ·) int32."""
    tx_t = jax.lax.bitcast_convert_type(tx_packed.astype(jnp.uint32), jnp.int32).T
    mk_t = jax.lax.bitcast_convert_type(masks.astype(jnp.uint32), jnp.int32).T
    return tx_t, mk_t


def support_count(
    tx_packed: jax.Array,
    masks: jax.Array,
    block: tuple[int, int] | str | None = None,
    block_n: int | None = None,
    block_c: int | None = None,
) -> jax.Array:
    """Support counts.  tx_packed (N, W) uint32, masks (C, W) uint32 ->
    (C,) int32.  Transposes to the kernel's (W, ·) lane layout; the
    kernel auto-pads N/C to its blocks (padded transactions count zero
    support, padded candidate outputs are sliced away)."""
    n, w = tx_packed.shape
    c, w2 = masks.shape
    assert w == w2
    tx_t, mk_t = _to_kernel_layout(tx_packed, masks)
    interp = not _on_tpu()
    bn, bc = _resolve_support_blocks(tx_t, mk_t, block, block_n, block_c, interp)
    return support_count_pallas(tx_t, mk_t, block_n=bn, block_c=bc, interpret=interp)


def support_count_prune(
    tx_packed: jax.Array,
    masks: jax.Array,
    min_count,
    block: tuple[int, int] | str | None = None,
    block_n: int | None = None,
    block_c: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused count + threshold: returns ``(counts (C,) int32, frequent
    (C,) bool)`` with ``frequent == counts >= min_count`` exactly — the
    Apriori level's candidate-hygiene step in ONE device pass, so the
    level loop reads back the (tiny) frequent mask instead of
    thresholding the raw count vector on host.  ``min_count`` is traced:
    distinct thresholds share one compilation.  Tuned blocks are shared
    with :func:`support_count` — the compute loop is identical, so one
    search serves both."""
    n, w = tx_packed.shape
    c, w2 = masks.shape
    assert w == w2
    tx_t, mk_t = _to_kernel_layout(tx_packed, masks)
    interp = not _on_tpu()
    bn, bc = _resolve_support_blocks(tx_t, mk_t, block, block_n, block_c, interp)
    return support_count_prune_pallas(
        tx_t, mk_t, min_count, block_n=bn, block_c=bc, interpret=interp
    )


def flash_attention(
    q: jax.Array,  # (B, Sq, H, Dh)
    k: jax.Array,  # (B, Skv, Kv, Dh)
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    cap: float = 0.0,
    block_q: int = 128,
    block_k: int = 256,
) -> jax.Array:
    """Flash attention with GQA; returns (B, Sq, H, Dh).

    Flattens (batch, heads) into the kernel's leading grid dim; the KV
    index map folds the GQA group so K/V are never repeated.  Pads Sq/Skv
    to the block sizes (padded keys sit behind an out-of-range causal/pad
    mask because padded q/k positions extend past the real length and the
    kernel's positional mask plus the final slice discard them)."""
    from repro.kernels.flash_attention import flash_attention_pallas

    b, sq, h, dh = q.shape
    _, skv, kvh, _ = k.shape
    tq = min(block_q, pad_to(sq, 8))
    tk = min(block_k, pad_to(skv, 8))
    sqp, skp = pad_to(sq, tq), pad_to(skv, tk)
    # padded keys are masked by causality (k_pos >= skv > any real q_pos);
    # without causality there is no mask to hide them
    assert causal or skp == skv, "non-causal flash requires Skv % block_k == 0"
    qf = jnp.pad(q, ((0, 0), (0, sqp - sq), (0, 0), (0, 0)))
    kf = jnp.pad(k, ((0, 0), (0, skp - skv), (0, 0), (0, 0)))
    vf = jnp.pad(v, ((0, 0), (0, skp - skv), (0, 0), (0, 0)))
    # (B, S, H, D) -> (B*H, S, D) with heads grouped per batch
    qf = qf.transpose(0, 2, 1, 3).reshape(b * h, sqp, dh)
    kf = kf.transpose(0, 2, 1, 3).reshape(b * kvh, skp, dh)
    vf = vf.transpose(0, 2, 1, 3).reshape(b * kvh, skp, dh)
    out = flash_attention_pallas(
        qf, kf, vf, causal=causal, window=window, cap=cap,
        block_q=tq, block_k=tk, interpret=not _on_tpu(),
    )
    out = out.reshape(b, h, sqp, dh).transpose(0, 2, 1, 3)
    return out[:, :sq]


def slstm_scan(wx: jax.Array, r: jax.Array, bias: jax.Array, state0, t_chunk: int = 16):
    """sLSTM sequence scan with VMEM-resident recurrent weights.

    wx (B, S, H, 4P) batch-major; state0 = (c, n, hid) each (B, H, P).
    Returns (hids (B, S, H, P), (cT, nT, hT)).  Pads S to the time-chunk
    (identity steps would corrupt state, so padding uses zero wx and the
    final state is captured from the real tail by re-running the remainder
    — instead we simply require S % t_chunk == 0 by choosing a divisor)."""
    from repro.kernels.slstm_cell import slstm_scan_pallas

    b, s, h, p4 = wx.shape
    tc = t_chunk
    while s % tc:
        tc //= 2
    tc = max(tc, 1)
    c0, n0, h0 = state0
    hids, cT, nT, hT = slstm_scan_pallas(
        jnp.moveaxis(wx, 1, 0), r, bias, c0, n0, h0, t_chunk=tc, interpret=not _on_tpu()
    )
    return jnp.moveaxis(hids, 0, 1), (cT, nT, hT)


def support_count_sites(
    tx_packed_s: jax.Array,
    masks_s: jax.Array,
    block: tuple[int, int] | str | None = None,
) -> jax.Array:
    """Fused site-axis support counting: ONE dispatch for S sites.

    tx_packed_s (S, N, W) uint32, masks_s (S, C, W) uint32 -> (S, C)
    int32 — the vmapped form of :func:`support_count` (vmap lifts the
    Pallas grid by one site dimension, so the whole fan-out runs as a
    single kernel launch instead of S host-loop dispatches).  Per-site
    padding semantics are unchanged.  The block config is resolved ONCE
    from the shared per-site shape BEFORE the vmap (autotuning times
    site 0's slice on a cache miss), so the fused dispatch runs tuned
    blocks too.
    """
    blk = _sites_support_blocks(tx_packed_s, masks_s, block)
    return jax.vmap(lambda t, m: support_count(t, m, block=blk))(tx_packed_s, masks_s)


def _sites_support_blocks(tx_packed_s, masks_s, block) -> tuple[int, int]:
    """Resolve the per-site support-count blocks for a fused site-axis
    dispatch: every site shares one padded shape, so site 0's slice
    stands in for all of them (tracers fall back to lookup/defaults
    inside :func:`_resolve_support_blocks`)."""
    if isinstance(block, tuple):
        return block
    tx_t, mk_t = _to_kernel_layout(tx_packed_s[0], masks_s[0])
    return _resolve_support_blocks(tx_t, mk_t, block, None, None, not _on_tpu())


def support_count_prune_sites(
    tx_packed_s: jax.Array,
    masks_s: jax.Array,
    min_counts: jax.Array,
    block: tuple[int, int] | str | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused site-axis count + threshold: ONE dispatch for S sites with
    PER-SITE thresholds.  tx_packed_s (S, N, W), masks_s (S, C, W),
    min_counts (S,) int32 -> (counts (S, C) int32, frequent (S, C)
    bool) — the vmapped form of :func:`support_count_prune` (the
    threshold is a mapped operand, so heterogeneous per-site minimum
    supports ride the same fused launch)."""
    blk = _sites_support_blocks(tx_packed_s, masks_s, block)
    mc = jnp.asarray(min_counts, jnp.int32)
    return jax.vmap(lambda t, m, c: support_count_prune(t, m, c, block=blk))(
        tx_packed_s, masks_s, mc
    )


def kmeans_assign_sites(
    xs: jax.Array,
    centers_s: jax.Array,
    block: int | str | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused site-axis K-Means assignment: ONE dispatch for S sites.

    xs (S, N, D), centers_s (S, K, D) -> (assign (S, N) int32,
    min_d2 (S, N) f32) — the vmapped form of :func:`kmeans_assign`.
    Like :func:`support_count_sites`, the block config resolves once
    from the shared per-site shape before the vmap.
    """
    blk = block
    if not isinstance(blk, int):
        # resolve from site 0's padded shape (lane-pad D/K as the
        # per-site wrapper will, so the memo key matches)
        n, d = xs.shape[1], xs.shape[2]
        k = centers_s.shape[1]
        dp = pad_to(max(d, 128), 128)
        kp = pad_to(max(k, 128), 128)
        if _is_tracer(xs) or _is_tracer(centers_s):
            interp = not _on_tpu()
            auto = blk == "auto" or (blk is None and _BLOCK_MODE == "auto")
            cfg = (
                autotune.lookup(autotune.kmeans_assign_key(n, dp, kp, jnp.float32, interp))
                if auto
                else None
            )
            blk = cfg if cfg is not None else autotune.DEFAULT_KMEANS_BLOCK
        else:
            xp = jnp.zeros((n, dp), jnp.float32).at[:, :d].set(xs[0].astype(jnp.float32))
            cp = jnp.full((kp, dp), 0.0, jnp.float32)
            cp = cp.at[:, :d].set(jnp.full((kp, d), BIG, jnp.float32))
            cp = cp.at[:k, :d].set(centers_s[0].astype(jnp.float32))
            blk = _resolve_kmeans_block(xp, cp, block, None, not _on_tpu())
    return jax.vmap(lambda x, c: kmeans_assign(x, c, block=blk))(xs, centers_s)


# re-export oracles for convenience
kmeans_assign_ref = ref.kmeans_assign_ref
support_count_ref = ref.support_count_ref
