"""Sufficient-statistics identities (paper §3.1) — unit + property tests.

The variance-based merge is only correct because SSE is additive under
the s(i,j) formula; these tests pin that invariant down exactly.
"""

import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic env: deterministic shim, no shrinking
    from repro.testing import given, settings, strategies as st

from repro.core.stats import (
    merge_cost,
    merge_stats,
    pairwise_sq_dists,
    stats_from_assignment,
    total_sse,
)


def direct_sse(x, center):
    return float(np.sum((x - center) ** 2))


def make_stats(x, assign, k):
    return stats_from_assignment(jnp.asarray(x), jnp.asarray(assign), k)


class TestStatsFromAssignment:
    def test_single_cluster(self):
        rng = np.random.default_rng(0)
        x = rng.normal(size=(50, 3)).astype(np.float32)
        st_ = make_stats(x, np.zeros(50, np.int32), 1)
        assert float(st_.sizes[0]) == 50
        np.testing.assert_allclose(np.asarray(st_.centers[0]), x.mean(0), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(float(st_.sse[0]), direct_sse(x, x.mean(0)), rtol=1e-3)

    def test_empty_cluster_slots(self):
        x = np.ones((10, 2), np.float32)
        st_ = make_stats(x, np.zeros(10, np.int32), 3)
        assert float(st_.sizes[1]) == 0 and float(st_.sizes[2]) == 0
        assert float(st_.sse[1]) == 0


class TestMergeFormula:
    @given(
        st.integers(2, 40),
        st.integers(2, 40),
        st.integers(1, 5),
        st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=30, deadline=None)
    def test_merged_sse_equals_pooled_sse(self, n1, n2, d, seed):
        """Paper's var_new = var_i + var_j + s(i,j) must equal the SSE of
        the pooled points around the pooled centroid — exactly."""
        rng = np.random.default_rng(seed)
        a = rng.normal(0, 1, (n1, d)).astype(np.float32)
        b = rng.normal(3, 2, (n2, d)).astype(np.float32)
        x = np.concatenate([a, b])
        assign = np.array([0] * n1 + [1] * n2, np.int32)
        st_ = make_stats(x, assign, 2)
        merged = merge_stats(st_, jnp.int32(0), jnp.int32(1))
        pooled_center = x.mean(0)
        np.testing.assert_allclose(
            float(merged.sse[0]), direct_sse(x, pooled_center), rtol=2e-3, atol=2e-3
        )
        np.testing.assert_allclose(np.asarray(merged.centers[0]), pooled_center, rtol=1e-4, atol=1e-4)
        assert float(merged.sizes[0]) == n1 + n2
        assert float(merged.sizes[1]) == 0  # slot j died

    def test_merge_cost_symmetry_and_masking(self):
        rng = np.random.default_rng(1)
        x = rng.normal(size=(30, 2)).astype(np.float32)
        assign = rng.integers(0, 3, 30).astype(np.int32)
        st_ = make_stats(x, assign, 4)  # slot 3 empty
        c = np.asarray(merge_cost(st_))
        assert np.all(np.isinf(np.diag(c)))
        assert np.all(np.isinf(c[3])) and np.all(np.isinf(c[:, 3]))
        live = c[:3, :3]
        np.testing.assert_allclose(live, live.T, rtol=1e-5)

    def test_total_sse_monotone_under_merge(self):
        """Merging can only increase total SSE (s(i,j) >= 0)."""
        rng = np.random.default_rng(2)
        x = rng.normal(size=(40, 3)).astype(np.float32)
        assign = rng.integers(0, 4, 40).astype(np.int32)
        st_ = make_stats(x, assign, 4)
        before = float(total_sse(st_))
        merged = merge_stats(st_, jnp.int32(0), jnp.int32(1))
        after = float(total_sse(merged))
        assert after >= before - 1e-3


class TestPairwiseDists:
    @given(st.integers(1, 20), st.integers(1, 20), st.integers(1, 6), st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_matches_numpy(self, na, nb, d, seed):
        rng = np.random.default_rng(seed)
        a = rng.normal(size=(na, d)).astype(np.float32)
        b = rng.normal(size=(nb, d)).astype(np.float32)
        got = np.asarray(pairwise_sq_dists(jnp.asarray(a), jnp.asarray(b)))
        want = ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
