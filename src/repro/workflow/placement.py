"""Placement policies — the matchmaking step of the paper's Condor setup.

The paper's deployment never pins a job to a site a priori: Condor
matchmaking assigns each job to a resource *when it becomes eligible*,
and that decision is where most of the grid-overhead variance the paper
measures comes from.  A ``PlacementPolicy`` makes that decision for the
workflow engine: at eligibility time (async mode: when the job's
matchmaking completes; staged mode: when its stage forms) the scheduler
hands the policy a :class:`PlacementRequest` snapshot of the grid —
candidate sites, per-site busy slots and FIFO queue depths, known
slot-release times, the link matrix and per-site speed factors — and the
policy returns the site the job will run on.

Policies:

  * ``fixed`` — honor the pre-assigned ``job.site`` (the engine's
    behavior before placement existed; bit-for-bit identical numbers);
  * ``round_robin`` — cycle through the candidate sites in index order,
    one step per placement decision;
  * ``random`` — uniform over the candidate sites from a seeded RNG
    (deterministic across runs with the same seed);
  * ``greedy_eta`` — pick the site minimizing estimated completion:
    queue wait (from current busy slots, FIFO depth, and known
    slot-release times) + stage-in/out from the link matrix + expected
    compute scaled by the site's speed factor (arXiv:1903.03008 shows
    partition-to-resource assignment dominates distributed-Apriori
    runtime on heterogeneous links; arXiv:1412.2673 motivates the skewed
    per-site speed/queue scenarios).

All policies are deterministic given the same DAG, model, and measured
times — ``reset()`` is called at the start of every engine run, so a
reused policy (or engine) replays identically.
"""

from __future__ import annotations

import random
from collections.abc import Sequence
from dataclasses import dataclass, field
from typing import TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (overhead -> placement)
    from repro.workflow.overhead import GridModel, JobSpec

POLICIES = ("fixed", "round_robin", "random", "greedy_eta")


@dataclass
class PlacementRequest:
    """What a policy sees at decision time: one job's staging profile and
    a snapshot of the grid.  ``busy_until`` holds the known simulated
    finish times of jobs currently occupying slots (async mode; staged
    mode leaves it empty and models contention through ``site_busy``
    alone).  ``service_est_s`` is the scheduler's running estimate of one
    job's service time (median of observed scheduled compute), used to
    price queue positions with unknown occupants."""

    name: str
    fixed_site: int
    input_bytes: int
    output_bytes: int
    expected_compute_s: float
    now: float
    model: "GridModel"
    sites: Sequence[int]
    workers: int
    site_busy: dict = field(default_factory=dict)
    queue_depth: dict = field(default_factory=dict)
    busy_until: dict = field(default_factory=dict)
    service_est_s: float = 0.0

    def queue_wait_s(self, site: int) -> float:
        """Estimated wait for a free slot at ``site``: zero while slots
        remain; otherwise the earliest known release (falling back to one
        service-time estimate) plus one estimate per job already ahead in
        line beyond that first release."""
        busy = self.site_busy.get(site, 0)
        queued = self.queue_depth.get(site, 0)
        if busy + queued < self.workers:
            return 0.0
        frees = self.busy_until.get(site, ())
        first = min(frees) - self.now if frees else self.service_est_s
        ahead = busy + queued - self.workers  # beyond the first release
        return max(0.0, first) + ahead * self.service_est_s

    def eta_s(self, site: int) -> float:
        """Estimated completion if the job ran at ``site``: queue wait +
        stage-in + speed-scaled compute + stage-out."""
        m = self.model
        return (
            self.queue_wait_s(site)
            + m.transfer_s(0, site, self.input_bytes)
            + m.site_compute_s(site, self.expected_compute_s)
            + m.transfer_s(site, 0, self.output_bytes)
        )


class PlacementPolicy:
    """Site chooser for one engine run.  Subclasses override ``place``;
    stateful policies also override ``reset`` (called once per run)."""

    name = "?"

    def reset(self) -> None:  # per-run state, nothing by default
        return None

    def candidate_sites(self, fixed_sites: Sequence[int], model: "GridModel") -> list[int]:
        """The site universe for this run.  Adaptive policies match over
        every site the model knows; ``fixed`` keeps exactly the
        pre-assigned sites (preserving the pre-placement engine's slot
        universe, and with it speculation's slot choices, bit-for-bit)."""
        return list(range(model.n_sites))

    def place(self, req: PlacementRequest) -> int:
        raise NotImplementedError


class FixedPlacement(PlacementPolicy):
    """Honor the DAG's pre-assigned sites — the engine's original
    behavior, kept as the baseline every adaptive policy is gated
    against."""

    name = "fixed"

    def candidate_sites(self, fixed_sites: Sequence[int], model: "GridModel") -> list[int]:
        return list(dict.fromkeys(fixed_sites))

    def place(self, req: PlacementRequest) -> int:
        return req.fixed_site


class RoundRobinPlacement(PlacementPolicy):
    """Cycle through the candidate sites in index order, advancing one
    step per placement decision (decision order is the engine's
    deterministic event order)."""

    name = "round_robin"

    def __init__(self) -> None:
        self._next = 0

    def reset(self) -> None:
        self._next = 0

    def place(self, req: PlacementRequest) -> int:
        sites = sorted(req.sites)
        site = sites[self._next % len(sites)]
        self._next += 1
        return site


class RandomPlacement(PlacementPolicy):
    """Uniform over the candidate sites from a seeded RNG.  The seed is
    part of the policy, so identical runs replay identical placements."""

    name = "random"

    def __init__(self, seed: int = 0) -> None:
        self.seed = seed
        self._rng = random.Random(seed)

    def reset(self) -> None:
        self._rng = random.Random(self.seed)

    def place(self, req: PlacementRequest) -> int:
        sites = sorted(req.sites)
        return sites[self._rng.randrange(len(sites))]


class GreedyEtaPlacement(PlacementPolicy):
    """Minimize estimated completion time over the candidate sites —
    the matchmaking rank expression of the paper's Condor deployment.
    Ties break toward the lowest site index (deterministic)."""

    name = "greedy_eta"

    def place(self, req: PlacementRequest) -> int:
        return min(sorted(req.sites), key=lambda s: (req.eta_s(s), s))


_FACTORIES = {
    "fixed": FixedPlacement,
    "round_robin": RoundRobinPlacement,
    "random": RandomPlacement,
    "greedy_eta": GreedyEtaPlacement,
}


def resolve_placement(placement: "str | PlacementPolicy | None") -> PlacementPolicy:
    """Resolve a policy name (or pass through an instance) to a policy.
    Unknown names raise with the valid set, mirroring the engine's
    schedule validation."""
    if placement is None:
        return FixedPlacement()
    if isinstance(placement, PlacementPolicy):
        return placement
    try:
        return _FACTORIES[placement]()
    except KeyError:
        raise ValueError(
            f"unknown placement {placement!r}; expected one of {POLICIES} or a PlacementPolicy"
        ) from None


def plan_specs(
    specs: "list[JobSpec]", model: "GridModel", placement: "str | PlacementPolicy | None"
) -> "list[JobSpec]":
    """Statically re-site a spec list the way ``placement`` would on an
    idle grid — the contention-free planning step behind the
    placement-aware analytical bounds (``overhead.estimate_dag`` /
    ``estimate_stages_from_specs``).  Decisions are made in spec order
    with every slot free, so the result is a lower-bound assignment, not
    a replay of the engine's queue-state-dependent choices (use
    ``RunReport.placements`` to bound an actual run)."""
    policy = resolve_placement(placement)
    policy.reset()
    sites = policy.candidate_sites([sp.site for sp in specs], model)
    out = []
    for sp in specs:
        req = PlacementRequest(
            name=sp.name,
            fixed_site=sp.site,
            input_bytes=sp.input_bytes,
            output_bytes=sp.output_bytes,
            expected_compute_s=sp.compute_s,
            now=0.0,
            model=model,
            sites=sites,
            workers=max(1, model.workers_per_site),
        )
        out.append(sp._replace(site=policy.place(req)))
    return out
