"""Pallas TPU kernel: itemset support counting over packed bitmaps.

The compute hot-spot of the paper's frequent-itemset algorithms: for every
candidate mask m and transaction t, hit = AND_w((t_w & m_w) == m_w);
support(m) = Σ_t hit.

Layout is transposed for TPU lane tiling: transactions arrive as (W, N)
int32 and candidates as (W, C) int32 so the *vector* dimensions (N, C) sit
on the 128-wide lane axis and W (≤ 32 words = 1024 items) is a small
static leading loop.  Each program materialises a (TN, TC) hit block on
the VPU and reduces it into a (TC,) partial; the grid is (C tiles, N
tiles) with N innermost so the output block accumulates sequentially
(TPU grid order guarantees the revisiting program sees its prior value).

VMEM per program: W·(TN + TC)·4 B + TN·TC·4 B ≈ 32·(512+512)·4 + 512²·4
≈ 1.2 MB ≪ 16 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import pad_to


def _kernel(tx_ref, mask_ref, out_ref):
    w = tx_ref.shape[0]
    tx = tx_ref[...]  # (W, TN) int32
    mk = mask_ref[...]  # (W, TC) int32
    hit = jnp.ones((tx.shape[1], mk.shape[1]), dtype=jnp.bool_)  # (TN, TC)
    for ww in range(w):  # static, small
        t = tx[ww][:, None]  # (TN, 1)
        m = mk[ww][None, :]  # (1, TC)
        hit &= (t & m) == m
    partial = jnp.sum(hit.astype(jnp.int32), axis=0)  # (TC,)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(pl.program_id(1) != 0)
    def _acc():
        out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("block_n", "block_c", "interpret"))
def support_count_pallas(
    tx_t: jax.Array,  # (W, N) int32 — transposed packed transactions
    masks_t: jax.Array,  # (W, C) int32 — transposed packed candidate masks
    block_n: int = 512,
    block_c: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Support counts for arbitrary (N, C): inputs are auto-padded to the
    block multiples.  Padded transactions are all-zero words, which match
    no non-empty mask; an all-zero (empty-itemset) mask WOULD match them,
    so its count is corrected by the pad row count after the kernel —
    padded rows therefore contribute zero support to every candidate.
    Padded candidate columns are sliced away before returning.  Block-
    multiple inputs take the original zero-copy path bit-for-bit.

    Zero-size fast paths: C=0 candidates (a dried-up Apriori level) or
    N=0 transactions (an empty delta batch) return without building a
    degenerate Pallas grid — every support over zero transactions is
    zero, and zero candidates have zero counts."""
    w, n = tx_t.shape
    w2, c = masks_t.shape
    assert w == w2, f"word-width mismatch: transactions {w} vs masks {w2}"
    if c == 0 or n == 0:
        return jnp.zeros((c,), jnp.int32)
    np_ = pad_to(max(n, block_n), block_n)
    cp_ = pad_to(max(c, block_c), block_c)
    tx_p = tx_t if np_ == n else jnp.zeros((w, np_), tx_t.dtype).at[:, :n].set(tx_t)
    mk_p = masks_t if cp_ == c else jnp.zeros((w, cp_), masks_t.dtype).at[:, :c].set(masks_t)
    grid = (cp_ // block_c, np_ // block_n)  # N innermost → sequential accumulation
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((w, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((w, block_c), lambda i, j: (0, i)),
        ],
        out_specs=pl.BlockSpec((block_c,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((cp_,), jnp.int32),
        interpret=interpret,
    )(tx_p, mk_p)[:c]
    if np_ != n:
        empty_mask = jnp.all(masks_t == 0, axis=0)  # matches the zero pad rows
        out = out - jnp.where(empty_mask, jnp.int32(np_ - n), jnp.int32(0))
    return out


def _prune_kernel(tx_ref, mask_ref, par_ref, out_ref, freq_ref):
    """``_kernel`` plus the level-hygiene step fused in: on the LAST
    transaction tile each candidate block corrects its own pad-row
    overcount (all-zero masks match the zero pad rows; ``par_ref[0]``
    carries the pad-row count) and emits the ``count >= min_count``
    frequent flag (``par_ref[1]``) next to the final count — one device
    pass returns both, so the level loop thresholds without a host
    round-trip of the raw count vector."""
    w = tx_ref.shape[0]
    tx = tx_ref[...]  # (W, TN) int32
    mk = mask_ref[...]  # (W, TC) int32
    hit = jnp.ones((tx.shape[1], mk.shape[1]), dtype=jnp.bool_)  # (TN, TC)
    for ww in range(w):  # static, small
        t = tx[ww][:, None]
        m = mk[ww][None, :]
        hit &= (t & m) == m
    partial = jnp.sum(hit.astype(jnp.int32), axis=0)  # (TC,)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(pl.program_id(1) != 0)
    def _acc():
        out_ref[...] += partial

    @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
    def _finalize():
        empty = jnp.ones((mk.shape[1],), dtype=jnp.bool_)
        for ww in range(w):
            empty &= mk[ww] == 0
        corrected = out_ref[...] - jnp.where(empty, par_ref[0], jnp.int32(0))
        out_ref[...] = corrected
        freq_ref[...] = (corrected >= par_ref[1]).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "block_c", "interpret"))
def support_count_prune_pallas(
    tx_t: jax.Array,  # (W, N) int32 — transposed packed transactions
    masks_t: jax.Array,  # (W, C) int32 — transposed packed candidate masks
    min_count: jax.Array | int,  # scalar int — the frequency threshold
    block_n: int = 512,
    block_c: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused count-then-threshold: returns ``(counts (C,) int32,
    frequent (C,) bool)`` where ``frequent == counts >= min_count``
    exactly — the Apriori level's candidate-hygiene step folded into the
    counting pass.  Same padding contract as :func:`support_count_pallas`
    (including the empty-mask pad correction, here applied IN-kernel so
    the emitted flags see corrected counts); ``min_count`` is a traced
    scalar, so distinct thresholds share one compilation per block
    config.  Zero-size fast paths mirror the plain kernel's."""
    w, n = tx_t.shape
    w2, c = masks_t.shape
    assert w == w2, f"word-width mismatch: transactions {w} vs masks {w2}"
    mc = jnp.asarray(min_count, jnp.int32)
    if c == 0 or n == 0:
        counts = jnp.zeros((c,), jnp.int32)
        return counts, counts >= mc
    np_ = pad_to(max(n, block_n), block_n)
    cp_ = pad_to(max(c, block_c), block_c)
    tx_p = tx_t if np_ == n else jnp.zeros((w, np_), tx_t.dtype).at[:, :n].set(tx_t)
    mk_p = masks_t if cp_ == c else jnp.zeros((w, cp_), masks_t.dtype).at[:, :c].set(masks_t)
    params = jnp.stack([jnp.full((), np_ - n, jnp.int32), mc])  # (2,)
    grid = (cp_ // block_c, np_ // block_n)  # N innermost → sequential accumulation
    counts, freq = pl.pallas_call(
        _prune_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((w, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((w, block_c), lambda i, j: (0, i)),
            pl.BlockSpec((2,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_c,), lambda i, j: (i,)),
            pl.BlockSpec((block_c,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((cp_,), jnp.int32),
            jax.ShapeDtypeStruct((cp_,), jnp.int32),
        ],
        interpret=interpret,
    )(tx_p, mk_p, params)
    return counts[:c], freq[:c].astype(jnp.bool_)
