"""Pallas TPU kernel: flash attention (forward) with GQA, causal,
sliding-window and logit-softcap support.

WHY (§Roofline): every dense-transformer train/prefill cell in the fleet
is memory-dominated, and the breakdowns show the dominant streams are the
flash-attention score/probability intermediates that XLA materialises in
HBM between the QKᵀ and PV matmuls.  This kernel keeps the (Tq, Tk) score
block, the online-softmax statistics and the output accumulator in VMEM:
HBM traffic drops to  Q+K+V reads + O write  — the canonical flash
result, here as the TPU-native adaptation (MXU matmuls on (Tq,Dh)x(Dh,Tk)
blocks, VPU for the exp/max lane ops).

Layout: q (BH, Sq, Dh), k/v (BKV, Skv, Dh) with BH = batch*heads and
BKV = batch*kv_heads; the kv BlockSpec index_map folds the GQA group
(bh -> bh // group) so grouped heads share K/V blocks WITHOUT a repeat.
Grid (BH, Sq/Tq, Skv/Tk), kv innermost (sequential) — m/l/acc live in
VMEM scratch across the kv iterations of one (bh, q-block).

VMEM per program: Tq·Dh (q) + 2·Tk·Dh (kv) + Tq·Tk (scores f32) + acc
≈ 128·128·4 + 2·256·128·2 + 128·256·4 + 128·128·4 ≈ 0.5 MB ≪ budget.

Numerics match `repro.models.attention.chunked_attention` (the jnp
oracle used for train/prefill) — validated in tests/test_kernels.py.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *, scale, causal, window, cap, tq, tk):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0].astype(jnp.float32) * scale  # (Tq, Dh)
    k = k_ref[0].astype(jnp.float32)  # (Tk, Dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    if cap:
        s = jnp.tanh(s / cap) * cap

    q_pos = qi * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
    k_pos = ki * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
    mask = jnp.ones((tq, tk), jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    # rows with no valid key yet: keep p exactly 0 (m_new == NEG there)
    p = jnp.where((m_new > NEG / 2)[:, None], p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=-1)
    m_s[...] = m_new
    pv = jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_s[...] = acc_s[...] * corr[:, None] + pv

    @pl.when(ki == pl.num_programs(2) - 1)
    def _fin():
        o_ref[0] = (acc_s[...] / jnp.maximum(l_s[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "cap", "block_q", "block_k", "interpret")
)
def flash_attention_pallas(
    q: jax.Array,  # (BH, Sq, Dh)
    k: jax.Array,  # (BKV, Skv, Dh) — BH % BKV == 0 (GQA)
    v: jax.Array,  # (BKV, Skv, Dh)
    causal: bool = True,
    window: int = 0,
    cap: float = 0.0,
    block_q: int = 128,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    bh, sq, dh = q.shape
    bkv, skv, _ = k.shape
    assert bh % bkv == 0
    group = bh // bkv
    tq = min(block_q, sq)
    tk = min(block_k, skv)
    assert sq % tq == 0 and skv % tk == 0, (sq, tq, skv, tk)
    grid = (bh, sq // tq, skv // tk)
    scale = 1.0 / math.sqrt(dh)
    from jax.experimental.pallas import tpu as pltpu

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, cap=cap, tq=tq, tk=tk
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tk, dh), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, tk, dh), lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq,), jnp.float32),
            pltpu.VMEM((tq,), jnp.float32),
            pltpu.VMEM((tq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
