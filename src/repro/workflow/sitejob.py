"""SiteJob — the shared unit of site-local mining work.

Both of the paper's applications (variance-based clustering and GFM/FDM
itemset mining) decompose into the same shape: a stage of per-site compute
jobs, a synchronization job over their outputs, and optionally more
per-site work.  ``SiteJob`` is that contract: the core algorithm modules
(`core.vclustering`, `core.gfm`, `core.fdm`) emit lists of SiteJobs, and
one scheduler — ``workflow.engine.Engine`` — executes any of them through
the same DAGMan-analog grid model.

``timed`` wraps a site job's callable so the engine's simulated clock is
fed the *measured* device compute time (blocking on all jax outputs)
rather than a host-side bracket that would include tracing overhead noise.
"""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax

from repro.workflow.dag import DAG, Job, TimedResult
from repro.workflow.overhead import JobSpec


@dataclass
class SiteJob:
    """One unit of site-local (or synchronization) work.

    ``fn`` receives the results of ``deps`` in order and does the real
    compute; ``site`` indexes into the grid model's link matrix for the
    staging-cost simulation; byte counts size the staged transfers.
    """

    name: str
    fn: Callable[..., Any]
    deps: list[str] = field(default_factory=list)
    site: int = 0
    input_bytes: int = 0
    output_bytes: int = 0
    retries: int = 2

    def to_job(self) -> Job:
        return Job(
            name=self.name,
            fn=self.fn,
            deps=list(self.deps),
            site=self.site,
            input_bytes=self.input_bytes,
            output_bytes=self.output_bytes,
            retries=self.retries,
        )


def timed(fn: Callable[..., Any], record: dict[str, float] | None = None, name: str = "") -> Callable[..., Any]:
    """Wrap ``fn`` to return a TimedResult with device-measured compute.

    Blocks until every jax array in the output is ready, so asynchronous
    dispatch cannot hide compute from the clock.  When ``record`` is given
    the measurement is also stored under ``name`` — the runtime uses this
    to cross-check the engine's ledger.
    """

    @functools.wraps(fn)
    def wrapper(*args):
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(*args))
        dt = time.perf_counter() - t0
        if record is not None:
            record[name or getattr(fn, "__name__", "job")] = dt
        return TimedResult(out, dt)

    return wrapper


def build_dag(site_jobs: list[SiteJob], name: str = "site-jobs") -> DAG:
    """Assemble SiteJobs into an executable DAG (insertion order must be
    topological, as with ``DAG.add``).  Duplicate job names and unknown
    or self dependencies are rejected by ``DAG.add`` with the offending
    job named — which also makes a cycle unconstructible here; cycles
    introduced by later mutation are caught by ``DAG.validate_acyclic``
    at run time."""
    dag = DAG(name)
    for sj in site_jobs:
        dag.add(sj.to_job())
    return dag


def replay_dag(specs: list[JobSpec], job_times: dict[str, float] | None = None) -> DAG:
    """Rebuild a workflow topology as a pure-simulation DAG: trivial jobs
    whose simulated compute is the recorded measurement (``job_times``,
    falling back to each spec's ``compute_s``).  Replaying the same specs
    and times through different engine schedules or link matrices isolates
    the scheduling policy — identical DAG/model/times, zero timing noise —
    which is how the sweep benchmark compares staged vs async fairly."""
    times = job_times or {}
    dag = DAG("replay")
    for sp in specs:
        sim = float(times.get(sp.name, sp.compute_s))
        dag.job(
            sp.name,
            lambda *a: TimedResult(None, 0.0),
            deps=list(sp.deps),
            site=sp.site,
            input_bytes=sp.input_bytes,
            output_bytes=sp.output_bytes,
            sim_compute_s=sim,
        )
    return dag


def job_specs(site_jobs: list[SiteJob], job_times: dict[str, float] | None = None) -> list[JobSpec]:
    """Strip SiteJobs down to the analytical ``overhead.JobSpec`` view,
    with compute times taken from a run's measured ``RunReport.job_times``
    — the inputs to ``estimate_dag`` / ``estimate_stages_from_specs``, so
    the paper's measured-vs-estimated comparison is calibrated by the same
    kernel timings that fed the simulated clock."""
    times = job_times or {}
    return [
        JobSpec(
            name=sj.name,
            deps=tuple(sj.deps),
            compute_s=float(times.get(sj.name, 0.0)),
            input_bytes=sj.input_bytes,
            output_bytes=sj.output_bytes,
            site=sj.site,
        )
        for sj in site_jobs
    ]
