"""Synthetic datasets matching the paper's experimental setup.

The paper (§5.2): "For the clustering task, the data is a set of random
Gaussian distributions.  For the frequent itemsets mining, synthetic
transactions from different sizes were generated."  We parameterise both
with fixed seeds for reproducibility.
"""

from __future__ import annotations

import numpy as np


def gaussian_mixture(
    seed: int,
    n_points: int,
    dim: int,
    n_components: int,
    spread: float = 10.0,
    sigma: float = 0.6,
) -> tuple[np.ndarray, np.ndarray]:
    """Random Gaussian mixture.  Returns (points (N, D) f32, labels (N,))."""
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-spread, spread, size=(n_components, dim)).astype(np.float32)
    comp = rng.integers(0, n_components, size=n_points)
    pts = centers[comp] + rng.normal(0.0, sigma, size=(n_points, dim)).astype(np.float32)
    return pts.astype(np.float32), comp


def split_sites(x: np.ndarray, n_sites: int, seed: int = 0) -> np.ndarray:
    """Shuffle and split points evenly into (s, n, D) site shards
    (the paper distributes the dataset uniformly over processes)."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(x))
    n = (len(x) // n_sites) * n_sites
    return x[idx[:n]].reshape(n_sites, -1, *x.shape[1:])


def ibm_transactions(
    seed: int,
    n_tx: int,
    n_items: int,
    avg_tx_len: int = 10,
    n_patterns: int = 20,
    avg_pattern_len: int = 4,
    corruption: float = 0.25,
) -> np.ndarray:
    """IBM Quest-style synthetic transaction generator (T_avg I_pat D_n).

    Draws maximal potentially-frequent patterns (exponential lengths around
    ``avg_pattern_len``), then assembles transactions from patterns with
    per-item corruption + random noise items.  Returns dense bool
    (n_tx, n_items).
    """
    rng = np.random.default_rng(seed)
    patterns = []
    weights = rng.exponential(1.0, n_patterns)
    weights /= weights.sum()
    for _ in range(n_patterns):
        ln = max(1, min(n_items, int(rng.poisson(avg_pattern_len))))
        patterns.append(rng.choice(n_items, size=ln, replace=False))

    dense = np.zeros((n_tx, n_items), dtype=bool)
    for t in range(n_tx):
        ln = max(1, int(rng.poisson(avg_tx_len)))
        got = 0
        while got < ln:
            p = patterns[rng.choice(n_patterns, p=weights)]
            keep = p[rng.random(len(p)) > corruption]
            dense[t, keep] = True
            got += max(len(keep), 1)
        # sprinkle noise items
        n_noise = rng.integers(0, 3)
        if n_noise:
            dense[t, rng.choice(n_items, size=n_noise, replace=False)] = True
    return dense


def split_transactions(dense: np.ndarray, n_sites: int, seed: int = 0) -> list[np.ndarray]:
    """Split a dense transaction DB into per-site shards (uneven tail ok)."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(dense))
    return [dense[s] for s in np.array_split(idx, n_sites)]


def token_batch(seed: int, batch: int, seq_len: int, vocab: int) -> dict[str, np.ndarray]:
    """Synthetic LM batch (tokens + next-token labels) for examples/tests."""
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, vocab, size=(batch, seq_len + 1), dtype=np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
