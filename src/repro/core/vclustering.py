"""Variance-based distributed clustering — the paper's Algorithm 1.

Pipeline (per the paper):
  1. Each site i clusters its local data into k_i sub-clusters (K-Means).
  2. Sites ship ONLY sufficient statistics (N, center, SSE) — KB-scale.
  3. "Logical merge": greedily merge the sub-cluster pair with the smallest
     variance increase s(i,j) while the merged variance stays below a
     threshold (experiments: 2x the largest individual sub-cluster SSE).
     The merge is deterministic given the gathered stats, so EVERY site can
     run it redundantly and obtain the identical global labeling — no
     designated aggregator, no broadcast-back (the paper's "merging is
     'logical'" property).
  4. Border perturbation: each global cluster contributes b border
     candidates; a candidate moves to the closest other global cluster when
     the move lowers the global SSE.  Done site-locally on each site's own
     points (paper: "no additional communications are required").

Two drivers:
  * ``vcluster_pooled`` — reference semantics on a (s, n, D) stack of site
    datasets in one process (vmap over sites).  This is the oracle used by
    tests and by single-host examples.
  * ``vcluster_shard_map`` — the distributed path: shard_map over a mesh
    axis, ``lax.all_gather`` of the stat triples as the single
    communication, redundant logical merge per shard.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.kmeans import kmeans
from repro.core.stats import (
    SuffStats,
    merge_cost,
    pairwise_sq_dists,
    stack_site_stats,
)


class VClusterConfig(NamedTuple):
    k_local: int = 20  # sub-clusters per site (paper experiments: 20)
    kmeans_iters: int = 25
    threshold_factor: float = 2.0  # tau = factor * max individual SSE
    # The paper's line 10 ("while var(C_i,C_j) < tau") is ambiguous between
    # the merged cluster's total variance and the *increase* s(i,j) ("s(i,j)
    # represents the increase in the variance while merging").  The
    # "increase" reading recovers planted structure (tests) and is the
    # default; "merged_var" is kept for the literal reading.
    criterion: str = "increase"  # "increase" (default) | "merged_var"
    border_candidates: int = 8  # b, per global cluster
    perturb_rounds: int = 1
    use_kernel: bool = False  # Pallas assignment kernel


class MergeResult(NamedTuple):
    labels: jax.Array  # (M,) int32 — root slot id per sub-cluster slot
    stats: SuffStats  # merged stats in root slots (dead slots size 0)
    n_merges: jax.Array  # () int32
    n_global: jax.Array  # () int32 — number of live global clusters


# ---------------------------------------------------------------------------
# Phase 2/3: logical merge over gathered sufficient statistics
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("criterion",))
def merge_subclusters(
    stats: SuffStats,
    threshold: jax.Array,
    criterion: str = "merged_var",
) -> MergeResult:
    """Greedy variance-constrained agglomeration over M sub-cluster slots.

    criterion "merged_var": merge while  sse_i + sse_j + s(i,j) < threshold
      (the paper's ``var(C_i, C_j) < tau``, tau = 2 x max individual SSE).
    criterion "increase":   merge while  s(i,j) < threshold.
    """
    m = stats.n_slots
    labels0 = jnp.arange(m, dtype=jnp.int32)

    def score(st: SuffStats) -> jax.Array:
        s = merge_cost(st)  # (M, M), inf on dead/diag
        if criterion == "merged_var":
            tot = st.sse[:, None] + st.sse[None, :]
            return jnp.where(jnp.isfinite(s), s + tot, jnp.inf)
        return s

    def cond(carry):
        st, labels, n_merges = carry
        sc = score(st)
        return jnp.min(sc) < threshold

    def body(carry):
        st, labels, n_merges = carry
        sc = score(st)
        flat = jnp.argmin(sc)
        i, j = flat // m, flat % m
        # merge j into i (paper's update formulas)
        ni, nj = st.sizes[i], st.sizes[j]
        ci, cj = st.centers[i], st.centers[j]
        n_new = ni + nj
        w = 1.0 / jnp.maximum(n_new, 1e-30)
        c_new = (ni * ci + nj * cj) * w
        s_ij = ni * nj * w * jnp.sum((ci - cj) ** 2)
        sse_new = st.sse[i] + st.sse[j] + s_ij
        st = SuffStats(
            sizes=st.sizes.at[i].set(n_new).at[j].set(0.0),
            centers=st.centers.at[i].set(c_new).at[j].set(0.0),
            sse=st.sse.at[i].set(sse_new).at[j].set(0.0),
        )
        labels = jnp.where(labels == labels[j], labels[i], labels)
        return st, labels, n_merges + 1

    st, labels, n_merges = jax.lax.while_loop(cond, body, (stats, labels0, jnp.int32(0)))
    n_global = jnp.sum((st.sizes > 0).astype(jnp.int32))
    return MergeResult(labels=labels, stats=st, n_merges=n_merges, n_global=n_global)


def paper_threshold(stats: SuffStats, factor: float) -> jax.Array:
    """tau = factor * max individual sub-cluster SSE (paper's setting)."""
    return factor * jnp.max(jnp.where(stats.sizes > 0, stats.sse, -jnp.inf))


def merge_gathered(per_site: SuffStats, cfg: VClusterConfig) -> MergeResult:
    """Logical merge over gathered per-site stats (s, k, ...) — the single
    deterministic computation every site runs redundantly after the one
    all_gather.  Shared by the pooled driver, the shard_map driver, and the
    runtime's sync job."""
    flat = stack_site_stats(per_site)
    tau = paper_threshold(flat, cfg.threshold_factor)
    return merge_subclusters(flat, tau, criterion=cfg.criterion)


# ---------------------------------------------------------------------------
# Phase 4: border perturbation (site-local, zero extra communication)
# ---------------------------------------------------------------------------


def perturb_site(
    x: jax.Array,  # (n, D) site-local points
    point_slot: jax.Array,  # (n,) int32 — sub-cluster SLOT id per point
    merged: MergeResult,
    b: int,
) -> tuple[jax.Array, SuffStats]:
    """Paper lines 13-24: move border candidates between global clusters when
    the global variance decreases.  Operates on this site's own points only,
    against the (replicated) global statistics; returns per-point global
    slot labels and this site's locally-updated copy of the global stats.

    Candidate selection: within each live global cluster, the b points of
    THIS site farthest from the global center ("find_border").  Move test
    for a single point x from cluster g to cluster j (treating {x} as a
    singleton merge, per the s(i,j) formula):
        gain_remove = N_g/(N_g-1) * d(c_g, x)^2
        cost_add    = N_j/(N_j+1) * d(c_j, x)^2
    Move iff cost_add < gain_remove (strict SSE decrease).
    """
    n, d = x.shape
    m = merged.stats.n_slots
    glabel = merged.labels[point_slot]  # (n,) global slot per point

    st = merged.stats
    d2_all = pairwise_sq_dists(x, st.centers)  # (n, M)

    alive = st.sizes > 0

    # --- border candidates: top-b farthest per global cluster, this site ---
    own_d2 = jnp.take_along_axis(d2_all, glabel[:, None], axis=1)[:, 0]  # (n,)
    # score matrix (M, n): distance if point belongs to cluster else -inf
    belong = glabel[None, :] == jnp.arange(m, dtype=jnp.int32)[:, None]  # (M, n)
    scores = jnp.where(belong, own_d2[None, :], -jnp.inf)
    # top-b point indices per cluster slot
    _, cand_idx = jax.lax.top_k(scores, min(b, n))  # (M, b)
    cand_valid = jnp.take_along_axis(scores, cand_idx, axis=1) > -jnp.inf

    cand_flat = cand_idx.reshape(-1)  # (M*b,)
    valid_flat = cand_valid.reshape(-1)

    def move_one(carry, ci):
        sizes, centers, sse, glabel = carry
        idx, ok = ci
        xi = x[idx]
        g = glabel[idx]
        dg2 = jnp.sum((xi - centers[g]) ** 2)
        # closest OTHER live global cluster
        d2 = jnp.sum((xi[None, :] - centers) ** 2, axis=-1)
        d2 = jnp.where(alive & (sizes > 0), d2, jnp.inf)
        d2 = d2.at[g].set(jnp.inf)
        j = jnp.argmin(d2).astype(jnp.int32)
        dj2 = d2[j]
        ng, nj = sizes[g], sizes[j]
        gain_remove = jnp.where(ng > 1, ng / jnp.maximum(ng - 1.0, 1e-30) * dg2, 0.0)
        cost_add = nj / (nj + 1.0) * dj2
        do = ok & (ng > 1) & jnp.isfinite(dj2) & (cost_add < gain_remove)

        def apply(args):
            sizes, centers, sse, glabel = args
            cg_new = jnp.where(ng > 1, (sizes[g] * centers[g] - xi) / jnp.maximum(ng - 1.0, 1e-30), centers[g])
            cj_new = (sizes[j] * centers[j] + xi) / (nj + 1.0)
            sizes = sizes.at[g].add(-1.0).at[j].add(1.0)
            centers = centers.at[g].set(cg_new).at[j].set(cj_new)
            sse = sse.at[g].add(-gain_remove).at[j].add(cost_add)
            glabel = glabel.at[idx].set(j)
            return sizes, centers, sse, glabel

        carry = jax.lax.cond(do, apply, lambda a: a, (sizes, centers, sse, glabel))
        return carry, do

    carry0 = (st.sizes, st.centers, st.sse, glabel)
    (sizes, centers, sse, glabel), moved = jax.lax.scan(
        move_one, carry0, (cand_flat, valid_flat)
    )
    return glabel, SuffStats(sizes=sizes, centers=centers, sse=sse)


# ---------------------------------------------------------------------------
# Drivers
# ---------------------------------------------------------------------------


class VClusterResult(NamedTuple):
    labels: jax.Array  # (s, n) global slot label per point
    merged: MergeResult
    site_stats: SuffStats  # (s, k, ...) pre-merge sub-cluster stats
    comm_bytes: jax.Array  # () — bytes of statistics exchanged (the ONLY comm)


def _site_local(key, x, cfg: VClusterConfig):
    res = kmeans(key, x, cfg.k_local, iters=cfg.kmeans_iters, use_kernel=cfg.use_kernel)
    return res.assign, res.stats


@functools.partial(jax.jit, static_argnames=("cfg",))
def _site_local_batch(keys, xs, cfg: VClusterConfig):
    """Fused fan-out: per-site K-Means for every site in ONE vmapped
    dispatch — what the batched execution backend calls instead of the
    per-site host loop."""
    return jax.vmap(lambda k, x: _site_local(k, x, cfg))(keys, xs)


@functools.partial(jax.jit, static_argnames=("b",))
def _perturb_batch(xs, slots, merged: MergeResult, b: int):
    """Fused fan-out: border perturbation for every site in ONE vmapped
    dispatch (site-local by construction — the merged stats are
    replicated, exactly as in the pooled driver)."""
    return jax.vmap(lambda x, s: perturb_site(x, s, merged, b)[0])(xs, slots)


@functools.partial(jax.jit, static_argnames=("b",))
def _perturb_batch_many(xs, slots, merged: MergeResult, b: int):
    """Like ``_perturb_batch`` but with one MergeResult PER MEMBER
    (leaves stacked on a leading axis) — the cross-request fused waves
    of the serving layer carry each request's own merge result."""
    return jax.vmap(lambda x, s, m: perturb_site(x, s, m, b)[0])(xs, slots, merged)


@functools.partial(jax.jit, static_argnames=("cfg",))
def vcluster_pooled(key: jax.Array, xs: jax.Array, cfg: VClusterConfig = VClusterConfig()) -> VClusterResult:
    """Reference driver: xs is (s, n, D) — s sites' datasets stacked.

    Semantically identical to the shard_map driver; the "gather" is free.
    """
    s, n, d = xs.shape
    keys = jax.random.split(key, s)
    assigns, per_site = jax.vmap(lambda k, x: _site_local(k, x, cfg))(keys, xs)
    merged = merge_gathered(per_site, cfg)

    k = cfg.k_local
    offsets = (jnp.arange(s, dtype=jnp.int32) * k)[:, None]
    point_slots = assigns + offsets  # (s, n) slot ids

    def site_perturb(x, slots):
        lbl, _ = perturb_site(x, slots, merged, cfg.border_candidates)
        return lbl

    labels = jax.vmap(site_perturb)(xs, point_slots)
    comm = jnp.asarray(s * k * (d + 2) * 4, jnp.int32)  # stats triples, f32
    return VClusterResult(labels=labels, merged=merged, site_stats=per_site, comm_bytes=comm)


def vcluster_shard_map(mesh, axis: str, cfg: VClusterConfig = VClusterConfig()):
    """Build the distributed driver: each shard along ``axis`` is one grid
    site.  The single communication is ``lax.all_gather`` of SuffStats
    (paper: "the only bookkeeping needed from the other sites is centers,
    sizes and variances").  Merge runs redundantly per site — identical
    output everywhere (logical merge).

    Returns fn(key (s,2) uint32 per-site keys, x_global (S*n, D)) ->
    (labels (S*n,), merged MergeResult replicated).
    """
    n_sites = mesh.shape[axis]
    k = cfg.k_local

    def body(keys, x):  # keys: (1, 2); x: (n, D) — this site's shard
        key = keys[0]
        assign, st = _site_local(key, x, cfg)
        gathered = jax.lax.all_gather(st, axis)  # (s, k, ...) tiny
        merged = merge_gathered(gathered, cfg)
        site_idx = jax.lax.axis_index(axis)
        slots = assign + site_idx.astype(jnp.int32) * k
        labels, _ = perturb_site(x, slots, merged, cfg.border_candidates)
        return labels, merged

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=(P(axis), P()),  # merged result identical on every site
        check_vma=False,
    )
    return fn


# ---------------------------------------------------------------------------
# SiteJob decomposition (the grid-workflow view of Algorithm 1)
# ---------------------------------------------------------------------------


def vcluster_site_jobs(
    key: jax.Array,
    xs: jax.Array,
    cfg: VClusterConfig = VClusterConfig(),
    *,
    sync=None,
    measured: dict | None = None,
) -> list:
    """Decompose Algorithm 1 into ``workflow.sitejob.SiteJob``s.

    Stage 1: per-site K-Means sub-clustering (``cluster_i``; the Pallas
    assignment kernel when ``cfg.use_kernel``).  Stage 2: the single
    synchronization (``merge``) — ``sync(per_site_stats) -> MergeResult``
    is injected by the runtime (shard_map all_gather on a device mesh, or
    the default in-process pooled merge).  Stage 3: per-site border
    perturbation (``perturb_i`` — no inter-site communication; the final
    point labels are staged back to the submit node, ``output_bytes``).
    The terminal ``collect`` job's result is a ``VClusterResult``.

    All jobs return TimedResults, so the engine's grid clock is advanced by
    real measured kernel time; ``measured`` (if given) receives the same
    numbers for cross-checking the engine's ledger.

    The per-site fan-outs (``cluster_i``, ``perturb_i``) also carry
    ``batch_key``/``batched_fn`` hooks: under the ``batched`` execution
    backend the whole fan-out runs as ONE vmapped dispatch across the
    site axis, with the measured batch time apportioned per job.
    """
    from repro.workflow.sitejob import SiteJob, timed, timed_batch

    xs = jnp.asarray(xs)
    s, n, d = xs.shape
    k = cfg.k_local
    keys = jax.random.split(key, s)
    stats_nbytes = k * (d + 2) * 4  # (N, center, SSE) triples, f32
    if sync is None:
        sync = functools.partial(merge_gathered, cfg=cfg)
    jobs: list[SiteJob] = []

    def cluster_fn(i):
        def fn():
            return _site_local(keys[i], xs[i], cfg)

        return fn

    def cluster_batched(bargs, argss):
        # bargs carry (site, site_key): a cross-request merged wave
        # (service fusion) executes under the FIRST member's closure, and
        # each member's PRNG key is request-specific (per-request seeds)
        # while the site data is pinned identical by the fuse signature
        idx = jnp.asarray([i for i, _ in bargs], dtype=jnp.int32)
        bkeys = jnp.stack([kk for _, kk in bargs])
        assigns, st = _site_local_batch(bkeys, xs[idx], cfg)
        return [
            (assigns[j], SuffStats(sizes=st.sizes[j], centers=st.centers[j], sse=st.sse[j]))
            for j in range(len(bargs))
        ]

    for i in range(s):
        jobs.append(
            SiteJob(
                name=f"cluster_{i}",
                fn=timed(cluster_fn(i), measured, f"cluster_{i}"),
                site=i,  # GridModel.transfer_s normalizes to its link matrix
                input_bytes=int(xs[i].nbytes),
                output_bytes=stats_nbytes,
                batch_key="cluster",
                batched_fn=timed_batch(cluster_batched, measured),
                batch_arg=(i, keys[i]),
            )
        )

    def merge_fn(*site_out):
        per_site = SuffStats(
            sizes=jnp.stack([st.sizes for _, st in site_out]),
            centers=jnp.stack([st.centers for _, st in site_out]),
            sse=jnp.stack([st.sse for _, st in site_out]),
        )
        return sync(per_site)

    jobs.append(
        SiteJob(
            name="merge",
            fn=timed(merge_fn, measured, "merge"),
            deps=[f"cluster_{i}" for i in range(s)],
            input_bytes=s * stats_nbytes,  # the all_gather payload
        )
    )

    def perturb_fn(i):
        def fn(site_out, merged):
            assign, _ = site_out
            slots = assign + jnp.int32(i * k)
            labels, _ = perturb_site(xs[i], slots, merged, cfg.border_candidates)
            return labels

        return fn

    def perturb_batched(bargs, argss):
        idx = jnp.asarray(bargs, dtype=jnp.int32)
        assigns = jnp.stack([site_out[0] for site_out, _ in argss])
        slots = assigns + (idx * jnp.int32(k))[:, None]
        mergeds = [m for _, m in argss]
        if all(m is mergeds[0] for m in mergeds):
            # one engine run: every member shares the same "merge" dep —
            # keep the exact broadcast path (bitwise-stable, what the
            # cross-backend conformance suite pins)
            labels = _perturb_batch(xs[idx], slots, mergeds[0], cfg.border_candidates)
        else:
            # cross-request merged wave: one MergeResult per member
            merged = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *mergeds)
            labels = _perturb_batch_many(xs[idx], slots, merged, cfg.border_candidates)
        return [labels[j] for j in range(len(bargs))]

    for i in range(s):
        jobs.append(
            SiteJob(
                name=f"perturb_{i}",
                fn=timed(perturb_fn(i), measured, f"perturb_{i}"),
                deps=[f"cluster_{i}", "merge"],
                site=i,  # GridModel.transfer_s normalizes to its link matrix
                output_bytes=n * 4,  # int32 point labels staged back
                batch_key="perturb",
                batched_fn=timed_batch(perturb_batched, measured),
                batch_arg=i,
            )
        )

    def collect_fn(merged, *rest):
        labels = jnp.stack(list(rest[:s]))
        site_out = rest[s:]
        per_site = SuffStats(
            sizes=jnp.stack([st.sizes for _, st in site_out]),
            centers=jnp.stack([st.centers for _, st in site_out]),
            sse=jnp.stack([st.sse for _, st in site_out]),
        )
        comm = jnp.asarray(s * stats_nbytes, jnp.int32)
        return VClusterResult(labels=labels, merged=merged, site_stats=per_site, comm_bytes=comm)

    jobs.append(
        SiteJob(
            name="collect",
            fn=timed(collect_fn, measured, "collect"),
            deps=["merge", *[f"perturb_{i}" for i in range(s)], *[f"cluster_{i}" for i in range(s)]],
        )
    )
    return jobs
