"""Quickstart: the paper's two algorithms end-to-end in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core.apriori import TransactionDB
from repro.core.fdm import fdm_mine
from repro.core.gfm import gfm_mine
from repro.core.vclustering import VClusterConfig, vcluster_pooled
from repro.data.synthetic import (
    gaussian_mixture,
    ibm_transactions,
    split_sites,
    split_transactions,
)

# ---- 1. variance-based distributed clustering (Algorithm 1) -------------
pts, _ = gaussian_mixture(seed=0, n_points=8000, dim=2, n_components=5, spread=12.0, sigma=0.6)
sites = split_sites(pts, n_sites=4, seed=1)  # 4 "grid sites"

cfg = VClusterConfig(k_local=10, kmeans_iters=20, border_candidates=6)
res = vcluster_pooled(jax.random.PRNGKey(0), jnp.asarray(sites), cfg)
print(f"[clustering] sites=4 k_local=10 -> {int(res.merged.n_global)} global clusters "
      f"after {int(res.merged.n_merges)} merges")
print(f"[clustering] communication: {int(res.comm_bytes)} bytes of sufficient statistics "
      f"(the raw data is {sites.size * 4} bytes — never moved)")

# ---- 2. grid-based frequent itemset mining (Algorithm 2) ----------------
dense = ibm_transactions(seed=1, n_tx=4000, n_items=48, avg_tx_len=8, n_patterns=10)
dbs = [TransactionDB.from_dense(s) for s in split_transactions(dense, 4, seed=0)]

g = gfm_mine(dbs, k=4, minsup=0.08)
f = fdm_mine(dbs, k=4, minsup=0.08)
assert g.frequent == f.frequent
print(f"[itemsets] {len(g.frequent)} globally frequent itemsets (sizes 1..4)")
print(f"[itemsets] GFM sync passes: {g.comm.rounds} | FDM sync passes: {f.comm.rounds} "
      f"(paper: 2 vs 4)")
