"""GFM — Grid-based Frequent-itemset Mining (the paper's Algorithm 2).

Protocol (faithful to §3.2):
  Phase 1 (fully local, zero communication): every site runs Apriori with
    LOCAL pruning only, producing its locally frequent itemsets of sizes
    1..k and caching every support it counted along the way.
  Phase 2 (the single synchronization):
    pass 1 — sites exchange their locally frequent itemsets WITH their
      local counts (one message per site: the union pool U is now known
      everywhere, partially counted);
    pass 2 — every site counts the pool entries it had NOT already counted
      locally ("remote support counts ... requested from other sites") and
      replies; global counts are now exact.
  Top-down search: itemsets failing the global test have their subsets
    examined top-down.  Under uniform local/global support ratios the
    standard lemma (globally frequent ⇒ locally frequent at ≥1 site)
    guarantees every candidate subset is already in U, so the descent adds
    ZERO extra communication rounds — which is exactly why the paper
    observes 2 passes (vs FDM's k).  With non-uniform thresholds the lemma
    breaks and the descent issues further (counted) rounds; we support both
    and report the realized round count.

Communication accounting mirrors the paper's evaluation: we report rounds
(synchronization passes) and bytes (itemset ids + 4-byte counts, broadcast
to the s-1 peers).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.apriori import (
    Itemset,
    LocalMineResult,
    TransactionDB,
    batched_local_apriori,
    count_supports,
    fused_count_sites,
    local_apriori,
    subsets_of,
)


@dataclass
class CommLog:
    """Synchronization/communication ledger (what the paper measures)."""

    rounds: int = 0
    bytes_sent: int = 0
    messages: int = 0
    count_calls: int = 0  # device support-count invocations
    per_round_bytes: list = field(default_factory=list)

    def add_round(self, payload_items: int, item_bytes: int, n_sites: int) -> None:
        # every site broadcasts to its s-1 peers (paper: iterative
        # peer-to-peer requests; we ledger the all-to-all equivalent)
        b = payload_items * item_bytes * (n_sites - 1)
        self.rounds += 1
        self.bytes_sent += b
        self.messages += n_sites * (n_sites - 1)
        self.per_round_bytes.append(b)


@dataclass
class GFMResult:
    frequent: dict[Itemset, int]  # globally frequent -> exact global count
    comm: CommLog
    local: list[LocalMineResult]
    pool_sizes: list[int]  # candidates exchanged per round
    n_total_tx: int


def _itemset_bytes(k: int) -> int:
    return 4 * k + 4  # item ids (4B each) + count


# ---------------------------------------------------------------------------
# Protocol phases — shared by the in-process driver (gfm_mine) and the
# SiteJob decomposition (gfm_site_jobs / runtime.GridRuntime)
# ---------------------------------------------------------------------------


def build_pool(local: list[LocalMineResult], k: int) -> tuple[list[Itemset], int]:
    """Phase 2 pass 1: the union pool of locally frequent itemsets and the
    exchanged payload size (itemset count announced across all sites)."""
    pool: set[Itemset] = set()
    payload = 0
    for lm in local:
        for lv in range(1, k + 1):
            pool.update(lm.frequent[lv])
            payload += len(lm.frequent[lv])
    return sorted(pool, key=lambda t: (len(t), t)), payload


def fill_missing(
    db: TransactionDB, lm: LocalMineResult, pool: list[Itemset], backend: str = "jnp"
) -> int:
    """Phase 2 pass 2, one site's share: count the pool entries this site
    had NOT already counted locally.  Mutates ``lm.counts`` (idempotent —
    re-running counts nothing) and returns the number counted."""
    missing = [its for its in pool if its not in lm.counts]
    if missing:
        sup = count_supports(db, missing, backend=backend)
        for its, c in zip(missing, sup):
            lm.counts[its] = int(c)
    return len(missing)


def aggregate_counts(pool: list[Itemset], local: list[LocalMineResult]) -> dict[Itemset, int]:
    """Exact global counts once every site has filled its missing supports."""
    return {its: sum(lm.counts[its] for lm in local) for its in pool}


def topdown_search(
    sites: list[TransactionDB],
    local: list[LocalMineResult],
    decided: dict[Itemset, tuple[int, bool]],
    g_min: int,
    comm: CommLog,
    k: int,
    backend: str,
    pool_sizes: list[int],
) -> None:
    """Top-down descent over subsets of globally-failed itemsets.

    Under uniform thresholds every candidate subset is already decided
    (the 2-pass lemma) and this issues ZERO extra rounds; with non-uniform
    thresholds it runs further counted rounds.  Mutates ``decided``,
    ``comm`` and ``pool_sizes``.
    """
    frontier: set[Itemset] = set()
    for its, (_, ok) in list(decided.items()):
        if not ok:
            for sub in subsets_of(its):
                if len(sub) >= 1 and sub not in decided:
                    frontier.add(sub)
    while frontier:
        batch = sorted(frontier, key=lambda t: (len(t), t))
        pool_sizes.append(len(batch))
        counts = np.zeros(len(batch), dtype=np.int64)
        for db, lm in zip(sites, local):
            missing = [its for its in batch if its not in lm.counts]
            if missing:
                sup = count_supports(db, missing, backend=backend)
                comm.count_calls += 1
                for its, c in zip(missing, sup):
                    lm.counts[its] = int(c)
            counts += np.array([lm.counts[its] for its in batch], dtype=np.int64)
        comm.add_round(len(batch) * len(sites), _itemset_bytes(k), len(sites))
        frontier = set()
        for its, c in zip(batch, counts):
            ok = int(c) >= g_min
            decided[its] = (int(c), ok)
            if not ok:
                for sub in subsets_of(its):
                    if len(sub) >= 1 and sub not in decided:
                        frontier.add(sub)


def gfm_mine(
    sites: list[TransactionDB],
    k: int,
    minsup: float,
    backend: str = "jnp",
    local_minsup: float | None = None,
) -> GFMResult:
    """Run the GFM protocol over ``sites``.

    minsup: global relative support threshold.
    local_minsup: per-site relative threshold for phase 1 (defaults to
      ``minsup`` — the uniform setting under which the 2-pass bound holds).
    """
    s = len(sites)
    n_total = sum(db.n_tx for db in sites)
    g_min = int(np.ceil(minsup * n_total))
    l_ratio = minsup if local_minsup is None else local_minsup
    comm = CommLog()

    # ---- Phase 1: independent local Apriori (no communication) ----
    local: list[LocalMineResult] = []
    for db in sites:
        lm = local_apriori(db, k, int(np.ceil(l_ratio * db.n_tx)), backend=backend)
        comm.count_calls += lm.count_calls
        local.append(lm)

    # ---- Phase 2 pass 1: exchange locally frequent itemsets + counts ----
    pool_sorted, payload = build_pool(local, k)
    comm.add_round(payload, _itemset_bytes(k), s)
    pool_sizes = [len(pool_sorted)]

    # ---- Phase 2 pass 2: fill in missing remote supports ----
    reply_payload = 0
    for db, lm in zip(sites, local):
        n_missing = fill_missing(db, lm, pool_sorted, backend=backend)
        if n_missing:
            comm.count_calls += 1
        reply_payload += n_missing
    comm.add_round(reply_payload, _itemset_bytes(k), s)

    global_counts = aggregate_counts(pool_sorted, local)
    decided: dict[Itemset, tuple[int, bool]] = {
        its: (c, c >= g_min) for its, c in global_counts.items()
    }

    # ---- Top-down search over subsets of failures ----
    # Under uniform thresholds every globally frequent subset is already in
    # the pool (lemma), so the descent adds no further rounds.
    topdown_search(sites, local, decided, g_min, comm, k, backend, pool_sizes)

    frequent = {its: c for its, (c, ok) in decided.items() if ok}
    return GFMResult(
        frequent=frequent,
        comm=comm,
        local=local,
        pool_sizes=pool_sizes,
        n_total_tx=n_total,
    )


# ---------------------------------------------------------------------------
# SiteJob decomposition (the grid-workflow view of Algorithm 2)
# ---------------------------------------------------------------------------


def gfm_site_jobs(
    sites: list[TransactionDB],
    k: int,
    minsup: float,
    backend: str = "jnp",
    local_minsup: float | None = None,
    measured: dict | None = None,
) -> list:
    """Decompose the GFM protocol into ``workflow.sitejob.SiteJob``s.

    ``apriori_i`` are the fully-local phase-1 jobs (Pallas support counting
    when ``backend="kernel"``); ``pool`` and ``decide`` bracket the single
    two-pass synchronization, with the ``recount_i`` jobs doing each site's
    missing-support counting in between.  The terminal ``decide`` job's
    result is a ``GFMResult`` with the same CommLog semantics as
    ``gfm_mine`` — exactly 2 rounds under uniform thresholds.

    The jobs share one CommLog, so run them without fault injection
    (a retried ``pool`` would ledger its round twice).  Both engine
    schedulers are safe: under ``schedule="async"`` the dependency edges
    alone order every CommLog mutation (pool after all aprioris, decide
    after all recounts), and speculation never re-executes a job's fn.

    The per-site fan-outs (``apriori_i``, ``recount_i``) also carry
    ``batch_key``/``batched_fn`` hooks: under the ``batched`` execution
    backend phase 1 runs as lockstep level rounds with one fused
    site-axis count dispatch per level (``batched_local_apriori``), and
    the missing-support recounts as one fused dispatch total
    (``fused_count_sites``) — result- and ledger-identical to the
    per-site loop.
    """
    from repro.workflow.sitejob import SiteJob, timed, timed_batch

    s = len(sites)
    n_total = sum(db.n_tx for db in sites)
    g_min = int(np.ceil(minsup * n_total))
    l_ratio = minsup if local_minsup is None else local_minsup
    comm = CommLog()
    pool_sizes: list[int] = []
    jobs: list[SiteJob] = []

    def apriori_fn(i):
        db = sites[i]

        def fn():
            return local_apriori(db, k, int(np.ceil(l_ratio * db.n_tx)), backend=backend)

        return fn

    def apriori_batched(bargs, argss):
        # bargs carry (site, local_min_count): in a cross-request merged
        # wave (service fusion — same shapes, different minsup) the FIRST
        # member's closure executes the whole group, so each member's
        # request-specific local threshold travels in its batch arg
        dbs = [sites[i] for i, _ in bargs]
        mins = [m for _, m in bargs]
        return batched_local_apriori(dbs, k, mins, backend=backend)

    for i in range(s):
        jobs.append(
            SiteJob(
                name=f"apriori_{i}",
                fn=timed(apriori_fn(i), measured, f"apriori_{i}"),
                site=i,  # GridModel.transfer_s normalizes to its link matrix
                input_bytes=int(np.asarray(sites[i].packed).nbytes),
                batch_key="apriori",
                batched_fn=timed_batch(apriori_batched, measured),
                batch_arg=(i, int(np.ceil(l_ratio * sites[i].n_tx))),
            )
        )

    def pool_fn(*local):
        for lm in local:
            comm.count_calls += lm.count_calls
        pool, payload = build_pool(list(local), k)
        comm.add_round(payload, _itemset_bytes(k), s)
        pool_sizes.append(len(pool))
        return pool

    jobs.append(
        SiteJob(
            name="pool",
            fn=timed(pool_fn, measured, "pool"),
            deps=[f"apriori_{i}" for i in range(s)],
        )
    )

    # The per-site recount jobs are CLOSURE-PURE: everything they know
    # flows in through their dependency results and out through their own
    # result.  Their device-count-call contribution to the shared CommLog
    # is ledgered by the downstream sync job (``decide``) from the shipped
    # ``n_missing`` values — under the multihost backend each recount runs
    # on its owning process only, so a closure mutation here would be lost
    # to the process that aggregates the ledger.
    def recount_fn(i):
        db = sites[i]

        def fn(lm, pool):
            n_missing = fill_missing(db, lm, pool, backend=backend)
            return lm, n_missing

        return fn

    def recount_batched(bargs, argss):
        # each member brings its own site's LocalMineResult AND its own
        # request's pool dep — within one engine run every member shares
        # the same pool object, but a cross-request merged wave (service
        # fusion) has one pool per request, so the pool must come from
        # each member's argss entry, never from member 0's
        missing_by = [[its for its in pool if its not in lm.counts] for lm, pool in argss]
        sups = fused_count_sites([sites[i] for i in bargs], missing_by, backend=backend)
        outs = []
        for (lm, _pool), missing, sup in zip(argss, missing_by, sups):
            if missing:
                for its, c in zip(missing, sup):
                    lm.counts[its] = int(c)
            outs.append((lm, len(missing)))
        return outs

    for i in range(s):
        jobs.append(
            SiteJob(
                name=f"recount_{i}",
                fn=timed(recount_fn(i), measured, f"recount_{i}"),
                deps=[f"apriori_{i}", "pool"],
                site=i,  # GridModel.transfer_s normalizes to its link matrix
                batch_key="recount",
                batched_fn=timed_batch(recount_batched, measured),
                batch_arg=i,
            )
        )

    def decide_fn(pool, *recounts):
        local = [lm for lm, _ in recounts]
        # each site that actually had missing pool entries made one device
        # count call during its recount — ledgered HERE, from the shipped
        # results, exactly as gfm_mine counts it
        comm.count_calls += sum(1 for _, nm in recounts if nm)
        comm.add_round(sum(nm for _, nm in recounts), _itemset_bytes(k), s)
        counts = aggregate_counts(pool, local)
        decided = {its: (c, c >= g_min) for its, c in counts.items()}
        topdown_search(sites, local, decided, g_min, comm, k, backend, pool_sizes)
        frequent = {its: c for its, (c, ok) in decided.items() if ok}
        return GFMResult(
            frequent=frequent, comm=comm, local=local, pool_sizes=pool_sizes, n_total_tx=n_total
        )

    jobs.append(
        SiteJob(
            name="decide",
            fn=timed(decide_fn, measured, "decide"),
            deps=["pool", *[f"recount_{i}" for i in range(s)]],
        )
    )
    return jobs
