"""Autotuner validation: tuned configs never change results (the
block-size contract, property-tested over odd shapes), the memo is hit
on the second call, persisted tables round-trip, and the ``block="auto"``
seam keeps the real registry apps digest-identical."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic env: deterministic shim, no shrinking
    from repro.testing import given, settings, strategies as st

from repro.core.apriori import pack_bool_matrix, pack_itemsets
from repro.kernels import autotune, ops, pad_to
from repro.kernels.kmeans_assign import BIG, kmeans_assign_pallas
from repro.kernels.support_count import support_count_pallas


@pytest.fixture(autouse=True)
def _fresh_cache():
    """Each test starts from an empty memo and the tiny smoke lattice
    (the full lattice sweep belongs to the benchmarks, not unit tests)."""
    autotune.clear_cache()
    prev = autotune.set_smoke(True)
    yield
    autotune.set_smoke(prev)
    autotune.clear_cache()


def _support_inputs(n, items, c, seed):
    rng = np.random.default_rng(seed)
    dense = rng.random((n, items)) < 0.3
    tx = jnp.asarray(pack_bool_matrix(dense))
    sets = [
        tuple(sorted(rng.choice(items, size=rng.integers(1, min(4, items) + 1), replace=False).tolist()))
        for _ in range(c)
    ]
    masks = jnp.asarray(pack_itemsets(sets, items))
    return tx, masks


class TestSearch:
    def test_candidates_deterministic_default_first(self):
        cands = autotune.support_count_candidates(4, 700, 300)
        assert cands[0] == autotune.DEFAULT_SUPPORT_BLOCKS
        assert cands == autotune.support_count_candidates(4, 700, 300)
        assert len(cands) == len(set(cands))
        kc = autotune.kmeans_assign_candidates(700, 128, 128)
        assert kc[0] == autotune.DEFAULT_KMEANS_BLOCK
        assert kc == autotune.kmeans_assign_candidates(700, 128, 128)

    def test_candidates_respect_vmem_budget(self):
        for bn, bc in autotune.support_count_candidates(32, 5000, 5000, smoke=False)[1:]:
            assert autotune.support_count_vmem(32, bn, bc) <= autotune.VMEM_BUDGET_BYTES
        for bn in autotune.kmeans_assign_candidates(5000, 1024, 1024, smoke=False)[1:]:
            assert autotune.kmeans_assign_vmem(1024, 1024, bn) <= autotune.VMEM_BUDGET_BYTES

    def test_pick_keeps_default_within_margin(self):
        default = autotune.DEFAULT_SUPPORT_BLOCKS
        # a 1% "win" is noise: default survives
        assert autotune._pick([(default, 1.00), ((128, 128), 0.99)]) == default
        # a beyond-margin win replaces it
        assert autotune._pick([(default, 1.00), ((128, 128), 0.50)]) == (128, 128)
        # default never loses to a slower candidate
        assert autotune._pick([(default, 1.00), ((128, 128), 2.00)]) == default

    def test_memo_hit_on_second_call(self):
        tx, masks = _support_inputs(300, 32, 40, seed=0)
        tx_t = jnp.asarray(np.asarray(tx).astype(np.int64).astype(np.int32)).T
        mk_t = jnp.asarray(np.asarray(masks).astype(np.int64).astype(np.int32)).T
        e1 = autotune.tune_support_count(tx_t, mk_t, interpret=True)
        stats = autotune.cache_stats()
        assert stats["misses"] == 1 and stats["entries"] == 1
        e2 = autotune.tune_support_count(tx_t, mk_t, interpret=True)
        assert e2 is e1  # the literal cached entry, nothing re-timed
        assert autotune.cache_stats()["hits"] == 1

    def test_key_buckets_at_lane_granularity(self):
        """Shapes padding to the same 128-multiple share one search (all
        lattice blocks are multiples of 128, so they tile identically)."""
        k1 = autotune.support_count_key(4, 129, 40, jnp.int32, True)
        k2 = autotune.support_count_key(4, 250, 3, jnp.int32, True)
        assert k1 == k2
        assert k1 != autotune.support_count_key(4, 257, 40, jnp.int32, True)
        assert k1 != autotune.support_count_key(4, 129, 40, jnp.int32, False)

    def test_lookup_is_pure(self):
        key = autotune.support_count_key(4, 100, 10, jnp.int32, True)
        assert autotune.lookup(key) is None
        assert autotune.cache_stats()["entries"] == 0


class TestTunedEqualsDefault:
    """Block size must never change results — tuned == default output,
    bit for bit, over odd (non-block-multiple) shapes."""

    @given(
        n=st.integers(1, 800),
        items=st.integers(1, 64),
        c=st.integers(1, 150),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=10, deadline=None)
    def test_support_count(self, n, items, c, seed):
        tx, masks = _support_inputs(n, items, c, seed)
        tx_t = jnp.asarray(np.asarray(tx).astype(np.int64).astype(np.int32)).T
        mk_t = jnp.asarray(np.asarray(masks).astype(np.int64).astype(np.int32)).T
        ent = autotune.tune_support_count(tx_t, mk_t, interpret=True)
        bn, bc = ent["config"]
        tuned = support_count_pallas(tx_t, mk_t, block_n=bn, block_c=bc, interpret=True)
        dn, dc = autotune.DEFAULT_SUPPORT_BLOCKS
        default = support_count_pallas(tx_t, mk_t, block_n=dn, block_c=dc, interpret=True)
        np.testing.assert_array_equal(np.asarray(tuned), np.asarray(default))

    @given(
        n=st.integers(1, 700),
        d=st.integers(1, 96),
        k=st.integers(1, 40),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=10, deadline=None)
    def test_kmeans_assign(self, n, d, k, seed):
        rng = np.random.default_rng(seed)
        dp, kp = pad_to(max(d, 128), 128), pad_to(max(k, 128), 128)
        xp = jnp.zeros((n, dp), jnp.float32).at[:, :d].set(
            jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        )
        cp = jnp.full((kp, dp), 0.0, jnp.float32)
        cp = cp.at[:, :d].set(jnp.full((kp, d), BIG, jnp.float32))
        cp = cp.at[:k, :d].set(jnp.asarray(rng.normal(size=(k, d)).astype(np.float32)))
        ent = autotune.tune_kmeans_assign(xp, cp, interpret=True)
        a_t, d_t = kmeans_assign_pallas(xp, cp, block_n=ent["config"], interpret=True)
        a_d, d_d = kmeans_assign_pallas(
            xp, cp, block_n=autotune.DEFAULT_KMEANS_BLOCK, interpret=True
        )
        np.testing.assert_array_equal(np.asarray(a_t), np.asarray(a_d))
        np.testing.assert_array_equal(np.asarray(d_t), np.asarray(d_d))

    def test_ops_auto_equals_default(self):
        """The ops-wrapper seam end-to-end: block='auto' == block=None."""
        tx, masks = _support_inputs(413, 48, 77, seed=5)
        np.testing.assert_array_equal(
            np.asarray(ops.support_count(tx, masks, block="auto")),
            np.asarray(ops.support_count(tx, masks)),
        )
        cnt, freq = ops.support_count_prune(tx, masks, 37, block="auto")
        want = np.asarray(ops.support_count(tx, masks))
        np.testing.assert_array_equal(np.asarray(cnt), want)
        np.testing.assert_array_equal(np.asarray(freq), want >= 37)


class TestTableRoundTrip:
    def test_save_load_reproduces_memo(self, tmp_path):
        tx, masks = _support_inputs(300, 32, 40, seed=1)
        tx_t = jnp.asarray(np.asarray(tx).astype(np.int64).astype(np.int32)).T
        mk_t = jnp.asarray(np.asarray(masks).astype(np.int64).astype(np.int32)).T
        ent = autotune.tune_support_count(tx_t, mk_t, interpret=True)
        path = str(tmp_path / "tuned.json")
        assert autotune.save_table(path) == 1
        autotune.clear_cache()
        assert autotune.load_table(path) == 1
        key = autotune.support_count_key(
            tx_t.shape[0], tx_t.shape[1], mk_t.shape[1], tx_t.dtype, True
        )
        assert autotune.lookup(key) == tuple(ent["config"])
        # a tune after load is a pure cache hit — no re-search
        again = autotune.tune_support_count(tx_t, mk_t, interpret=True)
        assert again["config"] == ent["config"]
        assert autotune.cache_stats()["misses"] == 0

    def test_load_replace_resets(self, tmp_path):
        tx, masks = _support_inputs(300, 32, 40, seed=2)
        tx_t = jnp.asarray(np.asarray(tx).astype(np.int64).astype(np.int32)).T
        mk_t = jnp.asarray(np.asarray(masks).astype(np.int64).astype(np.int32)).T
        autotune.tune_support_count(tx_t, mk_t, interpret=True)
        path = str(tmp_path / "tuned.json")
        autotune.save_table(path)
        autotune.tune_support_count(tx_t[:, :128], mk_t, interpret=True)
        assert autotune.cache_stats()["entries"] == 2
        autotune.load_table(path, replace=True)
        assert autotune.cache_stats()["entries"] == 1


class TestModeSeam:
    def test_set_default_block_validates_and_restores(self):
        prev = ops.set_default_block("auto")
        try:
            assert ops.default_block() == "auto"
            with pytest.raises(ValueError):
                ops.set_default_block("turbo")
        finally:
            ops.set_default_block(prev)

    def test_traced_caller_uses_memo_or_default(self):
        """Under jit the autotuner cannot time — a traced call must use
        the memoized winner when present and the default otherwise,
        never crash."""
        rng = np.random.default_rng(6)
        x = jnp.asarray(rng.normal(size=(200, 8)).astype(np.float32))
        c = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))

        @jax.jit
        def assign(x, c):
            a, _ = ops.kmeans_assign(x, c, block="auto")
            return a

        a_jit = assign(x, c)  # cold memo: default config under trace
        a_eager = ops.kmeans_assign(x, c)[0]
        np.testing.assert_array_equal(np.asarray(a_jit), np.asarray(a_eager))

    def test_conformance_digest_with_auto_blocks(self):
        """Registry apps stay digest-identical across inline x batched
        with the kernel count backend and block='auto' active — the
        acceptance criterion that autotuning changes speed, not results.
        (The multihost x kernel cell runs in the CI conformance matrix.)"""
        from repro.runtime.conformance import result_digest, run_app

        base = result_digest("gfm", run_app("gfm", 3, "staged", "inline"))
        for backend in ("inline", "batched"):
            got = result_digest(
                "gfm",
                run_app(
                    "gfm",
                    3,
                    "staged",
                    backend,
                    count_backend="kernel",
                    use_kernel=True,
                    block="auto",
                ),
            )
            assert got == base, backend
