"""Serving entry: prefill + batched greedy decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
        --prompt-len 24 --gen 16 --batch 2
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.models import transformer as T
from repro.models.config import reduced as reduce_cfg
from repro.sharding import ShapeAxes


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=configs.ARCHS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--batch", type=int, default=2)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    max_len = args.prompt_len + args.gen + (cfg.frontend_len if cfg.frontend != "none" and not cfg.is_encdec else 0)

    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt_len), dtype=np.int32))
    fe = None
    if cfg.frontend != "none":
        fe = jnp.asarray(rng.normal(size=(args.batch, cfg.frontend_len, cfg.d_model)).astype(np.float32))

    cache = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        T.cache_specs(cfg, args.batch, max_len),
        is_leaf=lambda x: isinstance(x, ShapeAxes),
    )

    prefill = jax.jit(lambda p, t, c, f: T.prefill(cfg, p, t, c, f, chunk=min(1024, max_len)))
    decode = jax.jit(lambda p, t, pos, c: T.decode_step(cfg, p, t, pos, c))

    t0 = time.time()
    logits, cache = prefill(params, toks, cache, fe)
    next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    print(f"[serve] prefill {args.prompt_len} tokens in {time.time() - t0:.2f}s")

    pos0 = args.prompt_len + (cfg.frontend_len if cfg.frontend != "none" and not cfg.is_encdec else 0)
    out = [next_tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, next_tok, jnp.int32(pos0 + i), cache)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out.append(next_tok)
    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    dt = time.time() - t0
    print(f"[serve] generated {args.gen} tokens/seq x {args.batch} seqs in {dt:.2f}s "
          f"({args.gen * args.batch / max(dt, 1e-9):.1f} tok/s)")
    print(f"[serve] sample: {gen[0][:12].tolist()}")


if __name__ == "__main__":
    main()
