"""Workload plugin registry — the ONE seam every mining application
passes through.

The paper runs two applications (distributed clustering, frequent-itemset
generation) on one grid workflow engine; the framework-over-apps
direction of "Toward a Distributed Knowledge Discovery system for Grid
systems" (arXiv:1704.03538) is a *family* of workloads over the same
kernels.  Before this module the family was hand-wired twice — ``run_*``
methods on ``GridRuntime`` and an if/elif chain plus parallel app tuples
in ``launch.serve`` — which is exactly the drift surface where "unknown
app" checks, dataset-kind checks and param defaults disagree.

Now every workload registers ONE :class:`WorkloadSpec`:

  * identity — ``name``, ``dataset_kind`` ("transactions" | "points"),
    ``description``;
  * **param schema** — ``Param`` entries with kind, default and docs;
    the spec owns coercion (``resolve``) and submit-time validation
    (``validate_submitted``: unknown/internal keys and NON-FINITE floats
    are rejected before a request is admitted — the malformed-params
    crash class dies here, not in the dispatch loop);
  * **result schema** — ``result_fields`` plus a ``digest`` callable
    producing the canonical JSON-able form the cross-backend conformance
    suite compares bit-for-bit;
  * **how to run it** — grid workloads provide ``build_jobs`` (SiteJob
    DAG + sync mode, consumed by ``GridRuntime.run``) and the service-side
    ``site_split``/``grid_params`` adapters; local (delta-served)
    workloads provide ``local_fn`` (+ optional ``finalize``);
  * **smoke params** — the canonical small-param points the service
    trace, the CI smoke and the registry-driven tests exercise.

Consumers are table-driven off this registry and NOTHING else:
``GridRuntime.run(app, ...)``, ``MiningService`` submit validation and
``_execute`` dispatch, ``runtime.conformance`` (apps, digests, job maps)
and the benches.  Registering a spec here is the WHOLE integration —
``cd_apriori`` (count-distribution Apriori, arXiv:1903.03008) and
``topk`` (streaming top-k frequent itemsets over the delta path) land
through this seam alone, as the proof.

``tools/check_registry.py`` and ``tests/test_registry.py`` run
:func:`validate_registry`, so an under-specified plugin fails CI — not a
tenant request.
"""

from __future__ import annotations

import math
from collections.abc import Callable
from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np

DATASET_KINDS = ("transactions", "points")
RUNNERS = ("grid", "local")
PARAM_KINDS = ("int", "float", "str", "bool", "any")


@dataclass(frozen=True)
class Param:
    """One entry of a workload's param schema.

    ``kind`` drives coercion (``int``/``float``/``str``/``bool``, or
    ``any`` for pass-through); ``default`` is installed by ``resolve``
    (None means "no value" — adapters substitute a context-dependent
    default, e.g. the service's ``n_sites``); ``internal`` params carry
    non-JSON values (PRNG keys, config objects) between runtime wrappers
    and builders and are REJECTED at service submit."""

    name: str
    kind: str = "any"
    default: Any = None
    doc: str = ""
    internal: bool = False

    def coerce(self, v: Any) -> Any:
        if v is None or self.kind == "any":
            return v
        try:
            if self.kind == "int":
                # bool is an int subclass; floats must be integral, not
                # truncated ("n_sites": 2.5 is a mistake, not 2)
                if isinstance(v, float) and (not math.isfinite(v) or v != int(v)):
                    raise ValueError(f"expected an integer, got {v!r}")
                return int(v)
            if self.kind == "float":
                return float(v)
            if self.kind == "bool":
                return bool(v)
            return str(v)
        except (TypeError, ValueError) as e:
            raise ValueError(
                f"param {self.name!r} expects {self.kind}, got {v!r} ({e})"
            ) from None


def _reject_nonfinite(name: str, v: Any) -> None:
    """Recursively reject non-finite floats in a submitted param value —
    ``params_key`` is total over them (the backstop), but a request
    carrying inf/nan minsup is malformed and must be a ledgered
    rejection, not a queued execution."""
    if isinstance(v, float) and not math.isfinite(v):
        raise ValueError(f"param {name!r} is non-finite ({v!r}); rejected at submit")
    if isinstance(v, dict):
        for k, x in v.items():
            _reject_nonfinite(f"{name}.{k}", x)
    elif isinstance(v, (list, tuple, set, frozenset)):
        for x in v:
            _reject_nonfinite(name, x)


@dataclass(frozen=True)
class RunContext:
    """What a ``build_jobs`` builder may use from its host runtime:
    the measured-times dict the jobs feed, the support-count backend, the
    kernel toggle, and (clustering) the runtime's sync-strategy factory
    ``cluster_sync(n_sites, cfg) -> (sync_fn | None, mode)``."""

    measured: dict = field(default_factory=dict)
    count_backend: str = "kernel"
    use_kernel: bool = True
    cluster_sync: Callable | None = None


@dataclass(frozen=True)
class WorkloadSpec:
    """Everything the framework needs to know about one mining workload.

    Grid workloads (``runner="grid"``) run a SiteJob DAG through
    ``GridRuntime.run``: ``build_jobs(data, params, ctx)`` returns
    ``(jobs, sync_mode)`` and ``terminal`` names the job whose result is
    the run's result.  ``site_split(ds, params, svc)`` and
    ``grid_params(params, svc)`` adapt a service dataset + submitted
    params into that call.  Local workloads (``runner="local"``) are
    served in-process from per-dataset incremental state:
    ``local_fn(ds, params, svc)`` returns the zero-arg callable the
    service ledgers as a single-job DAG; ``finalize(ds, params, value)``
    optionally folds the result back into dataset state (k-means
    warm-start centroids).

    ``exec_batch_key(ds, params)`` is the CROSS-REQUEST batching opt-in:
    given the dataset state and the resolved params (``n_sites``
    substituted by the service), it returns a hashable signature — two
    execution groups in the same service wave whose workloads report the
    SAME signature (same app, dataset, version, and the same signature
    tuple) run as ONE fused dispatch through the batched backend's
    ``batch_key`` machinery, with measured device time apportioned per
    request.  The signature must pin every value that changes job
    shapes, jit-static arguments, or DAG structure (``k`` levels,
    ``n_sites``/``split_seed``, ``k_local``/``iters``); only params the
    builders accept per-member (thresholds, seeds) may be left out.
    ``None`` (the default, and a valid return value) means the workload
    NEVER fuses across requests — e.g. ``kmeans``, whose warm-start
    ``finalize`` makes serial wave order observable."""

    name: str
    dataset_kind: str  # "transactions" | "points"
    runner: str  # "grid" | "local"
    description: str
    params: tuple[Param, ...]
    result_fields: tuple[str, ...]
    digest: Callable[[Any], dict]
    # grid runner pieces
    build_jobs: Callable | None = None
    terminal: str = "collect"
    site_split: Callable | None = None
    grid_params: Callable | None = None
    # local runner pieces
    local_fn: Callable | None = None
    finalize: Callable | None = None
    # cross-request batching opt-in: (ds, resolved_params) -> hashable
    # signature, or None to never fuse (see class docstring)
    exec_batch_key: Callable | None = None
    smoke_params: tuple[dict, ...] = ()
    conformance: bool = False  # part of the cross-backend conformance matrix

    def schema(self) -> dict[str, Param]:
        return {p.name: p for p in self.params}

    def public_params(self) -> tuple[Param, ...]:
        return tuple(p for p in self.params if not p.internal)

    def resolve(self, params: dict | None) -> dict:
        """Defaults + coercion over the full schema (internal params
        allowed) — what builders and executors consume.  Unknown keys
        raise: every consumer shares one param vocabulary."""
        out = {p.name: p.default for p in self.params}
        sch = self.schema()
        for k, v in (params or {}).items():
            if k not in sch:
                raise ValueError(
                    f"app {self.name!r} has no param {k!r}; "
                    f"known params: {tuple(sch)}"
                )
            out[k] = sch[k].coerce(v)
        return out

    def validate_submitted(self, params: dict | None) -> dict:
        """Submit-time validation: the coerced copy of exactly the keys
        the tenant sent.  Rejects unknown keys, internal-only keys, and
        non-finite numerics — with a ValueError naming the offender."""
        sch = self.schema()
        out: dict = {}
        for k, v in (params or {}).items():
            p = sch.get(str(k))
            if p is None or p.internal:
                public = tuple(q.name for q in self.public_params())
                raise ValueError(
                    f"app {self.name!r} does not accept param {k!r}; "
                    f"accepted params: {public}"
                )
            _reject_nonfinite(p.name, v)
            out[p.name] = p.coerce(v)
        return out


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, WorkloadSpec] = {}


def register(spec: WorkloadSpec) -> WorkloadSpec:
    if spec.name in _REGISTRY:
        raise ValueError(f"workload {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def workloads() -> tuple[WorkloadSpec, ...]:
    """Every registered spec, in registration order."""
    return tuple(_REGISTRY.values())


def app_names() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def conformance_apps() -> tuple[str, ...]:
    """The apps in the cross-backend conformance matrix (grid workloads
    whose digests must be bit-identical across execution backends)."""
    return tuple(s.name for s in _REGISTRY.values() if s.conformance)


def get_workload(name: str) -> WorkloadSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown app {name!r}; expected one of {app_names()}"
        ) from None


def validate_registry() -> list[str]:
    """Every registered workload must be fully specified — the CI check
    (``tools/check_registry.py``) that makes an under-specified plugin a
    build failure instead of a tenant-visible crash.  Returns
    human-readable problems (empty = clean)."""
    problems: list[str] = []
    for spec in _REGISTRY.values():
        where = f"workload {spec.name!r}"
        if not spec.name:
            problems.append("workload with empty name")
        if spec.dataset_kind not in DATASET_KINDS:
            problems.append(f"{where}: bad dataset_kind {spec.dataset_kind!r}")
        if spec.runner not in RUNNERS:
            problems.append(f"{where}: bad runner {spec.runner!r}")
        if not spec.description:
            problems.append(f"{where}: missing description")
        if not spec.params:
            problems.append(f"{where}: declares no param schema")
        seen: set[str] = set()
        for p in spec.params:
            if p.kind not in PARAM_KINDS:
                problems.append(f"{where}: param {p.name!r} has bad kind {p.kind!r}")
            if not p.doc:
                problems.append(f"{where}: param {p.name!r} has no doc")
            if p.name in seen:
                problems.append(f"{where}: duplicate param {p.name!r}")
            seen.add(p.name)
        if not spec.result_fields:
            problems.append(f"{where}: declares no result schema (result_fields)")
        if not callable(spec.digest):
            problems.append(f"{where}: digest is not callable")
        if spec.runner == "grid":
            for attr in ("build_jobs", "site_split", "grid_params"):
                if not callable(getattr(spec, attr)):
                    problems.append(f"{where}: grid workload missing {attr}")
            if not spec.terminal:
                problems.append(f"{where}: grid workload missing terminal job name")
        else:
            if not callable(spec.local_fn):
                problems.append(f"{where}: local workload missing local_fn")
        if spec.exec_batch_key is not None and not callable(spec.exec_batch_key):
            problems.append(f"{where}: exec_batch_key must be callable or None")
        if not spec.smoke_params:
            problems.append(f"{where}: declares no smoke_params")
        for sp in spec.smoke_params:
            try:
                spec.validate_submitted(sp)
            except ValueError as e:
                problems.append(f"{where}: smoke params {sp!r} invalid: {e}")
    return problems


def app_table_markdown() -> str:
    """The registry as a markdown table — README/docs app tables are
    REGENERATED from this, never hand-edited."""
    lines = [
        "| App | Data | Runner | Params | Result |",
        "|---|---|---|---|---|",
    ]
    for s in workloads():
        params = ", ".join(
            f"`{p.name}`" + (f"={p.default}" if p.default is not None else "")
            for p in s.public_params()
        )
        result = ", ".join(f"`{f}`" for f in s.result_fields)
        lines.append(
            f"| `{s.name}` | {s.dataset_kind} | {s.runner} | {params} | {result} |"
        )
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Shared digest helpers
# ---------------------------------------------------------------------------


def comm_digest(comm) -> dict:
    """CommLog in canonical JSON-able form (conformance compares it
    bit-for-bit across execution backends and processes)."""
    return {
        "rounds": int(comm.rounds),
        "bytes_sent": int(comm.bytes_sent),
        "messages": int(comm.messages),
        "count_calls": int(comm.count_calls),
        "per_round_bytes": [int(b) for b in comm.per_round_bytes],
    }


def _frequent_digest(frequent: dict) -> dict:
    return {",".join(map(str, its)): int(c) for its, c in sorted(frequent.items())}


# ---------------------------------------------------------------------------
# The built-in workload family
# ---------------------------------------------------------------------------
#
# Each registration below is the COMPLETE integration of that workload:
# GridRuntime.run, MiningService, conformance and the benches all discover
# it from here.


def _tx_sites(ds, p, svc) -> list:
    """Service-side split of a transactions dataset into per-site DBs."""
    from repro.core.apriori import TransactionDB
    from repro.data.synthetic import split_transactions

    n = p["n_sites"] if p["n_sites"] is not None else svc.n_sites
    return [
        TransactionDB.from_dense(s)
        for s in split_transactions(ds.pooled_dense(), int(n), seed=p["split_seed"])
    ]


def _pt_sites(ds, p, svc):
    from repro.data.synthetic import split_sites

    n = p["n_sites"] if p["n_sites"] is not None else svc.n_sites
    return split_sites(ds.pooled_points(), int(n), seed=p["split_seed"])


_SPLIT_PARAMS = (
    Param("n_sites", "int", None, "sites to split the dataset across (service default)"),
    Param("split_seed", "int", 0, "seed for the site split"),
)

_MINE_PARAMS = (
    Param("k", "int", 3, "maximum itemset size"),
    Param("minsup", "float", 0.1, "global minimum support fraction"),
)


def _mine_grid_params(p, svc) -> dict:
    return {"k": p["k"], "minsup": p["minsup"]}


def _mine_exec_key(ds, p) -> tuple:
    """Threshold-only cross-request variation for the level-synchronous
    miners (fdm / gfm / cd_apriori): ``k`` pins the DAG depth and
    ``n_sites``/``split_seed`` pin the padded site shapes, so two groups
    sharing this signature differ only in support thresholds — which the
    builders' fused fan-outs accept per member."""
    return (p["k"], p["n_sites"], p["split_seed"])


# -- apriori (local, delta-served) ------------------------------------------


def _apriori_local(ds, p, svc):
    if p["min_count"] is not None:
        mc = p["min_count"]
    else:
        mc = max(1, int(math.ceil(p["minsup"] * ds.delta.n_tx)))
    return lambda: ds.delta.query(p["k"], mc)


def _delta_exec_key(ds, p) -> tuple:
    """Delta-served local workloads (apriori / topk) fuse UNconditionally:
    every param point is accepted per member, because the fused local
    path just invokes each group's callable in wave order inside one
    merged engine run — identical to the serial per-group path, with the
    shared delta state serving every member from one warm cache.  kmeans
    deliberately has NO hook: its warm-start finalize makes results
    depend on whether a sibling's centroids landed before the callable
    was built, so fusing would change (legitimately) order-visible
    output."""
    return ()


def _digest_localmine(r) -> dict:
    return {
        "counts": _frequent_digest(r.counts),
        "frequent": {
            str(lv): [",".join(map(str, its)) for its in sorted(r.frequent[lv])]
            for lv in sorted(r.frequent)
        },
    }


register(WorkloadSpec(
    name="apriori",
    dataset_kind="transactions",
    runner="local",
    description="incremental Apriori over the dataset's delta state "
                "(bit-identical to from-scratch mining of the stream)",
    params=(
        Param("k", "int", 3, "maximum itemset size"),
        Param("minsup", "float", 0.1, "minimum support fraction (ignored if min_count given)"),
        Param("min_count", "int", None, "absolute minimum count (overrides minsup)"),
    ),
    result_fields=("counts", "frequent", "count_calls", "candidates_counted"),
    digest=_digest_localmine,
    local_fn=_apriori_local,
    exec_batch_key=_delta_exec_key,
    smoke_params=({"k": 3, "minsup": 0.3}, {"k": 2, "minsup": 0.4}),
))


# -- gfm (grid) --------------------------------------------------------------


def _gfm_build(data, p, ctx: RunContext):
    from repro.core.gfm import gfm_site_jobs

    jobs = gfm_site_jobs(
        data, p["k"], p["minsup"],
        backend=ctx.count_backend,
        local_minsup=p["local_minsup"],
        measured=ctx.measured,
    )
    return jobs, "host"


def _digest_gfm(r) -> dict:
    return {
        "frequent": _frequent_digest(r.frequent),
        "comm": comm_digest(r.comm),
        "pool_sizes": [int(x) for x in r.pool_sizes],
        "n_total_tx": int(r.n_total_tx),
    }


register(WorkloadSpec(
    name="gfm",
    dataset_kind="transactions",
    runner="grid",
    description="the paper's Grid Frequent-itemset Mining: per-site local "
                "Apriori, ONE 2-pass synchronization, top-down descent",
    params=_MINE_PARAMS + (
        Param("local_minsup", "float", None, "per-site local support (default: minsup)"),
    ) + _SPLIT_PARAMS,
    result_fields=("frequent", "comm", "local", "pool_sizes", "n_total_tx"),
    digest=_digest_gfm,
    build_jobs=_gfm_build,
    terminal="decide",
    site_split=_tx_sites,
    grid_params=_mine_grid_params,
    exec_batch_key=_mine_exec_key,
    smoke_params=({"k": 2, "minsup": 0.35}, {"k": 2, "minsup": 0.45}),
    conformance=True,
))


# -- fdm (grid) --------------------------------------------------------------


def _fdm_build(data, p, ctx: RunContext):
    from repro.core.fdm import fdm_site_jobs

    jobs = fdm_site_jobs(
        data, p["k"], p["minsup"], backend=ctx.count_backend, measured=ctx.measured
    )
    return jobs, "host"


def _digest_fdm(r) -> dict:
    return {
        "frequent": _frequent_digest(r.frequent),
        "comm": comm_digest(r.comm),
        "per_level_candidates": [int(c) for c in r.per_level_candidates],
    }


register(WorkloadSpec(
    name="fdm",
    dataset_kind="transactions",
    runner="grid",
    description="FDM baseline: k level-synchronous candidate/announce/"
                "remote-support rounds (the paper's comparison point)",
    params=_MINE_PARAMS + _SPLIT_PARAMS,
    result_fields=("frequent", "comm", "remote_count_time",
                   "total_count_time", "per_level_candidates"),
    digest=_digest_fdm,
    build_jobs=_fdm_build,
    terminal="collect",
    site_split=_tx_sites,
    grid_params=_mine_grid_params,
    exec_batch_key=_mine_exec_key,
    smoke_params=({"k": 2, "minsup": 0.35}, {"k": 2, "minsup": 0.45}),
    conformance=True,
))


# -- cd_apriori (grid, registered THROUGH the seam) --------------------------


def _cd_build(data, p, ctx: RunContext):
    from repro.core.cdapriori import cd_site_jobs

    jobs = cd_site_jobs(
        data, p["k"], p["minsup"], backend=ctx.count_backend, measured=ctx.measured
    )
    return jobs, "host"


def _digest_cd(r) -> dict:
    return {
        "frequent": _frequent_digest(r.frequent),
        "comm": comm_digest(r.comm),
        "per_level_candidates": [int(c) for c in r.per_level_candidates],
        "n_total_tx": int(r.n_total_tx),
    }


register(WorkloadSpec(
    name="cd_apriori",
    dataset_kind="transactions",
    runner="grid",
    description="count-distribution Apriori (arXiv:1903.03008): every site "
                "counts the one shared candidate set, one count-vector "
                "exchange per level",
    params=_MINE_PARAMS + _SPLIT_PARAMS,
    result_fields=("frequent", "comm", "per_level_candidates", "n_total_tx"),
    digest=_digest_cd,
    build_jobs=_cd_build,
    terminal="collect",
    site_split=_tx_sites,
    grid_params=_mine_grid_params,
    exec_batch_key=_mine_exec_key,
    smoke_params=({"k": 2, "minsup": 0.35}, {"k": 2, "minsup": 0.45}),
    conformance=True,
))


# -- topk (local, delta-served, registered THROUGH the seam) -----------------


def _topk_local(ds, p, svc):
    from repro.core.apriori import topk_itemsets

    return lambda: topk_itemsets(ds.delta, p["k"], p["top"], floor=p["floor"])


def _digest_topk(r) -> dict:
    return {
        "items": [[",".join(map(str, its)), int(c)] for its, c in r.items],
        "threshold": int(r.threshold),
        "k_max": int(r.k_max),
    }


register(WorkloadSpec(
    name="topk",
    dataset_kind="transactions",
    runner="local",
    description="streaming top-k frequent itemsets over the delta path "
                "(threshold-halving search, counts served from the cache)",
    params=(
        Param("k", "int", 3, "maximum itemset size"),
        Param("top", "int", 10, "how many itemsets to return"),
        Param("floor", "int", 1, "smallest support threshold the search may reach"),
    ),
    result_fields=("items", "threshold", "k_max", "count_calls"),
    digest=_digest_topk,
    local_fn=_topk_local,
    exec_batch_key=_delta_exec_key,
    smoke_params=({"k": 2, "top": 5}, {"k": 2, "top": 3}),
))


# -- kmeans (local, warm-started) -------------------------------------------


def _kmeans_local(ds, p, svc):
    from repro.core.kmeans import kmeans, kmeans_warm

    k, iters = p["k"], p["iters"]
    x = ds.pooled_points()
    warm = ds.warm_centers.get(k)
    if warm is not None:
        return lambda: kmeans_warm(x, warm, iters=iters, use_kernel=svc.use_kernel)
    key = jax.random.PRNGKey(p["seed"])
    return lambda: kmeans(key, x, k, iters=iters, use_kernel=svc.use_kernel)


def _kmeans_finalize(ds, p, value) -> None:
    ds.warm_centers[p["k"]] = np.asarray(value.centers)


def _digest_kmeans(r) -> dict:
    return {
        "assign": np.asarray(r.assign).astype(int).tolist(),
        "inertia": float(r.inertia),
    }


register(WorkloadSpec(
    name="kmeans",
    dataset_kind="points",
    runner="local",
    description="pooled K-Means, warm-started from the previous version's "
                "centroids after each append",
    params=(
        Param("k", "int", 3, "number of clusters"),
        Param("iters", "int", 25, "Lloyd iterations"),
        Param("seed", "int", 0, "PRNG seed for cold-start init"),
    ),
    result_fields=("centers", "assign", "inertia", "stats"),
    digest=_digest_kmeans,
    local_fn=_kmeans_local,
    finalize=_kmeans_finalize,
    smoke_params=({"k": 3, "iters": 10}, {"k": 4, "iters": 10}),
))


# -- vclustering (grid) ------------------------------------------------------


def _vcluster_build(data, p, ctx: RunContext):
    import jax.numpy as jnp

    from repro.core.vclustering import VClusterConfig, vcluster_site_jobs

    xs = jnp.asarray(data)
    cfg = p["cfg"]
    if cfg is None:
        cfg = VClusterConfig(
            k_local=p["k_local"], kmeans_iters=p["iters"], use_kernel=ctx.use_kernel
        )
    key = p["key"]
    if key is None:
        key = jax.random.PRNGKey(p["seed"])
    if ctx.cluster_sync is not None:
        sync, mode = ctx.cluster_sync(xs.shape[0], cfg)
    else:
        sync, mode = None, "pooled"
    jobs = vcluster_site_jobs(key, xs, cfg, sync=sync, measured=ctx.measured)
    return jobs, mode


def _vcluster_grid_params(p, svc) -> dict:
    from repro.core.vclustering import VClusterConfig

    return {
        "key": jax.random.PRNGKey(p["seed"]),
        "cfg": VClusterConfig(
            k_local=p["k_local"], kmeans_iters=p["iters"], use_kernel=svc.use_kernel
        ),
    }


def _vcluster_exec_key(ds, p) -> tuple | None:
    """``k_local``/``iters`` are jit-static in the site kernels and
    ``n_sites``/``split_seed`` pin the site shapes, so only the PRNG
    ``seed`` may vary across fused members (the cluster fan-out threads
    each member's key through its batch args).  Runtime callers passing
    explicit ``key``/``cfg`` objects never fuse — those are unhashable
    and bypass the seed/param schema entirely."""
    if p["key"] is not None or p["cfg"] is not None:
        return None
    return (p["k_local"], p["iters"], p["n_sites"], p["split_seed"])


def _digest_vclustering(r) -> dict:
    return {
        "labels": np.asarray(r.labels).astype(int).tolist(),
        "n_global": int(r.merged.n_global),
        "n_merges": int(r.merged.n_merges),
        "comm_bytes": int(r.comm_bytes),
    }


register(WorkloadSpec(
    name="vclustering",
    dataset_kind="points",
    runner="grid",
    description="the paper's Algorithm 1: per-site K-Means, all_gather + "
                "logical merge, border perturbation",
    params=(
        Param("k_local", "int", 8, "sub-clusters per site"),
        Param("iters", "int", 15, "K-Means iterations per site"),
        Param("seed", "int", 0, "PRNG seed"),
        Param("key", "any", None, "explicit jax PRNG key (runtime callers)", internal=True),
        Param("cfg", "any", None, "explicit VClusterConfig (runtime callers)", internal=True),
    ) + _SPLIT_PARAMS,
    result_fields=("labels", "merged", "comm_bytes"),
    digest=_digest_vclustering,
    build_jobs=_vcluster_build,
    terminal="collect",
    site_split=_pt_sites,
    grid_params=_vcluster_grid_params,
    exec_batch_key=_vcluster_exec_key,
    smoke_params=({"k_local": 4, "iters": 8}, {"k_local": 4, "iters": 8, "seed": 1}),
    conformance=True,
))
