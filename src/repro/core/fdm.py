"""FDM baseline — Fast Distributed Mining of association rules (Cheung et
al., PDIS'96), the comparison algorithm the paper implements.

Level-synchronous protocol: at every level l = 1..k
  1. every site generates candidates from the GLOBALLY frequent (l-1)-sets
     (global pruning — the thing GFM deliberately drops),
  2. counts them locally; locally frequent candidates are announced,
  3. remote support counts are computed on request for candidates announced
     by OTHER sites (FDM's "remote support computation" — the paper
     measures it at ~13% of FDM's total compute time),
  4. a synchronization produces the globally frequent l-sets.

⇒ k communication/synchronization rounds (the paper's "4 instead of 2"),
each a barrier.  Counting uses the same backend as GFM so the comparison
isolates the PROTOCOL difference, exactly as in the paper.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.core.apriori import (
    Itemset,
    TransactionDB,
    apriori_join,
    count_supports,
    item_supports,
)
from repro.core.gfm import CommLog, _itemset_bytes


@dataclass
class FDMResult:
    frequent: dict[Itemset, int]
    comm: CommLog
    remote_count_time: float  # seconds spent serving remote support requests
    total_count_time: float  # seconds in all support counting
    per_level_candidates: list[int]


def fdm_mine(
    sites: list[TransactionDB],
    k: int,
    minsup: float,
    backend: str = "jnp",
) -> FDMResult:
    s = len(sites)
    n_total = sum(db.n_tx for db in sites)
    g_min = int(np.ceil(minsup * n_total))
    comm = CommLog()
    frequent: dict[Itemset, int] = {}
    per_level: list[int] = []
    remote_t = 0.0
    total_t = 0.0

    l_min = [int(np.ceil(minsup * db.n_tx)) for db in sites]
    prev_global: list[Itemset] = []
    prev_local: list[set[Itemset]] = [set() for _ in sites]
    for level in range(1, k + 1):
        # -- per-site candidate generation: FDM joins GL(l-1) restricted to
        #    the sets ALSO locally frequent at this site (its local pruning;
        #    this is what shrinks per-site candidate sets vs plain Apriori
        #    but forces remote support requests later) --
        if level == 1:
            cands_by: list[list[Itemset]] = [
                [(i,) for i in range(db.n_items)] for db in sites
            ]
        else:
            cands_by = [
                apriori_join([its for its in prev_global if its in prev_local[i]])
                for i in range(s)
            ]
        union_cands = sorted(set().union(*map(set, cands_by)), key=lambda t: (len(t), t))
        per_level.append(len(union_cands))
        if not union_cands:
            break

        # -- local counting + per-site announcement of locally frequents --
        local_counts: list[dict[Itemset, int]] = []
        announced_by: list[set[Itemset]] = []
        payload = 0
        for i, db in enumerate(sites):
            t0 = time.perf_counter()
            if level == 1:
                sup = item_supports(db)
            else:
                sup = count_supports(db, cands_by[i], backend=backend)
            total_t += time.perf_counter() - t0
            comm.count_calls += 1
            cnt = {its: int(c) for its, c in zip(cands_by[i], np.asarray(sup))}
            local_counts.append(cnt)
            ann = {its for its in cands_by[i] if cnt[its] >= l_min[i]}
            announced_by.append(ann)
            payload += len(ann)

        announced = sorted(set().union(*announced_by), key=lambda t: (len(t), t))

        # -- remote support computation: each site serves requests for
        #    announced candidates it did NOT count locally (its pruning
        #    dropped them).  This is real extra compute — the step the paper
        #    measures at ~13% of FDM's total compute time. --
        for i, db in enumerate(sites):
            remote = [its for its in announced if its not in local_counts[i]]
            if remote:
                t0 = time.perf_counter()
                sup = count_supports(db, remote, backend=backend)
                dt = time.perf_counter() - t0
                remote_t += dt
                total_t += dt
                comm.count_calls += 1
                for its, c in zip(remote, np.asarray(sup)):
                    local_counts[i][its] = int(c)
            payload += len(remote)

        comm.add_round(payload, _itemset_bytes(level), s)

        # -- global decision --
        glob = []
        for its in announced:
            c = sum(lc[its] for lc in local_counts)
            if c >= g_min:
                glob.append((its, c))
        prev_global = [its for its, _ in glob]
        prev_local = [
            {its for its in prev_global if local_counts[i].get(its, 0) >= l_min[i]}
            for i in range(s)
        ]
        frequent.update(dict(glob))
        if not prev_global:
            break

    return FDMResult(
        frequent=frequent,
        comm=comm,
        remote_count_time=remote_t,
        total_count_time=total_t,
        per_level_candidates=per_level,
    )
