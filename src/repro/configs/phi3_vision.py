"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP patch frontend (STUB:
input_specs supplies precomputed patch embeddings)
[hf:microsoft/Phi-3-vision-128k-instruct]."""
from repro.configs.phi3_mini import CONFIG as _MINI

CONFIG = _MINI.scaled(
    name="phi-3-vision-4.2b",
    frontend="patch",
    frontend_len=576,  # 336px CLIP ViT-L/14 -> 24x24 patches
)
