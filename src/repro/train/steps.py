"""Step builders: synchronous train_step, GridLocal train_step (the
paper's minimal-sync pattern over the `pod` axis), prefill_step and
decode (serve) step.  Every builder returns pure functions plus the
ShapeAxes spec trees needed to derive in/out shardings for jit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.outer import OuterConfig, outer_init, outer_update
from repro.sharding import ShapeAxes
from repro.train.losses import chunked_softmax_ce


# ---------------------------------------------------------------------------
# State specs
# ---------------------------------------------------------------------------


def _zeros_like_spec(s: ShapeAxes, dtype=None) -> ShapeAxes:
    return ShapeAxes(shape=s.shape, dtype=dtype or s.dtype, axes=s.axes)


def train_state_specs(cfg: ModelConfig, n_pods: int = 0) -> dict:
    """ShapeAxes tree of the full train state (params + AdamW moments
    [+ GridLocal anchor/momentum]).  With n_pods > 0 every leaf gains a
    leading 'grid' axis of that size (one replica per pod, sharded over
    `pod` by the GRIDLOCAL rules)."""
    p_specs = T.param_specs(cfg)

    def is_sa(x):
        return isinstance(x, ShapeAxes)

    def f32(s):
        return ShapeAxes(shape=s.shape, dtype="float32", axes=s.axes)

    state = {
        "params": p_specs,
        "opt": {
            "step": ShapeAxes(shape=(), dtype="int32", axes=()),
            "m": jax.tree.map(f32, p_specs, is_leaf=is_sa),
            "v": jax.tree.map(f32, p_specs, is_leaf=is_sa),
        },
    }
    if n_pods:
        state = jax.tree.map(
            lambda s: ShapeAxes(shape=(n_pods, *s.shape), dtype=s.dtype, axes=("grid", *s.axes)),
            state,
            is_leaf=is_sa,
        )
        # outer anchor/momentum are identical on every pod — unstacked
        state["outer"] = {
            "anchor": jax.tree.map(f32, p_specs, is_leaf=is_sa),
            "momentum": jax.tree.map(f32, p_specs, is_leaf=is_sa),
        }
    return state


def materialize_state(cfg: ModelConfig, key: jax.Array) -> dict:
    params = T.init_params(cfg, key)
    return {"params": params, "opt": adamw_init(params)}


# ---------------------------------------------------------------------------
# Synchronous train step
# ---------------------------------------------------------------------------


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig = AdamWConfig(),
    loss_chunk: int = 512,
    grad_accum: int = 1,
):
    """Plain synchronous data-parallel/FSDP/TP step.  Gradients reduce over
    every batch-sharded axis (GSPMD inserts the all-reduces).

    grad_accum > 1 splits the global batch into microbatches scanned
    sequentially with fp32 gradient accumulation — activation memory drops
    ~linearly while keeping the same global batch semantics."""

    def loss_fn(params, batch):
        hidden, aux = T.forward_train(
            cfg, params, batch["tokens"], batch.get("frontend"), return_hidden=True
        )
        ce, n_tok = chunked_softmax_ce(cfg, params, hidden, batch["labels"], chunk=loss_chunk)
        loss = ce + aux["aux_loss"] + aux["z_loss"]
        return loss, {"ce": ce, "aux": aux["aux_loss"], "n_tok": n_tok}

    def grads_of(params, batch):
        if grad_accum == 1:
            return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

        def split(x):
            b = x.shape[0]
            assert b % grad_accum == 0, (b, grad_accum)
            return x.reshape(grad_accum, b // grad_accum, *x.shape[1:])

        micro = jax.tree.map(split, batch)

        def body(carry, mb):
            acc, loss_acc, met_acc = carry
            (loss, met), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32), acc, g)
            met_acc = {
                "ce": met_acc["ce"] + met["ce"],
                "aux": met_acc["aux"] + met["aux"],
                "n_tok": met_acc["n_tok"] + met["n_tok"],
            }
            return (acc, loss_acc + loss, met_acc), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        met0 = {"ce": jnp.float32(0), "aux": jnp.float32(0), "n_tok": jnp.int32(0)}
        (grads, loss, met), _ = jax.lax.scan(body, (zeros, jnp.float32(0), met0), micro)
        inv = 1.0 / grad_accum
        grads = jax.tree.map(lambda g: g * inv, grads)
        return (loss * inv, {**met, "ce": met["ce"] * inv, "aux": met["aux"] * inv}), grads

    def train_step(state, batch):
        (loss, metrics), grads = grads_of(state["params"], batch)
        new_params, new_opt, opt_metrics = adamw_update(opt_cfg, grads, state["opt"], state["params"])
        return {"params": new_params, "opt": new_opt}, {
            "loss": loss,
            **metrics,
            **opt_metrics,
        }

    return train_step


# ---------------------------------------------------------------------------
# GridLocal train step (paper technique: pod-local inner steps, one merge)
# ---------------------------------------------------------------------------


def make_gridlocal_train_step(
    cfg: ModelConfig,
    mesh,
    opt_cfg: AdamWConfig = AdamWConfig(),
    outer_cfg: OuterConfig = OuterConfig(),
    loss_chunk: int = 512,
    grad_accum: int = 1,
):
    """The paper's minimal-sync pattern over the `pod` axis.

    State layout: params/opt carry a leading `n_pods` axis sharded over
    `pod` (each pod = an independent "grid site" with its own model
    replica); the outer anchor/momentum are unstacked (identical across
    pods).  The inner step runs under ``vmap`` over the pod axis — because
    every op is elementwise in that axis, GSPMD keeps ALL inner collectives
    within a pod (no DCN traffic).  Every ``h_steps`` the pods merge via
    the paper's size-weighted sufficient-statistics aggregation (uniform
    token counts ⇒ mean over the pod axis — the ONLY cross-pod collective)
    followed by an outer Nesterov step; pods restart from the new anchor.
    """
    n_pods = mesh.shape["pod"]
    inner = make_train_step(cfg, opt_cfg, loss_chunk, grad_accum)
    p_specs = T.param_specs(cfg)

    def is_sa(x):
        return isinstance(x, ShapeAxes)

    def step_fn(state, batch):
        from repro.sharding import constrain

        def split(x):
            return x.reshape(n_pods, x.shape[0] // n_pods, *x.shape[1:])

        vbatch = jax.tree.map(split, batch)
        vstate = {"params": state["params"], "opt": state["opt"]}
        new_inner, metrics = jax.vmap(inner)(vstate, vbatch)
        step_ct = new_inner["opt"]["step"][0]

        def do_merge(args):
            params, outer = args
            # the single synchronization: aggregate the pods' sufficient
            # statistics (parameter sums weighted by examples — uniform
            # here) exactly as the paper merges per-site models.  The
            # merged leaves are constrained to the SAME intra-pod layout
            # as the inputs so the cross-pod all-reduce moves only each
            # device's shard (no involuntary resharding — §Perf iteration).
            if outer_cfg.compress == "int8":
                # gradient compression: only int8 deltas (+1 scale/leaf)
                # cross the pod boundary; anchor is pod-replicated.
                from repro.optim.outer import dequantize_delta, quantize_delta

                def merge_leaf(s, x, anchor):
                    delta = x.astype(jnp.float32) - anchor[None]
                    q, scale = quantize_delta(delta)
                    # sum in int16 so the cross-pod wire stays narrow
                    # (int8 ring-sum would overflow; int16 = 2x fewer
                    # bytes than the f32 mean)
                    q_sum = jnp.sum(q.astype(jnp.int16), axis=0)
                    q_mean = q_sum.astype(jnp.float32) / x.shape[0]
                    return constrain(anchor + dequantize_delta(q_mean, scale), s.axes)

                merged = jax.tree.map(
                    merge_leaf, p_specs, params, outer["anchor"], is_leaf=is_sa
                )
            else:
                merged = jax.tree.map(
                    lambda s, x: constrain(jnp.mean(x.astype(jnp.float32), axis=0), s.axes),
                    p_specs, params, is_leaf=lambda x: is_sa(x),
                )
            new_p, new_outer = outer_update(outer_cfg, outer, merged)
            new_outer = {
                k: jax.tree.map(
                    lambda s, x: constrain(x, s.axes), p_specs, new_outer[k], is_leaf=is_sa
                )
                for k in ("anchor", "momentum")
            }
            stacked = jax.tree.map(
                lambda s, a, p: constrain(
                    jnp.broadcast_to(a[None].astype(p.dtype), p.shape), ("grid", *s.axes)
                ),
                p_specs, new_p, params, is_leaf=is_sa,
            )
            return stacked, new_outer

        def no_merge(args):
            return args

        params, outer = jax.lax.cond(
            step_ct % outer_cfg.h_steps == 0,
            do_merge,
            no_merge,
            (new_inner["params"], state["outer"]),
        )
        out = {"params": params, "opt": new_inner["opt"], "outer": outer}
        metrics = jax.tree.map(lambda x: jnp.mean(x.astype(jnp.float32), axis=0), metrics)
        return out, metrics

    return step_fn


def gridlocal_init(cfg: ModelConfig, key: jax.Array, n_pods: int) -> dict:
    params = T.init_params(cfg, key)

    def stack(t):
        return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n_pods, *x.shape)), t)

    return {
        "params": stack(params),
        "opt": stack(adamw_init(params)),
        "outer": outer_init(params),
    }


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, chunk: int = 1024):
    def prefill_step(params, batch, cache):
        return T.prefill(cfg, params, batch["tokens"], cache, batch.get("frontend"), chunk=chunk)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_fn(params, batch, cache):
        return T.decode_step(cfg, params, batch["token"], batch["pos"], cache)

    return decode_fn
