"""Assigned input-shape set and per-cell input specs.

LM transformer shapes are seq_len x global_batch.  ``decode_*``/``long_*``
lower ``serve_step`` (one new token against a seq_len KV cache), NOT
``train_step``; ``prefill_*`` lowers the cache-building forward.
``long_500k`` requires sub-quadratic attention — pure full-attention archs
skip it (cfg.subquadratic gate, noted in DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.models.config import ModelConfig
from repro.models.layers import spec
from repro.sharding import ShapeAxes


@dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}


def cell_is_supported(cfg: ModelConfig, shape_name: str) -> bool:
    sh = SHAPES[shape_name]
    if sh.name == "long_500k" and not cfg.subquadratic:
        return False
    return True


def skip_reason(cfg: ModelConfig, shape_name: str) -> str:
    if not cell_is_supported(cfg, shape_name):
        return (
            "pure full-attention arch: 524k-token context is architecturally "
            "unsupported (quadratic prefill, unwindowed cache) — see DESIGN.md"
        )
    return ""


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ShapeAxes tree for every model input of this (arch x shape) cell.

    train:   {tokens, labels[, frontend]}
    prefill: {tokens[, frontend]}            (cache passed separately)
    decode:  {token, pos}                    (cache passed separately)
    """
    sh = SHAPES[shape_name]
    b, s = sh.global_batch, sh.seq_len
    tok_axes = ("batch", "seq")
    if sh.kind == "train":
        s_tok = s - (cfg.frontend_len if (cfg.frontend != "none" and not cfg.is_encdec) else 0)
        out = {
            "tokens": spec((b, s_tok), tok_axes, "int32"),
            "labels": spec((b, s_tok), tok_axes, "int32"),
        }
        if cfg.frontend != "none":
            out["frontend"] = spec(
                (b, cfg.frontend_len, cfg.d_model), ("batch", "frontend", None), cfg.dtype
            )
        return out
    if sh.kind == "prefill":
        s_tok = s - (cfg.frontend_len if (cfg.frontend != "none" and not cfg.is_encdec) else 0)
        out = {"tokens": spec((b, s_tok), tok_axes, "int32")}
        if cfg.frontend != "none":
            out["frontend"] = spec(
                (b, cfg.frontend_len, cfg.d_model), ("batch", "frontend", None), cfg.dtype
            )
        return out
    # decode
    return {
        "token": spec((b, 1), tok_axes, "int32"),
        "pos": ShapeAxes(shape=(), dtype="int32", axes=()),
    }
