"""Cross-request fused execution (PR: cross-request batching in the
mining service): digest identity between ``GridRuntime.run_many`` and
serial ``run`` across backends and schedules, service-level fusion
counters, and regressions for the three bugfixes that ride along —
bounded weighted-round-robin burst grants, ledgered queue-full
rejections, and the failed-execution ledger + failure memo."""

from __future__ import annotations

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from repro.testing import given, settings, strategies as st

from repro.core.apriori import TransactionDB
from repro.data.synthetic import (
    gaussian_mixture,
    ibm_transactions,
    split_sites,
    split_transactions,
)
from repro.launch.serve import MiningService
from repro.runtime.gridruntime import GridRuntime
from repro.workflow.registry import get_workload
from repro.workflow.requests import (
    MAX_BURST,
    MiningRequest,
    QueueFullError,
    TenantQueues,
)

DENSE = ibm_transactions(0, 60, 10)
MINE_APPS = ("fdm", "gfm", "cd_apriori")


def _tx_sites(n_sites: int = 2) -> list[TransactionDB]:
    return [
        TransactionDB.from_dense(s)
        for s in split_transactions(DENSE, n_sites, seed=0)
    ]


def _rt(backend: str = "batched", schedule: str = "staged") -> GridRuntime:
    return GridRuntime(
        count_backend="jnp", use_kernel=False, backend=backend, schedule=schedule
    )


def _tx_batch(seed: int, n_tx: int = 40, n_items: int = 8) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.random((n_tx, n_items)) < 0.45


def _service(**kw) -> MiningService:
    kw.setdefault("count_backend", "jnp")
    kw.setdefault("use_kernel", False)
    kw.setdefault("n_sites", 2)
    svc = MiningService(**kw)
    svc.register_dataset("tx", "transactions", n_items=8)
    svc.append_transactions("tx", _tx_batch(0))
    return svc


# ---------------------------------------------------------------------------
# Runtime level: run_many is digest-identical to serial run
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("schedule", ["staged", "async"])
@pytest.mark.parametrize("backend", ["inline", "batched"])
@pytest.mark.parametrize("app", MINE_APPS)
def test_run_many_digest_matches_serial(app, backend, schedule):
    """Merged-DAG execution must be bit-identical (per the workload's
    digest, which for cd_apriori includes the ledgered communication
    counters) to running each request alone — across both execution
    backends and both schedulers, with minsup chosen so the members
    exhaust at DIFFERENT levels (the per-member live/dead seam)."""
    spec = get_workload(app)
    sites = _tx_sites()
    params = [{"k": 2, "minsup": 0.3}, {"k": 2, "minsup": 0.6}]
    serial = [_rt(backend, schedule).run(app, sites, p) for p in params]
    fused = _rt(backend, schedule).run_many(app, [sites, sites], params)
    assert len(fused) == len(params)
    for s_run, f_run in zip(serial, fused):
        assert spec.digest(f_run.result) == spec.digest(s_run.result)
        assert f_run.backend == backend
        assert f_run.compute_s >= 0.0


@pytest.mark.parametrize("backend", ["inline", "batched"])
def test_run_many_vclustering_digest(backend):
    """Different PRNG seeds fuse (threaded through batch args); each
    member's labels/centers match its solo run exactly."""
    spec = get_workload("vclustering")
    pts, _ = gaussian_mixture(0, 120, 2, 3)
    xs = split_sites(pts, 2)
    params = [{"seed": s, "k_local": 4, "iters": 8} for s in (0, 1)]
    serial = [_rt(backend).run("vclustering", xs, p) for p in params]
    fused = _rt(backend).run_many("vclustering", [xs, xs], params)
    for s_run, f_run in zip(serial, fused):
        assert spec.digest(f_run.result) == spec.digest(s_run.result)


def test_run_many_apportions_measured_compute():
    rt = _rt("batched")
    sites = _tx_sites()
    params = [{"k": 2, "minsup": 0.3}, {"k": 2, "minsup": 0.45}]
    runs = rt.run_many("gfm", [sites, sites], params)
    # one engine invocation served both; each request got a positive
    # share of its own prefixed jobs' measured time
    assert runs[0].report is runs[1].report
    assert sum(r.compute_s for r in runs) > 0.0


def test_run_many_validation():
    rt = _rt()
    with pytest.raises(ValueError, match="param sets"):
        rt.run_many("gfm", [_tx_sites()], [])
    with pytest.raises(ValueError, match="local"):
        rt.run_many("topk", [_tx_sites()], [{"k": 2, "top": 5}])


@settings(max_examples=4, deadline=None)
@given(
    minsup_a=st.sampled_from([0.25, 0.35, 0.5]),
    minsup_b=st.sampled_from([0.3, 0.45, 0.65]),
    app=st.sampled_from(list(MINE_APPS)),
)
def test_fused_digest_property(minsup_a, minsup_b, app):
    """Property form of the digest-identity invariant: ANY threshold pair
    fuses without changing results."""
    spec = get_workload(app)
    sites = _tx_sites()
    params = [{"k": 2, "minsup": minsup_a}, {"k": 2, "minsup": minsup_b}]
    serial = [_rt().run(app, sites, p).result for p in params]
    fused = _rt().run_many(app, [sites, sites], params)
    for s_res, f_run in zip(serial, fused):
        assert spec.digest(f_run.result) == spec.digest(s_res)


# ---------------------------------------------------------------------------
# Service level: fusion counters + result identity with fusion disabled
# ---------------------------------------------------------------------------


def test_service_cross_request_fusion_matches_serial():
    queries = [
        ("a", "fdm", {"k": 2, "minsup": 0.3}),
        ("b", "fdm", {"k": 2, "minsup": 0.45}),
        ("c", "fdm", {"k": 2, "minsup": 0.6}),
        ("a", "gfm", {"k": 2, "minsup": 0.35}),
        ("b", "gfm", {"k": 2, "minsup": 0.5}),
    ]
    fsvc, ssvc = _service(), _service(fuse_requests=False)
    rids_f = [fsvc.submit(t, app, "tx", p) for t, app, p in queries]
    rids_s = [ssvc.submit(t, app, "tx", p) for t, app, p in queries]
    fsvc.drain(max_requests=8)
    ssvc.drain(max_requests=8)
    for rf, rs, (_t, app, _p) in zip(rids_f, rids_s, queries):
        assert fsvc.poll(rf) == "done" and ssvc.poll(rs) == "done"
        spec = get_workload(app)
        assert spec.digest(fsvc.result(rf)) == spec.digest(ssvc.result(rs))
    led_f, led_s = fsvc.ledger(), ssvc.ledger()
    # one dispatch for the fdm trio, one for the gfm pair
    assert led_f["executions"] == 5 and led_f["exec_groups"] == 5
    assert led_f["device_dispatches"] == 2
    assert led_f["fused_requests"] == 5
    assert all(fsvc.request(r).fused for r in rids_f)
    assert led_f["per_tenant"]["a"]["fused"] == 2
    # fusion off: one engine invocation per group, nothing marked fused
    assert led_s["device_dispatches"] == led_s["executions"] == 5
    assert led_s["fused_requests"] == 0
    assert not any(ssvc.request(r).fused for r in rids_s)


def test_service_local_workload_fuses_one_engine_run():
    fsvc, ssvc = _service(), _service(fuse_requests=False)
    spec = get_workload("topk")
    rf = [fsvc.submit("a", "topk", "tx", {"k": 2, "top": 5}),
          fsvc.submit("b", "topk", "tx", {"k": 2, "top": 3})]
    rs = [ssvc.submit("a", "topk", "tx", {"k": 2, "top": 5}),
          ssvc.submit("b", "topk", "tx", {"k": 2, "top": 3})]
    fsvc.step(max_requests=4)
    ssvc.step(max_requests=4)
    assert fsvc.device_dispatches == 1 and fsvc.executions == 2
    assert fsvc.fused_requests == 2
    for a, b in zip(rf, rs):
        assert spec.digest(fsvc.result(a)) == spec.digest(ssvc.result(b))


def test_service_fusion_respects_signature_boundaries():
    """Different k (DAG depth) must NOT fuse — distinct signatures run as
    separate dispatches even in one wave."""
    svc = _service()
    svc.submit("a", "fdm", "tx", {"k": 2, "minsup": 0.3})
    svc.submit("b", "fdm", "tx", {"k": 3, "minsup": 0.3})
    svc.step(max_requests=4)
    assert svc.executions == 2
    assert svc.device_dispatches == 2
    assert svc.fused_requests == 0


# ---------------------------------------------------------------------------
# Bugfix regression: bounded weighted-round-robin burst grants
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(w=st.floats(min_value=1e-9, max_value=1e9))
def test_grant_table_is_bounded(w):
    q = TenantQueues(weights={"a": w, "b": 1.0})
    for grant in q.grant_table().values():
        assert 1 <= grant <= MAX_BURST


def test_grant_table_preserves_moderate_ratios():
    assert TenantQueues(weights={"big": 3.0, "small": 1.0}).grant_table() == {
        "big": 3, "small": 1,
    }
    # fractional maps normalize by the smallest weight, ratios intact
    assert TenantQueues(weights={"big": 1.0, "small": 0.25}).grant_table() == {
        "big": 4, "small": 1,
    }


def test_extreme_fractional_weights_cannot_starve():
    """{a: 1.0, b: 1e-6} used to normalize into a ~1e6-pick burst for
    ``a`` before ``b`` was ever served; grants are now clamped to
    MAX_BURST, so ``b`` is picked within one bounded cycle."""
    q = TenantQueues(max_depth=64, weights={"hog": 1.0, "meek": 1e-6})
    assert q.grant_table() == {"hog": MAX_BURST, "meek": 1}
    for i in range(40):
        q.push(MiningRequest(request_id=i, tenant="hog", app="x", dataset="d"))
        q.push(MiningRequest(request_id=100 + i, tenant="meek", app="x", dataset="d"))
    picks = [q.pick().tenant for _ in range(2 * (MAX_BURST + 1))]
    assert "meek" in picks[: MAX_BURST + 1]


@settings(max_examples=15, deadline=None)
@given(
    w_a=st.floats(min_value=1e-6, max_value=1e6),
    w_b=st.floats(min_value=1e-6, max_value=1e6),
)
def test_no_starvation_under_any_weights(w_a, w_b):
    """Fairness property: with both tenants backlogged, EVERY tenant is
    picked within the first MAX_BURST + 1 picks, for any positive
    weight map whatsoever."""
    q = TenantQueues(max_depth=64, weights={"a": w_a, "b": w_b})
    for i in range(40):
        q.push(MiningRequest(request_id=i, tenant="a", app="x", dataset="d"))
        q.push(MiningRequest(request_id=1000 + i, tenant="b", app="x", dataset="d"))
    picks = [q.pick().tenant for _ in range(2 * (MAX_BURST + 1))]
    head = picks[: MAX_BURST + 1]
    assert "a" in head and "b" in head


# ---------------------------------------------------------------------------
# Bugfix regression: queue-full rejections are ledgered like param rejections
# ---------------------------------------------------------------------------


def test_queue_full_is_ledgered_like_param_rejection():
    svc = _service(max_depth=1)
    svc.submit("a", "apriori", "tx", {"k": 1, "minsup": 0.9})
    with pytest.raises(QueueFullError, match="full"):
        svc.submit("a", "apriori", "tx", {"k": 1, "minsup": 0.8})
    assert svc.rejected_full == 1
    led = svc.ledger()
    assert led["rejected_full"] == 1
    assert led["rejected_invalid"] == 0
    assert led["rejected"] == 1
    rej = [r for r in led["requests"] if r["status"] == "rejected"]
    assert len(rej) == 1
    # the fix: terminal state carries the reason and a finish time, like
    # the param-rejection path (it used to leave error=None, service_s=0)
    assert rej[0]["error"] and rej[0]["error"].startswith("QueueFullError")
    req = svc.request(rej[0]["request_id"])
    assert req.finished_at is not None
    assert led["per_tenant"]["a"]["rejected"] == 1


# ---------------------------------------------------------------------------
# Bugfix regression: failed executions are ledgered; failure memo with
# TTL-by-dataset-version
# ---------------------------------------------------------------------------

BAD = {"k": 2, "minsup": 0.3, "n_sites": 0}  # valid at submit, fails at split


def test_failed_execution_records_attempt():
    svc = _service()
    bad = svc.submit("a", "gfm", "tx", BAD)
    svc.step()
    req = svc.request(bad)
    assert req.status == "failed" and req.error
    # the fix: the attempt is ledgered — backend that ran and the
    # attempt's wall-time share (it used to leave backend=None, 0.0)
    assert req.backend == svc.backend_name
    assert req.compute_s >= 0.0
    assert svc.failures == 1
    led = svc.ledger()
    assert led["failures"] == 1 and led["failure_memo_hits"] == 0
    assert led["per_tenant"]["a"]["failed"] == 1


def test_failure_memo_short_circuits_resubmission():
    svc = _service()
    svc.submit("a", "gfm", "tx", BAD)
    svc.step()
    assert svc.failures == 1
    execs = svc.executions
    bad2 = svc.submit("a", "gfm", "tx", BAD)
    svc.step()
    req2 = svc.request(bad2)
    assert req2.status == "failed" and req2.error
    assert req2.backend == "failure-memo"
    assert svc.failure_memo_hits == 1
    assert svc.failures == 1  # a memo hit is not a new failure
    assert svc.executions == execs  # no device attempt was paid


def test_failure_memo_invalidated_by_dataset_version():
    """TTL-by-version: the memo key includes the dataset version, so an
    append retries the request for real instead of serving a stale
    verdict."""
    svc = _service()
    svc.submit("a", "gfm", "tx", BAD)
    svc.step()
    svc.append_transactions("tx", _tx_batch(1))
    bad3 = svc.submit("a", "gfm", "tx", BAD)
    svc.step()
    assert svc.request(bad3).backend == svc.backend_name  # a real attempt
    assert svc.failures == 2
    assert svc.failure_memo_hits == 0


def test_failure_memo_is_bounded():
    svc = _service(failure_memo_capacity=2)
    for minsup in (0.3, 0.4, 0.5):
        svc.submit("a", "gfm", "tx", {"k": 2, "minsup": minsup, "n_sites": 0})
        svc.step()
    assert svc.failures == 3
    assert len(svc._failure_memo) == 2  # oldest entry evicted
