"""End-to-end driver (the paper's kind): a distributed data-mining
pipeline executed through the DAGMan-analog workflow engine with fault
injection, rescue-restart and the grid overhead model.

Stages (per the paper's experimental setup):
  generate -> per-site local K-Means -> stat merge -> per-site Apriori ->
  GFM global phase -> report, with site jobs failing (and retried), and
  the whole run resumable from the rescue file.

    PYTHONPATH=src python examples/grid_mining_pipeline.py
"""

import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core.apriori import TransactionDB, local_apriori
from repro.core.gfm import gfm_mine
from repro.core.kmeans import kmeans
from repro.core.stats import SuffStats, stack_site_stats
from repro.core.vclustering import merge_subclusters, paper_threshold
from repro.data.synthetic import gaussian_mixture, ibm_transactions, split_sites, split_transactions
from repro.workflow.dag import DAG
from repro.workflow.engine import Engine
from repro.workflow.faults import FaultInjector
from repro.workflow.overhead import GridModel

N_SITES = 4
K_LOCAL = 8

print("== building site datasets ==")
pts, _ = gaussian_mixture(seed=0, n_points=6000, dim=2, n_components=4, spread=12.0, sigma=0.5)
xs = split_sites(pts, N_SITES, seed=1)
dense = ibm_transactions(seed=2, n_tx=4000, n_items=40, avg_tx_len=8, n_patterns=10)
tx_sites = [TransactionDB.from_dense(s) for s in split_transactions(dense, N_SITES, seed=0)]

dag = DAG("grid_mining")

# --- clustering branch: local K-Means per site, then logical merge ---
def make_cluster_job(i):
    def job():
        res = kmeans(jax.random.PRNGKey(i), jnp.asarray(xs[i]), K_LOCAL, iters=20)
        return res.stats  # ONLY sufficient statistics leave the site

    return job


for i in range(N_SITES):
    dag.job(f"cluster_{i}", make_cluster_job(i), site=i % 5,
            input_bytes=xs[i].nbytes, output_bytes=K_LOCAL * (2 + 2) * 4)

def merge_job(*site_stats):
    flat = stack_site_stats(
        SuffStats(
            sizes=jnp.stack([s.sizes for s in site_stats]),
            centers=jnp.stack([s.centers for s in site_stats]),
            sse=jnp.stack([s.sse for s in site_stats]),
        )
    )
    merged = merge_subclusters(flat, paper_threshold(flat, 2.0), criterion="increase")
    return int(merged.n_global)

dag.job("merge", merge_job, deps=[f"cluster_{i}" for i in range(N_SITES)])

# --- itemset branch: local Apriori per site, single global phase ---
for i in range(N_SITES):
    dag.job(f"apriori_{i}", (lambda i=i: local_apriori(tx_sites[i], 4, int(0.08 * tx_sites[i].n_tx))),
            site=i % 5, output_bytes=50_000)

def gfm_job(*_):
    return len(gfm_mine(tx_sites, 4, 0.08).frequent)

dag.job("gfm_global", gfm_job, deps=[f"apriori_{i}" for i in range(N_SITES)])
dag.job("report", lambda n_clusters, n_itemsets: (n_clusters, n_itemsets), deps=["merge", "gfm_global"])

# --- run with injected faults + rescue file ---
rescue = Path(tempfile.mkdtemp()) / "rescue.json"
engine = Engine(
    model=GridModel(),
    faults=FaultInjector(fail={"cluster_2": 1, "apriori_0": 1}),  # transient site failures
    rescue_path=rescue,
    overlap_prep=True,
    straggler_factor=4.0,
)
report = engine.run(dag)

n_clusters, n_itemsets = dag.jobs["report"].result
print(f"== pipeline result: {n_clusters} global clusters, {n_itemsets} frequent itemsets ==")
print(f"simulated grid wall: {report.wall_s:.1f}s  (compute {report.compute_s:.2f}s, "
      f"prep {report.prep_s:.1f}s, submit {report.submit_s:.1f}s)")
print(f"retries after injected faults: {report.retries}; overhead {report.overhead_pct():.1f}%")
print(f"rescue file: {rescue} (re-running resumes from the completed frontier)")
