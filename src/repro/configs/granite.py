"""granite-20b [dense] — llama-arch code model, MQA (kv=1) [arXiv:2405.04324]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab=49152,
    rope_theta=10_000.0,
    layer_pattern=("full",),
    norm="layernorm",
    act="gelu_mlp",  # GPT-BigCode-style 4x GELU MLP (matches the 20B param count)
    subquadratic=False,
)
