"""Multi-host backend: single-process fallback semantics in-process,
unit tests for the ownership/shipping primitives, and the CPU
two-subprocess ``jax.distributed`` smoke test.

The subprocess test is the CI guard for ROADMAP follow-on (a), now
completed: two host processes bring up one ``jax.distributed`` runtime,
agree on the global device topology, exchange data with a real
cross-process collective (gloo CPU backend), and run a SiteJob DAG
through ``Engine(backend="multihost")`` with TRUE site ownership — each
site's jobs execute on exactly one process, results ship to every
process, and the final results are identical everywhere.  (The full
2-/3-process × app × schedule matrix lives in
``tests/test_backend_conformance.py``.)
"""

import json
import pickle
import socket
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.compat import pack_payload, unpack_payload
from repro.launch.mesh import allgather_bytes, site_ownership
from repro.runtime.backends import MultiHostBackend
from repro.workflow.dag import DAG, TimedResult
from repro.workflow.engine import Engine
from repro.workflow.executor import ExecutionBackend, Partition
from repro.workflow.overhead import GridModel

SRC = str(Path(__file__).resolve().parent.parent / "src")


class TestSiteOwnership:
    def test_round_robin_uniform(self):
        assert site_ownership([0, 1, 2, 3], n_processes=2) == {0: 0, 1: 1, 2: 0, 3: 1}
        assert site_ownership([0, 1, 2, 3, 4], n_processes=3) == {
            0: 0, 1: 1, 2: 2, 3: 0, 4: 1,
        }

    def test_uneven_sites_stay_balanced(self):
        owner = site_ownership([0, 1, 2], n_processes=2)
        counts = [sum(1 for p in owner.values() if p == pid) for pid in range(2)]
        assert sorted(counts) == [1, 2]

    def test_single_process_owns_everything(self):
        assert set(site_ownership([0, 5, 9], n_processes=1).values()) == {0}

    def test_deterministic_and_order_insensitive(self):
        a = site_ownership([3, 1, 2, 0], n_processes=2)
        b = site_ownership([0, 1, 2, 3], n_processes=2)
        assert a == b

    def test_uniform_weights_cancel_to_round_robin(self):
        # a uniform per-site weight (e.g. GridModel.workers_per_site)
        # cannot change a balance — identical map with and without it
        uniform = {s: 4.0 for s in range(4)}
        assert site_ownership([0, 1, 2, 3], n_processes=2, site_weights=uniform) == {
            0: 0, 1: 1, 2: 0, 3: 1,
        }

    def test_heterogeneous_weights_skew_the_balance(self):
        # one heavy site fills its owner; the light sites pack elsewhere
        owner = site_ownership(
            [0, 1, 2], n_processes=2, site_weights={0: 10.0, 1: 1.0, 2: 1.0}
        )
        assert owner[0] == 0 and owner[1] == 1 and owner[2] == 1

    def test_mesh_capacity_proportional(self):
        # a process holding more mesh devices owns proportionally more
        # sites (2 devices on p0, 1 on p1 -> p0 owns 2 of 3 sites)
        class _Dev:
            def __init__(self, p):
                self.process_index = p

        class _Mesh:
            class devices:
                flat = [_Dev(0), _Dev(0), _Dev(1)]

        owner = site_ownership([0, 1, 2], mesh=_Mesh())
        assert owner == {0: 0, 1: 1, 2: 0}

    def test_invalid_process_count(self):
        with pytest.raises(ValueError, match="n_processes"):
            site_ownership([0], n_processes=0)


class TestPayloadShim:
    """compat.pack_payload/unpack_payload — the pytree-leaf serialization
    that lets non-array SiteJob outputs (itemset dicts, CommLogs) ride
    the process_allgather wire."""

    def test_jax_arrays_become_host_numpy(self):
        import jax.numpy as jnp

        tr = TimedResult((jnp.arange(4), {"k": jnp.ones((2, 2))}), 0.25)
        out = unpack_payload(pack_payload(tr))
        assert isinstance(out, TimedResult) and out.compute_s == 0.25
        arr, d = out.value
        assert isinstance(arr, np.ndarray) and arr.tolist() == [0, 1, 2, 3]
        assert isinstance(d["k"], np.ndarray) and d["k"].dtype == np.float32

    def test_itemset_dicts_round_trip(self):
        payload = {"frequent": {(0, 1): 7, (2,): 3}, "pool": [(0,), (0, 1)]}
        assert unpack_payload(pack_payload(payload)) == payload

    def test_mining_result_dataclasses_round_trip(self):
        from repro.core.gfm import CommLog

        comm = CommLog()
        comm.add_round(10, 8, 3)
        out = unpack_payload(pack_payload(TimedResult(comm, 0.0))).value
        assert out.rounds == 1 and out.bytes_sent == comm.bytes_sent

    def test_wire_is_plain_pickle_of_host_tree(self):
        # the wire must never require a live jax runtime to decode
        data = pack_payload([1, "x", None])
        assert pickle.loads(data) == [1, "x", None]


class TestAllgatherBytes:
    def test_single_process_identity(self):
        assert allgather_bytes(b"abc") == [b"abc"]
        assert allgather_bytes(b"") == [b""]


class TestSingleProcessFallback:
    """Without a coordinator the backend must degrade to inline
    execution over the local devices — safe everywhere."""

    def test_describe_single_process(self):
        be = MultiHostBackend()
        info = be.describe()
        assert info["is_multiprocess"] is False
        assert info["process_count"] == 1
        assert info["n_global_devices"] >= 1
        assert info["mesh_shape"] == {"sites": info["n_global_devices"]}

    def test_allgather_check_identity(self):
        be = MultiHostBackend()
        out = be.allgather_check(7.0)
        assert out.shape == (1, 1) and float(out[0, 0]) == 7.0

    def test_engine_runs_with_multihost_backend(self):
        dag = DAG("d")
        dag.job("a", lambda: 2)
        dag.job("b", lambda a: a + 3, deps=["a"])
        results = {}
        be = MultiHostBackend()
        rep = Engine(model=GridModel(prep_latency_s=0.0), backend=be).run(
            dag, results=results
        )
        assert results["b"] == 5
        assert rep.backend == "multihost"
        # no partition on a single process: everything executed locally
        assert rep.n_processes == 1 and rep.owned_jobs is None
        assert be.executed_log == ["a", "b"] and be.shipped_log == []

    def test_partition_none_single_process(self):
        dag = DAG("d")
        dag.job("a", lambda: 1, site=0)
        dag.job("b", lambda: 2, site=1)
        assert MultiHostBackend().partition(dag, GridModel()) is None

    def test_partition_sites_false_disables_ownership(self, monkeypatch):
        be = MultiHostBackend(partition_sites=False)
        be._ensure()
        monkeypatch.setattr(be, "is_multiprocess", True)
        dag = DAG("d")
        dag.job("a", lambda: 1, site=0)
        assert be.partition(dag, GridModel()) is None

    def test_partition_derives_from_mesh(self, monkeypatch):
        """Force the multi-process branch on a single-process runtime:
        every mesh device is local, so this process owns every site —
        the map is still derived and exposed."""
        be = MultiHostBackend()
        be._ensure()
        monkeypatch.setattr(be, "is_multiprocess", True)
        dag = DAG("d")
        dag.job("a", lambda: 1, site=0)
        dag.job("b", lambda: 2, site=1)
        dag.job("c", lambda: 3, site=0)
        part = be.partition(dag, GridModel())
        assert part is not None
        assert part.owned == frozenset({"a", "b", "c"})
        assert part.owner_of == {"a": 0, "b": 0, "c": 0}
        assert part.owned_sites == (0, 1)

    def test_owner_shipping_path_round_trips(self):
        """The owner-side ship path in-process: pack -> allgather
        (identity) -> unpack; untimed callables get the owner's host
        bracket; the engine-visible value is the round-tripped one."""
        from repro.workflow.executor import Partition as P

        be = MultiHostBackend()
        be._ensure()
        dag = DAG("d")
        job = dag.job("a", lambda: {"frequent": {(0, 1): 7}}, site=0)
        be._partition = P(
            owned=frozenset({"a"}),
            owner_of={"a": 0},
            n_processes=1,
            process_index=0,
            owned_sites=(0,),
        )
        out = be.call(job, [])
        assert isinstance(out, TimedResult)
        assert out.value == {"frequent": {(0, 1): 7}}
        assert out.compute_s >= 0.0
        assert be.executed_log == ["a"] and be.shipped_log == []

    def test_owned_job_exception_ships_instead_of_stranding_peers(self):
        """An owned job's fn raising must NOT propagate before the
        collective (the peers would deadlock in process_allgather) — the
        exception ships and every process raises it after the shipment."""
        from repro.workflow.executor import Partition as P

        be = MultiHostBackend()
        be._ensure()
        dag = DAG("d")

        def boom():
            raise ValueError("corrupt site data")

        job = dag.job("a", boom, site=0)
        be._partition = P(
            owned=frozenset({"a"}),
            owner_of={"a": 0},
            n_processes=1,
            process_index=0,
            owned_sites=(0,),
        )
        with pytest.raises(RuntimeError, match="failed on its owning process 0.*corrupt"):
            be.call(job, [])


class _RemoteStub(ExecutionBackend):
    """A backend that claims another process owns some jobs — exercises
    the engine's owner-only-timing invariant without a real runtime."""

    name = "stub"

    def __init__(self, owned: set[str], ship_timed: bool = True):
        self._owned = owned
        self.ship_timed = ship_timed

    def partition(self, dag, model=None) -> Partition:
        owner_of = {n: (0 if n in self._owned else 1) for n in dag.jobs}
        return Partition(
            owned=frozenset(self._owned),
            owner_of=owner_of,
            n_processes=2,
            process_index=0,
            owned_sites=(0,),
        )

    def call(self, job, args):
        if job.name in self._owned:
            return job.fn(*args)
        # pretend the owner shipped it
        out = job.fn(*args)
        return TimedResult(out, 0.125) if self.ship_timed else out


class TestEngineOwnershipContract:
    def test_report_carries_partition(self):
        dag = DAG("d")
        dag.job("a", lambda: 1, site=0)
        dag.job("b", lambda a: a + 1, deps=["a"], site=1)
        rep = Engine(
            model=GridModel(prep_latency_s=0.0), backend=_RemoteStub({"a"})
        ).run(dag)
        assert rep.n_processes == 2 and rep.process_index == 0
        assert rep.owned_jobs == ("a",) and rep.owned_sites == (0,)
        # the non-owned job's shipped time feeds the global ledger
        assert rep.job_times["b"] == pytest.approx(0.125)

    def test_non_owned_job_must_ship_timedresult(self):
        dag = DAG("d")
        dag.job("a", lambda: 1, site=0)
        dag.job("b", lambda a: a + 1, deps=["a"], site=1)
        eng = Engine(
            model=GridModel(prep_latency_s=0.0),
            backend=_RemoteStub({"a"}, ship_timed=False),
        )
        with pytest.raises(RuntimeError, match="owner-measured TimedResult"):
            eng.run(dag)


CHILD = textwrap.dedent(
    """
    import json, sys
    sys.path.insert(0, {src!r})
    import os
    os.environ["JAX_PLATFORMS"] = "cpu"
    import jax
    from repro.runtime.backends import MultiHostBackend
    from repro.workflow.dag import DAG, TimedResult
    from repro.workflow.engine import Engine
    from repro.workflow.overhead import GridModel

    pid = int(sys.argv[1])
    be = MultiHostBackend(
        coordinator_address="127.0.0.1:{port}", num_processes=2, process_id=pid
    )
    info = be.describe()
    gathered = be.allgather_check(float(pid + 1)).reshape(-1).tolist()

    # two sites, two processes: site i's job must execute ONLY on its
    # owning process; results ship and agree everywhere
    dag = DAG("smoke")
    dag.job("a", lambda: TimedResult(20, 0.0), site=0)
    dag.job("b", lambda a: TimedResult(a + 22, 0.0), deps=["a"], site=1)
    results = {{}}
    rep = Engine(model=GridModel(prep_latency_s=0.0), backend=be).run(
        dag, results=results
    )
    print("MULTIHOST " + json.dumps({{
        "pid": pid,
        "process_count": info["process_count"],
        "n_global_devices": info["n_global_devices"],
        "n_local_devices": info["n_local_devices"],
        "mesh_shape": info["mesh_shape"],
        "is_multiprocess": info["is_multiprocess"],
        "gathered": gathered,
        "result": int(results["b"]),
        "backend": rep.backend,
        "n_processes": rep.n_processes,
        "owned_jobs": list(rep.owned_jobs or []),
        "owned_sites": list(rep.owned_sites or []),
        "executed": list(be.executed_log),
        "shipped": sorted(be.shipped_log),
    }}), flush=True)
    """
)


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def test_two_process_cpu_smoke(tmp_path):
    """Two host processes, one distributed runtime: global topology,
    cross-process all_gather, true per-process site ownership, and
    identical shipped DAG results on both processes."""
    port = _free_port()
    script = tmp_path / "child.py"
    script.write_text(CHILD.format(src=SRC, port=port))
    procs = [
        subprocess.Popen(
            [sys.executable, str(script), str(pid)],
            stdout=subprocess.PIPE,
            stderr=subprocess.PIPE,
            text=True,
        )
        for pid in range(2)
    ]
    outs = []
    for p in procs:
        try:
            out, err = p.communicate(timeout=180)
        except subprocess.TimeoutExpired:
            for q in procs:
                q.kill()
            pytest.fail("multihost smoke subprocess timed out")
        assert p.returncode == 0, f"child failed:\nstdout:\n{out}\nstderr:\n{err}"
        outs.append(out)
    infos = []
    for out in outs:
        lines = [ln for ln in out.splitlines() if ln.startswith("MULTIHOST ")]
        assert lines, f"no smoke marker in child output: {out!r}"
        infos.append(json.loads(lines[0][len("MULTIHOST "):]))
    infos.sort(key=lambda d: d["pid"])
    for info in infos:
        assert info["is_multiprocess"] is True
        assert info["process_count"] == 2
        assert info["n_global_devices"] == 2
        assert info["n_local_devices"] == 1
        assert info["mesh_shape"] == {"sites": 2}
        # the cross-process collective really crossed processes
        assert info["gathered"] == [1.0, 2.0]
        # shipped results are identical on every process
        assert info["result"] == 42
        assert info["backend"] == "multihost"
        assert info["n_processes"] == 2
    # TRUE ownership: each site's job executed on exactly one process
    assert infos[0]["executed"] == ["a"] and infos[0]["shipped"] == ["b"]
    assert infos[1]["executed"] == ["b"] and infos[1]["shipped"] == ["a"]
    assert infos[0]["owned_jobs"] == ["a"] and infos[1]["owned_jobs"] == ["b"]
    assert infos[0]["owned_sites"] == [0] and infos[1]["owned_sites"] == [1]
