"""Multi-host execution backend — true per-process site ownership over a
``jax.distributed`` mesh.

ROADMAP follow-on (a), completed: the same SiteJob DAGs the single-host
runtime executes now distribute for real.  :class:`MultiHostBackend`
brings up the distributed runtime (``launch.mesh.init_multihost``),
builds the global device mesh spanning every host
(``make_multihost_mesh``), derives an explicit ``site -> process``
ownership map from it (``launch.mesh.site_ownership``: capacity-
proportional over the mesh's processes; per-site load weights are the
seam for heterogeneous slots — the scalar ``GridModel.workers_per_site``
is uniform and therefore balance-neutral), and then:

  * each process executes ONLY the jobs of its owned sites — a 3-process
    run really does run each site's mining on exactly one process
    (``executed_log`` is the audit trail the conformance harness checks);
  * execution is WAVE-FUSED by default (``fuse_waves=True``): at the
    first ``call`` of each ready wave the backend takes the whole wave
    (``executor.ready_wave``), groups it by ``batch_key``
    (``executor.group_wave``), runs ONE fused vmapped dispatch per group
    over its owned members (the ``sitejob.timed_batch`` contract — the
    fused call is measured once and each member's share is its
    owner-measured time), and ships ALL of the wave's results in ONE
    ``allgather_bytes`` round — so the collective count scales with
    ready WAVES, not jobs, which is the paper's communication-round
    overhead collapsed at its source.  ``fuse_waves=False`` restores the
    per-job shipment rounds (one collective per executed job);
  * every shipment moves owner-measured ``TimedResult`` payloads
    (``compat.pack_payload`` converts jax-array pytree leaves to host
    numpy and pickles non-array outputs such as itemset dicts), and the
    per-run counts are ledgered (``shipments`` / ``collective_rounds`` /
    ``shipped_results``, surfaced on ``RunReport``) so the O(jobs) ->
    O(waves) reduction is measurable, not asserted by hand;
  * every process keeps scheduling the WHOLE DAG — placement, the
    simulated clock and the ledger are globally consistent because every
    process sees the same owner-measured times, so both engine schedulers
    replay the identical event order everywhere and the wave shipments
    are the only collectives (the paper's synchronization traffic and
    nothing else).

Single-process fallback: without a coordinator the backend degrades to
inline execution over the local devices — same results, no distributed
state touched — so ``Engine(backend="multihost")`` is safe everywhere.

Determinism contract (why the shipments line up): both schedulers order
events only by (dag, model, placement seed, fault seed, measured times),
and the measured times are owner-authoritative everywhere, so every
process invokes ``call`` for the same jobs in the same order.  Keep
per-process state OUT of the scheduling inputs — e.g. a ``rescue_path``
resuming on one process only would desynchronize the collectives.

The serving layer (``launch.serve.MiningService``) treats this backend
as a drop-in execution strategy: a service built with
``backend="multihost"`` dispatches every coalesced tenant request
through the same ownership/shipping machinery, one run at a time.
"""

from __future__ import annotations

import time
from typing import Any

import jax
import numpy as np

from repro.compat import pack_payload, unpack_payload
from repro.launch.mesh import (
    allgather_bytes,
    allgather_payload,
    init_multihost,
    make_multihost_mesh,
    site_ownership,
)
from repro.workflow.dag import DAG, Job, TimedResult
from repro.workflow.executor import (
    ExecutionBackend,
    Partition,
    group_wave,
    ready_wave,
)


class _ShippedError:
    """Wire marker for an exception raised by an owned job's callable:
    the owner ships it instead of the result so every process raises the
    same failure AFTER the collective (raising before it would strand
    the peers inside ``process_allgather``, which has no timeout)."""

    def __init__(self, message: str):
        self.message = message


class MultiHostBackend(ExecutionBackend):
    """Site-partitioned DAG execution over a ``jax.distributed`` mesh.

    Parameters mirror ``jax.distributed.initialize``; all-None (the
    default) means "join an already-initialized runtime, or run
    single-process" — the backend never guesses a coordinator.

    ``partition_sites=False`` restores the pre-ownership SPMD-redundant
    mode (every process executes every job; no shipping);
    ``fuse_waves=False`` restores per-job shipment rounds (one collective
    per executed job) — both kept for A/B measurements against the
    wave-fused default.  ``force_partition=True`` derives the ownership
    map even on a single-process runtime (everything owned locally, the
    collectives degenerate to identity) — the seam that lets unit tests
    and the collective-count benchmark exercise the partitioned shipping
    paths and their ledger without a process group.
    """

    name = "multihost"

    def __init__(
        self,
        coordinator_address: str | None = None,
        num_processes: int | None = None,
        process_id: int | None = None,
        axis: str = "sites",
        partition_sites: bool = True,
        fuse_waves: bool = True,
        force_partition: bool = False,
    ):
        self.coordinator_address = coordinator_address
        self.num_processes = num_processes
        self.process_id = process_id
        self.axis = axis
        self.partition_sites = partition_sites
        self.fuse_waves = fuse_waves
        self.force_partition = force_partition
        self._ready = False
        self.is_multiprocess = False
        self.mesh = None
        self._partition: Partition | None = None
        self._dag: DAG | None = None
        self._results: dict | None = None
        # wave-fused shipping: results of the current ready wave, merged
        # from every process's shipment, consumed one ``call`` at a time
        self._wave_cache: dict[str, Any] = {}
        # audit trails for the conformance harness: which jobs' callables
        # ran in THIS process, and which arrived as shipped results
        self.executed_log: list[str] = []
        self.shipped_log: list[str] = []
        # per-run collective/shipment ledger (ExecutionBackend.ledger):
        # wave-fused shipping makes shipments O(waves); per-job O(jobs)
        self.shipments = 0
        self.collective_rounds = 0
        self.shipped_results = 0
        self.waves = 0
        if coordinator_address is not None or num_processes is not None:
            # explicit coordinator args = the caller WANTS a distributed
            # runtime, and jax.distributed.initialize must beat the
            # process's first XLA backend query (jax.process_count,
            # jax.random.PRNGKey, ...) — so bring it up eagerly at
            # construction, before anything else can touch jax.  All-None
            # construction stays lazy (safe everywhere).
            self._ensure()

    def ensure_initialized(self) -> None:
        """Public bring-up (idempotent): ``jax.distributed`` init + the
        global mesh.  MUST run before any jax backend query
        (``jax.process_count``, ``jax.devices``, any computation) in this
        process — callers that need topology facts ahead of ``Engine.run``
        (e.g. ``GridRuntime``'s sync-mode selection) call this first."""
        self._ensure()

    def _ensure(self) -> None:
        """Bring up the distributed runtime and the global mesh once."""
        if self._ready:
            return
        self.is_multiprocess = init_multihost(
            coordinator_address=self.coordinator_address,
            num_processes=self.num_processes,
            process_id=self.process_id,
        )
        self.mesh = make_multihost_mesh(axis=self.axis)
        self._ready = True

    def describe(self) -> dict:
        """Topology introspection (the smoke test's assertions): process
        layout and the global mesh this backend executes over."""
        self._ensure()
        return {
            "is_multiprocess": self.is_multiprocess,
            "process_index": jax.process_index(),
            "process_count": jax.process_count(),
            "n_global_devices": len(jax.devices()),
            "n_local_devices": len(jax.local_devices()),
            "mesh_shape": dict(self.mesh.shape) if self.mesh is not None else None,
            "axis": self.axis,
        }

    def allgather_check(self, value: float) -> np.ndarray:
        """Cross-process collective smoke: gather one scalar per process
        (identity on a single process) — the same wire ``call`` ships
        per-site results over."""
        self._ensure()
        arr = np.asarray([value], dtype=np.float32)
        if not self.is_multiprocess:
            return arr[None]
        from jax.experimental.multihost_utils import process_allgather

        return np.asarray(process_allgather(arr))

    # -- ownership ----------------------------------------------------------

    def begin_run(self, dag: DAG, results: dict) -> None:
        self._ensure()
        self._partition = None
        self._dag = dag
        self._results = results
        self._wave_cache.clear()
        self.executed_log.clear()
        self.shipped_log.clear()
        self.shipments = 0
        self.collective_rounds = 0
        self.shipped_results = 0
        self.waves = 0

    def ledger(self) -> dict:
        """The per-run collective/shipment counts (copied onto
        ``RunReport`` by the engine): ``shipments`` = result-shipment
        collectives performed, ``collective_rounds`` = underlying
        ``process_allgather`` rounds (two per shipment: lengths, then
        padded payloads), ``shipped_results`` = job results that arrived
        from OTHER processes.  All zero on an unpartitioned run."""
        return {
            "shipments": self.shipments,
            "collective_rounds": self.collective_rounds,
            "shipped_results": self.shipped_results,
        }

    def partition(self, dag: DAG, model=None) -> Partition | None:
        """Derive the ``site -> process`` ownership map for this DAG from
        the global mesh (every process computes the identical map) and
        project it onto job names.  Single-process runtimes — and
        ``partition_sites=False`` — return None: everything runs locally.
        """
        self._ensure()
        if not (self.is_multiprocess or self.force_partition) or not self.partition_sites:
            return None
        sites = sorted({j.site for j in dag.jobs.values()})
        # capacity-proportional over the mesh's processes; the grid
        # model's workers_per_site is a UNIFORM per-site weight, which
        # cancels out of the balance — per-site heterogeneous weights are
        # site_ownership's seam when the model grows them
        owner_by_site = site_ownership(sites, n_processes=jax.process_count(), mesh=self.mesh)
        me = jax.process_index()
        owner_of = {j.name: owner_by_site[j.site] for j in dag.jobs.values()}
        self._partition = Partition(
            owned=frozenset(n for n, p in owner_of.items() if p == me),
            owner_of=owner_of,
            n_processes=jax.process_count(),
            process_index=me,
            owned_sites=tuple(s for s, p in sorted(owner_by_site.items()) if p == me),
        )
        return self._partition

    # -- execution ----------------------------------------------------------

    def call(self, job: Job, args: list) -> Any:
        part = self._partition
        if part is None:
            # single process (or partitioning disabled): plain inline
            # execution — same results, no distributed state touched
            self.executed_log.append(job.name)
            return job.fn(*args)
        if self.fuse_waves and self._dag is not None:
            return self._call_wave(job, part)
        # per-job wire: also the path for direct call() usage outside a
        # begin_run/end_run bracket, where no DAG is available to wave over
        return self._call_per_job(job, args, part)

    def _call_per_job(self, job: Job, args: list, part: Partition) -> Any:
        if job.name in part.owned:
            # owner: execute for real, normalize to an owner-measured
            # TimedResult (untimed callables get the host bracket HERE, on
            # the one process that ran them), and ship it.  A raised
            # exception ships too — the peers are already committed to
            # joining this job's collective, so propagating it before the
            # shipment would leave them deadlocked in process_allgather;
            # instead everyone receives it and fails the run together.
            t0 = time.perf_counter()
            try:
                raw = job.fn(*args)
                if not isinstance(raw, TimedResult):
                    raw = TimedResult(raw, time.perf_counter() - t0)
                payload = pack_payload(raw)
                # logged only once the result is actually shippable, so
                # the audit trail never claims an execution whose peers
                # received a serialization failure instead
                self.executed_log.append(job.name)
            except Exception as e:  # noqa: BLE001 - shipped, not swallowed
                payload = pack_payload(_ShippedError(f"{type(e).__name__}: {e}"))
        else:
            payload = b""
        # one shipment per executed job (allgather_bytes = two
        # process_allgather rounds: lengths, then padded payloads); every
        # process joins — the schedulers' deterministic event order
        # guarantees they arrive in lockstep — and the owner's slot
        # carries the result
        shipped = allgather_bytes(payload)
        self.shipments += 1
        self.collective_rounds += 2
        out = unpack_payload(shipped[part.owner_of[job.name]])
        if job.name not in part.owned and not isinstance(out, _ShippedError):
            self.shipped_results += 1
        return self._adopt(job.name, out, part)

    # -- wave-fused execution ------------------------------------------------

    def _call_wave(self, job: Job, part: Partition) -> Any:
        """Wave-fused shipping: a cache miss means ``job`` opens a new
        ready wave — execute this process's owned slice of the whole wave
        (one fused dispatch per batch group) and ship every result in ONE
        collective; hits consume the merged wave cache."""
        if job.name not in self._wave_cache:
            self._ship_wave(part)
        out = self._wave_cache.pop(job.name)
        return self._adopt(job.name, out, part)

    def _ship_wave(self, part: Partition) -> None:
        assert self._dag is not None and self._results is not None
        wave = ready_wave(self._dag, self._results, skip=self._wave_cache)
        local: dict[str, Any] = {}
        ran: list[str] = []  # logged executed only once actually shipped
        for group in group_wave(wave):
            owned = [j for j in group if j.name in part.owned]
            if not owned:
                continue
            if len(owned) >= 2 and owned[0].batched_fn is not None:
                self._run_owned_fused(owned, local, ran)
            else:
                # singleton slice (or unbatchable job): the plain owner
                # bracket — no vmap-of-one overhead
                for j in owned:
                    local[j.name] = self._run_owned_one(j, ran)
        try:
            blob_ok = True
            shipped = allgather_payload(local)
        except Exception as e:  # noqa: BLE001 - a result that cannot
            # serialize must not strand the peers: re-join the collective
            # shipping errors for this process's whole slice instead
            blob_ok = False
            err = _ShippedError(f"{type(e).__name__}: {e}")
            shipped = allgather_payload(dict.fromkeys(local, err))
        if blob_ok:
            self.executed_log.extend(ran)
        self.shipments += 1
        self.collective_rounds += 2
        self.waves += 1
        # merge: the per-process slices are disjoint (each job has one
        # owner) and their union covers the wave — every process adopts
        # the identical round-tripped cache
        for pid, slice_ in enumerate(shipped):
            if pid != part.process_index:
                self.shipped_results += sum(
                    1 for v in slice_.values() if not isinstance(v, _ShippedError)
                )
            self._wave_cache.update(slice_)
        missing = [j.name for j in wave if j.name not in self._wave_cache]
        if missing:  # pragma: no cover - ownership covers every job
            raise RuntimeError(
                f"wave shipment incomplete: no owner shipped {missing!r}"
            )

    def _run_owned_fused(self, owned: list[Job], local: dict, ran: list[str]) -> None:
        """ONE fused dispatch over this process's owned slice of a batch
        group.  Only owned member names are passed to ``batched_fn``, so
        a ``timed_batch``-built group records measured shares for owned
        jobs ONLY — the owner-only timing invariant holds by
        construction (the ``owned=`` filter seam stays available for
        redundantly-executing backends).  An untimed fused fn gets the
        host bracket apportioned equally, mirroring ``timed_batch``."""
        names = [j.name for j in owned]
        t0 = time.perf_counter()
        try:
            argss = [[self._results[d] for d in j.deps] for j in owned]
            outs = owned[0].batched_fn(names, [j.batch_arg for j in owned], argss)
            if len(outs) != len(owned):
                raise RuntimeError(
                    f"batched_fn for {owned[0].batch_key!r} returned "
                    f"{len(outs)} results for {len(owned)} jobs"
                )
            share = (time.perf_counter() - t0) / max(len(owned), 1)
            for j, out in zip(owned, outs):
                local[j.name] = out if isinstance(out, TimedResult) else TimedResult(out, share)
            ran.extend(names)
        except Exception as e:  # noqa: BLE001 - shipped, not swallowed
            err = _ShippedError(f"{type(e).__name__}: {e}")
            for j in owned:
                local[j.name] = err

    def _run_owned_one(self, job: Job, ran: list[str]):
        """Execute one owned job for a wave shipment: owner-measured
        TimedResult (untimed callables get the host bracket HERE, on the
        one process that ran them) or a shipped error."""
        assert self._results is not None
        t0 = time.perf_counter()
        try:
            raw = job.fn(*[self._results[d] for d in job.deps])
            if not isinstance(raw, TimedResult):
                raw = TimedResult(raw, time.perf_counter() - t0)
            ran.append(job.name)
            return raw
        except Exception as e:  # noqa: BLE001 - shipped, not swallowed
            return _ShippedError(f"{type(e).__name__}: {e}")

    def _adopt(self, name: str, out: Any, part: Partition) -> TimedResult:
        """Normalize a shipped entry on every process: raise a shipped
        owner-side failure everywhere together, guard the wire contract,
        and adopt the round-tripped value (owner included) so the results
        dict is bit-identical on every process."""
        if isinstance(out, _ShippedError):
            raise RuntimeError(
                f"job {name!r} failed on its owning process "
                f"{part.owner_of[name]}: {out.message}"
            )
        if not isinstance(out, TimedResult):  # pragma: no cover - wire guard
            raise RuntimeError(
                f"shipped result for job {name!r} from process "
                f"{part.owner_of[name]} is not an owner-measured TimedResult"
            )
        if name not in part.owned:
            self.shipped_log.append(name)
        return out
