"""Paper §5.2.1 / Table 3 (GFM & FDM rows): frequent-itemset mining on
synthetic transactions distributed over sites.

Paper setup: 4e6 transactions over 200 processes, sizes 1..4, GFM ~25%
faster than FDM with 2 communication passes instead of 4, FDM remote
support computation ≈13% of its compute.  We run a CPU-scaled instance
(same structure: uniform split, k=4) and report measured compute + the
grid-modeled times from the paper's own link matrix.
"""

from __future__ import annotations

import time

from benchmarks.common import row
from repro.core.apriori import TransactionDB
from repro.core.fdm import fdm_mine
from repro.core.gfm import gfm_mine
from repro.data.synthetic import ibm_transactions, split_transactions
from repro.workflow.overhead import GridModel, estimate_stages


def run(n_tx: int = 40_000, n_items: int = 96, n_sites: int = 8, k: int = 4, minsup: float = 0.05):
    dense = ibm_transactions(seed=42, n_tx=n_tx, n_items=n_items, avg_tx_len=10, n_patterns=24)
    sites = [TransactionDB.from_dense(s) for s in split_transactions(dense, n_sites, seed=0)]

    t0 = time.perf_counter()
    g = gfm_mine(sites, k, minsup)
    t_gfm = time.perf_counter() - t0

    t0 = time.perf_counter()
    f = fdm_mine(sites, k, minsup)
    t_fdm = time.perf_counter() - t0

    assert g.frequent == f.frequent, "GFM and FDM must agree exactly"

    # Raw local compute: GFM deliberately does MORE of it (no global
    # pruning); its win is in synchronization — exactly the paper's
    # framing ("avoid many synchronization and communication steps ...
    # rather than minimizing local execution times").  Report both.
    speedup = (t_fdm - t_gfm) / t_fdm * 100
    row("gfm_local_compute", t_gfm, f"rounds={g.comm.rounds};bytes={g.comm.bytes_sent};frequent={len(g.frequent)}")
    row("fdm_local_compute", t_fdm, f"rounds={f.comm.rounds};bytes={f.comm.bytes_sent};remote_frac={f.remote_count_time / max(f.total_count_time, 1e-9):.3f}")

    # grid-modeled TOTAL (paper's §5.2.2 estimation + per-round sync):
    # each synchronization round pays the worst Table-2 link for its
    # payload plus a per-round barrier (submit/matchmaking latency).
    model = GridModel()

    def grid_total(t_compute, comm, rounds):
        stages = [[(t_compute / n_sites, 0, 0, s) for s in range(n_sites)]]
        est = estimate_stages(stages, model)
        for r in range(rounds):
            per_round = comm.per_round_bytes[r] if r < len(comm.per_round_bytes) else 0
            est += model.worst_transfer_s(per_round // max(n_sites, 1))
            est += model.submit_latency_s * n_sites  # barrier re-dispatch
        return est

    tot_gfm = grid_total(t_gfm, g.comm, g.comm.rounds)
    tot_fdm = grid_total(t_fdm + f.remote_count_time, f.comm, f.comm.rounds)
    gain = (tot_fdm - tot_gfm) / tot_fdm * 100
    row("gfm_grid_total", tot_gfm, f"2 sync rounds, Table 2 links")
    row("fdm_grid_total", tot_fdm, f"{f.comm.rounds} sync rounds + remote-support recount")
    row("gfm_vs_fdm_grid_gain", tot_fdm - tot_gfm, f"pct={gain:.1f};paper=25pct (grid totals; raw-compute delta={speedup:.1f}pct)")
    assert tot_gfm < tot_fdm, "GFM must win once synchronization is priced in"
    return g, f


if __name__ == "__main__":
    run()
