"""Version compatibility for the jax API surface this repo rides on.

The repo targets current jax (``jax.shard_map``, ``AbstractMesh(axis_sizes,
axis_names)``, dict-returning ``Compiled.cost_analysis``) but must also run
on the 0.4.x line baked into the CI/dev containers, where those entry
points live elsewhere or return different shapes.  Everything
version-sensitive is funnelled through here so the rest of the codebase
stays on the modern spelling.
"""

from __future__ import annotations

from typing import Any

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` (new) / ``jax.experimental.shard_map`` (0.4.x).

    The replication-checking kwarg was renamed check_rep -> check_vma; we
    accept the new name and translate.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )


def get_abstract_mesh():
    """``jax.sharding.get_abstract_mesh`` where it exists, else None.

    Callers treat None as "no abstract-mesh tracking" and fall back to the
    concrete context mesh (the pre-abstract-mesh behaviour).
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    return fn() if fn is not None else None


def abstract_mesh(axis_sizes: tuple[int, ...], axis_names: tuple[str, ...]):
    """``AbstractMesh(axis_sizes, axis_names)``; 0.4.x wants one tuple of
    (name, size) pairs."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def cost_analysis_dict(compiled) -> dict[str, Any]:
    """``Compiled.cost_analysis()`` as a flat dict.

    Old jaxlib returns a one-element list of dicts (one per computation);
    new jax returns the dict directly; either may be empty/None.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})
