"""Shared layer primitives: norms, RoPE, activations, param-spec helpers.

Parameters are described by ``ShapeAxes`` specs (shape + dtype + logical
axes) so the same definition serves (a) real initialisation for smoke
tests/examples and (b) ShapeDtypeStruct stand-ins for the multi-pod
dry-run.  Weights are stored fp32 (master copy); forward casts to the
config compute dtype.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.sharding import ShapeAxes


def spec(shape, axes, dtype="float32") -> ShapeAxes:
    return ShapeAxes(shape=tuple(shape), dtype=dtype, axes=tuple(axes))


def init_from_specs(key: jax.Array, specs, scale: float = 0.02):
    """Materialise a param pytree from ShapeAxes specs (normal init; norms
    get ones/zeros by convention of the trailing axis name)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, ShapeAxes))
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for k, s in zip(keys, leaves):
        if s.axes and s.axes[-1] == "norm_scale":
            out.append(jnp.ones(s.shape, s.dtype))
        elif s.axes and s.axes[-1] == "norm_bias":
            out.append(jnp.zeros(s.shape, s.dtype))
        else:
            fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
            std = min(scale, 1.0 / math.sqrt(max(fan_in, 1)))
            out.append(jax.random.normal(k, s.shape, jnp.float32).astype(s.dtype) * std)
    return jax.tree.unflatten(treedef, out)


def cast(x, dtype: str):
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(dt)


def norm_spec(cfg, d: int | None = None) -> dict:
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {
            "scale": spec((d,), ("norm_scale",)),
            "bias": spec((d,), ("norm_bias",)),
        }
    return {"scale": spec((d,), ("norm_scale",))}


def apply_norm(cfg, p: dict, x):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


# ---------------------------------------------------------------------------
# RoPE (with partial-rotary support for stablelm)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, pct: float, theta: float):
    rot = int(head_dim * pct) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x, positions, theta: float, pct: float = 1.0):
    """x: (..., S, H, Dh); positions: (..., S) int32."""
    dh = x.shape[-1]
    inv, rot = rope_frequencies(dh, pct, theta)
    if rot == 0:
        return x
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, rot/2)
    sin = jnp.sin(ang)[..., :, None, :]  # (..., S, 1, rot/2)
    cos = jnp.cos(ang)[..., :, None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2 :]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1.astype(x.dtype), out2.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# Activations / FFN
# ---------------------------------------------------------------------------


def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


GATED_ACTS = ("swiglu", "geglu")


def ffn_spec(cfg, d_ff: int | None = None) -> dict:
    d_ff = d_ff or cfg.d_ff
    d = cfg.d_model
    if cfg.act in GATED_ACTS:
        return {
            "w_gate": spec((d, d_ff), ("embed", "mlp")),
            "w_up": spec((d, d_ff), ("embed", "mlp")),
            "w_down": spec((d_ff, d), ("mlp", "embed")),
        }
    return {
        "w_up": spec((d, d_ff), ("embed", "mlp")),
        "w_down": spec((d_ff, d), ("mlp", "embed")),
    }


def apply_ffn(cfg, p: dict, x):
    from repro.sharding import constrain

    dt = x.dtype
    if cfg.act in GATED_ACTS:
        g = x @ p["w_gate"].astype(dt)
        u = x @ p["w_up"].astype(dt)
        act = jax.nn.silu if cfg.act == "swiglu" else jax.nn.gelu
        h = act(g) * u
    else:
        h = jax.nn.gelu(x @ p["w_up"].astype(dt))
    h = constrain(h, ("batch", "seq", "mlp"))
    return h @ p["w_down"].astype(dt)
