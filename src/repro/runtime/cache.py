"""Result cache for the continuous mining service — repeated queries on
unchanged data are free.

The serving layer's cache contract is VERSIONED: every completed mining
result is keyed by ``(dataset_version, app, params)``, where
``dataset_version`` is bumped by every append to the dataset.  A repeat
query against unchanged data hits; ANY data change produces a new
version and therefore a guaranteed miss — the cache can never serve a
stale result across an append, by key construction rather than by
invalidation bookkeeping (there is nothing to forget to invalidate).

``params`` is canonicalized (``params_key``) so dict ordering and
list/tuple spelling differences cannot split logically-identical
requests across cache entries — the same canonical key is what the
service uses to COALESCE concurrent identical requests into one
execution before the cache is even consulted.

Hit/miss/eviction accounting is first-class (``CacheStats``): the
service ledgers it per run and the service-level CI smoke gates on it.
"""

from __future__ import annotations

import math
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any


def params_key(params: dict | None) -> tuple:
    """Canonical, hashable form of a request's params dict: keys sorted,
    unhashable containers (lists/dicts/sets) converted to deterministic
    tuples.  Logically identical params map to the same key regardless
    of spelling — the coalescing and cache-keying contract.

    Total over JSON-ish values, including non-finite floats: ``inf`` and
    ``-inf`` pass through (they compare equal to themselves), and every
    ``nan`` canonicalizes to the one ``math.nan`` object (``nan != nan``
    would otherwise split logically-identical params into distinct
    keys).  The service rejects non-finite params at submit; totality
    here is the backstop that keeps a malformed key from ever crashing
    the dispatch loop."""
    return _canon(params or {})


def _canon(v: Any) -> Any:
    if isinstance(v, dict):
        return tuple((str(k), _canon(v[k])) for k in sorted(v, key=str))
    if isinstance(v, (list, tuple)):
        return tuple(_canon(x) for x in v)
    if isinstance(v, (set, frozenset)):
        return tuple(sorted((_canon(x) for x in v), key=repr))
    if isinstance(v, float):
        # int(v) raises on inf/nan (OverflowError / ValueError), so the
        # integral-float normalization must only see finite values
        if math.isnan(v):
            return math.nan  # the ONE nan object — identity makes keys equal
        if math.isinf(v):
            return v
        if v == int(v):
            # 0.1*3 style floats stay floats; clean integral floats
            # normalize so params={"k": 3.0} and {"k": 3} share an entry
            return int(v)
    return v


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    puts: int = 0

    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


class ResultCache:
    """LRU cache of completed mining results keyed by
    ``(dataset_name, dataset_version, app, params_key)``.

    ``capacity`` bounds the entry count (None = unbounded); eviction is
    least-recently-USED (a hit refreshes recency), so the hot repeated
    queries the serving layer exists for stay resident while one-off
    historical-version results age out first.
    """

    def __init__(self, capacity: int | None = 256):
        if capacity is not None and capacity < 1:
            raise ValueError(f"capacity must be >= 1 or None, got {capacity}")
        self.capacity = capacity
        self.stats = CacheStats()
        self._entries: OrderedDict[tuple, Any] = OrderedDict()

    @staticmethod
    def key(dataset: str, version: int, app: str, params: dict | None) -> tuple:
        return (str(dataset), int(version), str(app), params_key(params))

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: tuple) -> bool:
        return key in self._entries  # no stats side effect

    def get(self, key: tuple) -> Any | None:
        """The cached result, refreshed to most-recent, or None (ledgered
        as a miss — only call when actually attempting to serve)."""
        if key in self._entries:
            self.stats.hits += 1
            self._entries.move_to_end(key)
            return self._entries[key]
        self.stats.misses += 1
        return None

    def put(self, key: tuple, value: Any) -> None:
        self._entries[key] = value
        self._entries.move_to_end(key)
        self.stats.puts += 1
        if self.capacity is not None:
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.stats.evictions += 1
