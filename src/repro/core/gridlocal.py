"""GridLocal (paper technique → training) — single-host simulation.

The multi-pod implementation lives in ``repro.train.steps`` (vmap over the
`pod` axis + one cross-pod merge every H steps).  This module provides the
mesh-free simulation used by tests and examples: S sites train local
replicas independently and periodically merge by the paper's
size-weighted sufficient-statistics aggregation.  It also provides the
communication ledger comparing GridLocal against synchronous DP — the
quantity the paper optimises.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.outer import OuterConfig, outer_init, outer_update


@dataclass
class GridLocalReport:
    losses: list  # per outer round, mean across sites
    sync_bytes: int  # bytes exchanged by GridLocal (merges only)
    dp_bytes: int  # bytes synchronous DP would have exchanged (per-step)
    n_merges: int


def param_bytes(params) -> int:
    return sum(leaf.size * leaf.dtype.itemsize for leaf in jax.tree.leaves(params))


def simulate(
    loss_fn,  # loss_fn(params, batch) -> scalar
    params0,
    batches,  # (n_steps, n_sites, ...) pytree — per-site per-step batches
    n_sites: int,
    opt_cfg: AdamWConfig = AdamWConfig(warmup=0, decay_steps=10**9),
    outer_cfg: OuterConfig = OuterConfig(),
) -> tuple[object, GridLocalReport]:
    """Run GridLocal training; returns (final merged params, report)."""
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    update = jax.jit(lambda g, s, p: adamw_update(opt_cfg, g, s, p))

    site_params = [params0 for _ in range(n_sites)]
    site_opt = [adamw_init(params0) for _ in range(n_sites)]
    outer = outer_init(params0)
    pbytes = param_bytes(params0)

    n_steps = jax.tree.leaves(batches)[0].shape[0]
    losses = []
    n_merges = 0
    step_losses = []
    for step in range(n_steps):
        cur = []
        for s in range(n_sites):
            batch = jax.tree.map(lambda x: x[step, s], batches)
            loss, grads = grad_fn(site_params[s], batch)
            site_params[s], site_opt[s], _ = update(grads, site_opt[s], site_params[s])
            cur.append(float(loss))
        step_losses.append(sum(cur) / n_sites)

        if (step + 1) % outer_cfg.h_steps == 0:
            # the single synchronization: size-weighted merge (uniform sizes)
            merged = jax.tree.map(
                lambda *xs: sum(x.astype(jnp.float32) for x in xs) / n_sites, *site_params
            )
            new_p, outer = outer_update(outer_cfg, outer, merged)
            site_params = [new_p for _ in range(n_sites)]
            n_merges += 1
            losses.append(step_losses[-1])

    final = site_params[0]
    report = GridLocalReport(
        losses=losses,
        sync_bytes=n_merges * n_sites * pbytes,
        dp_bytes=n_steps * n_sites * pbytes,
        n_merges=n_merges,
    )
    return final, report
