"""DAGMan-analog workflow engine with a simulated grid clock.

Executes a DAG of Python jobs while modelling the grid behaviours the
paper measures:
  * workflow preparation latency (the paper's 295 s DAGMan observation)
    and per-job submit/matchmaking latency — optionally OVERLAPPED with
    running computation (`overlap_prep=True`), the optimisation the paper
    suggests ("partly overlapped by computations in the DAG");
  * data staging times from the Table 2 link matrix;
  * fault injection with DAGMan-style retries;
  * rescue files: a crashed run resumes from the last completed frontier
    (``rescue_path``), re-executing only unfinished jobs;
  * straggler mitigation: jobs whose simulated runtime exceeds
    ``straggler_factor`` x the stage median are duplicated and the fastest
    copy wins (speculative execution).

The COMPUTE time of each job is measured for real (wall clock of fn());
everything grid-related advances the simulated clock, so experiments are
deterministic and reproducible — the property Grid'5000 was built to
approximate and the paper laments ordinary grids lack.
"""

from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from pathlib import Path

from repro.workflow.dag import DAG, Job, TimedResult
from repro.workflow.faults import FaultInjector
from repro.workflow.overhead import GridModel


@dataclass
class RunReport:
    wall_s: float = 0.0  # simulated grid wall-clock
    compute_s: float = 0.0  # Σ measured job compute
    max_stage_compute_s: float = 0.0
    prep_s: float = 0.0
    submit_s: float = 0.0
    transfer_s: float = 0.0
    retries: int = 0
    speculative: int = 0
    job_times: dict = field(default_factory=dict)

    def overhead_pct(self) -> float:
        if self.wall_s <= 0:
            return 0.0
        return 100.0 * (self.wall_s - self.max_stage_compute_s) / self.wall_s


class Engine:
    def __init__(
        self,
        model: GridModel | None = None,
        faults: FaultInjector | None = None,
        rescue_path: str | Path | None = None,
        overlap_prep: bool = False,
        straggler_factor: float = 0.0,  # 0 = no speculation
    ):
        self.model = model or GridModel()
        self.faults = faults or FaultInjector()
        self.rescue_path = Path(rescue_path) if rescue_path else None
        self.overlap_prep = overlap_prep
        self.straggler_factor = straggler_factor

    # -- rescue bookkeeping --------------------------------------------------

    def _load_rescue(self, dag: DAG) -> set[str]:
        if self.rescue_path and self.rescue_path.exists():
            return set(json.loads(self.rescue_path.read_text()))
        return set()

    def _save_rescue(self, done: set[str]) -> None:
        if self.rescue_path:
            self.rescue_path.parent.mkdir(parents=True, exist_ok=True)
            self.rescue_path.write_text(json.dumps(sorted(done)))

    # -- execution ------------------------------------------------------------

    def run_site_jobs(self, site_jobs, name: str = "site-jobs") -> tuple[RunReport, dict]:
        """Execute a list of ``workflow.sitejob.SiteJob`` through the grid
        model — the one scheduler shared by clustering and itemset mining.
        Returns (report, results-by-job-name)."""
        from repro.workflow.sitejob import build_dag

        results: dict = {}
        rep = self.run(build_dag(site_jobs, name), results=results)
        return rep, results

    def run(self, dag: DAG, results: dict | None = None) -> RunReport:
        dag.validate_acyclic()
        rep = RunReport()
        results = results if results is not None else {}
        clock = 0.0

        # workflow preparation (the 295 s DAGMan latency).  With
        # overlap_prep the first stage's submission pipeline hides all but
        # a fixed connection setup.
        prep = self.model.prep_latency_s
        if self.overlap_prep:
            prep = min(prep, 10.0)
        clock += prep
        rep.prep_s = prep

        done = self._load_rescue(dag)
        for name in done:
            if name in dag.jobs:
                dag.jobs[name].status = "done"

        while not dag.done():
            stage = dag.ready()
            if not stage:
                failed = dag.failed()
                raise RuntimeError(f"workflow stuck; failed jobs: {[j.name for j in failed]}")

            stage_times: list[float] = []
            # submit latency: serial per job unless overlapped
            submit = self.model.submit_latency_s * len(stage)
            if self.overlap_prep:
                submit = self.model.submit_latency_s
            clock += submit
            rep.submit_s += submit

            for job in stage:
                t_job, attempts = self._run_job(job, results, rep)
                rep.retries += attempts - 1
                stage_times.append(t_job)

            # straggler speculation: duplicate the slowest job(s) if they
            # exceed factor x median — the duplicate "runs elsewhere" and
            # wins with the stage-median time.
            eff_times = list(stage_times)
            if self.straggler_factor and len(stage_times) >= 3:
                med = sorted(stage_times)[len(stage_times) // 2]
                for i, t in enumerate(eff_times):
                    if t > self.straggler_factor * med:
                        eff_times[i] = med  # speculative copy wins
                        rep.speculative += 1

            stage_wall = max(eff_times) if eff_times else 0.0
            rep.max_stage_compute_s += max(eff_times) if eff_times else 0.0
            clock += stage_wall

            done.update(j.name for j in stage if j.status == "done")
            self._save_rescue(done)

        rep.wall_s = clock
        return rep

    def _run_job(self, job: Job, results: dict, rep: RunReport) -> tuple[float, int]:
        """Execute one job (with retries); returns (simulated job time,
        attempts).  Simulated time = staging + measured compute."""
        transfer = self.model.transfer_s(0, job.site, job.input_bytes) + self.model.transfer_s(
            job.site, 0, job.output_bytes
        )
        rep.transfer_s += transfer
        attempts = 0
        while True:
            attempts += 1
            job.attempts = attempts
            job.status = "running"
            if self.faults.should_fail(job.name, attempts):
                if attempts > job.retries:
                    job.status = "failed"
                    raise RuntimeError(f"job {job.name} exhausted retries ({job.retries})")
                continue  # DAGMan retry
            t0 = time.perf_counter()
            args = [results[d] for d in job.deps]
            raw = job.fn(*args)
            if isinstance(raw, TimedResult):
                # the job measured its own device compute (SiteJob.timed);
                # the grid clock is calibrated by real kernels, not by our
                # host-side bracket around fn()
                job.result = raw.value
                dt = raw.compute_s + job.sim_compute_s
            else:
                job.result = raw
                dt = time.perf_counter() - t0 + job.sim_compute_s
            results[job.name] = job.result
            job.status = "done"
            rep.compute_s += dt
            rep.job_times[job.name] = dt
            return transfer + dt, attempts
