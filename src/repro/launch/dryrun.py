import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (the two lines above MUST precede every other import — jax locks the
#  device count at first initialisation)

"""Multi-pod dry-run: ``.lower().compile()`` every (architecture x input
shape) cell on the production meshes and persist cost/memory/collective
artifacts for the roofline analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all   # every cell, subprocess-isolated

Outputs land in experiments/dryrun/<arch>__<shape>__<mesh>[__<rules>].json.
"""

import argparse
import json
import sys
import time
from pathlib import Path

import jax

import repro.configs as configs
from repro.compat import cost_analysis_dict
from repro.configs.shapes import SHAPES, cell_is_supported, input_specs, skip_reason
from repro.launch.mesh import HW, make_production_mesh
from repro.models import transformer as T
from repro.roofline.analyze import roofline_terms
from repro.roofline.hlo_costs import analyze_hlo
from repro.sharding import (
    BASELINE,
    GRIDLOCAL,
    Rules,
    ShapeAxes,
    activate,
    specs_to_shardings,
    specs_to_structs,
)
from repro.train import steps as steps_mod

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

def _IS_SA(x):
    return isinstance(x, ShapeAxes)


def _as_dtype(tree, dtype: str):
    return jax.tree.map(
        lambda s: ShapeAxes(shape=s.shape, dtype=dtype if s.dtype.startswith("float") or s.dtype.startswith("bf") else s.dtype, axes=s.axes),
        tree,
        is_leaf=_IS_SA,
    )


def get_rules(name: str) -> Rules:
    from repro import sharding as sh

    table = {"baseline": BASELINE, "gridlocal": GRIDLOCAL}
    if name in table:
        return table[name]
    # experiment rules registered by the perf loop
    from repro.roofline import rule_variants

    return rule_variants.get(name)


def build_lowered(
    arch: str, shape_name: str, multi_pod: bool, rules_name: str, gridlocal: bool,
    grad_accum: int = 1, mesh_variant: str = "", cfg_overrides: dict | None = None,
):
    cfg = configs.get(arch)
    if cfg_overrides:
        cfg = cfg.scaled(**cfg_overrides)
    sh = SHAPES[shape_name]
    if mesh_variant:
        from repro.launch.mesh import make_variant_mesh

        mesh = make_variant_mesh(mesh_variant, multi_pod=multi_pod)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    if gridlocal and rules_name == "baseline":
        rules_name = "gridlocal"  # batch must NOT shard over pod; grid axis does
    rules = get_rules(rules_name)
    batch_specs = input_specs(cfg, shape_name)

    with activate(mesh, rules):
        if sh.kind == "train" and gridlocal:
            assert multi_pod, "GridLocal needs the pod axis"
            n_pods = mesh.shape["pod"]
            state_specs = steps_mod.train_state_specs(cfg, n_pods=n_pods)
            fn = steps_mod.make_gridlocal_train_step(cfg, mesh, grad_accum=grad_accum)
            st_sh = specs_to_shardings(state_specs, GRIDLOCAL, mesh)
            b_sh = specs_to_shardings(batch_specs, rules, mesh)
            jfn = jax.jit(fn, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None), donate_argnums=0)
            args = (specs_to_structs(state_specs, GRIDLOCAL, mesh), specs_to_structs(batch_specs, rules, mesh))
            lowered = jfn.lower(*args)
        elif sh.kind == "train":
            state_specs = steps_mod.train_state_specs(cfg)
            fn = steps_mod.make_train_step(cfg, grad_accum=grad_accum)
            st_sh = specs_to_shardings(state_specs, rules, mesh)
            b_sh = specs_to_shardings(batch_specs, rules, mesh)
            jfn = jax.jit(fn, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None), donate_argnums=0)
            lowered = jfn.lower(
                specs_to_structs(state_specs, rules, mesh), specs_to_structs(batch_specs, rules, mesh)
            )
        else:
            param_specs = _as_dtype(T.param_specs(cfg), cfg.dtype)  # bf16 serving weights
            cache_specs = T.cache_specs(cfg, sh.global_batch, sh.seq_len)
            p_sh = specs_to_shardings(param_specs, rules, mesh)
            c_sh = specs_to_shardings(cache_specs, rules, mesh)
            b_sh = specs_to_shardings(batch_specs, rules, mesh)
            if sh.kind == "prefill":
                fn = steps_mod.make_prefill_step(cfg)
            else:
                fn = steps_mod.make_decode_step(cfg)
            jfn = jax.jit(fn, in_shardings=(p_sh, b_sh, c_sh), out_shardings=(None, c_sh), donate_argnums=2)
            lowered = jfn.lower(
                specs_to_structs(param_specs, rules, mesh),
                specs_to_structs(batch_specs, rules, mesh),
                specs_to_structs(cache_specs, rules, mesh),
            )
    return cfg, sh, mesh, lowered


HBM_BUDGET = 16e9  # v5e per-chip HBM


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    rules_name: str = "baseline",
    gridlocal: bool = False,
    save: bool = True,
    grad_accum: int = 0,  # 0 = auto: double until the step fits HBM (<=8)
    mesh_variant: str = "",
    cfg_overrides: dict | None = None,
) -> dict:
    cfg = configs.get(arch)
    if not cell_is_supported(cfg, shape_name):
        rec = {
            "arch": arch, "shape": shape_name, "mesh": "2x16x16" if multi_pod else "16x16",
            "rules": rules_name, "status": "SKIP", "reason": skip_reason(cfg, shape_name),
        }
        if save:
            _save(rec, arch, shape_name, multi_pod, rules_name, gridlocal)
        return rec

    auto = grad_accum == 0
    accum = max(grad_accum, 1)
    while True:
        rec = _run_cell_once(arch, shape_name, multi_pod, rules_name, gridlocal, accum, mesh_variant, cfg_overrides)
        peak = rec["memory"]["peak_est_bytes"]
        if (
            auto
            and rec["kind"] == "train"
            and peak > HBM_BUDGET
            and accum < 8
        ):
            print(f"[dryrun] peak {peak/1e9:.1f} GB > HBM; retrying with grad_accum={accum*2}")
            accum *= 2
            continue
        break
    if save:
        _save(rec, arch, shape_name, multi_pod, rules_name, gridlocal)
    return rec


def _run_cell_once(arch, shape_name, multi_pod, rules_name, gridlocal, grad_accum, mesh_variant="", cfg_overrides=None) -> dict:
    sh = SHAPES[shape_name]
    t0 = time.time()
    cfg, sh, mesh, lowered = build_lowered(arch, shape_name, multi_pod, rules_name, gridlocal, grad_accum, mesh_variant, cfg_overrides)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = cost_analysis_dict(compiled)
    t0 = time.time()
    hlo = compiled.as_text()
    costs = analyze_hlo(hlo, chips_per_pod=256)  # trip-count-aware per-device costs
    t_analyze = time.time() - t0

    chips = 512 if multi_pod else 256
    n_params = T.param_count(cfg)
    n_active = T.active_param_count(cfg)
    if sh.kind == "train":
        tokens = sh.global_batch * sh.seq_len
        model_flops = 6 * n_active * tokens
    elif sh.kind == "prefill":
        tokens = sh.global_batch * sh.seq_len
        model_flops = 2 * n_active * tokens
    else:
        tokens = sh.global_batch
        model_flops = 2 * n_active * tokens

    flops = costs.flops  # per-device, while-loops multiplied by trip count
    byts = costs.traffic_bytes
    terms = roofline_terms(flops, byts, costs.coll_bytes_total, chips, HW, per_device=True)

    def _m(attr):
        return int(getattr(mem, attr, 0) or 0)

    rec = {
        "arch": arch,
        "shape": shape_name,
        "kind": sh.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "rules": rules_name,
        "gridlocal": gridlocal,
        "status": "OK",
        "n_params": n_params,
        "n_active_params": n_active,
        "tokens_per_step": tokens,
        "model_flops": model_flops,
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": byts,
        "model_vs_hlo_flops": model_flops / max(flops * chips, 1e-30),
        "collectives": costs.as_dict(),
        "cost_analysis_raw": {  # XLA's own numbers (while bodies counted ONCE)
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "memory": {
            "argument_bytes": _m("argument_size_in_bytes"),
            "output_bytes": _m("output_size_in_bytes"),
            "temp_bytes": _m("temp_size_in_bytes"),
            "alias_bytes": _m("alias_size_in_bytes"),
            "generated_code_bytes": _m("generated_code_size_in_bytes"),
            "peak_est_bytes": _m("argument_size_in_bytes") + _m("output_size_in_bytes") + _m("temp_size_in_bytes") - _m("alias_size_in_bytes"),
        },
        "roofline": terms,
        "grad_accum": grad_accum,
        "timing": {"lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2), "analyze_s": round(t_analyze, 2)},
    }
    return rec


def _save(rec, arch, shape_name, multi_pod, rules_name, gridlocal):
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}__{shape_name}__{mesh_tag}"
    if rules_name != "baseline":
        tag += f"__{rules_name}"
    if gridlocal:
        tag += "__gridlocal"
    path = OUT_DIR / f"{tag}.json"
    path.write_text(json.dumps(rec, indent=2))
    print(f"[dryrun] wrote {path}")


def _summ(rec: dict) -> str:
    if rec.get("status") == "SKIP":
        return f"SKIP ({rec['reason'][:60]}...)"
    r = rec["roofline"]
    return (
        f"OK flops/dev={rec['hlo_flops_per_device']:.3e} bytes/dev={rec['hlo_bytes_per_device']:.3e} "
        f"coll={rec['collectives']['total_bytes']:.3e} dom={r['dominant']} "
        f"frac={r['roofline_fraction']:.3f} compile={rec['timing']['compile_s']}s"
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--rules", default="baseline")
    ap.add_argument("--gridlocal", action="store_true")
    ap.add_argument("--all", action="store_true", help="run every cell in subprocesses")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=0, help="0 = auto-fit HBM")
    args = ap.parse_args(argv)

    if args.all:
        import subprocess

        cells = [(a, s, mp) for a in configs.ARCHS for s in SHAPES for mp in (False, True)]
        failures = []
        for a, s, mp in cells:
            mesh_tag = "2x16x16" if mp else "16x16"
            out = OUT_DIR / f"{a}__{s}__{mesh_tag}.json"
            if args.skip_existing and out.exists():
                print(f"[dryrun] skip existing {out.name}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", a, "--shape", s]
            if mp:
                cmd.append("--multi-pod")
            print("[dryrun] >>>", " ".join(cmd), flush=True)
            r = subprocess.run(cmd, env={**os.environ})
            if r.returncode != 0:
                failures.append((a, s, mp))
        if failures:
            print("[dryrun] FAILURES:", failures)
            sys.exit(1)
        print("[dryrun] all cells OK")
        return

    assert args.arch and args.shape, "--arch/--shape required (or --all)"
    rec = run_cell(args.arch, args.shape, args.multi_pod, args.rules, args.gridlocal, grad_accum=args.grad_accum)
    print(f"[dryrun] {args.arch} x {args.shape} ({rec['mesh']}): {_summ(rec)}")
    if rec.get("status") == "OK":
        print(json.dumps(rec["roofline"], indent=2))
        print(json.dumps(rec["memory"], indent=2))


if __name__ == "__main__":
    main()
