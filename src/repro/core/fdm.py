"""FDM baseline — Fast Distributed Mining of association rules (Cheung et
al., PDIS'96), the comparison algorithm the paper implements.

Level-synchronous protocol: at every level l = 1..k
  1. every site generates candidates from the GLOBALLY frequent (l-1)-sets
     (global pruning — the thing GFM deliberately drops),
  2. counts them locally; locally frequent candidates are announced,
  3. remote support counts are computed on request for candidates announced
     by OTHER sites (FDM's "remote support computation" — the paper
     measures it at ~13% of FDM's total compute time),
  4. a synchronization produces the globally frequent l-sets.

⇒ k communication/synchronization rounds (the paper's "4 instead of 2"),
each a barrier.  Counting uses the same backend as GFM so the comparison
isolates the PROTOCOL difference, exactly as in the paper.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.core.apriori import (
    Itemset,
    TransactionDB,
    apriori_join,
    count_supports,
    fused_count_sites,
    item_supports,
)
from repro.core.gfm import CommLog, _itemset_bytes


@dataclass
class FDMResult:
    frequent: dict[Itemset, int]
    comm: CommLog
    remote_count_time: float  # seconds spent serving remote support requests
    total_count_time: float  # seconds in all support counting
    per_level_candidates: list[int]


def site_candidates(
    level: int, db: TransactionDB, prev_global: list[Itemset], prev_local_i: set[Itemset]
) -> list[Itemset]:
    """FDM per-site candidate generation: GL(l-1) restricted to the sets
    ALSO locally frequent at this site (local pruning), prefix-joined.
    Level 1 seeds with every singleton."""
    if level == 1:
        return [(i,) for i in range(db.n_items)]
    return apriori_join([its for its in prev_global if its in prev_local_i])


def fdm_mine(
    sites: list[TransactionDB],
    k: int,
    minsup: float,
    backend: str = "jnp",
) -> FDMResult:
    s = len(sites)
    n_total = sum(db.n_tx for db in sites)
    g_min = int(np.ceil(minsup * n_total))
    comm = CommLog()
    frequent: dict[Itemset, int] = {}
    per_level: list[int] = []
    remote_t = 0.0
    total_t = 0.0

    l_min = [int(np.ceil(minsup * db.n_tx)) for db in sites]
    prev_global: list[Itemset] = []
    prev_local: list[set[Itemset]] = [set() for _ in sites]
    for level in range(1, k + 1):
        # -- per-site candidate generation: FDM joins GL(l-1) restricted to
        #    the sets ALSO locally frequent at this site (its local pruning;
        #    this is what shrinks per-site candidate sets vs plain Apriori
        #    but forces remote support requests later) --
        cands_by: list[list[Itemset]] = [
            site_candidates(level, sites[i], prev_global, prev_local[i]) for i in range(s)
        ]
        union_cands = sorted(set().union(*map(set, cands_by)), key=lambda t: (len(t), t))
        per_level.append(len(union_cands))
        if not union_cands:
            break

        # -- local counting + per-site announcement of locally frequents --
        local_counts: list[dict[Itemset, int]] = []
        announced_by: list[set[Itemset]] = []
        payload = 0
        for i, db in enumerate(sites):
            t0 = time.perf_counter()
            if level == 1:
                sup = item_supports(db)
            else:
                sup = count_supports(db, cands_by[i], backend=backend)
            total_t += time.perf_counter() - t0
            if level == 1 or cands_by[i]:
                comm.count_calls += 1  # only real device invocations
            cnt = {its: int(c) for its, c in zip(cands_by[i], np.asarray(sup))}
            local_counts.append(cnt)
            ann = {its for its in cands_by[i] if cnt[its] >= l_min[i]}
            announced_by.append(ann)
            payload += len(ann)

        announced = sorted(set().union(*announced_by), key=lambda t: (len(t), t))

        # -- remote support computation: each site serves requests for
        #    announced candidates it did NOT count locally (its pruning
        #    dropped them).  This is real extra compute — the step the paper
        #    measures at ~13% of FDM's total compute time. --
        for i, db in enumerate(sites):
            remote = [its for its in announced if its not in local_counts[i]]
            if remote:
                t0 = time.perf_counter()
                sup = count_supports(db, remote, backend=backend)
                dt = time.perf_counter() - t0
                remote_t += dt
                total_t += dt
                comm.count_calls += 1
                for its, c in zip(remote, np.asarray(sup)):
                    local_counts[i][its] = int(c)
            payload += len(remote)

        comm.add_round(payload, _itemset_bytes(level), s)

        # -- global decision --
        glob = []
        for its in announced:
            c = sum(lc[its] for lc in local_counts)
            if c >= g_min:
                glob.append((its, c))
        prev_global = [its for its, _ in glob]
        prev_local = [
            {its for its in prev_global if local_counts[i].get(its, 0) >= l_min[i]}
            for i in range(s)
        ]
        frequent.update(dict(glob))
        if not prev_global:
            break

    return FDMResult(
        frequent=frequent,
        comm=comm,
        remote_count_time=remote_t,
        total_count_time=total_t,
        per_level_candidates=per_level,
    )


# ---------------------------------------------------------------------------
# SiteJob decomposition (level-synchronous FDM through the one scheduler)
# ---------------------------------------------------------------------------


def fdm_site_jobs(
    sites: list[TransactionDB],
    k: int,
    minsup: float,
    backend: str = "jnp",
    measured: dict | None = None,
) -> list:
    """Decompose FDM into ``workflow.sitejob.SiteJob``s: per level l,
    ``count_l_i`` (local counting) -> ``announce_l`` (locally-frequent
    exchange) -> ``remote_l_i`` (remote support computation) ->
    ``decide_l`` (global synchronization, one ledgered round).  All k
    levels are laid out statically; levels past exhaustion no-op.  The
    terminal ``collect`` job's result is an ``FDMResult`` equal to
    ``fdm_mine``'s.  The per-site jobs are closure-pure (ledger flags and
    timings travel in their results; only the sync jobs touch the shared
    CommLog), so the DAG partitions cleanly over multihost site ownership.
    Run without fault injection (a retried sync job would ledger twice).
    Safe under both engine schedulers: each level's ledger mutations are
    ordered by the dependency chain (count -> announce -> remote ->
    decide), which ``schedule="async"`` preserves.

    The per-level fan-outs (``count_l_i``, ``remote_l_i``) carry
    ``batch_key``/``batched_fn`` hooks: under the ``batched`` execution
    backend each level's counting runs as ONE fused site-axis dispatch
    (``fused_count_sites``) — result- and ledger-identical to the
    per-site loop.
    """
    from repro.workflow.sitejob import SiteJob, timed, timed_batch

    s = len(sites)
    n_total = sum(db.n_tx for db in sites)
    g_min = int(np.ceil(minsup * n_total))
    l_min = [int(np.ceil(minsup * db.n_tx)) for db in sites]
    comm = CommLog()
    per_level: list[int] = []
    jobs: list[SiteJob] = []

    # The per-site jobs (count_l_i, remote_l_i) are CLOSURE-PURE: their
    # CommLog contribution ("counted" device-invocation flags) and their
    # measured counting time ("t") travel IN their results, and the sync
    # jobs (decide_l, collect) — which always co-locate with the shared
    # ledger under the multihost backend's site ownership — fold them into
    # ``comm`` and the FDMResult timings.  A closure mutation inside a
    # per-site job would be stranded on its owning process.

    def count_fn(level, i):
        db = sites[i]

        def fn(prev=None):
            if level > 1 and (prev is None or not prev["global"]):
                return None  # search exhausted at an earlier level
            prev_global = prev["global"] if prev else []
            prev_local_i = prev["local"][i] if prev else set()
            cands = site_candidates(level, db, prev_global, prev_local_i)
            t0 = time.perf_counter()
            sup = item_supports(db) if level == 1 else count_supports(db, cands, backend=backend)
            dt = time.perf_counter() - t0
            # counted: a real device invocation, as fdm_mine ledgers it
            counted = level == 1 or bool(cands)
            cnt = {its: int(c) for its, c in zip(cands, np.asarray(sup))}
            ann = {its for its in cands if cnt[its] >= l_min[i]}
            return {"cnt": cnt, "ann": ann, "t": dt, "counted": counted}

        return fn

    def count_batched(level):
        def fused(bargs, argss):
            # ``bargs`` carry ``(site, l_min_site)``: in a cross-request
            # merged wave (service fusion — same shapes, different minsup)
            # the FIRST member's closure executes the whole group, so each
            # member's request-specific local threshold must travel in its
            # batch arg, not the closure.  Exhaustion is per MEMBER: each
            # member's prev dep is its own request's decide, so requests
            # may exhaust at different levels (within one request all
            # members share one decide dep, which degenerates to the old
            # all-or-nothing early-out exactly).
            prevs = [args[0] if args else None for args in argss]
            live = [
                j for j in range(len(bargs))
                if level == 1 or (prevs[j] is not None and prevs[j]["global"])
            ]
            outs: list[dict | None] = [None] * len(bargs)
            if not live:
                return outs
            cands_by = [
                site_candidates(
                    level,
                    sites[bargs[j][0]],
                    prevs[j]["global"] if prevs[j] else [],
                    prevs[j]["local"][bargs[j][0]] if prevs[j] else set(),
                )
                for j in live
            ]
            t0 = time.perf_counter()
            if level == 1:
                sups = [item_supports(sites[bargs[j][0]]) for j in live]
            else:
                sups = fused_count_sites(
                    [sites[bargs[j][0]] for j in live], cands_by, backend=backend
                )
            share = (time.perf_counter() - t0) / max(len(live), 1)
            for j, cands, sup in zip(live, cands_by, sups):
                _i, lmin = bargs[j]
                cnt = {its: int(c) for its, c in zip(cands, np.asarray(sup))}
                outs[j] = {
                    "cnt": cnt,
                    "ann": {its for its in cands if cnt[its] >= lmin},
                    "t": share,
                    "counted": level == 1 or bool(cands),
                }
            return outs

        return fused

    def announce_fn(level):
        def fn(*outs):
            if any(o is None for o in outs):
                return None  # search exhausted (all-or-nothing per level)
            union_cands = set()
            announced = set()
            payload = 0
            for o in outs:
                union_cands.update(o["cnt"].keys())
                announced.update(o["ann"])
                payload += len(o["ann"])
            per_level.append(len(union_cands))
            if not union_cands:
                return None
            return {
                "announced": sorted(announced, key=lambda t: (len(t), t)),
                "payload": payload,
            }

        return fn

    def remote_fn(level, i):
        db = sites[i]

        def fn(cout, ann):
            if cout is None or ann is None:
                return None
            remote = [its for its in ann["announced"] if its not in cout["cnt"]]
            dt = 0.0
            if remote:
                t0 = time.perf_counter()
                sup = count_supports(db, remote, backend=backend)
                dt = time.perf_counter() - t0
                for its, c in zip(remote, np.asarray(sup)):
                    cout["cnt"][its] = int(c)
            # carry this site's count-phase ledger entries forward — the
            # downstream decide job folds them into the shared CommLog
            return {
                "cnt": cout["cnt"],
                "n_remote": len(remote),
                "count_t": cout["t"],
                "count_counted": cout["counted"],
                "remote_t": dt,
            }

        return fn

    def remote_batched(level):
        def fused(bargs, argss):
            # each member brings its own request's count + announce deps;
            # exhausted members (cross-request fusion: another request's
            # search may have ended earlier) pass through as None while
            # the live members share one fused dispatch
            live = [
                j for j in range(len(bargs))
                if argss[j][0] is not None and argss[j][1] is not None
            ]
            outs: list[dict | None] = [None] * len(bargs)
            if not live:
                return outs
            remote_by = [
                [its for its in argss[j][1]["announced"] if its not in argss[j][0]["cnt"]]
                for j in live
            ]
            t0 = time.perf_counter()
            sups = fused_count_sites([sites[bargs[j]] for j in live], remote_by, backend=backend)
            dt = time.perf_counter() - t0 if any(remote_by) else 0.0
            share = dt / max(sum(1 for r in remote_by if r), 1)
            for j, remote, sup in zip(live, remote_by, sups):
                cout = argss[j][0]
                if remote:
                    for its, c in zip(remote, np.asarray(sup)):
                        cout["cnt"][its] = int(c)
                outs[j] = {
                    "cnt": cout["cnt"],
                    "n_remote": len(remote),
                    "count_t": cout["t"],
                    "count_counted": cout["counted"],
                    "remote_t": share if remote else 0.0,
                }
            return outs

        return fused

    def decide_fn(level):
        def fn(ann, *remotes):
            if ann is None:
                return None
            # ann non-None implies every count (and hence remote) is live,
            # so remotes[i] is site i's counts — positional, no filtering.
            # The per-site device-invocation flags shipped with the remote
            # results are ledgered HERE (one +1 per real count call, as
            # fdm_mine counts them): counts first, then remote serves.
            comm.count_calls += sum(1 for r in remotes if r["count_counted"])
            comm.count_calls += sum(1 for r in remotes if r["n_remote"])
            comm.add_round(
                ann["payload"] + sum(r["n_remote"] for r in remotes), _itemset_bytes(level), s
            )
            glob = []
            for its in ann["announced"]:
                c = sum(r["cnt"].get(its, 0) for r in remotes)
                if c >= g_min:
                    glob.append((its, c))
            prev_global = [its for its, _ in glob]
            prev_local = [
                {its for its in prev_global if remotes[i]["cnt"].get(its, 0) >= l_min[i]}
                for i in range(s)
            ]
            return {
                "global": prev_global,
                "local": prev_local,
                "frequent": dict(glob),
                "count_t": sum(r["count_t"] for r in remotes),
                "remote_t": sum(r["remote_t"] for r in remotes),
            }

        return fn

    for level in range(1, k + 1):
        prev_dep = [f"decide_{level - 1}"] if level > 1 else []
        count_batched_fn = timed_batch(count_batched(level), measured)
        remote_batched_fn = timed_batch(remote_batched(level), measured)
        for i in range(s):
            jobs.append(
                SiteJob(
                    name=f"count_{level}_{i}",
                    fn=timed(count_fn(level, i), measured, f"count_{level}_{i}"),
                    deps=list(prev_dep),
                    site=i,  # GridModel.transfer_s normalizes to its link matrix
                    batch_key=f"count_{level}",
                    batched_fn=count_batched_fn,
                    batch_arg=(i, l_min[i]),
                )
            )
        jobs.append(
            SiteJob(
                name=f"announce_{level}",
                fn=timed(announce_fn(level), measured, f"announce_{level}"),
                deps=[f"count_{level}_{i}" for i in range(s)],
            )
        )
        for i in range(s):
            jobs.append(
                SiteJob(
                    name=f"remote_{level}_{i}",
                    fn=timed(remote_fn(level, i), measured, f"remote_{level}_{i}"),
                    deps=[f"count_{level}_{i}", f"announce_{level}"],
                    site=i,  # GridModel.transfer_s normalizes to its link matrix
                    batch_key=f"remote_{level}",
                    batched_fn=remote_batched_fn,
                    batch_arg=i,
                )
            )
        jobs.append(
            SiteJob(
                name=f"decide_{level}",
                fn=timed(decide_fn(level), measured, f"decide_{level}"),
                deps=[f"announce_{level}", *[f"remote_{level}_{i}" for i in range(s)]],
            )
        )

    def collect_fn(*decisions):
        frequent: dict[Itemset, int] = {}
        remote_t = 0.0
        total_t = 0.0
        for dec in decisions:
            if dec is not None:
                frequent.update(dec["frequent"])
                remote_t += dec["remote_t"]
                total_t += dec["count_t"] + dec["remote_t"]
        return FDMResult(
            frequent=frequent,
            comm=comm,
            remote_count_time=remote_t,
            total_count_time=total_t,
            per_level_candidates=per_level,
        )

    jobs.append(
        SiteJob(
            name="collect",
            fn=timed(collect_fn, measured, "collect"),
            deps=[f"decide_{level}" for level in range(1, k + 1)],
        )
    )
    return jobs
