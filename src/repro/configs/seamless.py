"""seamless-m4t-large-v2 [audio] — encoder-decoder text backbone; speech
frontend is a STUB (input_specs supplies precomputed frame embeddings)
[arXiv:2308.11596].  24 encoder + 24 decoder layers."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    n_layers=24,       # decoder
    n_enc_layers=24,   # encoder
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab=256_206,
    layer_pattern=("full",),
    norm="layernorm",
    act="gelu_mlp",
    frontend="frames",
    frontend_len=1024,
    tie_embeddings=False,
    subquadratic=False,
)
