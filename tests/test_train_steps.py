"""Train-step semantics: grad-accum equivalence, chunked-CE correctness,
GridLocal simulation (paper technique) behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, lr_at
from repro.optim.outer import OuterConfig
from repro.train.losses import chunked_softmax_ce
from repro.train.steps import make_train_step, materialize_state

CFG = ModelConfig(
    n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
    d_ff=64, vocab=64, dtype="float32", remat="none",
)


def batch_of(seed=0, b=4, s=16, vocab=64):
    rng = np.random.default_rng(seed)
    t = rng.integers(0, vocab, (b, s + 1), dtype=np.int32)
    return {"tokens": jnp.asarray(t[:, :-1]), "labels": jnp.asarray(t[:, 1:])}


class TestChunkedCE:
    @pytest.mark.parametrize("chunk", [4, 8, 16])
    def test_matches_direct_ce(self, chunk):
        params = T.init_params(CFG, jax.random.PRNGKey(0))
        batch = batch_of()
        hidden, _ = T.forward_train(CFG, params, batch["tokens"], return_hidden=True, chunk=16)
        ce, n = chunked_softmax_ce(CFG, params, hidden, batch["labels"], chunk=chunk)
        logits = T.logits_from(CFG, params, hidden)
        logp = jax.nn.log_softmax(logits, axis=-1)
        direct = -jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1).mean()
        np.testing.assert_allclose(float(ce), float(direct), rtol=1e-5)
        assert int(n) == batch["labels"].size

    def test_label_masking(self):
        params = T.init_params(CFG, jax.random.PRNGKey(0))
        batch = batch_of()
        labels = batch["labels"].at[:, :8].set(-1)
        hidden, _ = T.forward_train(CFG, params, batch["tokens"], return_hidden=True, chunk=16)
        _, n = chunked_softmax_ce(CFG, params, hidden, labels, chunk=8)
        assert int(n) == labels.size // 2


class TestGradAccum:
    def test_accum_equals_full_batch(self):
        """grad_accum=4 must produce the same update as accum=1 (mean-of-
        microbatch-grads == full-batch grad for mean losses over equal
        microbatches)."""
        state0 = materialize_state(CFG, jax.random.PRNGKey(1))
        batch = batch_of(b=8)
        opt = AdamWConfig(lr=1e-3, warmup=0, grad_clip=0.0)
        s1, m1 = jax.jit(make_train_step(CFG, opt, loss_chunk=16, grad_accum=1))(state0, batch)
        state0b = materialize_state(CFG, jax.random.PRNGKey(1))
        s4, m4 = jax.jit(make_train_step(CFG, opt, loss_chunk=16, grad_accum=4))(state0b, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
        for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s4["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-6)


class TestAdamW:
    def test_lr_schedule_warmup_then_decay(self):
        cfg = AdamWConfig(lr=1.0, warmup=10, decay_steps=100, min_lr_frac=0.1)
        assert float(lr_at(cfg, jnp.int32(5))) == pytest.approx(0.5)
        assert float(lr_at(cfg, jnp.int32(10))) == pytest.approx(1.0, rel=1e-3)
        assert float(lr_at(cfg, jnp.int32(100))) == pytest.approx(0.1, rel=1e-3)

    def test_grad_clip_bounds_update(self):
        params = {"w": jnp.zeros((4,))}
        st = adamw_init(params)
        huge = {"w": jnp.full((4,), 1e9)}
        cfg = AdamWConfig(lr=0.1, warmup=0, grad_clip=1.0, weight_decay=0.0)
        new_p, _, metrics = adamw_update(cfg, huge, st, params)
        assert float(metrics["grad_norm"]) > 1e8
        assert np.all(np.abs(np.asarray(new_p["w"])) < 1.0)

    def test_weight_decay_shrinks(self):
        params = {"w": jnp.ones((4,))}
        st = adamw_init(params)
        zero_g = {"w": jnp.zeros((4,))}
        cfg = AdamWConfig(lr=0.1, warmup=0, weight_decay=0.5, grad_clip=0.0)
        new_p, _, _ = adamw_update(cfg, zero_g, st, params)
        assert np.all(np.asarray(new_p["w"]) < 1.0)


class TestGridLocalSimulation:
    def test_technique_trains_and_cuts_comm(self):
        """The paper's minimal-sync training: loss must decrease AND the
        communication ledger must show the Hx reduction vs synchronous DP."""
        from repro.core.gridlocal import simulate

        rng = np.random.default_rng(0)
        w_true = rng.normal(size=(8, 1)).astype(np.float32)

        def loss_fn(params, batch):
            pred = batch["x"] @ params["w"]
            return jnp.mean((pred - batch["y"]) ** 2)

        n_steps, n_sites = 64, 4
        xs = rng.normal(size=(n_steps, n_sites, 64, 8)).astype(np.float32)
        ys = xs @ w_true + 0.01 * rng.normal(size=(n_steps, n_sites, 64, 1)).astype(np.float32)
        batches = {"x": jnp.asarray(xs), "y": jnp.asarray(ys)}
        params0 = {"w": jnp.zeros((8, 1))}

        # paper-faithful aggregation (plain size-weighted merge) recovers w
        outer = OuterConfig(h_steps=8, outer_lr=1.0, outer_momentum=0.0)
        final, rep = simulate(
            loss_fn, params0, batches, n_sites,
            opt_cfg=AdamWConfig(lr=5e-2, warmup=0, decay_steps=10**9, weight_decay=0.0),
            outer_cfg=outer,
        )
        assert rep.n_merges == 8
        assert rep.losses[-1] < rep.losses[0] * 0.5
        # the paper's point: comm divided by H
        assert rep.sync_bytes * outer.h_steps == rep.dp_bytes
        np.testing.assert_allclose(np.asarray(final["w"]), w_true, atol=0.1)

        # beyond-paper outer Nesterov (DiLoCo-style) also trains
        final2, rep2 = simulate(
            loss_fn, params0, batches, n_sites,
            opt_cfg=AdamWConfig(lr=5e-2, warmup=0, decay_steps=10**9, weight_decay=0.0),
            outer_cfg=OuterConfig(h_steps=8, outer_lr=0.7, outer_momentum=0.9),
        )
        assert rep2.losses[-1] < rep2.losses[0] * 0.5


class TestOuterCompression:
    def test_quantize_roundtrip_error_bounded(self):
        from repro.optim.outer import dequantize_delta, quantize_delta

        rng = np.random.default_rng(0)
        delta = jnp.asarray(rng.normal(0, 0.01, (64, 32)).astype(np.float32))
        q, scale = quantize_delta(delta)
        back = dequantize_delta(q.astype(jnp.float32), scale)
        err = float(jnp.max(jnp.abs(back - delta)))
        assert err <= float(scale) / 127.0 + 1e-9
        assert q.dtype == jnp.int8


class TestPipelineDeterminism:
    def test_stream_pure_in_seed_step(self):
        from repro.data.pipeline import TokenStream

        s1 = TokenStream(vocab=100, global_batch=4, seq_len=8, seed=3)
        s2 = TokenStream(vocab=100, global_batch=4, seq_len=8, seed=3)
        b1, b2 = s1.batch_at(7), s2.batch_at(7)
        for k in b1:
            np.testing.assert_array_equal(b1[k], b2[k])
        b3 = s1.batch_at(8)
        assert not np.array_equal(b1["tokens"], b3["tokens"])


class TestMoELocalDispatch:
    def test_local_equals_global_when_capacity_unbinding(self):
        """With unbinding capacity no token is ever dropped, so local
        (per-group top-C) and global dispatch are numerically identical;
        with binding capacity they may drop different tokens (expected)."""
        import dataclasses

        import repro.configs as C
        from repro.models import transformer as T
        from repro.models.config import reduced

        base = reduced(C.get("deepseek-moe-16b"))
        loose = dataclasses.replace(base.moe, capacity_factor=float(base.moe.n_experts))
        cfg0 = base.scaled(moe=loose)
        cfg1 = cfg0.scaled(moe_dispatch_groups=2)
        params = T.init_params(cfg0, jax.random.PRNGKey(0))
        rng = np.random.default_rng(1)
        toks = jnp.asarray(rng.integers(0, cfg0.vocab, (4, 32), dtype=np.int32))
        l0, _ = T.forward_train(cfg0, params, toks, chunk=16)
        l1, _ = T.forward_train(cfg1, params, toks, chunk=16)
        np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), rtol=2e-3, atol=2e-3)
