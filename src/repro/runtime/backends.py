"""Multi-host execution backend — the ``jax.distributed`` mesh scaffold.

ROADMAP follow-on (a): swap the single-process site mesh for a
multi-process one so the same SiteJob DAGs distribute for real.  This
module is the scaffold for that swap: :class:`MultiHostBackend` brings
up the distributed runtime (``launch.mesh.init_multihost``), builds the
global device mesh spanning every host (``make_multihost_mesh``), and
executes the workflow SPMD-redundantly — every process runs the same DAG
over the same inputs, which is the paper's "logical merge" redundancy
applied to the whole workflow: deterministic job callables make every
process's results identical without any cross-process result shipping,
while mesh collectives (all_gather under shard_map) already span hosts.

What this scaffold gives the next PR:
  * process bring-up + global mesh construction behind one object;
  * a CPU two-subprocess smoke path (gloo collectives) exercised in CI,
    so the multi-process plumbing cannot rot;
  * the ``ExecutionBackend.call`` seam where per-site jobs will be
    routed to their owning process (site % process_count) once results
    ship via ``process_allgather`` instead of running redundantly.

Single-process fallback: without a coordinator the backend degrades to
inline execution over the local devices — same results, no distributed
state touched — so ``Engine(backend="multihost")`` is safe everywhere.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np

from repro.launch.mesh import init_multihost, make_multihost_mesh
from repro.workflow.dag import Job
from repro.workflow.executor import ExecutionBackend


class MultiHostBackend(ExecutionBackend):
    """SPMD-redundant DAG execution over a ``jax.distributed`` mesh.

    Parameters mirror ``jax.distributed.initialize``; all-None (the
    default) means "join an already-initialized runtime, or run
    single-process" — the backend never guesses a coordinator.
    """

    name = "multihost"

    def __init__(
        self,
        coordinator_address: str | None = None,
        num_processes: int | None = None,
        process_id: int | None = None,
        axis: str = "sites",
    ):
        self.coordinator_address = coordinator_address
        self.num_processes = num_processes
        self.process_id = process_id
        self.axis = axis
        self._ready = False
        self.is_multiprocess = False
        self.mesh = None

    def _ensure(self) -> None:
        """Bring up the distributed runtime and the global mesh once."""
        if self._ready:
            return
        self.is_multiprocess = init_multihost(
            coordinator_address=self.coordinator_address,
            num_processes=self.num_processes,
            process_id=self.process_id,
        )
        self.mesh = make_multihost_mesh(axis=self.axis)
        self._ready = True

    def describe(self) -> dict:
        """Scaffold introspection (the smoke test's assertions): process
        topology and the global mesh this backend executes over."""
        self._ensure()
        return {
            "is_multiprocess": self.is_multiprocess,
            "process_index": jax.process_index(),
            "process_count": jax.process_count(),
            "n_global_devices": len(jax.devices()),
            "n_local_devices": len(jax.local_devices()),
            "mesh_shape": dict(self.mesh.shape) if self.mesh is not None else None,
            "axis": self.axis,
        }

    def allgather_check(self, value: float) -> np.ndarray:
        """Cross-process collective smoke: gather one scalar per process
        (identity on a single process).  This is the wire the next PR
        ships per-site results over."""
        self._ensure()
        arr = np.asarray([value], dtype=np.float32)
        if not self.is_multiprocess:
            return arr[None]
        from jax.experimental.multihost_utils import process_allgather

        return np.asarray(process_allgather(arr))

    def begin_run(self, dag, results) -> None:
        self._ensure()

    def call(self, job: Job, args: list) -> Any:
        # SPMD-redundant: every process executes every job over the
        # global mesh.  Deterministic callables => identical results on
        # every process (the paper's logical-merge property), so no
        # cross-process result staging is needed yet.
        return job.fn(*args)
