"""Paper §5.2.1 / Table 3 (V-Clustering row): variance-based distributed
clustering.

Paper setup: 5e7 samples over 200 processes, K-Means with 20 sub-clusters
per process, merge threshold 2x the largest sub-cluster variance; actual
compute ≈2% of the 1050 s grid wall time (the rest is middleware).  We
run a CPU-scaled instance, report the measured compute, the KB-scale
communication (the paper's key asymmetry) and the grid-modeled wall time
with the 295 s DAGMan prep latency -> the 98% overhead figure.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro.core.vclustering import VClusterConfig, vcluster_pooled
from repro.data.synthetic import gaussian_mixture, split_sites
from repro.workflow.overhead import GridModel, estimate_stages, overhead_pct


def run(n_points: int = 200_000, dim: int = 8, n_sites: int = 8, k_local: int = 20):
    pts, _ = gaussian_mixture(7, n_points, dim, n_components=12, spread=20.0, sigma=0.8)
    xs = split_sites(pts, n_sites, seed=1)
    cfg = VClusterConfig(k_local=k_local, kmeans_iters=20, border_candidates=8)

    fn = jax.jit(lambda key, x: vcluster_pooled(key, x, cfg))
    key = jax.random.PRNGKey(0)
    xj = jnp.asarray(xs)
    res = fn(key, xj)  # compile + run
    jax.block_until_ready(res.labels)

    t0 = time.perf_counter()
    res = fn(key, xj)
    jax.block_until_ready(res.labels)
    t_compute = time.perf_counter() - t0

    data_bytes = xs.size * 4
    comm = int(res.comm_bytes)
    row(
        "vcluster_compute",
        t_compute,
        f"n_global={int(res.merged.n_global)};comm_bytes={comm};data_bytes={data_bytes};ratio={data_bytes / comm:.0f}x",
    )

    # grid model: the paper's Table 3 structure — local clustering stage +
    # merge stage vs the full engine with DAGMan prep.
    model = GridModel()
    est = estimate_stages(
        [
            [(t_compute / n_sites, xs[0].nbytes, comm // n_sites, s) for s in range(n_sites)],
            [(0.01, comm, 0, 0)],
        ],
        model,
    )
    measured = model.prep_latency_s + model.submit_latency_s * (n_sites + 1) + est
    ovh = overhead_pct(measured, est)
    row("vcluster_grid_estimated", est, "analytical lower bound")
    row("vcluster_grid_measured", measured, f"overhead_pct={ovh:.1f};paper=98pct")
    return res


if __name__ == "__main__":
    run()
