"""Fault-tolerant sharded checkpointing (no orbax dependency).

Design for 1000+ nodes:
  * every host writes ONLY its local shards (`process_index` namespacing);
  * a manifest records the pytree structure, logical axes and step, so a
    restore may resize the mesh/sharding freely (elastic restart) — layout
    is re-derived from logical axes + the CURRENT rules, never stored;
  * atomic commit: writes go to  step_<n>.tmp/  and are renamed after the
    manifest fsync — a crash mid-write never corrupts the latest step;
  * async mode hands the (host-local) arrays to a writer thread, so the
    train loop overlaps checkpoint I/O with compute (the paper's job-prep
    overhead lesson: hide the slow path behind useful work);
  * retention keeps the newest K steps ("rescue" restarts use the newest
    complete one, matching DAGMan's rescue-DAG semantics).
"""

from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3, async_mode: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_mode = async_mode
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state, wait: bool = False) -> None:
        """Snapshot `state` (a pytree of jax/np arrays) at `step`."""
        self.check()  # surface async failures from previous saves
        # materialise to host memory synchronously (cheap; device->host)
        flat = [(k, np.asarray(v)) for k, v in _flatten_with_paths(state)]
        if self.async_mode:
            self.wait()
            self._thread = threading.Thread(target=self._write, args=(step, flat), daemon=True)
            self._thread.start()
            if wait:
                self.wait()
        else:
            self._write(step, flat)

    def _write(self, step: int, flat) -> None:
        try:
            proc = jax.process_index()
            tmp = self.dir / f"step_{step:010d}.tmp"
            final = self.dir / f"step_{step:010d}"
            shard_dir = tmp / f"proc_{proc:05d}"
            shard_dir.mkdir(parents=True, exist_ok=True)
            manifest = {"step": step, "time": time.time(), "keys": []}
            for key, arr in flat:
                fname = key.replace("/", "__") + ".npy"
                np.save(shard_dir / fname, arr)
                manifest["keys"].append({"key": key, "dtype": str(arr.dtype), "shape": list(arr.shape)})
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():  # same step re-saved: keep the committed one
                shutil.rmtree(tmp, ignore_errors=True)
            else:
                tmp.rename(final)  # atomic commit
            self._gc()
        except Exception as e:  # surfaced on next save()/check()
            self._error = e

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def check(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint write failed: {err}") from err

    # -- restore ------------------------------------------------------------

    def all_steps(self) -> list[int]:
        return sorted(
            int(p.name.split("_")[1]) for p in self.dir.glob("step_*") if not p.name.endswith(".tmp")
        )

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like, step: int | None = None):
        """Restore into the structure of `like` (a pytree of arrays or
        ShapeDtypeStructs).  Returns the restored pytree (numpy leaves —
        caller device_puts with its CURRENT shardings: elastic restart)."""
        self.wait()
        self.check()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        base = self.dir / f"step_{step:010d}"
        proc = jax.process_index()
        shard_dir = base / f"proc_{proc:05d}"
        flat_like = _flatten_with_paths(like)
        leaves = []
        for key, leaf in flat_like:
            fname = key.replace("/", "__") + ".npy"
            arr = np.load(shard_dir / fname)
            expect = tuple(leaf.shape)
            if tuple(arr.shape) != expect:
                raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs expected {expect}")
            leaves.append(arr)
        treedef = jax.tree.structure(like)
        return jax.tree.unflatten(treedef, leaves)
