"""GridRuntime: real site-local compute scheduled through the grid
workflow engine — pooled/shard_map equivalence, measured-time feedback
into the simulated clock, and the paper's 2-round GFM claim end-to-end."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.apriori import TransactionDB
from repro.core.fdm import fdm_mine
from repro.core.gfm import gfm_mine
from repro.core.vclustering import VClusterConfig, vcluster_pooled
from repro.data.synthetic import (
    gaussian_mixture,
    ibm_transactions,
    split_sites,
    split_transactions,
)
from repro.runtime import GridRuntime
from repro.workflow.engine import Engine
from repro.workflow.overhead import GridModel


def fast_engine():
    return Engine(model=GridModel(prep_latency_s=0, submit_latency_s=0))


def cluster_sites(n_sites=4, n=2000):
    pts, _ = gaussian_mixture(0, n, 2, 4, spread=12.0, sigma=0.5)
    return split_sites(pts, n_sites, seed=1)


def tx_sites(n_sites=4, n_tx=1000, n_items=30):
    dense = ibm_transactions(seed=2, n_tx=n_tx, n_items=n_items, avg_tx_len=6, n_patterns=8)
    return dense, [TransactionDB.from_dense(s) for s in split_transactions(dense, n_sites, seed=0)]


CFG = VClusterConfig(k_local=6, kmeans_iters=15, border_candidates=4)


class TestVClusteringRuntime:
    def test_matches_pooled_reference_driver(self):
        """The job-decomposed pipeline reproduces the one-process driver
        exactly (same per-site kmeans, same logical merge, same perturb)."""
        xs = cluster_sites()
        rt = GridRuntime(engine=fast_engine(), sync="pooled", use_kernel=False)
        run = rt.run_vclustering(jax.random.PRNGKey(0), xs, CFG)
        ref = vcluster_pooled(jax.random.PRNGKey(0), jnp.asarray(xs), CFG)
        assert int(run.result.merged.n_global) == int(ref.merged.n_global)
        assert np.array_equal(np.asarray(run.result.merged.labels), np.asarray(ref.merged.labels))
        assert np.array_equal(np.asarray(run.result.labels), np.asarray(ref.labels))

    def test_engine_clock_uses_measured_compute(self):
        """(b) The engine's reported compute_s is exactly the sum of the
        runtime's device-measured job times — the TimedResult feedback, not
        the engine's own host-side bracket."""
        xs = cluster_sites()
        rt = GridRuntime(engine=fast_engine(), sync="pooled", use_kernel=False)
        run = rt.run_vclustering(jax.random.PRNGKey(0), xs, CFG)
        jt = run.report.job_times
        assert set(jt) == set(run.measured)
        for name, t in run.measured.items():
            assert jt[name] == pytest.approx(t, abs=0), name  # bit-identical feedthrough
            assert t > 0.0
        assert run.report.compute_s == pytest.approx(sum(jt.values()), rel=1e-12)
        # the simulated grid wall includes the measured compute
        assert run.report.wall_s >= max(jt.values())

    def test_kernel_path_runs_through_engine(self):
        """Pallas assignment kernel (interpret mode on CPU) end-to-end."""
        xs = cluster_sites(n=800)
        rt = GridRuntime(engine=fast_engine(), sync="pooled", use_kernel=True)
        run = rt.run_vclustering(jax.random.PRNGKey(0), xs)
        assert int(run.result.merged.n_global) >= 1
        assert run.result.labels.shape == (4, 200)

    def test_shard_map_requires_mesh(self):
        xs = cluster_sites()
        rt = GridRuntime(engine=fast_engine(), sync="shard_map", use_kernel=False)
        if len(jax.devices()) >= 4:
            pytest.skip("host has enough devices; requirement satisfied")
        with pytest.raises(RuntimeError, match="shard_map sync requires"):
            rt.run_vclustering(jax.random.PRNGKey(0), xs, CFG)


RUNTIME_EQUIV = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "SRC")
import jax, numpy as np
from repro.core.vclustering import VClusterConfig
from repro.data.synthetic import gaussian_mixture, split_sites
from repro.runtime import GridRuntime
from repro.workflow.engine import Engine
from repro.workflow.overhead import GridModel

pts, _ = gaussian_mixture(0, 2000, 2, 4, spread=12.0, sigma=0.5)
xs = split_sites(pts, 4, seed=1)
cfg = VClusterConfig(k_local=6, kmeans_iters=15, border_candidates=4)
eng = lambda: Engine(model=GridModel(prep_latency_s=0, submit_latency_s=0))

pool = GridRuntime(engine=eng(), sync="pooled", use_kernel=False)
shard = GridRuntime(engine=eng(), sync="shard_map", use_kernel=False)
rp = pool.run_vclustering(jax.random.PRNGKey(0), xs, cfg)
rs = shard.run_vclustering(jax.random.PRNGKey(0), xs, cfg)
assert rs.sync_mode == "shard_map", rs.sync_mode
assert rp.sync_mode == "pooled", rp.sync_mode
# (a) identical merge labelings and point labels, bit for bit
assert np.array_equal(np.asarray(rp.result.merged.labels), np.asarray(rs.result.merged.labels))
assert np.array_equal(np.asarray(rp.result.labels), np.asarray(rs.result.labels))
assert int(rp.result.merged.n_global) == int(rs.result.merged.n_global)
print("RUNTIME_EQUIV_OK")
"""


class TestShardMapSync:
    def test_pooled_and_shard_map_agree_bit_for_bit(self):
        """(a) The distributed all_gather sync and the pooled fallback give
        identical merge labelings (4 host devices in a subprocess)."""
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        script = RUNTIME_EQUIV.replace("SRC", os.path.abspath(src))
        p = subprocess.run(
            [sys.executable, "-c", script], capture_output=True, text=True, timeout=600,
            env={**os.environ, "JAX_PLATFORMS": "cpu"},
        )
        assert "RUNTIME_EQUIV_OK" in p.stdout, p.stdout + p.stderr


class TestGFMRuntime:
    def test_two_rounds_under_uniform_thresholds(self):
        """(c) GFM through the runtime synchronizes exactly twice when
        local == global thresholds (the paper's 2-vs-k headline)."""
        _, sites = tx_sites()
        rt = GridRuntime(engine=fast_engine(), count_backend="jnp")
        run = rt.run_gfm(sites, 3, 0.08)
        assert run.result.comm.rounds == 2

    def test_matches_gfm_mine(self):
        _, sites = tx_sites()
        rt = GridRuntime(engine=fast_engine(), count_backend="jnp")
        run = rt.run_gfm(sites, 3, 0.08)
        _, sites2 = tx_sites()
        ref = gfm_mine(sites2, 3, 0.08)
        assert run.result.frequent == ref.frequent
        assert run.result.comm.rounds == ref.comm.rounds
        assert run.result.comm.bytes_sent == ref.comm.bytes_sent

    def test_nonuniform_thresholds_issue_extra_rounds(self):
        """With looser local thresholds the 2-pass lemma breaks and the
        top-down descent must ledger additional rounds — same behaviour as
        the in-process driver."""
        _, sites = tx_sites()
        rt = GridRuntime(engine=fast_engine(), count_backend="jnp")
        run = rt.run_gfm(sites, 3, 0.08, local_minsup=0.30)
        _, sites2 = tx_sites()
        ref = gfm_mine(sites2, 3, 0.08, local_minsup=0.30)
        assert run.result.comm.rounds == ref.comm.rounds >= 2
        assert run.result.frequent == ref.frequent

    def test_engine_clock_uses_measured_compute(self):
        _, sites = tx_sites()
        rt = GridRuntime(engine=fast_engine(), count_backend="jnp")
        run = rt.run_gfm(sites, 3, 0.08)
        jt = run.report.job_times
        assert set(jt) == set(run.measured)
        assert run.report.compute_s == pytest.approx(sum(jt.values()), rel=1e-12)


class TestFDMRuntime:
    def test_matches_fdm_mine(self):
        """FDM through the one shared scheduler equals the in-process
        baseline: same frequents, same k-round ledger, same candidates."""
        _, sites = tx_sites()
        rt = GridRuntime(engine=fast_engine(), count_backend="jnp")
        run = rt.run_fdm(sites, 3, 0.08)
        _, sites2 = tx_sites()
        ref = fdm_mine(sites2, 3, 0.08)
        assert run.result.frequent == ref.frequent
        assert run.result.comm.rounds == ref.comm.rounds
        assert run.result.per_level_candidates == ref.per_level_candidates

    def test_skewed_split_count_call_parity(self):
        """A site with zero candidates at some level must ledger the same
        count_calls through the job decomposition as through fdm_mine
        (regression: the job path used to skip the per-site call that
        fdm_mine ledgered, or vice versa)."""
        dense = ibm_transactions(seed=2, n_tx=400, n_items=20, avg_tx_len=5, n_patterns=6)
        def mk():
            return [TransactionDB.from_dense(dense[:3]), TransactionDB.from_dense(dense[3:])]

        ref = fdm_mine(mk(), 3, 0.1)
        rt = GridRuntime(engine=fast_engine(), count_backend="jnp")
        run = rt.run_fdm(mk(), 3, 0.1)
        assert run.result.comm.count_calls == ref.comm.count_calls
        assert run.result.comm.bytes_sent == ref.comm.bytes_sent
        assert run.result.frequent == ref.frequent

    def test_gfm_needs_fewer_rounds_than_fdm(self):
        """The paper's protocol comparison, reproduced through the runtime:
        GFM's single synchronization vs FDM's per-level barriers."""
        _, sites = tx_sites()
        rt = GridRuntime(engine=fast_engine(), count_backend="jnp")
        g = rt.run_gfm(sites, 3, 0.08)
        _, sites2 = tx_sites()
        f = rt.run_fdm(sites2, 3, 0.08)
        assert g.result.comm.rounds < f.result.comm.rounds


class TestAsyncRuntime:
    """schedule="async" threaded through GridRuntime: identical mining
    results, wall no worse than staged, analytical estimates attached."""

    def test_vclustering_async_matches_pooled_reference(self):
        xs = cluster_sites()
        rt = GridRuntime(engine=fast_engine(), sync="pooled", use_kernel=False, schedule="async")
        run = rt.run_vclustering(jax.random.PRNGKey(0), xs, CFG)
        ref = vcluster_pooled(jax.random.PRNGKey(0), jnp.asarray(xs), CFG)
        assert run.schedule == "async"
        assert int(run.result.merged.n_global) == int(ref.merged.n_global)
        assert np.array_equal(np.asarray(run.result.labels), np.asarray(ref.labels))

    def test_gfm_async_matches_staged(self):
        _, sites = tx_sites()
        arun = GridRuntime(engine=fast_engine(), count_backend="jnp", schedule="async").run_gfm(
            sites, 3, 0.08
        )
        _, sites2 = tx_sites()
        srun = GridRuntime(engine=fast_engine(), count_backend="jnp").run_gfm(sites2, 3, 0.08)
        assert arun.schedule == "async" and srun.schedule == "staged"
        assert arun.result.frequent == srun.result.frequent
        assert arun.result.comm.rounds == srun.result.comm.rounds == 2

    def test_fdm_async_matches_in_process_baseline(self):
        _, sites = tx_sites()
        run = GridRuntime(engine=fast_engine(), count_backend="jnp", schedule="async").run_fdm(
            sites, 3, 0.08
        )
        _, sites2 = tx_sites()
        ref = fdm_mine(sites2, 3, 0.08)
        assert run.result.frequent == ref.frequent
        assert run.result.comm.rounds == ref.comm.rounds

    def test_estimates_attached_and_bounded(self):
        """The measured-calibrated analytical bounds ride on RuntimeRun and
        lower-bound the simulated wall (paper measured-vs-estimated)."""
        _, sites = tx_sites()
        run = GridRuntime(engine=fast_engine(), count_backend="jnp", schedule="async").run_gfm(
            sites, 3, 0.08
        )
        assert 0 < run.estimated_s <= run.estimated_staged_s + 1e-9
        assert run.report.wall_s >= run.estimated_s - 1e-6
        assert 0.0 <= run.est_overhead_pct() <= 100.0


class TestBenchRuntime:
    def test_smoke_writes_valid_json(self, tmp_path):
        """The benchmark emits a parseable BENCH_runtime.json with the
        trajectory keys CI tracks."""
        import json

        out = tmp_path / "BENCH_runtime.json"
        sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))
        from benchmarks import bench_runtime

        payload = bench_runtime.run(smoke=True, out=str(out), use_kernel=False)
        on_disk = json.loads(out.read_text())
        assert on_disk["meta"]["smoke"] is True
        for app in ("vclustering", "gfm", "fdm"):
            for key in ("wall_s", "compute_s", "overhead_pct", "rounds", "bytes"):
                assert key in on_disk[app], (app, key)
        assert on_disk["gfm"]["rounds"] == 2
        assert payload["vclustering"]["n_global"] >= 1
