"""Cross-backend conformance suite: inline × batched × multihost(2p, 3p)
× both mining apps (+ the FDM baseline) × both engine schedules.

The contract (see ``repro.runtime.conformance``): backends change HOW
job callables execute, never WHAT the mining computes or WHAT the
scheduler decides — result digests must be bit-for-bit identical and
fixed-placement scheduling fingerprints exactly equal.

The multihost cells run through the real subprocess harness (2 and 3
``jax.distributed`` CPU processes with gloo collectives, deliberately
UNEVEN site counts) and are skipped gracefully when distributed init is
unavailable in the environment.  Their per-process execution logs are
the acceptance audit for true distribution: each site's jobs execute in
exactly one process.
"""

import json
import os
import socket
import subprocess
import sys
from pathlib import Path

import pytest

from repro.runtime import conformance
from repro.runtime.conformance import APPS, MARKER, SCHEDULES

SRC = str(Path(__file__).resolve().parent.parent / "src")

# (n_processes, n_sites, fuse): sites deliberately do NOT divide evenly
# over the processes, so the ownership map must handle ragged partitions.
# The plain groups pin the per-job shipment mode (--fuse 0, one collective
# per executed job); the *_batched groups run the wave-fused default
# (--fuse 1, one collective per ready wave) — digests must be bit-for-bit
# identical across ALL of them.  CI note: pytest -k matches substrings, so
# the matrix selects with expressions like "(2p and not batched)".
GROUPS = {
    "2p": (2, 3, 0),
    "3p": (3, 4, 0),
    "2p_batched": (2, 3, 1),
    "3p_batched": (3, 4, 1),
    # kernel count backend with autotuned blocks active: digests AND
    # fingerprints must still equal the parent's jnp inline reference —
    # the autotuner's never-changes-results contract under true
    # distribution (completing the inline x batched x multihost matrix
    # with block="auto")
    "kauto": (2, 3, 1),
}
# per-group extra child argv / env (the kauto group flips the compute
# path; the smoke lattice keeps its in-child autotune searches tiny)
GROUP_ARGS = {"kauto": ["--count-backend", "kernel", "--block", "auto"]}
GROUP_ENV = {"kauto": {"REPRO_AUTOTUNE_SMOKE": "1"}}
CELLS = [(app, sched) for app in APPS for sched in SCHEDULES]

# init failures that mean "this environment cannot run jax.distributed",
# not "the backend is broken" — those cells skip instead of failing
_SKIP_PATTERNS = (
    "jax.distributed",
    "coordinator",
    "DEADLINE_EXCEEDED",
    "UNAVAILABLE",
    "gloo",
    "distributed runtime",
)


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _launch_group(
    nprocs: int,
    n_sites: int,
    fuse: int = 1,
    extra_args: list[str] | None = None,
    extra_env: dict[str, str] | None = None,
) -> dict:
    port = _free_port()
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.update(extra_env or {})
    procs = [
        subprocess.Popen(
            [
                sys.executable,
                "-m",
                "repro.runtime.conformance",
                "--pid", str(pid),
                "--nprocs", str(nprocs),
                "--port", str(port),
                "--sites", str(n_sites),
                "--fuse", str(fuse),
                *(extra_args or []),
            ],
            stdout=subprocess.PIPE,
            stderr=subprocess.PIPE,
            text=True,
            env=env,
        )
        for pid in range(nprocs)
    ]
    reports, errors = [], []
    for p in procs:
        try:
            out, err = p.communicate(timeout=420)
        except subprocess.TimeoutExpired:
            for q in procs:
                q.kill()
            return {"error": "conformance child timed out", "skippable": False}
        if p.returncode != 0:
            errors.append(err[-4000:])
            continue
        lines = [ln for ln in out.splitlines() if ln.startswith(MARKER)]
        if not lines:
            errors.append(f"no conformance marker in child output: {out[-2000:]!r}")
            continue
        reports.append(json.loads(lines[0][len(MARKER):]))
    if errors:
        text = "\n".join(errors)
        return {
            "error": text,
            "skippable": any(pat in text for pat in _SKIP_PATTERNS),
        }
    reports.sort(key=lambda r: r["pid"])
    return {"reports": reports, "nprocs": nprocs, "n_sites": n_sites}


_group_cache: dict = {}


def _group(name: str) -> dict:
    if name not in _group_cache:
        nprocs, n_sites, fuse = GROUPS[name]
        _group_cache[name] = _launch_group(
            nprocs, n_sites, fuse, GROUP_ARGS.get(name), GROUP_ENV.get(name)
        )
        _write_artifact()
    g = _group_cache[name]
    if "error" in g:
        if g.get("skippable"):
            pytest.skip(f"jax.distributed unavailable here: {g['error'][:400]}")
        pytest.fail(f"multihost conformance group {name} failed:\n{g['error']}")
    return g


def _write_artifact() -> None:
    """Upload trail for CI: the per-group digests + fingerprints."""
    path = os.environ.get("CONFORMANCE_OUT")
    if path:
        Path(path).write_text(json.dumps(_group_cache, indent=2, sort_keys=True))


def _cell(report: dict, app: str, schedule: str) -> dict:
    for cell in report["cells"]:
        if cell["multihost"]["app"] == app and cell["multihost"]["schedule"] == schedule:
            return cell
    raise AssertionError(f"cell ({app}, {schedule}) missing from child report")


_inline_cache: dict = {}


def _inline_reference(app: str, n_sites: int, schedule: str, backend="inline") -> dict:
    """Parent-process reference cell (inline or batched), cached."""
    key = (app, n_sites, schedule, str(backend))
    if key not in _inline_cache:
        _inline_cache[key] = conformance.conformance_cell(app, n_sites, schedule, backend)
    return _inline_cache[key]


# ---------------------------------------------------------------------------
# in-process cells: batched vs inline
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("schedule", SCHEDULES)
@pytest.mark.parametrize("app", APPS)
def test_batched_matches_inline(app, schedule):
    """batched must agree with inline on digests AND fingerprints for
    every app × schedule (at the conformance harness's site counts)."""
    for n_sites in {g[1] for g in GROUPS.values()}:
        ref = _inline_reference(app, n_sites, schedule)
        got = _inline_reference(app, n_sites, schedule, backend="batched")
        assert got["digest"] == ref["digest"]
        assert got["fingerprint"] == ref["fingerprint"]


@pytest.mark.parametrize("app", APPS)
def test_multihost_single_process_matches_inline(app):
    """Engine(backend="multihost") without a coordinator must degrade to
    inline execution — same digests, same fingerprints, no partition."""
    from repro.runtime.backends import MultiHostBackend

    nprocs, n_sites, _fuse = GROUPS["2p"]
    be = MultiHostBackend()
    ref = _inline_reference(app, n_sites, "staged")
    run = conformance.run_app(app, n_sites, "staged", be)
    assert conformance.result_digest(app, run) == ref["digest"]
    assert conformance.schedule_fingerprint(run.report) == ref["fingerprint"]
    assert run.n_processes == 1 and run.owned_sites is None
    # single-process fallback still executes everything locally
    assert sorted(be.executed_log) == sorted(run.report.job_times)


# ---------------------------------------------------------------------------
# multihost subprocess cells (2 and 3 processes, uneven sites)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("group", sorted(GROUPS))
@pytest.mark.parametrize("app,schedule", CELLS)
def test_multihost_matches_inline(group, app, schedule):
    """Every process's multihost digest and fingerprint must equal the
    inline baseline computed in the same process."""
    g = _group(group)
    for report in g["reports"]:
        cell = _cell(report, app, schedule)
        assert cell["multihost"]["digest"] == cell["inline"]["digest"], (
            f"pid {report['pid']}: multihost result diverged from inline"
        )
        assert cell["multihost"]["fingerprint"] == cell["inline"]["fingerprint"], (
            f"pid {report['pid']}: scheduling fingerprint diverged"
        )


@pytest.mark.parametrize("group", sorted(GROUPS))
@pytest.mark.parametrize("app,schedule", CELLS)
def test_multihost_identical_across_processes(group, app, schedule):
    """All processes of one run must agree bit-for-bit with each other
    AND with the parent process's own inline reference."""
    g = _group(group)
    cells = [_cell(r, app, schedule) for r in g["reports"]]
    first = cells[0]["multihost"]
    for cell in cells[1:]:
        assert cell["multihost"]["digest"] == first["digest"]
        assert cell["multihost"]["fingerprint"] == first["fingerprint"]
    ref = _inline_reference(app, g["n_sites"], schedule)
    assert first["digest"] == ref["digest"]
    assert first["fingerprint"] == ref["fingerprint"]


@pytest.mark.parametrize("group", sorted(GROUPS))
@pytest.mark.parametrize("app,schedule", CELLS)
def test_each_sites_jobs_execute_on_exactly_one_process(group, app, schedule):
    """The acceptance audit: per-process execution logs partition the DAG
    — each job (and hence each site's whole job set) executes in exactly
    one process; everything else arrives shipped."""
    g = _group(group)
    cells = [_cell(r, app, schedule) for r in g["reports"]]
    job_sites = cells[0]["multihost"]["job_sites"]
    executed_by = [set(c["multihost"]["executed"]) for c in cells]
    # pairwise disjoint, union covers the whole DAG
    union: set = set()
    for i, ex in enumerate(executed_by):
        assert not (union & ex), f"jobs executed on more than one process: {union & ex}"
        union |= ex
    assert union == set(job_sites)
    # each SITE's jobs live entirely in one process, and that process is
    # the one claiming ownership of the site
    for pid, cell in enumerate(cells):
        mh = cell["multihost"]
        owned_sites = set(mh["owned_sites"])
        for name in mh["executed"]:
            assert job_sites[name] in owned_sites
        for name, site in job_sites.items():
            if site in owned_sites:
                assert name in executed_by[pid]
    # ownership maps agree across processes and partition the site set
    all_sites = {s for _, s in job_sites.items()}
    claimed: list = []
    for cell in cells:
        claimed.extend(cell["multihost"]["owned_sites"])
    assert sorted(claimed) == sorted(all_sites)
    # shipped = the complement of executed, exactly
    for cell, ex in zip(cells, executed_by):
        assert set(cell["multihost"]["shipped"]) == set(job_sites) - ex


@pytest.mark.parametrize("group", sorted(GROUPS))
def test_fault_injection_under_distribution(group):
    """A seeded injected failure retries identically on every process and
    the mined result still matches inline-under-the-same-fault."""
    g = _group(group)
    for report in g["reports"]:
        fc = report["fault_cell"]
        assert fc["retries_mh"] == fc["retries_inline"] == 1
        assert fc["digest_mh"] == fc["digest_inline"]
        assert fc["n_processes"] == g["nprocs"]


@pytest.mark.parametrize("group", sorted(GROUPS))
def test_shipment_ledger(group):
    """The collective-count ledger: wave-fused groups ship once per ready
    WAVE (strictly fewer collectives than jobs on these fan-out DAGs);
    per-job groups ship once per job — the O(jobs) -> O(waves) reduction,
    measured on the real distributed runs."""
    g = _group(group)
    fused = bool(GROUPS[group][2])
    for report in g["reports"]:
        assert report["fuse_waves"] is fused
        for cell in report["cells"]:
            mh = cell["multihost"]
            led = mh["ledger"]
            n_jobs = len(mh["job_sites"])
            # allgather_bytes = two process_allgather rounds per shipment
            assert led["collective_rounds"] == 2 * led["shipments"]
            # every non-owned job's result arrived through a shipment
            assert led["shipped_results"] == len(mh["shipped"])
            if fused:
                assert led["shipments"] == led["waves"]
                assert led["shipments"] < n_jobs
            else:
                assert led["waves"] == 0
                assert led["shipments"] == n_jobs


@pytest.mark.parametrize("group", sorted(GROUPS))
def test_topology(group):
    """The distributed runtime really is multi-process with one global
    device per process (CPU CI shape)."""
    g = _group(group)
    for report in g["reports"]:
        topo = report["topology"]
        assert topo["is_multiprocess"] is True
        assert topo["process_count"] == g["nprocs"]
        assert topo["n_global_devices"] == g["nprocs"]
