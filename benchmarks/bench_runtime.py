"""End-to-end runtime benchmark: both mining applications through
``GridRuntime`` + the grid workflow engine, with real (Pallas) kernels
feeding the simulated clock.

Emits the usual CSV rows AND writes a machine-readable
``BENCH_runtime.json`` so CI can track the perf trajectory per-PR:

    {"meta": {...},
     "vclustering": {"wall_s", "compute_s", "overhead_pct", "rounds",
                     "bytes", "sync_mode", "n_global"},
     "gfm":         {"wall_s", "compute_s", "overhead_pct", "rounds",
                     "bytes", "n_frequent"},
     "fdm":         {... same keys as gfm ...}}

    PYTHONPATH=src python -m benchmarks.bench_runtime [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import platform

import jax

from benchmarks.common import row


def _report_block(run, rounds: int, comm_bytes: int, extra: dict) -> dict:
    rep = run.report
    return {
        "wall_s": rep.wall_s,
        "compute_s": rep.compute_s,
        "critical_compute_s": rep.critical_compute_s,
        "critical_transfer_s": rep.critical_transfer_s,
        "overhead_pct": rep.overhead_pct(),
        "prep_s": rep.prep_s,
        "submit_s": rep.submit_s,
        "transfer_s": rep.transfer_s,
        "rounds": rounds,
        "bytes": comm_bytes,
        "n_jobs": len(rep.job_times),
        "sync_mode": run.sync_mode,
        "schedule": run.schedule,
        "estimated_s": run.estimated_s,
        "estimated_staged_s": run.estimated_staged_s,
        "est_overhead_pct": run.est_overhead_pct(),
        **extra,
    }


def run(
    smoke: bool = False,
    out: str = "BENCH_runtime.json",
    use_kernel: bool | None = None,
    schedule: str = "staged",
    exec_backend: str = "inline",
) -> dict:
    from repro.core.apriori import TransactionDB
    from repro.core.vclustering import VClusterConfig
    from repro.data.synthetic import (
        gaussian_mixture,
        ibm_transactions,
        split_sites,
        split_transactions,
    )
    from repro.runtime import GridRuntime

    if use_kernel is None:
        # Pallas kernels compile natively on TPU; on CPU they run in
        # interpret mode, tractable only at smoke sizes
        use_kernel = smoke or jax.default_backend() == "tpu"

    n_sites = 4
    if smoke:
        n_pts, dim, k_local, iters = 1200, 2, 6, 10
        n_tx, n_items, k_items, minsup = 800, 24, 3, 0.1
    else:
        n_pts, dim, k_local, iters = 20_000, 8, 12, 25
        n_tx, n_items, k_items, minsup = 8000, 60, 4, 0.05

    pts, _ = gaussian_mixture(0, n_pts, dim, 4, spread=12.0, sigma=0.6)
    xs = split_sites(pts, n_sites, seed=1)
    dense = ibm_transactions(seed=2, n_tx=n_tx, n_items=n_items, avg_tx_len=8, n_patterns=10)
    sites = [TransactionDB.from_dense(s) for s in split_transactions(dense, n_sites, seed=0)]

    backend = "kernel" if use_kernel else "jnp"
    rt = GridRuntime.for_sites(
        n_sites, use_kernel=use_kernel, count_backend=backend, schedule=schedule,
        backend=exec_backend,
    )
    cfg = VClusterConfig(k_local=k_local, kmeans_iters=iters, use_kernel=use_kernel)

    vrun = rt.run_vclustering(jax.random.PRNGKey(0), xs, cfg)
    vres = vrun.result
    row(
        "runtime_vclustering_wall",
        vrun.report.wall_s,
        f"overhead={vrun.report.overhead_pct():.1f}%;sync={vrun.sync_mode}",
    )
    row("runtime_vclustering_compute", vrun.report.compute_s, f"n_global={int(vres.merged.n_global)}")

    grun = rt.run_gfm(sites, k_items, minsup)
    gres = grun.result
    row("runtime_gfm_wall", grun.report.wall_s, f"overhead={grun.report.overhead_pct():.1f}%")
    row(
        "runtime_gfm_compute",
        grun.report.compute_s,
        f"rounds={gres.comm.rounds};frequent={len(gres.frequent)}",
    )

    frun = rt.run_fdm(sites, k_items, minsup)
    fres = frun.result
    row(
        "runtime_fdm_compute",
        frun.report.compute_s,
        f"rounds={fres.comm.rounds};frequent={len(fres.frequent)}",
    )

    payload = {
        "meta": {
            "smoke": smoke,
            "backend": backend,
            "n_devices": len(jax.devices()),
            "jax_backend": jax.default_backend(),
            "python": platform.python_version(),
            "jax": jax.__version__,
            "n_sites": n_sites,
            "schedule": schedule,
            "exec_backend": exec_backend,
            "clustering_shape": [n_pts, dim, k_local],
            "itemsets_shape": [n_tx, n_items, k_items, minsup],
        },
        "vclustering": _report_block(
            vrun,
            rounds=1,  # the single stats all_gather
            comm_bytes=int(vres.comm_bytes),
            extra={"n_global": int(vres.merged.n_global)},
        ),
        "gfm": _report_block(
            grun,
            rounds=gres.comm.rounds,
            comm_bytes=gres.comm.bytes_sent,
            extra={"n_frequent": len(gres.frequent)},
        ),
        "fdm": _report_block(
            frun,
            rounds=fres.comm.rounds,
            comm_bytes=fres.comm.bytes_sent,
            extra={"n_frequent": len(fres.frequent)},
        ),
    }
    if out:
        out_path = pathlib.Path(out)
        if out_path.parent != pathlib.Path("."):
            out_path.parent.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(payload, indent=2))
        print(f"# wrote {out}", flush=True)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny shapes for CI")
    ap.add_argument("--out", default="BENCH_runtime.json")
    ap.add_argument(
        "--kernel",
        choices=["auto", "on", "off"],
        default="auto",
        help="Pallas kernels: auto = smoke/TPU only",
    )
    ap.add_argument(
        "--schedule",
        choices=["staged", "async"],
        default="staged",
        help="engine scheduler: stage-barrier or event-driven",
    )
    ap.add_argument(
        "--backend",
        choices=["inline", "batched", "multihost"],
        default="inline",
        help="execution backend: per-job host loop, fused vmapped fan-outs, "
        "or the jax.distributed site-ownership backend (single-process "
        "fallback unless launched under a coordinator; under one, each "
        "process executes only its owned sites and ships results)",
    )
    args = ap.parse_args()
    from repro.launch.mesh import tuned_platform

    tuned_platform()  # apply the tuned XLA flag set (GPU) before first use
    run(
        smoke=args.smoke,
        out=args.out,
        use_kernel=None if args.kernel == "auto" else args.kernel == "on",
        schedule=args.schedule,
        exec_backend=args.backend,
    )


if __name__ == "__main__":
    main()
