"""Overhead-sweep study: site-count x link-matrix x compute-scale x
schedule-mode x placement-policy over both mining applications, with
real-kernel-calibrated job times.

This reproduces the paper's Table 3 measured-vs-estimated overhead
comparison (the 295 s DAGMan preparation, serial per-job matchmaking and
Table 2 staging dominating cheap mining workflows) and quantifies how
much of that overhead the event-driven ``schedule="async"`` engine
recovers by overlapping submission with computation — the optimisation
the paper suggests ("partly overlapped by computations in the DAG") —
in the style of the companion study arXiv:1903.03008's site-count sweeps.

The placement axis runs the async scheduler under every matchmaking
policy (``fixed`` a-priori sites vs ``round_robin`` / seeded ``random``
/ ``greedy_eta`` adaptive placement); the ``skewed`` link variant
(per-site degraded Table 2 matrix + heterogeneous per-site compute
speeds, ``GridModel.skewed()``) is the scenario where matchmaking
dominates (arXiv:1412.2673), and the CI gate requires ``greedy_eta``
wall <= ``fixed`` wall there.  Staged cells keep fixed placement — they
are the Table 3 reproduction.

Methodology: each (application, site count) point is CALIBRATED by one
real run through ``GridRuntime`` (jitted site-local compute; per-job
device times recorded), then every links x schedule x placement cell
REPLAYS the captured DAG and measured times through the engine
deterministically.  Replaying isolates the scheduling policy — identical
DAG, model and job times across cells, zero timing noise — so
staged-vs-async and fixed-vs-adaptive deltas are exact and the CI
regression gate is stable across hosts.

The execution-backend axis calibrates each (app, site count) point
twice — once per ``workflow.executor`` backend: ``inline`` (one
dispatch per job; the full links x placement product and the Table 3
reproduction) and ``batched`` (each fan-out fused into ONE vmapped
dispatch, measured batch time apportioned per job; replayed on the
canonical grid5000/fixed cells).  The ``backend_comparisons`` block
pairs the two per (app, n_sites, schedule, scale); the CI gate requires
batched wall <= inline wall on the >=8-site fan-out-heavy cells.

Writes ``BENCH_sweep.json``::

    {"meta":  {...},
     "cells": [{"app", "n_sites", "links", "schedule", "placement",
                "exec_backend", "wall_s", "compute_s",
                "critical_compute_s", "critical_transfer_s", "prep_s",
                "submit_s", "transfer_s", "overhead_pct", "estimated_s",
                "estimated_staged_s", "est_overhead_pct", "n_jobs"}, ...],
     "comparisons": [{"app", "n_sites", "links", "wall_staged_s",
                      "wall_async_s", "recovered_s",
                      "recovered_pct_of_overhead"}, ...],
     "placement_comparisons": [{"app", "n_sites", "links",
                                "compute_scale", "wall_fixed_s",
                                "wall_greedy_eta_s", "recovered_s"}, ...],
     "backend_comparisons": [{"app", "n_sites", "links", "schedule",
                              "compute_scale", "wall_inline_s",
                              "wall_batched_s",
                              "critical_compute_inline_s",
                              "critical_compute_batched_s",
                              "recovered_s"}, ...],
     "table3":  [{"app", "n_sites", "measured_s", "estimated_s",
                  "est_overhead_pct"}, ...]}

The engine runs the paper-faithful configuration (full preparation
latency, serial matchmaking: ``overlap_prep=False``), so the staged
grid5000 cells ARE the Table 3 reproduction and the async cells show the
recovery.

    PYTHONPATH=src python -m benchmarks.bench_sweep [--smoke] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import platform

import jax

from benchmarks.common import row
from repro.workflow.overhead import overhead_pct
from repro.workflow.placement import POLICIES

LINK_VARIANTS = ("grid5000", "lan", "skewed")
SCHEDULES = ("staged", "async")
# the placement axis applies to the async scheduler (matchmaking is what
# the event-driven engine models); staged cells pin placement="fixed"
PLACEMENTS = POLICIES  # ("fixed", "round_robin", "random", "greedy_eta")
# execution-backend axis: which backend CALIBRATED the job times that a
# cell replays.  "inline" is the one-dispatch-per-job host loop (the
# full axis product — and the bit-for-bit continuation of pre-backend
# baselines); "batched" fuses each fan-out into one vmapped dispatch and
# replays on the canonical grid5000/fixed cells, where the CI gate
# requires batched wall <= inline wall on the >=8-site fan-outs
EXEC_BACKENDS = ("inline", "batched")
# what-if compute scaling of the calibrated job times (sim_compute_s
# replay): x1 is the paper's cheap-mining regime where overheads dominate
# and there is nothing to overlap; larger factors approach paper-scale
# datasets where the async engine's submit/compute overlap pays off
COMPUTE_SCALES = (1, 50)
COMPUTE_SCALES_FULL = (1, 10, 100)


def _cell(
    rep,
    app: str,
    n_sites: int,
    links: str,
    scale: int,
    est_dag: float,
    est_staged: float,
    exec_backend: str = "inline",
) -> dict:
    est = est_dag if rep.schedule == "async" else est_staged
    return {
        "app": app,
        "n_sites": n_sites,
        "links": links,
        "compute_scale": scale,
        "schedule": rep.schedule,
        "placement": rep.placement,
        "exec_backend": exec_backend,
        "wall_s": rep.wall_s,
        "compute_s": rep.compute_s,
        "critical_compute_s": rep.critical_compute_s,
        "critical_transfer_s": rep.critical_transfer_s,
        "prep_s": rep.prep_s,
        "submit_s": rep.submit_s,
        "transfer_s": rep.transfer_s,
        "overhead_pct": rep.overhead_pct(),
        "estimated_s": est_dag,
        "estimated_staged_s": est_staged,
        "est_overhead_pct": overhead_pct(rep.wall_s, est),
        "n_jobs": len(rep.job_times),
    }


def run(smoke: bool = False, out: str = "BENCH_sweep.json", use_kernel: bool | None = None) -> dict:
    from repro.core.apriori import TransactionDB
    from repro.core.vclustering import VClusterConfig
    from repro.data.synthetic import (
        gaussian_mixture,
        ibm_transactions,
        split_sites,
        split_transactions,
    )
    from repro.runtime import GridRuntime
    from repro.workflow.engine import Engine
    from repro.workflow.overhead import (
        GridModel,
        estimate_dag,
        estimate_stages_from_specs,
    )
    from repro.workflow.sitejob import replay_dag

    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"

    # 8 sites is the fan-out-heavy point the batched-vs-inline backend
    # gate runs on, so even the smoke sweep carries it
    site_counts = [2, 4, 8]
    if smoke:
        n_pts, dim, k_local, iters = 1200, 2, 6, 10
        n_tx, n_items, k_items, minsup = 800, 24, 3, 0.1
    else:
        n_pts, dim, k_local, iters = 8000, 4, 8, 15
        n_tx, n_items, k_items, minsup = 4000, 40, 3, 0.05

    pts, _ = gaussian_mixture(0, n_pts, dim, 4, spread=12.0, sigma=0.6)
    dense = ibm_transactions(seed=2, n_tx=n_tx, n_items=n_items, avg_tx_len=8, n_patterns=10)
    backend = "kernel" if use_kernel else "jnp"
    cfg = VClusterConfig(k_local=k_local, kmeans_iters=iters, use_kernel=use_kernel)

    def run_app(app: str, n_sites: int, rt: GridRuntime):
        if app == "vclustering":
            xs = split_sites(pts, n_sites, seed=1)
            return rt.run_vclustering(jax.random.PRNGKey(0), xs, cfg)
        sites = [TransactionDB.from_dense(s) for s in split_transactions(dense, n_sites, seed=0)]
        return rt.run_gfm(sites, k_items, minsup)

    def calibrate(app: str, n_sites: int, exec_backend: str = "inline"):
        """One real run: jitted site-local compute, per-job device times.
        A throwaway warm-up first so JIT compilation does not pollute the
        measurement.  The returned specs are the DAG the runtime actually
        executed (``RuntimeRun.specs``), measured times included —
        ``exec_backend`` selects HOW the fan-outs executed (inline host
        loop vs one fused vmapped dispatch with apportioned times)."""
        def fresh():
            return GridRuntime(
                engine=Engine(model=GridModel(), overlap_prep=True, backend=exec_backend),
                sync="pooled", use_kernel=use_kernel, count_backend=backend,
            )

        run_app(app, n_sites, fresh())  # warm-up (compilation)
        return run_app(app, n_sites, fresh()).specs

    scales = COMPUTE_SCALES if smoke else COMPUTE_SCALES_FULL
    cells: list[dict] = []
    comparisons: list[dict] = []
    placement_comparisons: list[dict] = []
    backend_comparisons: list[dict] = []
    for app in ("vclustering", "gfm"):
        for n_sites in site_counts:
            specs_by = {be: calibrate(app, n_sites, be) for be in EXEC_BACKENDS}
            for links in LINK_VARIANTS:
                # "skewed" is the heterogeneous grid: degraded per-site
                # links AND per-site compute speeds — the matchmaking
                # scenario the placement gate runs on
                model = GridModel.skewed() if links == "skewed" else GridModel(links=links)
                for scale in scales:
                    per_schedule: dict[str, dict] = {}
                    per_placement: dict[str, dict] = {}
                    per_backend: dict[tuple[str, str], dict] = {}
                    for exec_backend in EXEC_BACKENDS:
                        # the full links x placement product runs on the
                        # inline calibration (the Table 3 reproduction and
                        # the pre-backend baseline continuation); batched
                        # cells replay the canonical grid5000/fixed point,
                        # where the backend gate compares the two
                        if exec_backend != "inline" and links != "grid5000":
                            continue
                        scaled = [
                            sp._replace(compute_s=sp.compute_s * scale)
                            for sp in specs_by[exec_backend]
                        ]
                        for schedule in SCHEDULES:
                            # the placement axis applies to async (the
                            # matchmaker); staged is the Table 3 reproduction
                            placements = (
                                PLACEMENTS
                                if schedule == "async" and exec_backend == "inline"
                                else ("fixed",)
                            )
                            for placement in placements:
                                # deterministic replay: paper-faithful grid
                                # (full DAGMan prep, serial matchmaking),
                                # calibrated times
                                eng = Engine(
                                    model=model,
                                    overlap_prep=False,
                                    schedule=schedule,
                                    placement=placement,
                                )
                                rep = eng.run(replay_dag(scaled))
                                # bounds priced at the sites the policy chose
                                placed = [
                                    sp._replace(site=rep.placements.get(sp.name, sp.site))
                                    for sp in scaled
                                ]
                                est_dag = estimate_dag(placed, model)
                                est_staged = estimate_stages_from_specs(placed, model)
                                cell = _cell(
                                    rep, app, n_sites, links, scale, est_dag, est_staged,
                                    exec_backend,
                                )
                                cells.append(cell)
                                if exec_backend == "inline" and placement == "fixed":
                                    per_schedule[schedule] = cell
                                if exec_backend == "inline" and schedule == "async":
                                    per_placement[placement] = cell
                                if placement == "fixed":
                                    per_backend[(schedule, exec_backend)] = cell
                                row(
                                    f"sweep_{app}_s{n_sites}_{links}_x{scale}"
                                    f"_{schedule}_{placement}_{exec_backend}",
                                    cell["wall_s"],
                                    f"overhead={cell['overhead_pct']:.1f}%;"
                                    f"est={cell['estimated_s']:.2f}s",
                                )
                    staged, async_ = per_schedule["staged"], per_schedule["async"]
                    recovered = staged["wall_s"] - async_["wall_s"]
                    overhead = staged["wall_s"] - staged["estimated_staged_s"]
                    comparisons.append(
                        {
                            "app": app,
                            "n_sites": n_sites,
                            "links": links,
                            "compute_scale": scale,
                            "wall_staged_s": staged["wall_s"],
                            "wall_async_s": async_["wall_s"],
                            "recovered_s": recovered,
                            "recovered_pct_of_overhead": (
                                100.0 * recovered / overhead if overhead > 0 else 0.0
                            ),
                        }
                    )
                    fixed, greedy = per_placement["fixed"], per_placement["greedy_eta"]
                    placement_comparisons.append(
                        {
                            "app": app,
                            "n_sites": n_sites,
                            "links": links,
                            "compute_scale": scale,
                            "wall_fixed_s": fixed["wall_s"],
                            "wall_greedy_eta_s": greedy["wall_s"],
                            "recovered_s": fixed["wall_s"] - greedy["wall_s"],
                        }
                    )
                    if links == "grid5000":
                        # fused site-compute vs the host loop, identical
                        # grid model and topology: the wall delta is pure
                        # calibrated-compute difference (the CI gate
                        # requires batched <= inline on >=8-site cells)
                        for schedule in SCHEDULES:
                            icell = per_backend[(schedule, "inline")]
                            bcell = per_backend[(schedule, "batched")]
                            backend_comparisons.append(
                                {
                                    "app": app,
                                    "n_sites": n_sites,
                                    "links": links,
                                    "schedule": schedule,
                                    "compute_scale": scale,
                                    "wall_inline_s": icell["wall_s"],
                                    "wall_batched_s": bcell["wall_s"],
                                    "critical_compute_inline_s": icell["critical_compute_s"],
                                    "critical_compute_batched_s": bcell["critical_compute_s"],
                                    "recovered_s": icell["wall_s"] - bcell["wall_s"],
                                }
                            )

    # Table 3 reproduction: the paper's measured-vs-estimated overhead at
    # its own scale point (grid5000 links, unscaled compute, staged)
    table3 = [
        {
            "app": c["app"],
            "n_sites": c["n_sites"],
            "measured_s": c["wall_s"],
            "estimated_s": c["estimated_staged_s"],
            "est_overhead_pct": c["est_overhead_pct"],
        }
        for c in cells
        if c["links"] == "grid5000"
        and c["schedule"] == "staged"
        and c["compute_scale"] == 1
        and c["exec_backend"] == "inline"
    ]

    payload = {
        "meta": {
            "smoke": smoke,
            "backend": backend,
            "n_devices": len(jax.devices()),
            "jax_backend": jax.default_backend(),
            "python": platform.python_version(),
            "jax": jax.__version__,
            "site_counts": site_counts,
            "links": list(LINK_VARIANTS),
            "schedules": list(SCHEDULES),
            "placements": list(PLACEMENTS),
            "exec_backends": list(EXEC_BACKENDS),
            "compute_scales": list(scales),
            "clustering_shape": [n_pts, dim, k_local],
            "itemsets_shape": [n_tx, n_items, k_items, minsup],
        },
        "cells": cells,
        "comparisons": comparisons,
        "placement_comparisons": placement_comparisons,
        "backend_comparisons": backend_comparisons,
        "table3": table3,
    }
    if out:
        out_path = pathlib.Path(out)
        if out_path.parent != pathlib.Path("."):
            out_path.parent.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(payload, indent=2))
        print(f"# wrote {out}", flush=True)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny shapes + fewer site counts for CI")
    ap.add_argument("--out", default="BENCH_sweep.json")
    ap.add_argument(
        "--kernel",
        choices=["auto", "on", "off"],
        default="auto",
        help="Pallas kernels: auto = TPU only (interpret mode is too slow to sweep on CPU)",
    )
    args = ap.parse_args()
    run(
        smoke=args.smoke,
        out=args.out,
        use_kernel=None if args.kernel == "auto" else args.kernel == "on",
    )


if __name__ == "__main__":
    main()
