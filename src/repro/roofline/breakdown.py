"""Per-instruction traffic/collective breakdown of a dry-run cell — the
profiler for the §Perf hillclimbing loop (our 'profile' is the lowered
HLO, per the CPU-only methodology).

    PYTHONPATH=src python -m repro.roofline.breakdown --arch xlstm-1.3b --shape train_4k
"""

from __future__ import annotations

import re

from repro.roofline.hlo_costs import (
    TRIP_RE,
    _operands,
    _shape_bytes,
    parse_computations,
    traffic_of,
)

SKIP = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast", "after-all",
    "partition-id", "replica-id", "while", "conditional", "call",
}


def multipliers(comps, entry):
    mult: dict[str, float] = {}

    def visit(name, m):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        for ins in comps[name].instrs:
            if ins.op == "while":
                tm = TRIP_RE.search(ins.line)
                trips = float(tm.group(1)) if tm else 1.0
                bm = re.search(r"body=%?([^\s,)]+)", ins.line)
                cm = re.search(r"condition=%?([^\s,)]+)", ins.line)
                if bm:
                    visit(bm.group(1), m * trips)
                if cm:
                    visit(cm.group(1), m * (trips + 1))
            elif ins.op == "call":
                km = re.search(r"to_apply=%?([^\s,)]+)", ins.line)
                if km:
                    visit(km.group(1), m)
            elif ins.op == "conditional":
                for key in ("true_computation", "false_computation"):
                    km = re.search(rf"{key}=%?([^\s,)]+)", ins.line)
                    if km:
                        visit(km.group(1), m)
                bm = re.search(r"branch_computations=\{([^}]*)\}", ins.line)
                if bm:
                    for b in re.findall(r"%?([^\s,]+)", bm.group(1)):
                        visit(b, m)

    visit(entry, 1.0)
    return mult


def top_traffic(hlo_text: str, k: int = 30):
    comps, entry = parse_computations(hlo_text)
    mult = multipliers(comps, entry)
    items = []
    for cname, m in mult.items():
        comp = comps[cname]
        for ins in comp.instrs:
            if ins.op in SKIP:
                continue
            t = m * traffic_of(ins, comp, comps)
            meta = re.search(r'op_name="([^"]+)"', ins.line)
            items.append((t, m, ins.op, ins.type_str[:44], (meta.group(1)[-72:] if meta else ""), cname[:28]))
    items.sort(reverse=True)
    return items[:k]


def top_collectives(hlo_text: str, k: int = 20):
    comps, entry = parse_computations(hlo_text)
    mult = multipliers(comps, entry)
    items = []
    for cname, m in mult.items():
        comp = comps[cname]
        for ins in comp.instrs:
            if ins.op.split("-start")[0] in (
                "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
            ):
                res = _shape_bytes(ins.type_str)
                opb = sum(_shape_bytes(comp.symtab.get(o, "")) for o in _operands(ins))
                meta = re.search(r'op_name="([^"]+)"', ins.line)
                items.append((m * max(res, opb), m, ins.op, ins.type_str[:44], (meta.group(1)[-72:] if meta else "")))
    items.sort(reverse=True)
    return items[:k]


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--rules", default="baseline")
    ap.add_argument("--gridlocal", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()

    from repro.launch.dryrun import build_lowered

    cfg, sh, mesh, lowered = build_lowered(
        args.arch, args.shape, args.multi_pod, args.rules, args.gridlocal, args.grad_accum
    )
    txt = lowered.compile().as_text()
    print(f"== top traffic instructions ({args.arch} x {args.shape}) ==")
    for t, m, op, ts, name, cn in top_traffic(txt, args.top):
        print(f"{t:10.3e}  x{m:6.0f} {op:18s} {ts:44s} {name}")
    print("\n== top collectives ==")
    for t, m, op, ts, name in top_collectives(txt, args.top):
        print(f"{t:10.3e}  x{m:6.0f} {op:18s} {ts:44s} {name}")


if __name__ == "__main__":
    main()
