"""Unified model configuration covering all assigned architecture families:
dense/GQA transformers, local+global alternating attention, MoE (coarse and
fine-grained with shared experts), Mamba2 hybrids, xLSTM, and enc-dec."""

from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    n_shared_experts: int = 0  # deepseek-style always-on experts
    top_k: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    aux_loss: float = 1e-2


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    expand: int = 2
    d_conv: int = 4
    head_dim: int = 64
    chunk: int = 128


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 2
    n_kv_heads: int = 2
    head_dim: int = 64
    d_ff: int = 256
    vocab: int = 256

    # attention details
    rope_theta: float = 10_000.0
    rope_pct: float = 1.0  # stablelm: partial rotary
    window: int = 0  # sliding-window size for 'swa' layers (0 = unused)
    layer_pattern: tuple[str, ...] = ("full",)  # cycled over layers:
    #   'full' | 'swa' | 'mamba2' | 'mlstm' | 'slstm'
    prefix_pattern: tuple[str, ...] = ()  # static leading layers (deepseek: dense first layer)
    attn_softcap: float = 0.0  # gemma2: 50.0
    final_softcap: float = 0.0  # gemma2: 30.0
    norm: str = "rmsnorm"  # 'rmsnorm' | 'layernorm'
    act: str = "swiglu"  # 'swiglu' | 'gelu' | 'gelu_mlp'
    post_norm: bool = False  # gemma2 pre+post block norms
    qk_norm: bool = False
    embed_scale: bool = False  # gemma: embeddings * sqrt(d_model)

    # MoE / SSM subconfigs (None → dense FFN / no ssm layers)
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    # MoE dispatch locality: 0 = global top-C per expert; G > 1 = top-C
    # within each of G token groups (aligned with the `data` shards, so
    # the dispatch gather/scatter stays device-local and the only
    # cross-device movement is the EP all-to-all)  [§Perf iteration]
    moe_dispatch_groups: int = 0

    # zamba2: shared (weight-tied) attention block applied every group
    shared_attn_every: int = 0  # period in layers (0 = none)

    # enc-dec (seamless): encoder layer count; n_layers = decoder layers
    n_enc_layers: int = 0

    # modality frontend (STUB: precomputed embeddings enter via input_specs)
    frontend: str = "none"  # 'none' | 'patch' | 'frames'
    frontend_len: int = 0  # embeddings per sample at train/prefill

    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    # does the arch support ~500k-token decode? (sub-quadratic / windowed)
    subquadratic: bool = False

    # remat policy for train: 'none' | 'full' | 'dots'
    remat: str = "full"

    # pad the embedding/vocab param dim so TP over `model` always divides
    # (MaxText-style); logits over padded ids are masked to -inf.
    vocab_pad_multiple: int = 16

    # run the sLSTM recurrence in the VMEM-resident-weights Pallas kernel
    # (TPU only / interpret mode on CPU; see kernels/slstm_cell.py)
    slstm_kernel: bool = False
    # run full-sequence attention in the Pallas flash kernel (scores stay
    # in VMEM; see kernels/flash_attention.py).  Off by default: Mosaic
    # cannot lower in the CPU dry-run, and the chunked-jnp path is the
    # numerics oracle.
    flash_kernel: bool = False

    @property
    def vocab_padded(self) -> int:
        m = self.vocab_pad_multiple
        return (self.vocab + m - 1) // m * m

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def pattern_period(self) -> int:
        return len(self.layer_pattern)

    def blocks(self) -> list[str]:
        """Resolved per-layer block kinds (prefix + cycled pattern)."""
        body = self.n_layers - len(self.prefix_pattern)
        out = list(self.prefix_pattern)
        for i in range(body):
            out.append(self.layer_pattern[i % self.pattern_period])
        return out

    def scaled(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests: same block pattern /
    feature set, small dims."""
    period = cfg.pattern_period
    n_layers = max(2 * period, len(cfg.prefix_pattern) + period)
    if cfg.shared_attn_every:
        n_layers = max(n_layers, 2 * cfg.shared_attn_every)
    moe = None
    if cfg.moe:
        ne = min(cfg.moe.n_experts, 4)
        tk = min(cfg.moe.top_k, 2)
        moe = MoEConfig(
            n_experts=ne,
            n_shared_experts=min(cfg.moe.n_shared_experts, 1),
            top_k=tk,
            expert_d_ff=64,
            # worst-case capacity (cap == T): smoke-scale routers are
            # untrained and heavily skewed, and capacity drops would break
            # prefill/decode parity (decode never competes for capacity)
            capacity_factor=max(cfg.moe.capacity_factor, ne / max(tk, 1)),
        )
    ssm = None
    if cfg.ssm:
        ssm = SSMConfig(d_state=16, expand=2, d_conv=4, head_dim=16, chunk=16)
    return replace(
        cfg,
        name=cfg.name + "-reduced",
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab=512,
        window=min(cfg.window, 32) if cfg.window else 0,
        moe=moe,
        ssm=ssm,
        n_enc_layers=2 if cfg.n_enc_layers else 0,
        frontend_len=4 if cfg.frontend != "none" else 0,
        shared_attn_every=min(cfg.shared_attn_every, 3) if cfg.shared_attn_every else 0,
        dtype="float32",
        remat="none",
    )
