"""Collective-count comparison: per-job vs wave-fused result shipping.

The paper attributes the dominant grid overhead to per-job communication
rounds; the multihost backend's wave-fused shipping collapses them from
O(jobs) to O(ready waves).  This bench makes that reduction visible in
every PR's CI logs: each conformance app x schedule cell runs twice
through a force-partitioned ``MultiHostBackend`` (single process, the
collectives degenerate to identity — the LEDGER is what's measured, and
it counts shipments identically to a real process group), once with
``fuse_waves=False`` (PR-5 per-job rounds) and once with the wave-fused
default, and the shipment counts print side by side.

    PYTHONPATH=src python -m benchmarks.bench_collectives --sites 8
"""

from __future__ import annotations

import argparse
import json

from repro.runtime.backends import MultiHostBackend
from repro.runtime.conformance import APPS, SCHEDULES, run_app


def run(n_sites: int = 8, out: str | None = None) -> dict:
    report = {"n_sites": n_sites, "cells": []}
    print(f"# collective rounds per run, {n_sites} sites (per-job vs wave-fused shipping)")
    print("app,schedule,jobs,shipments_per_job,shipments_per_wave,waves,reduction_pct")
    for app in APPS:
        for schedule in SCHEDULES:
            counts: dict[str, dict] = {}
            for mode, fuse in (("per_job", False), ("per_wave", True)):
                be = MultiHostBackend(force_partition=True, fuse_waves=fuse)
                rr = run_app(app, n_sites, schedule, be)
                counts[mode] = dict(be.ledger(), waves=int(be.waves), jobs=len(rr.report.job_times))
            pj = counts["per_job"]["shipments"]
            pw = counts["per_wave"]["shipments"]
            cell = {
                "app": app,
                "schedule": schedule,
                "jobs": counts["per_job"]["jobs"],
                "shipments_per_job": pj,
                "shipments_per_wave": pw,
                "waves": counts["per_wave"]["waves"],
                "reduction_pct": 100.0 * (1 - pw / pj) if pj else 0.0,
            }
            report["cells"].append(cell)
            print(
                f"{app},{schedule},{cell['jobs']},{pj},{pw},{cell['waves']},"
                f"{cell['reduction_pct']:.0f}"
            )
    if out:
        try:
            with open(out, "w") as fh:
                json.dump(report, fh, indent=2, sort_keys=True)
        except FileNotFoundError:
            # name the missing directory and the fix instead of a bare
            # traceback — CI passes a relative path from the repo root
            raise SystemExit(
                f"bench_collectives: cannot write {out!r} — its directory does "
                f"not exist; create it (mkdir -p) or pass --out with an "
                f"existing directory"
            ) from None
        print(f"# wrote {out}")
    return report


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sites", type=int, default=8)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    run(n_sites=args.sites, out=args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
