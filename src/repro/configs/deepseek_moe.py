"""deepseek-moe-16b [moe] — 2 shared + 64 routed experts top-6, fine-grained
(expert d_ff 1408); first layer is a dense FFN [arXiv:2401.06066]."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,  # dense FFN of the first (prefix) layer
    vocab=102400,
    prefix_pattern=("full_dense",),
    layer_pattern=("full",),
    moe=MoEConfig(n_experts=64, n_shared_experts=2, top_k=6, expert_d_ff=1408),
    norm="rmsnorm",
    act="swiglu",
    tie_embeddings=False,
    subquadratic=False,
)
