"""GridRuntime — execute the paper's mining applications on real devices
through the simulated grid.

The paper's central measurement is the gap between what a grid workflow
engine *spends* (preparation, submission, staging) and what the mining
itself *costs*.  The seed repo modelled the grid side with canned numbers;
this runtime closes the loop: every ``workflow.dag.Job`` maps onto jitted
site-local compute (the Pallas ``kmeans_assign`` kernel for K-Means
sub-clustering, the Pallas ``support_count`` kernel for GFM phase-1 local
Apriori over bitmap TransactionDBs), the single synchronization runs as a
real ``all_gather`` under ``shard_map`` on a ``launch.mesh``-built device
mesh (pooled vmap fallback when the host has too few devices), and each
job's measured wall time feeds the engine's simulated clock via
``TimedResult`` — so reported overhead percentages are calibrated by real
kernels.

    rt = GridRuntime.for_sites(4)                  # mesh if >=4 devices
    run = rt.run_vclustering(jax.random.PRNGKey(0), xs)
    run.result.labels, run.report.overhead_pct(), run.sync_mode
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.stats import SuffStats
from repro.core.vclustering import (
    MergeResult,
    VClusterConfig,
    merge_gathered,
)
from repro.launch.mesh import make_site_mesh
from repro.workflow.registry import RunContext, get_workload
from repro.workflow.engine import Engine, RunReport
from repro.workflow.executor import ExecutionBackend
from repro.workflow.overhead import (
    GridModel,
    estimate_dag,
    estimate_stages_from_specs,
    overhead_pct,
)
from repro.workflow.placement import resolve_placement
from repro.workflow.sitejob import job_specs, merge_owner_times


def _backend_differs(backend: str | ExecutionBackend, engine: Engine) -> bool:
    """Whether a requested backend requires rebuilding the engine.  An
    instance is honored by IDENTITY (a configured BatchedBackend with a
    custom min_batch must not be silently dropped just because its name
    matches); a name is compared as a string — no throwaway instance."""
    if isinstance(backend, ExecutionBackend):
        return backend is not engine.backend
    return backend != engine.backend.name


@dataclass
class RuntimeRun:
    """One application run: the mining result, the engine's grid report,
    and the runtime's own per-job device-time measurements (the numbers
    that were fed into the simulated clock)."""

    result: Any
    report: RunReport
    measured: dict[str, float] = field(default_factory=dict)
    sync_mode: str = "pooled"  # how the single synchronization executed
    schedule: str = "staged"  # which engine scheduler executed the DAG
    placement: str = "fixed"  # which matchmaking policy placed the jobs
    backend: str = "inline"  # which execution backend ran the callables
    # multi-host ownership (multihost backend): how many jax.distributed
    # processes cooperated, and which grid sites THIS process executed —
    # None means the run was not partitioned (every job ran locally)
    n_processes: int = 1
    owned_sites: tuple | None = None
    # the analytical view of the DAG that was actually executed (deps,
    # bytes, the sites the policy actually chose, measured compute) —
    # feed to overhead.estimate_* or sitejob.replay_dag; the sweep
    # benchmark replays exactly these
    specs: list = field(default_factory=list)
    # analytical bounds (paper §5.2.2), calibrated by the measured job
    # times: per-job critical path (the async ideal) and the stage-barrier
    # formula (the staged ideal)
    estimated_s: float = 0.0
    estimated_staged_s: float = 0.0

    def est_overhead_pct(self) -> float:
        """Table 3's 'Estimated overhead': measured wall vs the analytical
        bound matching this run's schedule mode."""
        est = self.estimated_s if self.schedule == "async" else self.estimated_staged_s
        return overhead_pct(self.report.wall_s, est)


@dataclass
class FusedRun:
    """One request's slice of a cross-request fused run
    (:meth:`GridRuntime.run_many`): its own mining result, its share of
    the measured device compute (summed from the merged report's per-job
    times under this request's name prefix), and the shared
    :class:`RunReport` of the ONE engine invocation that served every
    member."""

    result: Any
    compute_s: float
    backend: str
    report: RunReport


class GridRuntime:
    """Maps SiteJobs from the core algorithms onto one grid scheduler.

    ``sync`` selects how the clustering synchronization runs:
      * "auto" (default): shard_map all_gather over a device mesh when one
        with a site-sized axis is available, else the pooled fallback;
      * "shard_map": require the mesh (raises without enough devices);
      * "pooled": force the single-device vmap-equivalent path.
    Both paths are bit-identical — the logical merge is deterministic on
    the gathered statistics (the paper's redundant "logical merging").
    """

    def __init__(
        self,
        engine: Engine | None = None,
        mesh=None,
        axis: str = "sites",
        sync: str = "auto",
        use_kernel: bool = True,
        count_backend: str = "kernel",
        schedule: str | None = None,
        placement: str | None = None,
        backend: str | ExecutionBackend | None = None,
    ):
        if sync not in ("auto", "shard_map", "pooled"):
            raise ValueError(f"unknown sync mode {sync!r}")
        # ``schedule`` / ``placement`` / ``backend`` thread the engine's
        # scheduler mode ("staged" | "async"), matchmaking policy
        # ("fixed" | "round_robin" | "random" | "greedy_eta") and
        # execution backend ("inline" | "batched" | "multihost") through
        # the runtime; None keeps the given engine's own settings (or the
        # Engine defaults) untouched.  A caller-supplied engine is never
        # mutated — a differing setting gets an equivalent engine.
        #
        # Runtime-built engines default to the BATCHED backend: the
        # conformance suite proves it bit-identical to inline, and fused
        # fan-out dispatch is the raw-speed win for wide grids.  Pass
        # ``backend="inline"`` (or an explicit engine) to restore the
        # per-job host loop.
        if engine is None:
            engine = Engine(
                model=GridModel(),
                overlap_prep=True,
                schedule=schedule or "staged",
                placement=placement or "fixed",
                backend=backend or "batched",
            )
        elif (
            (schedule is not None and engine.schedule != schedule)
            or (placement is not None and resolve_placement(engine.placement).name != placement)
            or (backend is not None and _backend_differs(backend, engine))
        ):
            engine = Engine(
                model=engine.model,
                faults=engine.faults,
                rescue_path=engine.rescue_path,
                overlap_prep=engine.overlap_prep,
                straggler_factor=engine.straggler_factor,
                schedule=schedule or engine.schedule,
                placement=placement if placement is not None else engine.placement,
                backend=backend if backend is not None else engine.backend,
                trace=engine.trace,
            )
        self.engine = engine
        self.mesh = mesh
        self.axis = axis
        self.sync = sync
        self.use_kernel = use_kernel
        self.count_backend = count_backend

    @classmethod
    def for_sites(cls, n_sites: int, **kw) -> "GridRuntime":
        """Runtime with a launch.mesh site mesh when the host has enough
        devices (otherwise mesh=None and the pooled path is used)."""
        return cls(mesh=make_site_mesh(n_sites, kw.get("axis", "sites")), **kw)

    # -- synchronization strategies -----------------------------------------

    def _cluster_sync(self, n_sites: int, cfg: VClusterConfig):
        """Returns (sync_fn, mode) for the merge job."""
        be = self.engine.backend
        partitioned = getattr(be, "partition_sites", False)
        if partitioned and hasattr(be, "ensure_initialized"):
            # bring the distributed runtime up BEFORE any jax backend
            # query: jax.distributed.initialize must precede the first
            # process_count()/devices() call in this process, and this
            # method runs ahead of Engine.run's own begin_run bring-up
            be.ensure_initialized()
        if partitioned and jax.process_count() > 1:
            # A site-PARTITIONED multi-host run executes the merge job on
            # ONE owning process, so its sync must not be a mesh-spanning
            # collective (a shard_map over the global mesh entered from a
            # single process would deadlock the other hosts).  The pooled
            # merge is bit-identical — the paper's redundant logical
            # merge — and the shipped result reaches every process.
            # (SPMD-redundant multi-process runs — partition_sites=False —
            # enter the collective from every process and keep shard_map.)
            if self.sync == "shard_map":
                raise RuntimeError(
                    "sync='shard_map' is not supported on a site-partitioned "
                    "multi-process runtime: the merge job executes on its "
                    "owning process only; use sync='pooled' (bit-identical "
                    "logical merge) or MultiHostBackend(partition_sites=False)"
                )
            return None, "pooled"
        mesh = self.mesh
        if self.sync != "pooled" and mesh is None:
            mesh = make_site_mesh(n_sites, self.axis)
        usable = (
            mesh is not None
            and self.axis in mesh.shape
            and mesh.shape[self.axis] == n_sites
        )
        if self.sync == "shard_map" and not usable:
            raise RuntimeError(
                f"shard_map sync requires a mesh with {self.axis}={n_sites} "
                f"(have {dict(mesh.shape) if mesh is not None else None})"
            )
        if self.sync == "pooled" or not usable:
            return None, "pooled"  # vcluster_site_jobs defaults to merge_gathered

        axis = self.axis

        def sync(per_site: SuffStats) -> MergeResult:
            # place each site's stat triple on its device; the body's
            # all_gather is the protocol's single communication, and the
            # replicated merge is the paper's redundant logical merge
            sharded = jax.device_put(per_site, NamedSharding(mesh, P(axis)))

            def body(st: SuffStats) -> MergeResult:
                st = SuffStats(sizes=st.sizes[0], centers=st.centers[0], sse=st.sse[0])
                gathered = jax.lax.all_gather(st, axis)  # (s, k, ...) tiny
                return merge_gathered(gathered, cfg)

            fn = shard_map(
                body, mesh=mesh, in_specs=(P(axis),), out_specs=P(), check_vma=False
            )
            return fn(sharded)

        return sync, "shard_map"

    # -- applications --------------------------------------------------------

    def _finish_run(self, jobs, rep: RunReport, result, measured, sync_mode: str) -> RuntimeRun:
        """Attach the measured-time-calibrated analytical bounds to a run.
        The specs carry the sites the placement policy ACTUALLY chose
        (``rep.placements``), so the bounds price the executed assignment
        rather than the builders' pre-assigned sites."""
        if rep.owned_jobs is not None:
            # partitioned (multi-host) run: this process only measured its
            # OWNED jobs — complete the record with the owner-measured
            # times the engine ledgered from shipped results, so
            # job_specs(strict=True) and the estimators see one
            # owner-authoritative time per job on every process
            measured = merge_owner_times(measured, rep.job_times, rep.owned_jobs)
        specs = job_specs(jobs, rep.job_times)
        if rep.placements:
            specs = [sp._replace(site=rep.placements.get(sp.name, sp.site)) for sp in specs]
        model = self.engine.model
        return RuntimeRun(
            result=result,
            report=rep,
            measured=measured,
            sync_mode=sync_mode,
            schedule=rep.schedule,
            placement=rep.placement,
            backend=rep.backend,
            n_processes=rep.n_processes,
            owned_sites=rep.owned_sites,
            specs=specs,
            estimated_s=estimate_dag(specs, model),
            estimated_staged_s=estimate_stages_from_specs(specs, model),
        )

    def run(self, app: str, data, params: dict | None = None) -> RuntimeRun:
        """Run ANY registered grid workload: the registry's
        :class:`~repro.workflow.registry.WorkloadSpec` resolves the params,
        builds the SiteJob DAG and names the terminal job; this method
        supplies the runtime context (count backend, kernel toggle, sync
        strategy) and the engine.  The ``run_vclustering``/``run_gfm``/
        ``run_fdm`` methods are thin wrappers over this — a workload
        registered through the registry needs NO runtime change."""
        spec = get_workload(app)
        if spec.runner != "grid":
            raise ValueError(
                f"app {app!r} is a {spec.runner!r} workload, not a grid DAG; "
                "serve it through launch.serve.MiningService"
            )
        p = spec.resolve(params)
        measured: dict[str, float] = {}
        ctx = RunContext(
            measured=measured,
            count_backend=self.count_backend,
            use_kernel=self.use_kernel,
            cluster_sync=self._cluster_sync,
        )
        jobs, mode = spec.build_jobs(data, p, ctx)
        rep, results = self.engine.run_site_jobs(jobs, name=spec.name)
        return self._finish_run(jobs, rep, results[spec.terminal], measured, mode)

    def run_many(self, app: str, datas: list, params_list: list) -> list[FusedRun]:
        """Run SEVERAL same-app requests as ONE engine invocation — the
        cross-request batching seam the serving layer dispatches through.

        Each request's SiteJob DAG is built independently (its own
        resolved params, its own closures/ledgers) and merged into one
        job list under a ``r{j}/`` name prefix; ``batch_key``s are left
        UNPREFIXED, so same-shape fan-out jobs from different requests
        land in the same wave groups and the batched backend executes
        them as one fused dispatch (the builders' batch args carry every
        request-specific value — thresholds, PRNG keys, delta states —
        so the first member's closure can serve the whole merged group).
        The caller is responsible for only merging requests whose
        workload reports the same ``exec_batch_key`` signature; anything
        that varies job shapes or jit-static arguments must stay in
        separate calls.

        Returns one :class:`FusedRun` per request, in order: its own
        terminal result plus its measured device-compute share (the sum
        of the merged report's per-job times under its prefix — the same
        apportioning ``timed_batch`` does per job within a fused group).
        """
        spec = get_workload(app)
        if spec.runner != "grid":
            raise ValueError(
                f"app {app!r} is a {spec.runner!r} workload, not a grid DAG; "
                "serve it through launch.serve.MiningService"
            )
        if len(datas) != len(params_list):
            raise ValueError(
                f"run_many: {len(datas)} datasets vs {len(params_list)} param sets"
            )
        all_jobs: list = []
        modes: list[str] = []
        for j, (data, params) in enumerate(zip(datas, params_list)):
            p = spec.resolve(params)
            ctx = RunContext(
                measured={},
                count_backend=self.count_backend,
                use_kernel=self.use_kernel,
                cluster_sync=self._cluster_sync,
            )
            jobs, mode = spec.build_jobs(data, p, ctx)
            modes.append(mode)
            prefix = f"r{j}/"
            for job in jobs:
                job.name = prefix + job.name
                job.deps = [prefix + d for d in job.deps]
            all_jobs.extend(jobs)
        if len(set(modes)) > 1:
            raise RuntimeError(
                f"run_many: requests resolved to different sync modes {modes}"
            )
        rep, results = self.engine.run_site_jobs(
            all_jobs, name=f"{spec.name}x{len(datas)}"
        )
        outs: list[FusedRun] = []
        for j in range(len(datas)):
            prefix = f"r{j}/"
            compute = sum(
                t for name, t in rep.job_times.items() if name.startswith(prefix)
            )
            outs.append(
                FusedRun(
                    result=results[prefix + spec.terminal],
                    compute_s=compute,
                    backend=rep.backend,
                    report=rep,
                )
            )
        return outs

    def run_vclustering(
        self, key: jax.Array, xs, cfg: VClusterConfig | None = None
    ) -> RuntimeRun:
        """Algorithm 1 end-to-end: per-site K-Means (Pallas assignment
        kernel by default) -> all_gather + logical merge -> per-site border
        perturbation, scheduled through the grid engine."""
        if cfg is None:
            cfg = VClusterConfig(use_kernel=self.use_kernel)
        return self.run("vclustering", xs, {"key": key, "cfg": cfg})

    def run_gfm(
        self, sites, k: int, minsup: float, local_minsup: float | None = None
    ) -> RuntimeRun:
        """Algorithm 2 end-to-end: per-site local Apriori (Pallas support
        counting by default), then the single 2-pass synchronization and
        top-down descent, scheduled through the grid engine."""
        return self.run(
            "gfm", sites, {"k": k, "minsup": minsup, "local_minsup": local_minsup}
        )

    def run_fdm(self, sites, k: int, minsup: float) -> RuntimeRun:
        """FDM baseline through the same scheduler (k level-synchronous
        rounds) — the comparison the paper draws against GFM."""
        return self.run("fdm", sites, {"k": k, "minsup": minsup})
