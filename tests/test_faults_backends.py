"""Fault-injection property tests for the BATCHED and MULTIHOST
execution backends (hypothesis, with the deterministic repro.testing
fallback for hermetic environments).

The invariant under test, across random fan-out DAGs × random injected
fault maps × both engine schedulers: retries, rescue and speculation
NEVER lose or duplicate a job's committed result —

  * every job ends "done" with exactly the value its fn computes, and
    its fn (or its slice of a fused dispatch) executes EXACTLY once per
    run (an injected failure consumes a retry, never a re-execution of
    the fused batch: the batched backend's cache is consumed exactly
    once);
  * a crashed run's rescue file resumes without re-executing completed
    jobs on either backend;
  * speculation duplicates simulated time only — the real callable still
    runs exactly once.

Inline paths were already property-tested (test_scheduler_invariants,
test_workflow); these pin the same guarantees onto the dispatch-fusing
and ownership/shipping backends.  The multihost cells here run in three
in-process modes: partition-free single-process fallback ("multihost"),
force-partitioned wave-fused shipping ("multihost_fused" — the fused-
over-mesh default path, with the collectives degenerating to identity)
and force-partitioned per-job shipping ("multihost_perjob"); the true
multi-process fault cell lives in the subprocess conformance harness
(tests/test_backend_conformance.py::test_fault_injection_under_distribution).
"""

import random
from pathlib import Path

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic container: deterministic fallback
    from repro.testing import given, settings, strategies as st

from repro.runtime.backends import MultiHostBackend
from repro.workflow.dag import DAG, TimedResult
from repro.workflow.engine import SCHEDULES, Engine
from repro.workflow.executor import BatchedBackend
from repro.workflow.faults import FaultInjector
from repro.workflow.overhead import GridModel
from repro.workflow.sitejob import timed_batch

N_SITES = 4


def _model() -> GridModel:
    return GridModel(prep_latency_s=0.0, submit_latency_s=0.0)


def fanout_dag(n_leaves: int, counts: dict, retries: int = 3):
    """``n_leaves`` batchable site jobs + a collector.  ``counts`` tallies
    REAL executions per job name — fn path and fused path both count —
    so the exactly-once property is directly observable."""

    def leaf_fn(i):
        def fn():
            counts[f"leaf_{i}"] = counts.get(f"leaf_{i}", 0) + 1
            return TimedResult(10 * i, 0.0)

        return fn

    def fused(bargs, argss):
        for i in bargs:
            counts[f"leaf_{i}"] = counts.get(f"leaf_{i}", 0) + 1
        return [10 * i for i in bargs]

    bf = timed_batch(fused)
    dag = DAG("fanout")
    for i in range(n_leaves):
        dag.job(
            f"leaf_{i}",
            leaf_fn(i),
            site=i % N_SITES,
            retries=retries,
            batch_key="leaf",
            batched_fn=bf,
            batch_arg=i,
        )
    def collect(*xs):
        counts["collect"] = counts.get("collect", 0) + 1
        return TimedResult(sum(xs), 0.0)

    dag.job("collect", collect, deps=[f"leaf_{i}" for i in range(n_leaves)], retries=retries)
    return dag


def fault_map(seed: int, n_leaves: int) -> dict[str, int]:
    """Random injected-failure map: up to half the jobs fail 1-2 attempts
    (within the retry budget of 3)."""
    rng = random.Random(seed)
    names = [f"leaf_{i}" for i in range(n_leaves)] + ["collect"]
    return {n: rng.randint(1, 2) for n in names if rng.random() < 0.4}


KINDS = ["batched", "multihost", "multihost_fused", "multihost_perjob"]


def _backend(kind: str):
    if kind == "batched":
        return BatchedBackend()
    if kind == "multihost_fused":
        return MultiHostBackend(force_partition=True)
    if kind == "multihost_perjob":
        return MultiHostBackend(force_partition=True, fuse_waves=False)
    return MultiHostBackend()


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    n_leaves=st.integers(min_value=1, max_value=6),
    schedule=st.sampled_from(SCHEDULES),
    kind=st.sampled_from(KINDS),
)
def test_faults_never_lose_or_duplicate_results(seed, n_leaves, schedule, kind):
    counts: dict[str, int] = {}
    dag = fanout_dag(n_leaves, counts)
    faults = fault_map(seed, n_leaves)
    results: dict = {}
    eng = Engine(
        model=_model(),
        faults=FaultInjector(fail=dict(faults)),
        schedule=schedule,
        backend=_backend(kind),
    )
    rep = eng.run(dag, results=results)
    # no lost results: every job committed, with the correct value
    assert results["collect"] == sum(10 * i for i in range(n_leaves))
    for i in range(n_leaves):
        assert results[f"leaf_{i}"] == 10 * i
    assert all(j.status == "done" for j in dag.jobs.values())
    # no duplicated execution: each callable ran exactly once — retries
    # consumed the batched cache / re-attempt, never a second execution
    assert counts == {name: 1 for name in dag.jobs}
    # every injected failure shows up as exactly one ledgered retry
    assert rep.retries == sum(faults.values())


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    kind=st.sampled_from(KINDS),
)
def test_batched_cache_consumed_exactly_once(seed, kind):
    """After any faulty run the batched backend's fuse cache (and the
    multihost backend's wave cache) is empty: every pre-executed peer
    result was handed out exactly once."""
    counts: dict[str, int] = {}
    dag = fanout_dag(5, counts)
    be = _backend(kind)
    eng = Engine(
        model=_model(),
        faults=FaultInjector(fail=fault_map(seed, 5)),
        backend=be,
    )
    results: dict = {}
    eng.run(dag, results=results)
    assert counts == {name: 1 for name in dag.jobs}
    if isinstance(be, BatchedBackend):
        assert be._cache == {}
    if isinstance(be, MultiHostBackend):
        assert be._wave_cache == {}


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    schedule=st.sampled_from(SCHEDULES),
    kind=st.sampled_from(KINDS),
)
def test_rescue_resumes_without_reexecution(seed, schedule, kind):
    """Exhausting the collector's retries crashes the run AFTER the leaf
    frontier committed; the rescued rerun completes WITHOUT re-executing
    any committed job (the driver re-injects rescued values, DAGMan
    rescue-DAG style) — on both backends, both schedulers."""
    import json as _json
    import tempfile

    n = 3 + seed % 3
    rescue = Path(tempfile.mkdtemp()) / f"r_{seed}_{schedule}_{kind}.json"
    counts: dict[str, int] = {}
    dag = fanout_dag(n, counts, retries=1)
    # the collector fails more times than its retry budget -> crash
    eng = Engine(
        model=_model(),
        faults=FaultInjector(fail={"collect": 5}),
        rescue_path=rescue,
        schedule=schedule,
        backend=_backend(kind),
    )
    with pytest.raises(RuntimeError, match="exhausted retries"):
        eng.run(dag, results={})
    assert rescue.exists()
    done_first = set(_json.loads(rescue.read_text()))
    assert done_first == {f"leaf_{i}" for i in range(n)}, "leaf frontier must be rescued"
    assert counts == {f"leaf_{i}": 1 for i in range(n)}
    # second run: same DAG shape, fault gone, SAME rescue file; the
    # driver re-injects the rescued values
    counts2: dict[str, int] = {}
    dag2 = fanout_dag(n, counts2, retries=1)
    results: dict = {
        f"leaf_{i}": 10 * i for i in range(n) if f"leaf_{i}" in done_first
    }
    eng2 = Engine(
        model=_model(), rescue_path=rescue, schedule=schedule, backend=_backend(kind)
    )
    eng2.run(dag2, results=results)
    assert results["collect"] == sum(10 * i for i in range(n))
    # committed jobs were NOT re-executed; only the collector ran
    assert counts2 == {"collect": 1}


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    kind=st.sampled_from(KINDS),
    schedule=st.sampled_from(SCHEDULES),
)
def test_speculation_never_duplicates_execution(seed, kind, schedule):
    """Straggler speculation duplicates SIMULATED time only: with an
    outlier sim_compute_s job, speculative copies appear in the report
    but every callable still runs exactly once."""
    counts: dict[str, int] = {}
    dag = fanout_dag(5, counts)
    # make one leaf a simulated straggler
    dag.jobs[f"leaf_{seed % 5}"].sim_compute_s = 50.0
    for i in range(5):
        if i != seed % 5:
            dag.jobs[f"leaf_{i}"].sim_compute_s = 1.0
    results: dict = {}
    eng = Engine(
        model=_model(),
        straggler_factor=3.0,
        schedule=schedule,
        backend=_backend(kind),
    )
    rep = eng.run(dag, results=results)
    assert results["collect"] == sum(10 * i for i in range(5))
    assert counts == {name: 1 for name in dag.jobs}
    assert rep.speculative >= 1


def test_wave_ledger_counts_waves_not_jobs():
    """The collective-count ledger on a wide fan-out DAG: wave-fused
    shipping performs exactly one shipment per READY WAVE (here 2: the
    leaf fan-out, then the collector), while per-job mode ships once per
    job — the O(jobs) -> O(waves) reduction, surfaced on RunReport."""
    n = 8
    counts: dict[str, int] = {}
    dag = fanout_dag(n, counts)
    be = _backend("multihost_fused")
    results: dict = {}
    rep = Engine(model=_model(), backend=be).run(dag, results=results)
    assert results["collect"] == sum(10 * i for i in range(n))
    assert be.waves == 2
    assert rep.shipments == be.shipments == 2
    assert rep.collective_rounds == 4  # two process_allgather rounds each
    assert rep.shipped_results == 0  # one process owns every site
    # per-job mode on the identical DAG: one shipment per job
    counts2: dict[str, int] = {}
    dag2 = fanout_dag(n, counts2)
    be2 = _backend("multihost_perjob")
    rep2 = Engine(model=_model(), backend=be2).run(dag2, results={})
    assert be2.waves == 0
    assert rep2.shipments == n + 1
    assert rep2.collective_rounds == 2 * (n + 1)


def test_wave_ledger_resets_per_run():
    """begin_run zeroes the ledger: RunReport counts are per-run, not
    cumulative across an engine's lifetime."""
    be = _backend("multihost_fused")
    eng = Engine(model=_model(), backend=be)
    for _ in range(2):
        counts: dict[str, int] = {}
        rep = eng.run(fanout_dag(4, counts), results={})
        assert rep.shipments == be.shipments == 2


def test_wave_faults_consume_cache_not_collectives():
    """Injected faults retry against the wave cache: the shipment count
    stays at the wave count no matter how many retries fire (a retry must
    never trigger a fresh collective, or the processes of a real group
    would desynchronize)."""
    counts: dict[str, int] = {}
    dag = fanout_dag(6, counts)
    be = _backend("multihost_fused")
    rep = Engine(
        model=_model(),
        faults=FaultInjector(fail={"leaf_1": 2, "leaf_4": 1, "collect": 2}),
        backend=be,
    ).run(dag, results={})
    assert rep.retries == 5
    assert rep.shipments == 2
    assert counts == {name: 1 for name in dag.jobs}


def test_wave_ships_owner_failure_as_shared_error():
    """A real exception inside an owned job's callable ships with the
    wave and raises AFTER the collective, naming the owning process — the
    contract that keeps the peers out of a stranded allgather."""
    dag = DAG("boom")

    def bad():
        raise ValueError("boom")

    dag.job("a", bad, retries=0)
    be = _backend("multihost_fused")
    with pytest.raises(RuntimeError, match="failed on its owning process"):
        Engine(model=_model(), backend=be).run(dag, results={})


def test_inline_backend_reports_no_ledger():
    """Local backends expose no collective ledger; RunReport keeps the
    zero defaults."""
    counts: dict[str, int] = {}
    rep = Engine(model=_model(), backend=BatchedBackend()).run(
        fanout_dag(4, counts), results={}
    )
    assert (rep.shipments, rep.collective_rounds, rep.shipped_results) == (0, 0, 0)


def test_rescue_skips_batched_fuse_for_done_jobs():
    """A rescued (already-done) job must not be pulled into a fused
    dispatch: the batched backend only fuses peers that still need
    executing."""
    counts: dict[str, int] = {}
    dag = fanout_dag(3, counts)
    # mark leaf_0 as already done (rescue semantics) with its result
    dag.jobs["leaf_0"].status = "done"
    results = {"leaf_0": 0}
    eng = Engine(model=_model(), backend=BatchedBackend())
    eng.run(dag, results=results)
    assert counts.get("leaf_0", 0) == 0
    assert counts["leaf_1"] == 1 and counts["leaf_2"] == 1
    assert results["collect"] == 30
