"""Workload plugin registry: the one seam every consumer derives from.

Covers the registry contract itself (validation, schemas, coercion), the
equivalence of the legacy ``run_*`` wrappers with the generic
``GridRuntime.run``, the registry-added workloads (count-distribution
Apriori, streaming top-k) end-to-end through inline AND batched backends
and through ``MiningService`` requests, and the single-source-of-truth
properties the serving layer's two old "unknown app" sites used to
drift on."""

from __future__ import annotations

import math

import jax
import numpy as np
import pytest

from repro.core.apriori import DeltaApriori, bruteforce_frequent, topk_itemsets
from repro.core.cdapriori import cd_mine
from repro.core.fdm import fdm_mine
from repro.data.synthetic import ibm_transactions
from repro.launch.serve import APPS, MiningService
from repro.runtime.conformance import (
    _K_ITEMSETS,
    _MINSUP,
    conformance_cell,
    make_inputs,
    result_digest,
    run_app,
)
from repro.workflow.registry import (
    Param,
    app_names,
    app_table_markdown,
    conformance_apps,
    get_workload,
    validate_registry,
    workloads,
)

# ---------------------------------------------------------------------------
# Registry contract
# ---------------------------------------------------------------------------


def test_registry_fully_specified():
    """Every registered workload declares a complete spec — the same
    check tools/check_registry.py gates in CI."""
    assert validate_registry() == []


def test_registry_contains_the_family():
    names = app_names()
    for expected in ("apriori", "gfm", "fdm", "kmeans", "vclustering",
                     "cd_apriori", "topk"):
        assert expected in names
    assert set(conformance_apps()) == {"vclustering", "gfm", "fdm", "cd_apriori"}


def test_unknown_app_error_names_the_family():
    with pytest.raises(ValueError, match="unknown app"):
        get_workload("word2vec")


def test_param_coercion_and_defaults():
    spec = get_workload("gfm")
    p = spec.resolve({"k": "4", "minsup": "0.2"})
    assert p["k"] == 4 and isinstance(p["k"], int)
    assert p["minsup"] == pytest.approx(0.2)
    assert p["split_seed"] == 0 and p["n_sites"] is None
    with pytest.raises(ValueError, match="no param"):
        spec.resolve({"bogus": 1})
    with pytest.raises(ValueError, match="expects int"):
        spec.resolve({"k": 2.5})


def test_validate_submitted_rejects_internal_and_nonfinite():
    spec = get_workload("vclustering")
    ok = spec.validate_submitted({"k_local": 4, "iters": 8})
    assert ok == {"k_local": 4, "iters": 8}
    with pytest.raises(ValueError, match="does not accept"):
        spec.validate_submitted({"key": jax.random.PRNGKey(0)})  # internal
    mine = get_workload("apriori")
    for bad in (float("inf"), float("-inf"), float("nan")):
        with pytest.raises(ValueError, match="non-finite"):
            mine.validate_submitted({"minsup": bad})
    with pytest.raises(ValueError, match="non-finite"):
        mine.validate_submitted({"min_count": math.inf})


def test_app_table_markdown_lists_every_app():
    table = app_table_markdown()
    for spec in workloads():
        assert f"`{spec.name}`" in table


# ---------------------------------------------------------------------------
# One source of truth: serve-side validation == registry
# ---------------------------------------------------------------------------


def _service(n_items: int = 10) -> MiningService:
    svc = MiningService(count_backend="jnp", use_kernel=False, n_sites=2)
    svc.register_dataset("tx", "transactions", n_items=n_items)
    svc.register_dataset("pts", "points", dim=2)
    svc.append_transactions("tx", ibm_transactions(0, 120, n_items))
    rng = np.random.default_rng(0)
    svc.append_points("pts", rng.normal(size=(90, 2)).astype(np.float32))
    return svc


def test_submit_validated_set_equals_registered_set():
    """serve.APPS IS the registry — the two old hand-maintained app lists
    (submit's tuple and _execute's if/elif chain) cannot drift again."""
    assert tuple(APPS) == app_names()
    svc = _service()
    for spec in workloads():
        ds = "tx" if spec.dataset_kind == "transactions" else "pts"
        wrong = "pts" if ds == "tx" else "tx"
        rid = svc.submit("t", spec.name, ds, dict(spec.smoke_params[0]))
        assert svc.poll(rid) == "queued"  # every registered app is admissible
        with pytest.raises(ValueError, match="dataset"):
            svc.submit("t", spec.name, wrong, dict(spec.smoke_params[0]))


def test_execute_fallback_unreachable_for_registered_apps():
    """Every registered app runs end-to-end through a MiningService
    request — there is no per-app branch left in _execute to fall off
    (the old dead-end 'unknown app' raise is structurally gone)."""
    svc = _service()
    rids = {}
    for spec in workloads():
        ds = "tx" if spec.dataset_kind == "transactions" else "pts"
        rids[spec.name] = svc.submit("t", spec.name, ds, dict(spec.smoke_params[0]))
    svc.drain()
    for name, rid in rids.items():
        assert svc.poll(rid) == "done", (name, svc.request(rid).error)


def test_new_workloads_through_service_with_accounting():
    """The registry-added apps keep cache/coalescing accounting intact:
    identical concurrent requests coalesce into one execution, repeats
    after completion are cache hits."""
    svc = _service()
    a = svc.submit("t0", "cd_apriori", "tx", {"k": 2, "minsup": 0.3})
    b = svc.submit("t1", "cd_apriori", "tx", {"k": 2, "minsup": 0.3})
    svc.step()
    assert svc.request(b).coalesced_into == a
    assert svc.executions == 1 and svc.coalesced == 1
    c = svc.submit("t2", "cd_apriori", "tx", {"k": 2, "minsup": 0.3})
    t = svc.submit("t2", "topk", "tx", {"k": 2, "top": 5})
    svc.step()
    assert svc.request(c).cache_hit and svc.request(c).backend == "cache"
    assert svc.poll(t) == "done" and not svc.request(t).cache_hit
    t2 = svc.submit("t0", "topk", "tx", {"k": 2, "top": 5})
    svc.step()
    assert svc.request(t2).cache_hit
    assert svc.executions == 2  # one cd_apriori + one topk


# ---------------------------------------------------------------------------
# Generic run == legacy wrappers; new apps across execution backends
# ---------------------------------------------------------------------------


def test_wrappers_equal_generic_run():
    """run_vclustering/run_gfm/run_fdm are thin wrappers over the generic
    registry-backed run: bit-identical digests either way."""
    from repro.core.vclustering import VClusterConfig
    from repro.runtime.gridruntime import GridRuntime

    xs, dbs = make_inputs(3)
    cfg = VClusterConfig(k_local=3, kmeans_iters=5, use_kernel=False)
    for app, call, params in (
        ("gfm", lambda rt: rt.run_gfm(dbs, _K_ITEMSETS, _MINSUP),
         {"k": _K_ITEMSETS, "minsup": _MINSUP}),
        ("fdm", lambda rt: rt.run_fdm(dbs, _K_ITEMSETS, _MINSUP),
         {"k": _K_ITEMSETS, "minsup": _MINSUP}),
        ("vclustering", lambda rt: rt.run_vclustering(jax.random.PRNGKey(0), xs, cfg),
         {"key": jax.random.PRNGKey(0), "cfg": cfg}),
    ):
        rt = GridRuntime(backend="inline", sync="pooled", use_kernel=False,
                         count_backend="jnp")
        legacy = result_digest(app, call(rt))
        data = xs if app == "vclustering" else dbs
        generic = result_digest(app, rt.run(app, data, params))
        assert legacy == generic, app
    # the wrapper's no-cfg default is the paper config (k_local=20), NOT
    # the service default — pinned so the registry defaults can't drift it
    run = GridRuntime(backend="inline", sync="pooled", use_kernel=False,
                      count_backend="jnp").run_vclustering(jax.random.PRNGKey(0), xs)
    assert run.result.merged.labels.shape[0] == len(xs) * 20  # s * k_local slots


def test_generic_run_rejects_local_workloads():
    from repro.runtime.gridruntime import GridRuntime

    rt = GridRuntime(backend="inline", sync="pooled", use_kernel=False,
                     count_backend="jnp")
    with pytest.raises(ValueError, match="local"):
        rt.run("apriori", None, {})


def test_cd_apriori_inline_batched_bit_identical():
    """The registry-added grid workload satisfies the conformance
    contract: inline and batched digests AND fingerprints match."""
    for schedule in ("staged", "async"):
        cell_in = conformance_cell("cd_apriori", 4, schedule, "inline")
        cell_ba = conformance_cell("cd_apriori", 4, schedule, "batched")
        assert cell_in["digest"] == cell_ba["digest"]
        assert cell_in["fingerprint"] == cell_ba["fingerprint"]


def test_cd_apriori_matches_oracles():
    """SiteJob decomposition == in-process cd_mine == bruteforce counts,
    and the frequents agree with FDM over the same sites (same global
    threshold, different protocol)."""
    xs, dbs = make_inputs(4)
    run = run_app("cd_apriori", 4, "staged", "inline")
    oracle = cd_mine(dbs, _K_ITEMSETS, _MINSUP, backend="jnp")
    spec = get_workload("cd_apriori")
    assert spec.digest(run.result) == spec.digest(oracle)
    n_total = sum(db.n_tx for db in dbs)
    dense = ibm_transactions(seed=2, n_tx=n_total, n_items=dbs[0].n_items,
                             avg_tx_len=5, n_patterns=4)
    g_min = int(np.ceil(_MINSUP * n_total))
    assert dict(bruteforce_frequent(dense, _K_ITEMSETS, g_min)) == dict(oracle.frequent)
    fdm = fdm_mine(dbs, _K_ITEMSETS, _MINSUP, backend="jnp")
    assert dict(fdm.frequent) == dict(oracle.frequent)
    # CD ledger: one count-vector exchange per level, every site counts
    assert oracle.comm.rounds == len([c for c in oracle.per_level_candidates if c])


def test_topk_matches_bruteforce_ranking():
    n_items = 10
    dense = ibm_transactions(3, 150, n_items, avg_tx_len=4, n_patterns=3)
    delta = DeltaApriori(n_items, backend="jnp")
    delta.append(dense)
    res = topk_itemsets(delta, 2, 7)
    counts = dict(bruteforce_frequent(dense, 2, 1))
    best = sorted(counts.items(), key=lambda ic: (-ic[1], len(ic[0]), ic[0]))[:7]
    assert res.items == best
    assert all(c >= res.threshold for _, c in res.items)
    # served again from the same delta state: no new device passes
    res2 = topk_itemsets(delta, 2, 7)
    assert res2.items == res.items and res2.count_calls == 0


def test_registering_requires_unique_names():
    from repro.workflow.registry import WorkloadSpec, register

    spec = get_workload("gfm")
    with pytest.raises(ValueError, match="already registered"):
        register(spec)
    # and Param kinds are validated through validate_registry on a bad spec
    bad = WorkloadSpec(
        name="", dataset_kind="nope", runner="nope", description="",
        params=(Param("x", "complex"),), result_fields=(), digest=None,
    )
    from repro.workflow import registry as reg

    reg._REGISTRY["__bad__"] = bad
    try:
        problems = validate_registry()
        assert any("bad dataset_kind" in p for p in problems)
        assert any("bad runner" in p for p in problems)
        assert any("bad kind" in p for p in problems)
        assert any("result schema" in p for p in problems)
    finally:
        del reg._REGISTRY["__bad__"]
    assert validate_registry() == []
