"""Analytical overhead model — the paper's §5.2.2.

estimated_time(workflow) = Σ over stages of max over parallel jobs of
(compute + transfer), with transfer times from a measured link matrix.
The paper compares this "ideal" bound against grid execution and finds
98% overhead for the cheap clustering workflow (Table 3); the engine
reproduces the measured side with its simulated job-prep latencies.

GRID5000_LINKS reproduces the paper's Table 2 (Mb/s - ms) exactly.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

# Table 2: average bandwidths (Mb/s) and latencies (ms) among the sites.
# Order: Orsay, Toulouse, Rennes, Nancy, Sophia.  None on the diagonal.
SITES = ["Orsay", "Toulouse", "Rennes", "Nancy", "Sophia"]
BW_MBPS = [
    [None, 16.15, 57.73, 90.77, 17.63],
    [38.97, None, 26.08, 28.89, 35.74],
    [66.33, 12.71, None, 44.63, 26.96],
    [106.63, 14.13, 44.54, None, 30.01],
    [21.45, 17.41, 26.93, 30.14, None],
]
LAT_MS = [
    [None, 15, 8, 5, 28],
    [15, None, 19, 17, 14],
    [8, 19, None, 11, 19],
    [5, 17, 11, None, 17],
    [28, 14, 19, 17, None],
]
LOCAL_BW_MBPS = 941.0
LOCAL_LAT_MS = 0.07

# §5.3: measured Condor/DAGMan workflow preparation latency (a 2-job DAG
# on a laptop) — "about 295 seconds ... the interval between the workflow
# launching and the first job submission".
DAGMAN_PREP_S = 295.0


@dataclass(frozen=True)
class GridModel:
    prep_latency_s: float = DAGMAN_PREP_S
    submit_latency_s: float = 3.0  # per-job scheduling/matchmaking cost
    n_sites: int = 5

    def transfer_s(self, src: int, dst: int, nbytes: int) -> float:
        """Transfer time for nbytes between sites (Table 2 units)."""
        if nbytes <= 0:
            return 0.0
        if src == dst:
            bw, lat = LOCAL_BW_MBPS, LOCAL_LAT_MS
        else:
            i, j = src % len(SITES), dst % len(SITES)
            bw = BW_MBPS[i][j] or LOCAL_BW_MBPS
            lat = LAT_MS[i][j] or LOCAL_LAT_MS
        return lat / 1e3 + (nbytes * 8) / (bw * 1e6)

    def worst_transfer_s(self, nbytes: int) -> float:
        worst = 0.0
        for i in range(len(SITES)):
            for j in range(len(SITES)):
                if i != j:
                    worst = max(worst, self.transfer_s(i, j, nbytes))
        return worst


def estimate_stages(stages: list[list[tuple[float, int, int, int]]], model: GridModel) -> float:
    """Ideal (analytical) execution time of a staged workflow.

    stages: list of stages; each stage is a list of parallel jobs
    (compute_s, input_bytes, output_bytes, site).  Per the paper: overall
    time = Σ_stage max_job (transfer_in + compute + transfer_out),
    transfers measured against the submit site (site 0).
    """
    total = 0.0
    for stage in stages:
        worst = 0.0
        for compute_s, in_b, out_b, site in stage:
            t = model.transfer_s(0, site, in_b) + compute_s + model.transfer_s(site, 0, out_b)
            worst = max(worst, t)
        total += worst
    return total


def overhead_pct(measured_s: float, estimated_s: float) -> float:
    """Table 3's 'Estimated overhead' column."""
    if measured_s <= 0:
        return 0.0
    return 100.0 * (measured_s - estimated_s) / measured_s
