"""Sharded input pipeline: deterministic, restartable token batches.

Production shape: each host draws only its addressable shard of the
global batch (`process_index`/`process_count` striding), the stream is a
pure function of (seed, step) so a restarted job resumes mid-stream
exactly (checkpoint stores just the step), and device placement uses the
same logical-axis rules as everything else.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np

from repro.sharding import Rules, logical_to_pspec


@dataclass
class TokenStream:
    """Synthetic LM token stream (stands in for a tokenized corpus reader;
    the interface — `batch_at(step)` pure in (seed, step) — is what the
    fault-tolerance machinery relies on)."""

    vocab: int
    global_batch: int
    seq_len: int
    seed: int = 0
    frontend_len: int = 0
    d_model: int = 0

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        toks = rng.integers(0, self.vocab, size=(self.global_batch, self.seq_len + 1), dtype=np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.frontend_len:
            out["frontend"] = rng.normal(0, 1, (self.global_batch, self.frontend_len, self.d_model)).astype(
                np.float32
            )
        return out

    def host_batch_at(self, step: int) -> dict:
        """This host's stripe of the global batch (multi-host layout)."""
        full = self.batch_at(step)
        n, i = jax.process_count(), jax.process_index()
        return jax.tree.map(lambda x: x[i::n], full)


def device_put_batch(batch: dict, mesh, rules: Rules, axes=("batch", "seq")):
    """Place a host batch onto the mesh with rule-derived shardings."""
    from jax.sharding import NamedSharding

    def put(x):
        ax = axes[: x.ndim] + (None,) * max(0, x.ndim - len(axes))
        sh = NamedSharding(mesh, logical_to_pspec(ax, x.shape, rules, mesh))
        return jax.device_put(x, sh)

    return jax.tree.map(put, batch)
