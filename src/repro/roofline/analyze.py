"""Roofline term derivation from compiled dry-run artifacts.

compute   = HLO_FLOPs        / (chips * peak_FLOP/s)
memory    = HLO_bytes        / (chips * HBM_bw)
collective= collective_bytes / (chips * link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are
NOT there — we parse the optimized (post-SPMD) HLO text and sum operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (async -start variants counted once, -done skipped).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")

COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclass
class CollectiveStats:
    bytes_by_type: dict = field(default_factory=dict)
    count_by_type: dict = field(default_factory=dict)
    total_bytes: int = 0

    def as_dict(self):
        return {
            "bytes_by_type": self.bytes_by_type,
            "count_by_type": self.count_by_type,
            "total_bytes": self.total_bytes,
        }


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum RESULT-side operand sizes of every collective op instance."""
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        lhs_rhs = s.split("=", 1)
        rhs = lhs_rhs[1].lstrip()
        m = re.match(r"(?:\(|)([a-z0-9\[\],{}:TSE# ]*?)\)? ?([a-z\-]+)\(", rhs)
        # find which collective op (if any) this instruction is
        op = None
        for c in COLLECTIVES:
            if re.search(rf"\b{c}(-start)?\(", rhs):
                op = c
                break
            if re.search(rf"\b{c}-done\(", rhs):
                op = "skip"
                break
        if op is None or op == "skip":
            continue
        # result shapes are between '=' and the op name
        head = rhs.split(op)[0]
        b = sum(shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(head))
        st.bytes_by_type[op] = st.bytes_by_type.get(op, 0) + b
        st.count_by_type[op] = st.count_by_type.get(op, 0) + 1
        st.total_bytes += b
    return st


def roofline_terms(
    flops: float,
    hlo_bytes: float,
    coll_bytes: float,
    chips: int,
    hw: dict,
    per_device: bool = True,
) -> dict:
    """All three terms in SECONDS.  ``per_device=True`` means flops/bytes
    already describe one device's partitioned module (XLA cost analysis of
    the post-SPMD executable); otherwise divide by chip count."""
    div = 1 if per_device else chips
    t_compute = (flops / div) / hw["peak_flops_bf16"]
    t_memory = (hlo_bytes / div) / hw["hbm_bw"]
    t_coll = (coll_bytes / div) / hw["ici_bw"]
    dom = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll), key=lambda kv: kv[1]
    )[0]
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dom,
        "bound_s": max(t_compute, t_memory, t_coll),
        # fraction of the roofline bound that is useful compute
        "roofline_fraction": t_compute / max(t_compute, t_memory, t_coll, 1e-30),
    }
