"""Logical-axis sharding rules: divisibility fallbacks, axis-reuse
prevention, spec building — pure-host tests (AbstractMesh, no devices)."""

import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import abstract_mesh
from repro.sharding import BASELINE, GRIDLOCAL, ShapeAxes, logical_to_pspec

MESH1 = abstract_mesh((16, 16), ("data", "model"))
MESH2 = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


class TestLogicalToPspec:
    def test_basic_tp(self):
        sp = logical_to_pspec(("embed", "mlp"), (4096, 16384), BASELINE, MESH1)
        assert sp == P("data", "model")

    def test_batch_uses_pod_and_data(self):
        sp = logical_to_pspec(("batch", "seq"), (256, 4096), BASELINE, MESH2)
        assert sp == P(("pod", "data"))

    def test_batch_single_pod_mesh_drops_pod(self):
        sp = logical_to_pspec(("batch", "seq"), (256, 4096), BASELINE, MESH1)
        assert sp == P("data")

    def test_indivisible_dim_falls_back_to_replicated(self):
        # 8 experts cannot shard over model=16
        sp = logical_to_pspec(("experts", "embed", "expert_mlp"), (8, 6144, 16384), BASELINE, MESH1)
        assert sp == P(None, "data", "model")
        # 64 experts CAN
        sp2 = logical_to_pspec(("experts", "embed", "expert_mlp"), (64, 2048, 1408), BASELINE, MESH1)
        assert sp2[0] == "model"

    def test_axis_never_reused_across_dims(self):
        # batch takes data; kv_seq would also want data -> dropped
        sp = logical_to_pspec(
            ("batch", "kv_seq", "kv_heads", None), (128, 32768, 4, 256), BASELINE, MESH1
        )
        assert sp == P("data")  # trailing Nones trimmed; no double 'data'

    def test_batch1_long_context_gives_data_to_cache(self):
        sp = logical_to_pspec(
            ("batch", "kv_seq", "kv_heads", None), (1, 524288, 4, 256), BASELINE, MESH1
        )
        assert sp[0] is None
        assert sp[1] == "data"

    def test_partial_divisibility_prefix(self):
        # dim 32 with rule (pod, data) = 2*16: full product divides
        sp = logical_to_pspec(("batch",), (32,), BASELINE, MESH2)
        assert sp == P(("pod", "data"))
        # dim 2 only allows pod (singleton tuples canonicalize to the bare
        # axis name on current jax; older versions keep them distinct)
        sp2 = logical_to_pspec(("batch",), (2,), BASELINE, MESH2)
        assert sp2 in (P("pod"), P(("pod",)))


class TestShapeAxes:
    def test_struct_with_and_without_mesh(self):
        sa = ShapeAxes(shape=(64, 128), dtype="float32", axes=("embed", "mlp"))
        s0 = sa.struct()
        assert s0.shape == (64, 128) and s0.sharding is None

    def test_default_axes_fill(self):
        sa = ShapeAxes(shape=(3, 4, 5), dtype="int32")
        assert sa.axes == (None, None, None)

    def test_axes_length_checked(self):
        with pytest.raises(AssertionError):
            ShapeAxes(shape=(3, 4), dtype="f4", axes=("a",))


class TestGridlocalRules:
    def test_grid_axis_maps_to_pod(self):
        sp = logical_to_pspec(("grid", "vocab", "embed"), (2, 32000, 4096), GRIDLOCAL, MESH2)
        assert sp[0] == "pod"

    def test_gridlocal_batch_excludes_pod(self):
        sp = logical_to_pspec(("batch", "seq"), (256, 4096), GRIDLOCAL, MESH2)
        assert sp == P("data")
