"""Trip-count-aware cost extraction from optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE, which
undercounts scanned layer stacks (and the collectives inside them) by the
trip count.  This module parses ``compiled.as_text()`` into computations,
builds the call graph (while bodies x known_trip_count, conditional
branches, calls), and accumulates per-device:

  * flops            — 2*M*N*K for every dot (+1 flop/elem for reduces)
  * traffic_bytes    — HBM traffic estimate: operand+result bytes of every
                       top-level fusion/dot/copy/etc (fusion internals are
                       by construction register/VMEM-resident)
  * collective bytes — per type, max(operand, result) bytes per instance
                       (≈ wire volume for AG/AR/RS/A2A/CP), tagged
                       pod-crossing when a replica group spans pods

Known limits (documented in EXPERIMENTS.md): elementwise flops ignored
(VPU-dominated terms underestimate a few %), conditional branches both
counted, convolutions not used by our models.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

ARRAY_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# name and '=' prefix; the op is found separately (types may contain
# tuples with /*index=N*/ comments, so a single regex over the type fails)
ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%([^\s=]+)\s*=\s*(.*)$")
OP_RE = re.compile(r"\b([a-z][a-z0-9\-]*)\(")
COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([^\s(]+)\s*\(.*\)\s*->\s*.*\{")
TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
CALLEE_RE = re.compile(r"(?:condition|body|to_apply|calls|branch_computations)=\{?%?([^,)}\s]+(?:, ?%[^,)}\s]+)*)\}?")

COLLECTIVE_OPS = {
    "all-reduce": "all-reduce", "all-reduce-start": "all-reduce",
    "all-gather": "all-gather", "all-gather-start": "all-gather",
    "reduce-scatter": "reduce-scatter",
    "all-to-all": "all-to-all",
    "collective-permute": "collective-permute", "collective-permute-start": "collective-permute",
}
SKIP_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast", "after-all",
    "partition-id", "replica-id", "all-reduce-done", "all-gather-done",
    "collective-permute-done", "custom-call", "iota", "rng-bit-generator",
}
# ops whose operands+result we count as HBM traffic at top level
TRAFFIC_OPS_EXTRA = {
    "fusion", "dot", "copy", "reduce", "sort", "gather", "scatter", "broadcast",
    "dynamic-slice", "dynamic-update-slice", "transpose", "reshape", "slice",
    "concatenate", "convert", "pad", "select", "add", "multiply", "subtract",
    "divide", "exponential", "tanh", "compare", "reduce-window", "convolution",
}


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    tot = 0
    for dt, dims in ARRAY_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        tot += n * _DTYPE_BYTES[dt]
    return tot


def _shape_dims(type_str: str) -> list[int]:
    m = ARRAY_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    line: str
    op_pos: int = 0  # offset of the op call within `line` (operand parsing)


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    symtab: dict = field(default_factory=dict)  # %name -> type_str


def parse_computations(text: str) -> tuple[dict, str]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            if s.endswith("{") and ("->" in s):
                m = COMP_HDR_RE.match(s)
                if m:
                    name = m.group(1)
                    cur = Computation(name=name)
                    if s.startswith("ENTRY"):
                        entry = name
            continue
        if s == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = ASSIGN_RE.match(line)
        if m:
            nm, rest = m.group(1), m.group(2)
            om = OP_RE.search(rest)
            if not om:
                continue
            tstr = rest[: om.start()].strip()
            op = om.group(1)
            cur.symtab[nm] = tstr
            cur.instrs.append(Instr(name=nm, type_str=tstr, op=op, line=rest, op_pos=om.start()))
    return comps, entry


def _operands(instr: "Instr") -> list[str]:
    """Operand %names of an instruction (parens right after the op name)."""
    line = instr.line
    i = line.find("(", instr.op_pos)
    if i < 0:
        return []
    depth = 0
    j = i
    for j in range(i, len(line)):
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
            if depth == 0:
                break
    inner = line[i + 1 : j]
    return re.findall(r"%([^\s,()]+)", inner)


def _dot_flops(instr: Instr, comp: Computation) -> float:
    ops = _operands(instr)
    if not ops:
        return 0.0
    lhs_t = comp.symtab.get(ops[0], "")
    lhs_dims = _shape_dims(lhs_t)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.line)
    contract = 1
    if m and m.group(1):
        for d in m.group(1).split(","):
            contract *= lhs_dims[int(d)] if int(d) < len(lhs_dims) else 1
    out = _shape_dims(instr.type_str)
    return 2.0 * math.prod(out or [0]) * contract


def _parse_replica_groups(line: str) -> list[list[int]]:
    m = re.search(r"replica_groups=\{(\{[0-9, ]+\}(?:, ?\{[0-9, ]+\})*)\}", line)
    if m:
        return [
            [int(x) for x in g.split(",") if x.strip()]
            for g in re.findall(r"\{([0-9, ]+)\}", m.group(1))
        ]
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?", line)
    if m:
        ng, sz = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        n = math.prod(dims)
        ids = list(range(n))
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            # reshape to dims, transpose by perm, flatten
            import itertools

            arr = ids
            # build multi-d index walk
            strides = [0] * len(dims)
            acc = 1
            for i in range(len(dims) - 1, -1, -1):
                strides[i] = acc
                acc *= dims[i]
            out = []
            shape_t = [dims[p] for p in perm]
            for idx in itertools.product(*[range(d) for d in shape_t]):
                orig = sum(idx[k] * strides[perm[k]] for k in range(len(perm)))
                out.append(orig)
            ids = out
        return [ids[i * sz : (i + 1) * sz] for i in range(ng)]
    return []


def _fusion_root(ins: Instr, comps: dict):
    """Root instruction of a fusion's called computation (the last instr —
    HLO prints the ROOT last)."""
    km = re.search(r"calls=%?([^\s,)]+)", ins.line)
    if not km or km.group(1) not in comps:
        return None, None
    sub = comps[km.group(1)]
    return (sub.instrs[-1] if sub.instrs else None), sub


def _fusion_param_bytes(sub: Computation, skip: set[str] = frozenset()) -> dict[int, float]:
    """Effective read-bytes per fusion parameter index.

    A fusion parameter whose ONLY consumers are dynamic-slice/gather ops
    reads just the slice window(s), not the whole buffer (scan bodies
    slicing their stacked xs; KV-cache reads of the live prefix are NOT
    sliced and stay fully charged)."""
    # param name -> index, and name -> full bytes
    param_idx: dict[str, int] = {}
    for i2 in sub.instrs:
        if i2.op == "parameter":
            m = re.search(r"parameter\((\d+)\)", i2.line)
            if m:
                param_idx[i2.name] = int(m.group(1))
    sliced_bytes: dict[str, float] = {}
    full_consumers: set[str] = set()
    for i2 in sub.instrs:
        if i2.op == "parameter":
            continue
        ops = _operands(i2)
        for o in ops:
            if o in param_idx:
                if i2.op in ("dynamic-slice", "gather", "slice"):
                    sliced_bytes[o] = sliced_bytes.get(o, 0.0) + _shape_bytes(i2.type_str)
                else:
                    full_consumers.add(o)
    out: dict[int, float] = {}
    for name, idx in param_idx.items():
        if name in skip:  # in-place accumulator: aliased, not re-read
            out[idx] = 0.0
            continue
        full = _shape_bytes(sub.symtab.get(name, ""))
        if name in full_consumers or name not in sliced_bytes:
            out[idx] = full
        else:
            out[idx] = min(full, sliced_bytes[name])
    return out


def traffic_of(ins: Instr, comp: Computation, comps: dict) -> float:
    """HBM traffic estimate for one top-level instruction.

    In-place patterns (dynamic-update-slice — scan output stacking,
    KV-cache writes — including when fused as a fusion root) are charged
    for the touched SLICE, not the whole accumulator buffer; fusion
    parameters consumed only through dynamic-slice are charged the slice."""
    if ins.op in SKIP_OPS or ins.op in ("while", "conditional", "call"):
        return 0.0
    res = _shape_bytes(ins.type_str)
    if ins.op in ("dynamic-slice", "gather", "slice"):
        return 2.0 * res  # reads only the slice
    if ins.op == "dynamic-update-slice":
        ops = _operands(ins)
        upd = _shape_bytes(comp.symtab.get(ops[1], "")) if len(ops) > 1 else 0
        return 2.0 * upd
    if ins.op == "fusion":
        root, sub = _fusion_root(ins, comps)
        write = res
        skip: set[str] = set()
        if root is not None and root.op == "dynamic-update-slice":
            rops = _operands(root)
            write = 2.0 * (_shape_bytes(sub.symtab.get(rops[1], "")) if len(rops) > 1 else 0)
            if rops:
                skip.add(rops[0])  # the in-place accumulator buffer
        if sub is not None:
            pb = _fusion_param_bytes(sub, skip)
            reads = sum(pb.get(i, 0.0) for i in range(len(_operands(ins))))
            return write + reads
        return write + sum(_shape_bytes(comp.symtab.get(o, "")) for o in _operands(ins))
    opb = sum(_shape_bytes(comp.symtab.get(o, "")) for o in _operands(ins))
    return res + opb


@dataclass
class HloCosts:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    coll_bytes_by_type: dict = field(default_factory=dict)
    coll_count_by_type: dict = field(default_factory=dict)
    coll_bytes_cross_pod: float = 0.0
    coll_bytes_total: float = 0.0
    while_trips: dict = field(default_factory=dict)

    def as_dict(self):
        return {
            "flops": self.flops,
            "traffic_bytes": self.traffic_bytes,
            "bytes_by_type": self.coll_bytes_by_type,
            "count_by_type": self.coll_count_by_type,
            "cross_pod_bytes": self.coll_bytes_cross_pod,
            "total_bytes": self.coll_bytes_total,
        }


def analyze_hlo(text: str, chips_per_pod: int = 256) -> HloCosts:
    comps, entry = parse_computations(text)
    out = HloCosts()

    # reachable computations with multipliers (ENTRY x1; while bodies x trip)
    mult: dict[str, float] = {}

    def visit(name: str, m: float):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        comp = comps[name]
        for ins in comp.instrs:
            if ins.op == "while":
                tm = TRIP_RE.search(ins.line)
                trips = float(tm.group(1)) if tm else 1.0
                cm = re.search(r"condition=%?([^\s,)]+)", ins.line)
                bm = re.search(r"body=%?([^\s,)]+)", ins.line)
                if bm:
                    visit(bm.group(1), m * trips)
                if cm:
                    visit(cm.group(1), m * (trips + 1))
            elif ins.op == "conditional":
                bm = re.search(r"branch_computations=\{([^}]*)\}", ins.line)
                tm = re.search(r"(?:true|false)_computation=%?([^\s,)]+)", ins.line)
                if bm:
                    for b in re.findall(r"%?([^\s,]+)", bm.group(1)):
                        visit(b, m)
                for key in ("true_computation", "false_computation"):
                    km = re.search(rf"{key}=%?([^\s,)]+)", ins.line)
                    if km:
                        visit(km.group(1), m)
            elif ins.op == "call":
                km = re.search(r"to_apply=%?([^\s,)]+)", ins.line)
                if km:
                    visit(km.group(1), m)

    if entry:
        visit(entry, 1.0)

    # fusion sub-computations: dots can hide inside fusions — count their
    # flops with the PARENT's multiplier, but not their traffic.
    fusion_parent: dict[str, float] = {}
    for cname, m in mult.items():
        for ins in comps[cname].instrs:
            if ins.op == "fusion":
                km = re.search(r"calls=%?([^\s,)]+)", ins.line)
                if km:
                    fusion_parent[km.group(1)] = fusion_parent.get(km.group(1), 0.0) + m

    for cname, m in mult.items():
        comp = comps[cname]
        for ins in comp.instrs:
            if ins.op == "dot":
                out.flops += m * _dot_flops(ins, comp)
            elif ins.op in ("reduce", "reduce-window"):
                ops = _operands(ins)
                if ops:
                    out.flops += m * _shape_bytes(comp.symtab.get(ops[0], "")) / 4.0
            if ins.op in COLLECTIVE_OPS:
                ctype = COLLECTIVE_OPS[ins.op]
                res_b = _shape_bytes(ins.type_str)
                if ins.op.endswith("-start"):
                    res_b = res_b / 2  # start result = (input, output) tuple
                opb = sum(_shape_bytes(comp.symtab.get(o, "")) for o in _operands(ins))
                b = m * max(res_b, opb)
                out.coll_bytes_by_type[ctype] = out.coll_bytes_by_type.get(ctype, 0.0) + b
                out.coll_count_by_type[ctype] = out.coll_count_by_type.get(ctype, 0) + int(m)
                out.coll_bytes_total += b
                groups = _parse_replica_groups(ins.line)
                if any(len({d // chips_per_pod for d in g}) > 1 for g in groups):
                    out.coll_bytes_cross_pod += b
            out.traffic_bytes += m * traffic_of(ins, comp, comps)

    # dots inside fusions
    for fname, m in fusion_parent.items():
        if fname in comps:
            comp = comps[fname]
            for ins in comp.instrs:
                if ins.op == "dot":
                    out.flops += m * _dot_flops(ins, comp)

    return out
