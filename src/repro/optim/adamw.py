"""AdamW with fp32 master weights + moments (pure pytree ops, no optax).

The optimizer state carries the same logical axes as the parameters, so
FSDP sharding of weights automatically ZeRO-shards the moments.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(cfg: AdamWConfig, step) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = step / jnp.maximum(cfg.warmup, 1)
    prog = jnp.clip((step - cfg.warmup) / jnp.maximum(cfg.decay_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup, warm, cos)


def adamw_init(params):
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(leaf.astype(jnp.float32) ** 2) for leaf in leaves))


def adamw_update(cfg: AdamWConfig, grads, state, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) if cfg.grad_clip else 1.0
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        p32 = p.astype(jnp.float32)
        new_p = p32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p32)
        return new_p.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_params, {"step": step, "m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr}
