"""Named sharding-rule variants used by the §Perf hillclimbing loop.

Each variant is a full Rules table; the dry-run accepts ``--rules <name>``
so every experiment in EXPERIMENTS.md §Perf is reproducible by name.
"""

from __future__ import annotations

from repro.sharding import BASELINE, Rules

_REGISTRY: dict[str, Rules] = {}


def register(name: str, table: dict) -> Rules:
    r = Rules(name=name, table=table)
    _REGISTRY[name] = r
    return r


def get(name: str) -> Rules:
    if name in _REGISTRY:
        return _REGISTRY[name]
    raise KeyError(f"unknown rules variant {name!r}; known: {sorted(_REGISTRY)}")


# --- variants -------------------------------------------------------------

# V1: no FSDP — weights replicated over `data` (pure DP+TP). Trades memory
# for the removal of the per-step weight all-gathers.
register("no_fsdp", {**BASELINE.table, "embed": ()})

# V2: sequence-sharded activations (sequence parallelism for the norm/ffn
# segments): batch over data, seq over model for activations.
register("seqpar", {**BASELINE.table, "seq": ("model",)})

# V3: decode cache sharded over model axis too (more shards for the
# long-context cache; frees `data` for batch).
register("cache_model", {**BASELINE.table, "kv_seq": ("model",), "batch": ("pod", "data")})

# V4: expert-parallel preference for MoE dispatch capacity over model
register("ep_cap_model", {**BASELINE.table, "expert_cap": ("model",)})

# V5: vocab unsharded (replicated head) — for small-vocab archs where the
# gather/all-reduce of the sharded head dominates.
register("vocab_replicated", {**BASELINE.table, "vocab": ()})

# V6: 2D-factorised MoE mesh (data, expert, model): true expert parallelism
# for coarse-expert models (pairs with launch.mesh.make_variant_mesh("moe2d")).
register(
    "moe_2d",
    {
        **BASELINE.table,
        # experts get EP over `expert` (8) x TP over `model` (2); everything
        # NON-expert keeps full 16-way TP by sharding over the combined
        # (expert, model) axes — attention must not pay for the mesh split.
        "experts": ("expert",),
        "expert_cap": ("data",),
        "expert_mlp": ("model",),
        "heads": ("expert", "model"),
        "kv_heads": ("expert",),
        "mlp": ("expert", "model"),
        "vocab": ("expert", "model"),
        "embed": ("data",),
        "ssm_inner": ("expert", "model"),
        "mlstm_inner": ("expert", "model"),
    },
)
