"""Multi-host execution backend — true per-process site ownership over a
``jax.distributed`` mesh.

ROADMAP follow-on (a), completed: the same SiteJob DAGs the single-host
runtime executes now distribute for real.  :class:`MultiHostBackend`
brings up the distributed runtime (``launch.mesh.init_multihost``),
builds the global device mesh spanning every host
(``make_multihost_mesh``), derives an explicit ``site -> process``
ownership map from it (``launch.mesh.site_ownership``: capacity-
proportional over the mesh's processes; per-site load weights are the
seam for heterogeneous slots — the scalar ``GridModel.workers_per_site``
is uniform and therefore balance-neutral), and then:

  * each process executes ONLY the jobs of its owned sites — a 3-process
    run really does run each site's mining on exactly one process
    (``executed_log`` is the audit trail the conformance harness checks);
  * each executed job's result — wrapped in an owner-measured
    ``TimedResult`` — ships to every process through one
    ``allgather_bytes`` shipment (two ``process_allgather`` rounds:
    lengths, then padded payloads; ``compat.pack_payload`` converts
    jax-array pytree leaves to host numpy and pickles non-array outputs
    such as itemset dicts);
  * every process keeps scheduling the WHOLE DAG — placement, the
    simulated clock and the ledger are globally consistent because every
    process sees the same owner-measured times, so both engine schedulers
    replay the identical event order everywhere and the per-job shipments
    are the only collectives (the paper's synchronization traffic and
    nothing else).

Single-process fallback: without a coordinator the backend degrades to
inline execution over the local devices — same results, no distributed
state touched — so ``Engine(backend="multihost")`` is safe everywhere.

Determinism contract (why the shipments line up): both schedulers order
events only by (dag, model, placement seed, fault seed, measured times),
and the measured times are owner-authoritative everywhere, so every
process invokes ``call`` for the same jobs in the same order.  Keep
per-process state OUT of the scheduling inputs — e.g. a ``rescue_path``
resuming on one process only would desynchronize the collectives.
"""

from __future__ import annotations

import time
from typing import Any

import jax
import numpy as np

from repro.compat import pack_payload, unpack_payload
from repro.launch.mesh import (
    allgather_bytes,
    init_multihost,
    make_multihost_mesh,
    site_ownership,
)
from repro.workflow.dag import DAG, Job, TimedResult
from repro.workflow.executor import ExecutionBackend, Partition


class _ShippedError:
    """Wire marker for an exception raised by an owned job's callable:
    the owner ships it instead of the result so every process raises the
    same failure AFTER the collective (raising before it would strand
    the peers inside ``process_allgather``, which has no timeout)."""

    def __init__(self, message: str):
        self.message = message


class MultiHostBackend(ExecutionBackend):
    """Site-partitioned DAG execution over a ``jax.distributed`` mesh.

    Parameters mirror ``jax.distributed.initialize``; all-None (the
    default) means "join an already-initialized runtime, or run
    single-process" — the backend never guesses a coordinator.

    ``partition_sites=False`` restores the pre-ownership SPMD-redundant
    mode (every process executes every job; no shipping) — kept for A/B
    measurements of shipping vs redundancy.
    """

    name = "multihost"

    def __init__(
        self,
        coordinator_address: str | None = None,
        num_processes: int | None = None,
        process_id: int | None = None,
        axis: str = "sites",
        partition_sites: bool = True,
    ):
        self.coordinator_address = coordinator_address
        self.num_processes = num_processes
        self.process_id = process_id
        self.axis = axis
        self.partition_sites = partition_sites
        self._ready = False
        self.is_multiprocess = False
        self.mesh = None
        self._partition: Partition | None = None
        # audit trails for the conformance harness: which jobs' callables
        # ran in THIS process, and which arrived as shipped results
        self.executed_log: list[str] = []
        self.shipped_log: list[str] = []
        if coordinator_address is not None or num_processes is not None:
            # explicit coordinator args = the caller WANTS a distributed
            # runtime, and jax.distributed.initialize must beat the
            # process's first XLA backend query (jax.process_count,
            # jax.random.PRNGKey, ...) — so bring it up eagerly at
            # construction, before anything else can touch jax.  All-None
            # construction stays lazy (safe everywhere).
            self._ensure()

    def ensure_initialized(self) -> None:
        """Public bring-up (idempotent): ``jax.distributed`` init + the
        global mesh.  MUST run before any jax backend query
        (``jax.process_count``, ``jax.devices``, any computation) in this
        process — callers that need topology facts ahead of ``Engine.run``
        (e.g. ``GridRuntime``'s sync-mode selection) call this first."""
        self._ensure()

    def _ensure(self) -> None:
        """Bring up the distributed runtime and the global mesh once."""
        if self._ready:
            return
        self.is_multiprocess = init_multihost(
            coordinator_address=self.coordinator_address,
            num_processes=self.num_processes,
            process_id=self.process_id,
        )
        self.mesh = make_multihost_mesh(axis=self.axis)
        self._ready = True

    def describe(self) -> dict:
        """Topology introspection (the smoke test's assertions): process
        layout and the global mesh this backend executes over."""
        self._ensure()
        return {
            "is_multiprocess": self.is_multiprocess,
            "process_index": jax.process_index(),
            "process_count": jax.process_count(),
            "n_global_devices": len(jax.devices()),
            "n_local_devices": len(jax.local_devices()),
            "mesh_shape": dict(self.mesh.shape) if self.mesh is not None else None,
            "axis": self.axis,
        }

    def allgather_check(self, value: float) -> np.ndarray:
        """Cross-process collective smoke: gather one scalar per process
        (identity on a single process) — the same wire ``call`` ships
        per-site results over."""
        self._ensure()
        arr = np.asarray([value], dtype=np.float32)
        if not self.is_multiprocess:
            return arr[None]
        from jax.experimental.multihost_utils import process_allgather

        return np.asarray(process_allgather(arr))

    # -- ownership ----------------------------------------------------------

    def begin_run(self, dag: DAG, results: dict) -> None:
        self._ensure()
        self._partition = None
        self.executed_log.clear()
        self.shipped_log.clear()

    def partition(self, dag: DAG, model=None) -> Partition | None:
        """Derive the ``site -> process`` ownership map for this DAG from
        the global mesh (every process computes the identical map) and
        project it onto job names.  Single-process runtimes — and
        ``partition_sites=False`` — return None: everything runs locally.
        """
        self._ensure()
        if not self.is_multiprocess or not self.partition_sites:
            return None
        sites = sorted({j.site for j in dag.jobs.values()})
        # capacity-proportional over the mesh's processes; the grid
        # model's workers_per_site is a UNIFORM per-site weight, which
        # cancels out of the balance — per-site heterogeneous weights are
        # site_ownership's seam when the model grows them
        owner_by_site = site_ownership(sites, n_processes=jax.process_count(), mesh=self.mesh)
        me = jax.process_index()
        owner_of = {j.name: owner_by_site[j.site] for j in dag.jobs.values()}
        self._partition = Partition(
            owned=frozenset(n for n, p in owner_of.items() if p == me),
            owner_of=owner_of,
            n_processes=jax.process_count(),
            process_index=me,
            owned_sites=tuple(s for s, p in sorted(owner_by_site.items()) if p == me),
        )
        return self._partition

    # -- execution ----------------------------------------------------------

    def call(self, job: Job, args: list) -> Any:
        part = self._partition
        if part is None:
            # single process (or partitioning disabled): plain inline
            # execution — same results, no distributed state touched
            self.executed_log.append(job.name)
            return job.fn(*args)
        if job.name in part.owned:
            # owner: execute for real, normalize to an owner-measured
            # TimedResult (untimed callables get the host bracket HERE, on
            # the one process that ran them), and ship it.  A raised
            # exception ships too — the peers are already committed to
            # joining this job's collective, so propagating it before the
            # shipment would leave them deadlocked in process_allgather;
            # instead everyone receives it and fails the run together.
            t0 = time.perf_counter()
            try:
                raw = job.fn(*args)
                if not isinstance(raw, TimedResult):
                    raw = TimedResult(raw, time.perf_counter() - t0)
                payload = pack_payload(raw)
                # logged only once the result is actually shippable, so
                # the audit trail never claims an execution whose peers
                # received a serialization failure instead
                self.executed_log.append(job.name)
            except Exception as e:  # noqa: BLE001 - shipped, not swallowed
                payload = pack_payload(_ShippedError(f"{type(e).__name__}: {e}"))
        else:
            payload = b""
        # one shipment per executed job (allgather_bytes = two
        # process_allgather rounds: lengths, then padded payloads); every
        # process joins — the schedulers' deterministic event order
        # guarantees they arrive in lockstep — and the owner's slot
        # carries the result
        shipped = allgather_bytes(payload)
        out = unpack_payload(shipped[part.owner_of[job.name]])
        if isinstance(out, _ShippedError):
            raise RuntimeError(
                f"job {job.name!r} failed on its owning process "
                f"{part.owner_of[job.name]}: {out.message}"
            )
        if not isinstance(out, TimedResult):  # pragma: no cover - wire guard
            raise RuntimeError(
                f"shipped result for job {job.name!r} from process "
                f"{part.owner_of[job.name]} is not an owner-measured TimedResult"
            )
        if job.name not in part.owned:
            self.shipped_log.append(job.name)
        # every process — owner included — adopts the round-tripped value,
        # so the results dict is bit-identical everywhere
        return out
