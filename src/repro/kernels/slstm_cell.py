"""Pallas TPU kernel: sLSTM recurrence with VMEM-resident weights.

WHY (§Perf, xlstm-1.3b x train_4k): the sLSTM layer is a strictly
sequential per-timestep recurrence.  Under plain XLA every timestep
re-reads the recurrent weight R (h, p, 4p) — bf16 ≈ 8 MB — from HBM:
4096 steps x 6 layers ≈ 2·10^14 B/step of pure weight re-reads, which is
what makes the xlstm train cell the worst roofline cell in the fleet.

This kernel pins R (+bias) in VMEM for the whole sequence and carries the
(c, n, hid) state in VMEM scratch across a SEQUENTIAL grid over time
chunks: R is fetched once (Pallas skips re-copies for blocks whose index
map is constant), wx streams in chunk by chunk, h streams out.  Per-chunk
VMEM: R 8 MB + wx chunk T·B·H·4P + state ≈ well under the ~16 MB window
at T=16.

HBM traffic collapses to  wx read + hids write + R once:
    4096·16·4·2048·2 B  +  4096·16·4·512·4 B  +  8 MB   ≈ 1.2 GB/layer
vs ≈ 2·10^11 B/layer for the XLA path — a ~170x reduction of the
dominant term (recorded in EXPERIMENTS.md §Perf as an analytic entry: the
Mosaic kernel cannot lower in the CPU dry-run; correctness is validated
with interpret=True against ``repro.models.xlstm.apply_slstm``).

Gate math matches the JAX reference exactly:
    z,i,f,o = split(wx_t + hid@R + b);  c = σ(f)·c + σ(i)·tanh(z)
    n = σ(f)·n + σ(i);  hid = σ(o)·c/max(n,1)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(wx_ref, r_ref, b_ref, c0_ref, n0_ref, h0_ref,
            hids_ref, cT_ref, nT_ref, hT_ref,
            c_s, n_s, h_s):
    """Grid: (S/T,) sequential over time chunks.

    wx_ref:  (T, B, H, 4P)   — this chunk's input projections
    r_ref:   (H, P, 4P)      — recurrent weights (VMEM-resident)
    b_ref:   (H, 4P)
    c0/n0/h0:(B, H, P)       — initial state (read at chunk 0)
    hids_ref:(T, B, H, P)    — per-step hidden outputs
    cT/nT/hT:(B, H, P)       — final state (written at the last chunk)
    c_s/n_s/h_s: VMEM scratch (B, H, P) f32 — state carried across chunks
    """
    t_chunk = wx_ref.shape[0]
    n_heads = wx_ref.shape[2]
    p = h0_ref.shape[-1]

    @pl.when(pl.program_id(0) == 0)
    def _init():
        c_s[...] = c0_ref[...].astype(jnp.float32)
        n_s[...] = n0_ref[...].astype(jnp.float32)
        h_s[...] = h0_ref[...].astype(jnp.float32)

    r = r_ref[...].astype(jnp.float32)  # (H, P, 4P) — stays in VMEM
    b = b_ref[...].astype(jnp.float32)

    def step(t, _):
        hid = h_s[...]  # (B, H, P) f32
        wx_t = wx_ref[t].astype(jnp.float32)  # (B, H, 4P)
        # per-head block-diagonal recurrence on the MXU
        rec = jax.lax.dot_general(
            hid.transpose(1, 0, 2), r, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )  # (H, B, 4P)
        g = wx_t + rec.transpose(1, 0, 2) + b[None]
        z = jnp.tanh(g[..., :p])
        i = jax.nn.sigmoid(g[..., p : 2 * p])
        f = jax.nn.sigmoid(g[..., 2 * p : 3 * p])
        o = jax.nn.sigmoid(g[..., 3 * p :])
        c = f * c_s[...] + i * z
        n = f * n_s[...] + i
        hid_new = o * c / jnp.maximum(n, 1.0)
        c_s[...] = c
        n_s[...] = n
        h_s[...] = hid_new
        hids_ref[t] = hid_new.astype(hids_ref.dtype)
        return ()

    jax.lax.fori_loop(0, t_chunk, step, ())

    @pl.when(pl.program_id(0) == pl.num_programs(0) - 1)
    def _fin():
        cT_ref[...] = c_s[...].astype(cT_ref.dtype)
        nT_ref[...] = n_s[...].astype(nT_ref.dtype)
        hT_ref[...] = h_s[...].astype(hT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("t_chunk", "interpret"))
def slstm_scan_pallas(
    wx: jax.Array,  # (S, B, H, 4P) time-major input projections
    r: jax.Array,  # (H, P, 4P)
    bias: jax.Array,  # (H, 4P)
    c0: jax.Array,  # (B, H, P)
    n0: jax.Array,
    h0: jax.Array,
    t_chunk: int = 16,
    interpret: bool = False,
):
    s, b_, h, p4 = wx.shape
    p = p4 // 4
    assert s % t_chunk == 0, (s, t_chunk)
    grid = (s // t_chunk,)
    dt = wx.dtype
    out_shapes = [
        jax.ShapeDtypeStruct((s, b_, h, p), dt),  # hids
        jax.ShapeDtypeStruct((b_, h, p), dt),  # cT
        jax.ShapeDtypeStruct((b_, h, p), dt),  # nT
        jax.ShapeDtypeStruct((b_, h, p), dt),  # hT
    ]
    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t_chunk, b_, h, p4), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((h, p, p4), lambda i: (0, 0, 0)),  # constant: fetched once
            pl.BlockSpec((h, p4), lambda i: (0, 0)),
            pl.BlockSpec((b_, h, p), lambda i: (0, 0, 0)),
            pl.BlockSpec((b_, h, p), lambda i: (0, 0, 0)),
            pl.BlockSpec((b_, h, p), lambda i: (0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((t_chunk, b_, h, p), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((b_, h, p), lambda i: (0, 0, 0)),
            pl.BlockSpec((b_, h, p), lambda i: (0, 0, 0)),
            pl.BlockSpec((b_, h, p), lambda i: (0, 0, 0)),
        ],
        out_shape=out_shapes,
        scratch_shapes=[
            pltpu.VMEM((b_, h, p), jnp.float32),
            pltpu.VMEM((b_, h, p), jnp.float32),
            pltpu.VMEM((b_, h, p), jnp.float32),
        ],
        interpret=interpret,
    )(wx, r, bias, c0, n0, h0)
