"""Production training entry.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b --reduced \
        --steps 20 --ckpt-dir /tmp/ck

On real hardware this runs the full config on the production mesh; on this
CPU container use --reduced (same code path, tiny dims, 1-device mesh).
Features exercised: rule-derived shardings, deterministic restartable data
stream, async sharded checkpointing with auto-resume, GridLocal outer loop
when --gridlocal and the mesh has a pod axis.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import TokenStream, device_put_batch
from repro.models import transformer as T
from repro.models.config import reduced as reduce_cfg
from repro.optim.adamw import AdamWConfig
from repro.sharding import BASELINE, activate
from repro.train.steps import make_train_step, materialize_state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b", choices=configs.ARCHS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"))
    rules = BASELINE

    print(f"[train] {cfg.name}: {T.param_count(cfg) / 1e6:.2f}M params on {n_dev} device(s)")
    stream = TokenStream(
        vocab=cfg.vocab, global_batch=args.global_batch, seq_len=args.seq_len, seed=0,
        frontend_len=cfg.frontend_len if cfg.frontend != "none" else 0, d_model=cfg.d_model,
    )
    opt_cfg = AdamWConfig(lr=args.lr, warmup=5, decay_steps=max(args.steps, 10))

    with activate(mesh, rules):
        step_fn = jax.jit(
            make_train_step(cfg, opt_cfg, loss_chunk=min(512, args.seq_len), grad_accum=args.grad_accum),
            donate_argnums=0,
        )
        state = materialize_state(cfg, jax.random.PRNGKey(0))

        start = 0
        ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
        if ck and args.resume and ck.latest_step() is not None:
            start = ck.latest_step()
            state = jax.tree.map(jnp.asarray, ck.restore(state))
            print(f"[train] resumed from step {start}")

        t0 = time.time()
        for step in range(start, args.steps):
            batch = device_put_batch(stream.host_batch_at(step), mesh, rules)
            state, metrics = step_fn(state, batch)
            if step % 5 == 0 or step == args.steps - 1:
                print(
                    f"[train] step {step:5d} loss {float(metrics['loss']):.4f} "
                    f"lr {float(metrics['lr']):.2e} gnorm {float(metrics['grad_norm']):.3f} "
                    f"({(time.time() - t0) / max(step - start + 1, 1):.2f}s/step)"
                )
            if ck and (step + 1) % args.ckpt_every == 0:
                ck.save(step + 1, state)
        if ck:
            ck.save(args.steps, state, wait=True)
            print(f"[train] checkpoints: {ck.all_steps()}")


if __name__ == "__main__":
    main()
