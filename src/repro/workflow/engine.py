"""DAGMan-analog workflow engine with a simulated grid clock.

Executes a DAG of Python jobs while modelling the grid behaviours the
paper measures:
  * workflow preparation latency (the paper's 295 s DAGMan observation)
    and per-job submit/matchmaking latency — optionally OVERLAPPED with
    running computation (`overlap_prep=True`), the optimisation the paper
    suggests ("partly overlapped by computations in the DAG");
  * data staging times from the Table 2 link matrix;
  * fault injection with DAGMan-style retries;
  * rescue files: a crashed run resumes from the last completed frontier
    (``rescue_path``), re-executing only unfinished jobs;
  * straggler mitigation: speculative duplicates of outlier jobs, first
    completion wins (``straggler_factor``).  The detector is
    per-scheduler: staged compares each job's stage total (staging +
    compute) against the stage median; async compares measured compute
    against the compute median of already-started jobs (staging is a
    deterministic model quantity there, not a straggler symptom).

Two schedulers share those semantics:

  * ``schedule="staged"`` — the original stage-barrier loop: the ready
    frontier runs as one synchronous stage, the next frontier only after
    the whole stage completes.  This is what a level-synchronous grid
    deployment does and what ``overhead.estimate_stages`` bounds.
  * ``schedule="async"`` — an event-driven simulator: each job
    independently walks submit -> stage-in -> compute -> stage-out on a
    simulated-clock event queue, becomes eligible the moment its last
    dependency completes (no barrier), pays its matchmaking latency in a
    pipelined fashion (submissions overlap each other and running
    computation), and contends for per-site worker slots
    (``GridModel.workers_per_site``) through per-site FIFO queues.
    Its analytical bound is ``overhead.estimate_dag``.  Because staged
    mode models unlimited per-site parallelism within a stage, async
    wall <= staged wall is guaranteed only while per-site concurrency
    stays within the worker slots (true for both applications' DAGs,
    which run one job per site per wave).

The COMPUTE time of each job is measured for real (wall clock of fn());
everything grid-related advances the simulated clock, so experiments are
deterministic and reproducible — the property Grid'5000 was built to
approximate and the paper laments ordinary grids lack.

HOW a job's callable executes is delegated to a pluggable execution
backend (``workflow.executor``): ``backend="inline"`` is the sequential
host loop (default, bit-for-bit the original engine), ``"batched"``
fuses ready shape-identical fan-out jobs into one vmapped device call,
``"multihost"`` executes over a ``jax.distributed`` process mesh.  Both
schedulers route every fn invocation through ``ExecutionBackend.call``;
scheduling semantics (faults, retries, rescue, speculation, the clock)
are backend-independent.
"""

from __future__ import annotations

import heapq
import json
import time
from collections import deque
from dataclasses import dataclass, field
from pathlib import Path

from repro.workflow.dag import DAG, Job, TimedResult
from repro.workflow.executor import ExecutionBackend, resolve_backend
from repro.workflow.faults import FaultInjector
from repro.workflow.overhead import GridModel
from repro.workflow.placement import (
    PlacementPolicy,
    PlacementRequest,
    resolve_placement,
)

SCHEDULES = ("staged", "async")


@dataclass
class RunReport:
    wall_s: float = 0.0  # simulated grid wall-clock
    compute_s: float = 0.0  # Σ measured job compute
    # The critical path through the schedule, split into its mining-compute
    # and data-staging components.  Everything else on the wall clock
    # (preparation, submission, queue waits, barrier gaps) is overhead by
    # construction; staging is ALSO overhead — the grid moved bytes the
    # mining never needed moved — so overhead_pct() charges it as such.
    critical_compute_s: float = 0.0
    critical_transfer_s: float = 0.0
    prep_s: float = 0.0
    submit_s: float = 0.0  # Σ submit latency charged (may overlap compute)
    transfer_s: float = 0.0  # Σ staging over ALL jobs, not just critical
    retries: int = 0
    speculative: int = 0
    schedule: str = "staged"
    job_times: dict = field(default_factory=dict)
    # matchmaking: which policy placed the jobs, and where each job
    # actually ran (job name -> site) — for fixed placement this echoes
    # the DAG's pre-assigned sites
    placement: str = "fixed"
    placements: dict = field(default_factory=dict)
    # which execution backend ran the job callables (workflow.executor)
    backend: str = "inline"
    # multi-host ownership (ExecutionBackend.partition): how many
    # processes cooperated on this run, which one this report came from,
    # and which jobs/sites executed LOCALLY (None = no partitioning —
    # every job ran in this process).  The clock and the ledger above are
    # globally consistent regardless: non-owned jobs are scheduled with
    # owner-measured shipped times.
    n_processes: int = 1
    process_index: int = 0
    owned_jobs: tuple | None = None
    owned_sites: tuple | None = None
    # collective/shipment ledger (ExecutionBackend.ledger): how many
    # result-shipment collectives the backend performed this run, the
    # underlying allgather rounds they cost, and how many job results
    # arrived shipped from other processes.  Wave-fused shipping makes
    # shipments scale with ready WAVES; the per-job mode scales with
    # jobs — the paper's communication-round count, made measurable.
    shipments: int = 0
    collective_rounds: int = 0
    shipped_results: int = 0

    @property
    def critical_path_s(self) -> float:
        return self.critical_compute_s + self.critical_transfer_s

    @property
    def max_stage_compute_s(self) -> float:
        """Backward-compat alias for the pre-split field.  Historically this
        accumulated transfer+compute per stage under a compute-only name,
        which made overhead_pct() silently credit staging as mining time."""
        return self.critical_path_s

    def overhead_pct(self) -> float:
        """Share of the wall clock that is grid overhead rather than mining
        compute (prep + submission + staging + waits), Table 3 style."""
        if self.wall_s <= 0:
            return 0.0
        return 100.0 * (self.wall_s - self.critical_compute_s) / self.wall_s


class Engine:
    def __init__(
        self,
        model: GridModel | None = None,
        faults: FaultInjector | None = None,
        rescue_path: str | Path | None = None,
        overlap_prep: bool = False,
        straggler_factor: float = 0.0,  # 0 = no speculation
        schedule: str = "staged",
        placement: str | PlacementPolicy = "fixed",
        backend: str | ExecutionBackend = "inline",
        trace: list | None = None,
    ):
        if schedule not in SCHEDULES:
            raise ValueError(f"unknown schedule {schedule!r}; expected one of {SCHEDULES}")
        resolve_placement(placement)  # fail fast on unknown policy names
        self.model = model or GridModel()
        self.faults = faults or FaultInjector()
        self.rescue_path = Path(rescue_path) if rescue_path else None
        self.overlap_prep = overlap_prep
        self.straggler_factor = straggler_factor
        self.schedule = schedule
        self.placement = placement
        # how job callables execute (inline host loop / batched fused
        # site-compute / multihost site partitioning) — scheduler
        # decisions are backend-independent; see workflow.executor
        self.backend = resolve_backend(backend)
        self._backend = self.backend  # per-run override lives here
        self._partition = None  # per-run ownership (ExecutionBackend.partition)
        # optional observability hook: when a list is given, both
        # schedulers append (t, kind, job, site, site_busy_after) records
        # — the scheduler-invariant test suite audits these
        self.trace = trace

    def _trace(self, t: float, kind: str, job: str, site: int, busy: int) -> None:
        if self.trace is not None:
            self.trace.append((t, kind, job, site, busy))

    # -- rescue bookkeeping --------------------------------------------------

    def _load_rescue(self, dag: DAG) -> set[str]:
        if self.rescue_path and self.rescue_path.exists():
            return set(json.loads(self.rescue_path.read_text()))
        return set()

    def _save_rescue(self, done: set[str]) -> None:
        if self.rescue_path:
            self.rescue_path.parent.mkdir(parents=True, exist_ok=True)
            self.rescue_path.write_text(json.dumps(sorted(done)))

    # -- execution ------------------------------------------------------------

    def run_site_jobs(self, site_jobs, name: str = "site-jobs") -> tuple[RunReport, dict]:
        """Execute a list of ``workflow.sitejob.SiteJob`` through the grid
        model — the one scheduler shared by clustering and itemset mining.
        Returns (report, results-by-job-name)."""
        from repro.workflow.sitejob import build_dag

        results: dict = {}
        rep = self.run(build_dag(site_jobs, name), results=results)
        return rep, results

    def run(
        self,
        dag: DAG,
        results: dict | None = None,
        schedule: str | None = None,
        placement: str | PlacementPolicy | None = None,
        backend: str | ExecutionBackend | None = None,
    ) -> RunReport:
        schedule = schedule or self.schedule
        if schedule not in SCHEDULES:
            raise ValueError(f"unknown schedule {schedule!r}; expected one of {SCHEDULES}")
        policy = resolve_placement(placement if placement is not None else self.placement)
        policy.reset()  # per-run state (RNG, round-robin cursor)
        self._backend = resolve_backend(backend) if backend is not None else self.backend
        dag.validate_acyclic()
        rep = RunReport(schedule=schedule, placement=policy.name, backend=self._backend.name)
        results = results if results is not None else {}
        self._backend.begin_run(dag, results)
        # multi-host ownership: a distributed backend partitions the DAG's
        # sites over its processes (the model is passed so a backend can
        # derive per-site load weights from it); the engine keeps
        # scheduling EVERY job — the simulated clock/ledger must stay
        # globally consistent — but only owned jobs execute here, the
        # rest arrive as shipped results
        self._partition = self._backend.partition(dag, self.model)
        if self._partition is not None:
            rep.n_processes = self._partition.n_processes
            rep.process_index = self._partition.process_index
            rep.owned_jobs = tuple(sorted(self._partition.owned))
            rep.owned_sites = tuple(self._partition.owned_sites)

        # workflow preparation (the 295 s DAGMan latency).  With
        # overlap_prep the first stage's submission pipeline hides all but
        # a fixed connection setup.
        prep = self.model.prep_latency_s
        if self.overlap_prep:
            prep = min(prep, 10.0)
        rep.prep_s = prep

        done = self._load_rescue(dag)
        for name in done:
            if name in dag.jobs:
                dag.jobs[name].status = "done"

        if schedule == "async":
            self._run_async(dag, results, rep, done, policy)
        else:
            self._run_staged(dag, results, rep, done, policy)
        led = self._backend.ledger()
        if led is not None:
            rep.shipments = int(led.get("shipments", 0))
            rep.collective_rounds = int(led.get("collective_rounds", 0))
            rep.shipped_results = int(led.get("shipped_results", 0))
        return rep

    # -- matchmaking ----------------------------------------------------------

    @staticmethod
    def _median(samples: list[float]) -> float:
        return sorted(samples)[len(samples) // 2] if samples else 0.0

    def _request(
        self,
        job: Job,
        now: float,
        sites: list[int],
        workers: int,
        site_busy: dict,
        queue_depth: dict,
        busy_until: dict,
        samples: list[float],
    ) -> PlacementRequest:
        """Snapshot the grid for one placement decision.  The expected
        compute is the job's own simulated time when declared (replay
        DAGs carry calibrated times there), else the running median of
        scheduled compute observed so far — the matchmaker cannot see a
        measurement that has not happened yet."""
        med = self._median(samples)
        expected = job.sim_compute_s if job.sim_compute_s > 0 else med
        return PlacementRequest(
            name=job.name,
            fixed_site=job.site,
            input_bytes=job.input_bytes,
            output_bytes=job.output_bytes,
            expected_compute_s=expected,
            now=now,
            model=self.model,
            sites=sites,
            workers=workers,
            site_busy=site_busy,
            queue_depth=queue_depth,
            busy_until=busy_until,
            service_est_s=med,
        )

    # -- staged (stage-barrier) scheduler -------------------------------------

    def _run_staged(
        self, dag: DAG, results: dict, rep: RunReport, done: set[str], policy: PlacementPolicy
    ) -> None:
        model = self.model
        workers = max(1, model.workers_per_site)
        sites = policy.candidate_sites([j.site for j in dag.jobs.values()], model)
        samples: list[float] = []  # scheduled compute of completed jobs
        clock = rep.prep_s

        while not dag.done():
            stage = dag.ready()
            if not stage:
                failed = dag.failed()
                raise RuntimeError(f"workflow stuck; failed jobs: {[j.name for j in failed]}")

            # matchmaking: place every job of the stage before it runs.
            # The stage itself has no slot limit (the barrier model runs
            # the whole frontier in parallel), so contention is priced
            # through the per-stage assignment count alone.
            stage_load: dict[int, int] = {}
            for job in stage:
                job.site = policy.place(
                    self._request(job, clock, sites, workers, stage_load, {}, {}, samples)
                )
                rep.placements[job.name] = job.site
                stage_load[job.site] = stage_load.get(job.site, 0) + 1

            # submit latency: serial per job unless overlapped
            submit = self.model.submit_latency_s * len(stage)
            if self.overlap_prep:
                submit = self.model.submit_latency_s
            clock += submit
            rep.submit_s += submit

            splits: list[tuple[float, float]] = []  # (transfer, compute) per job
            for job in stage:
                transfer, dt, attempts = self._execute(job, results, rep, done)
                rep.retries += attempts - 1
                sim_dt = model.site_compute_s(job.site, dt)
                samples.append(sim_dt)
                splits.append((transfer, sim_dt))
                self._trace(clock, "start", job.name, job.site, stage_load[job.site])

            # straggler speculation: duplicate the slowest job(s) if they
            # exceed factor x median — the duplicate "runs elsewhere" and
            # wins with the stage-median time (charged entirely as compute,
            # since the winning copy's own staging is not modelled).
            eff = list(splits)
            if self.straggler_factor and len(splits) >= 3:
                totals = sorted(tr + dt for tr, dt in splits)
                med = totals[len(totals) // 2]
                for i, (tr, dt) in enumerate(eff):
                    if tr + dt > self.straggler_factor * med:
                        eff[i] = (0.0, med)  # speculative copy wins
                        rep.speculative += 1

            if eff:
                tr_c, dt_c = max(eff, key=lambda p: p[0] + p[1])
                rep.critical_transfer_s += tr_c
                rep.critical_compute_s += dt_c
                clock += tr_c + dt_c

            for job in stage:
                self._trace(clock, "finish", job.name, job.site, 0)
            done.update(j.name for j in stage if j.status == "done")
            self._save_rescue(done)

        rep.wall_s = clock

    # -- async (event-driven) scheduler ---------------------------------------

    def _run_async(
        self, dag: DAG, results: dict, rep: RunReport, done: set[str], policy: PlacementPolicy
    ) -> None:
        """Simulated-clock event queue: every job independently walks
        submit -> stage-in -> compute -> stage-out; per-site worker slots
        (``GridModel.workers_per_site``) model contention via FIFO queues;
        a job is submitted the instant its last dependency completes, and
        the placement policy matches it to a site when that matchmaking
        round completes (the "arrive" event) — fixed placement echoes the
        pre-assigned ``job.site``, adaptive policies decide from the
        queue-state snapshot at that instant.

        fn() executes at slot-acquisition order on the simulated clock, so
        jobs sharing mutable state (the CommLog builders) still observe
        dependency order.  Determinism: events tie-break on insertion
        sequence and every policy is seeded/reset per run, so identical
        (dag, model, measured times, seed) replay identically.
        """
        model = self.model
        workers = max(1, model.workers_per_site)
        t0 = rep.prep_s

        heap: list[tuple[float, int, str, str]] = []  # (time, seq, kind, job)
        seq = 0

        def push(t: float, kind: str, name: str) -> None:
            nonlocal seq
            heapq.heappush(heap, (t, seq, kind, name))
            seq += 1

        pending = {
            j.name: sum(1 for d in j.deps if dag.jobs[d].status != "done")
            for j in dag.jobs.values()
            if j.status != "done"
        }
        finish_t: dict[str, float] = {n: t0 for n in done if n in dag.jobs}
        pred: dict[str, str | None] = dict.fromkeys(finish_t)
        # (transfer, compute) on the schedule for finished jobs
        split: dict[str, tuple[float, float]] = dict.fromkeys(finish_t, (0.0, 0.0))
        # the slot universe: fixed placement keeps exactly the DAG's
        # pre-assigned sites (bit-for-bit the pre-placement engine, slot
        # choices of speculation included); adaptive policies match over
        # every site the grid model knows
        sites = policy.candidate_sites([j.site for j in dag.jobs.values()], model)
        site_busy: dict[int, int] = {s: 0 for s in sites}
        site_queue: dict[int, deque[str]] = {}  # FIFO of jobs waiting for a slot
        samples: list[float] = []  # scheduled compute of started jobs
        samples_base: list[float] = []  # the same, in baseline (speed-1) units
        clock = t0

        def submit(name: str, t_elig: float) -> None:
            """Charge per-job matchmaking latency and schedule arrival at
            the job's site.  Event-driven submission is inherently
            pipelined — each job pays the latency, but submissions overlap
            each other and running computation (the paper's "partly
            overlapped by computations in the DAG"), unlike the staged
            scheduler's serial per-stage submit loop."""
            lat = model.submit_latency_s
            rep.submit_s += lat
            push(t_elig + lat, "arrive", name)

        # jobs whose compute is in flight on the simulated clock:
        # name -> {t_start, transfer_in, transfer_out, dt, t_done, spec}
        running: dict[str, dict] = {}
        version: dict[str, int] = {}

        def maybe_speculate(t_now: float) -> None:
            """Online straggler detection: whenever a new compute sample
            lands, any in-flight job whose measured compute exceeds
            factor x the sample median gets a speculative duplicate on a
            second free slot — first completion wins, so its finish event
            is rescheduled to the duplicate's (lazy-deleted via version).
            Evaluated at every start (not only a job's own) so a straggler
            that started BEFORE enough peers had been observed is still
            caught, and at every slot release so a detection deferred by a
            full grid fires as soon as capacity exists."""
            if not self.straggler_factor or len(samples) < 3:
                return
            med = sorted(samples)[len(samples) // 2]
            for name, r in running.items():
                if r["spec"] or r["dt"] <= self.straggler_factor * med:
                    continue
                job = dag.jobs[name]
                spec_site = self._spec_site(job.site, site_busy, workers)
                if spec_site is None:
                    continue  # every slot in the grid is busy
                # a straggler is only observable once its compute is
                # actually running — never during its stage-in, even though
                # the simulator knows dt up-front
                detect = max(t_now, r["t_start"] + r["transfer_in"])
                # the duplicate stages the input to ITS slot and stages the
                # result back — speculation pays real bandwidth, it cannot
                # finish before its own input arrives
                tr_dup = model.transfer_s(0, spec_site, job.input_bytes) + model.transfer_s(
                    spec_site, 0, job.output_bytes
                )
                # the duplicate's run is estimated at the baseline-units
                # median scaled by ITS site's speed — a copy landing on a
                # slow site must not "win" in fast-site time
                med_base = sorted(samples_base)[len(samples_base) // 2]
                new_done = detect + tr_dup + model.site_compute_s(spec_site, med_base)
                if new_done >= r["t_done"]:
                    continue  # duplicate would not beat the original
                site_busy[spec_site] += 1  # the duplicate's slot
                r["spec"] = True
                r["t_done"] = new_done
                rep.speculative += 1
                rep.transfer_s += tr_dup
                self._trace(detect, "speculate", name, spec_site, site_busy[spec_site])
                # the winning chain: original stage-in (transfer) + original
                # compute until detection + duplicate staging (transfer) +
                # the duplicate's median run — the compute part is always
                # >= med, never negative
                transfer = r["transfer_in"] + tr_dup
                split[name] = (transfer, new_done - r["t_start"] - transfer)
                version[name] += 1
                push(new_done, "spec_release", f"{spec_site}")
                push(new_done, "finish", f"{name}@{version[name]}")

        def start(job: Job, t: float, gate: str | None) -> None:
            """Acquire a slot at ``t`` and run the job's full bracket."""
            site_busy[job.site] += 1
            transfer_in = model.transfer_s(0, job.site, job.input_bytes)
            transfer_out = model.transfer_s(job.site, 0, job.output_bytes)
            rep.transfer_s += transfer_in + transfer_out
            dt, attempts = self._attempt(job, results, rep, done)
            rep.retries += attempts - 1
            # the schedule sees the site-speed-scaled duration; job_times
            # and compute_s keep the measured baseline
            sim_dt = model.site_compute_s(job.site, dt)
            samples.append(sim_dt)
            samples_base.append(dt)
            t_done = t + transfer_in + sim_dt + transfer_out
            pred[job.name] = gate
            split[job.name] = (transfer_in + transfer_out, sim_dt)
            running[job.name] = {
                "t_start": t,
                "transfer_in": transfer_in,
                "transfer_out": transfer_out,
                "dt": sim_dt,
                "t_done": t_done,
                "spec": False,
            }
            version[job.name] = 0
            push(t_done, "finish", f"{job.name}@0")
            self._trace(t, "start", job.name, job.site, site_busy[job.site])
            maybe_speculate(t)

        for job in dag.jobs.values():  # insertion order = deterministic
            if job.status != "done" and pending[job.name] == 0:
                submit(job.name, t0)

        def busy_until() -> dict[int, list[float]]:
            """Known slot-release times per site — what the matchmaker
            may legitimately see (finish times of jobs whose compute is
            already in flight on the simulated clock)."""
            out: dict[int, list[float]] = {}
            for rname, r in running.items():
                out.setdefault(dag.jobs[rname].site, []).append(r["t_done"])
            return out

        def pop_queue(site: int, t: float, releaser: str | None) -> None:
            q = site_queue.get(site)
            if q and site_busy[site] < workers:
                # the slot release, not the dependency, gated this job
                start(dag.jobs[q.popleft()], t, releaser)

        while heap:
            t, _, kind, name = heapq.heappop(heap)
            if kind == "finish":
                # payload is "<job>@<version>"; events superseded by a
                # speculative reschedule are lazily dropped — before the
                # clock update, or the phantom original would stretch the
                # wall past the duplicate's win
                name, _, ver = name.rpartition("@")
                if int(ver) != version[name]:
                    continue
            clock = max(clock, t)
            if kind == "spec_release":
                site = int(name)
                site_busy[site] -= 1
                self._trace(t, "spec_release", "", site, site_busy[site])
                pop_queue(site, t, None)
                maybe_speculate(t)  # the freed slot may admit a duplicate
                continue
            if kind == "arrive":
                # matchmaking completes: the policy assigns the site from
                # the queue-state snapshot at this instant (fixed echoes
                # the pre-assigned job.site)
                job = dag.jobs[name]
                job.site = policy.place(
                    self._request(
                        job,
                        t,
                        sites,
                        workers,
                        site_busy,
                        {s: len(q) for s, q in site_queue.items()},
                        busy_until(),
                        samples,
                    )
                )
                rep.placements[name] = job.site
                if site_busy[job.site] < workers:
                    start(job, t, pred.get(name))  # gated by latest dep
                else:
                    site_queue.setdefault(job.site, deque()).append(name)
                    self._trace(t, "queue", name, job.site, site_busy[job.site])
                continue
            # kind == "finish"
            job = dag.jobs[name]
            del running[name]
            site_busy[job.site] -= 1
            self._trace(t, "finish", name, job.site, site_busy[job.site])
            finish_t[name] = t
            done.add(name)
            self._save_rescue(done)
            for dep in dag.jobs.values():
                if dep.status != "done" and name in dep.deps:
                    pending[dep.name] -= 1
                    if pending[dep.name] == 0:
                        pred[dep.name] = name  # eligibility gated by this job
                        submit(dep.name, t)
            pop_queue(job.site, t, name)
            maybe_speculate(t)  # the freed slot may admit a duplicate

        if not dag.done():
            failed = dag.failed()
            raise RuntimeError(f"workflow stuck; failed jobs: {[j.name for j in failed]}")

        rep.wall_s = clock
        self._credit_critical_path(finish_t, pred, split, rep)

    def _spec_site(self, site: int, site_busy: dict[int, int], workers: int) -> int | None:
        """Pick the slot for a speculative duplicate: the least-loaded OTHER
        site (lowest id on ties), falling back to this site's spare slot;
        None when every slot in the grid is busy (no speculation)."""
        candidates = sorted(
            (busy, s) for s, busy in site_busy.items() if s != site and busy < workers
        )
        if candidates:
            return candidates[0][1]
        if site_busy.get(site, 0) < workers:
            return site
        return None

    def _credit_critical_path(
        self,
        finish_t: dict[str, float],
        pred: dict[str, str | None],
        split: dict[str, tuple[float, float]],
        rep: RunReport,
    ) -> None:
        """Walk the gating chain back from the last job to finish, summing
        its staging vs compute; submit latencies and waits between links are
        the remainder of the wall clock, i.e. pure overhead."""
        if not finish_t:
            return
        cur: str | None = max(finish_t, key=lambda n: (finish_t[n], n))
        while cur is not None:
            tr, dt = split[cur]
            rep.critical_transfer_s += tr
            rep.critical_compute_s += dt
            cur = pred.get(cur)

    # -- one job --------------------------------------------------------------

    def _attempt(self, job: Job, results: dict, rep: RunReport, done: set[str]) -> tuple[float, int]:
        """Execute one job with DAGMan retries; returns (measured compute
        seconds, attempts).  Injected failures cost no simulated time (the
        retry is immediate); exhaustion saves the rescue frontier and
        raises."""
        attempts = 0
        while True:
            attempts += 1
            job.attempts = attempts
            job.status = "running"
            if self.faults.should_fail(job.name, attempts):
                if attempts > job.retries:
                    job.status = "failed"
                    self._save_rescue(done)
                    raise RuntimeError(f"job {job.name} exhausted retries ({job.retries})")
                continue  # DAGMan retry
            t0 = time.perf_counter()
            args = [results[d] for d in job.deps]
            # the execution backend decides HOW fn runs (inline dispatch,
            # fused batch, multihost mesh); scheduling semantics around it
            # — faults, retries, rescue, the simulated clock — are ours
            raw = self._backend.call(job, args)
            if isinstance(raw, TimedResult):
                # the job measured its own device compute (SiteJob.timed);
                # the grid clock is calibrated by real kernels, not by our
                # host-side bracket around fn()
                job.result = raw.value
                dt = raw.compute_s + job.sim_compute_s
            else:
                if self._partition is not None and job.name not in self._partition.owned:
                    # owner-only timing invariant: a job that executed on
                    # another process MUST arrive as an owner-measured
                    # TimedResult — bracketing the collective wait here
                    # would feed a process-local (and divergent) time into
                    # the globally-consistent clock/ledger
                    raise RuntimeError(
                        f"job {job.name!r} is owned by process "
                        f"{self._partition.owner_of.get(job.name)} but its shipped "
                        f"result carries no owner-measured TimedResult"
                    )
                job.result = raw
                dt = time.perf_counter() - t0 + job.sim_compute_s
            results[job.name] = job.result
            job.status = "done"
            rep.compute_s += dt
            rep.job_times[job.name] = dt
            return dt, attempts

    def _execute(
        self, job: Job, results: dict, rep: RunReport, done: set[str]
    ) -> tuple[float, float, int]:
        """Staged-mode wrapper: charge both staging legs and run the
        attempts loop; returns (transfer, compute, attempts)."""
        transfer = self.model.transfer_s(0, job.site, job.input_bytes) + self.model.transfer_s(
            job.site, 0, job.output_bytes
        )
        rep.transfer_s += transfer
        dt, attempts = self._attempt(job, results, rep, done)
        return transfer, dt, attempts
