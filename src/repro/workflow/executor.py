"""Pluggable execution backends — HOW the mesh executes what the
scheduler decides.

The engine's schedulers (staged / async) decide WHEN each job becomes
eligible and WHERE it runs (placement); an :class:`ExecutionBackend`
decides HOW the job's callable actually executes on the hardware.  The
split is the layer the paper attributes most lost performance to: the S
site-local mining jobs of every fan-out stage (``cluster_i``,
``apriori_i``, ``recount_i``, ``perturb_i``) are embarrassingly parallel
on the simulated grid, but a host Python loop dispatching them
one-at-a-time serializes them on the device.

Backends:

  * ``inline`` (the bare ``Engine`` default) — the reference behavior,
    bit-for-bit: each job's ``fn`` is called in scheduler order, one
    dispatch per job.
  * ``batched`` (the ``GridRuntime`` default since the inline->batched
    flip) — groups ready shape-identical fan-out jobs by their
    ``batch_key`` and dispatches ONE fused (vmapped) call across the
    site axis via the group's ``batched_fn``, then apportions the
    measured batch wall time equally per job — so the simulated grid
    clock, ``RunReport.job_times`` and the ``overhead.estimate_dag``
    calibration stay honest: each site's job is credited what one
    site's share of the fused call cost, which is what a real grid
    site would have spent.
  * ``multihost`` (``repro.runtime.backends.MultiHostBackend``) — true
    multi-host execution over a ``jax.distributed`` process mesh: grid
    sites are partitioned over the processes (``launch.mesh.
    site_ownership``), each process executes ONLY its owned jobs, and
    per-job results ship to every process via ``process_allgather`` —
    the paper's site-partitioned deployment, with result shipping as
    the only cross-process traffic.

The scheduler contract is :meth:`ExecutionBackend.call` (replacing the
engine's direct ``job.fn(*args)`` invocation inside ``Engine._attempt``)
plus the optional :meth:`ExecutionBackend.partition` ownership hook.
Everything else — fault injection, retries, rescue files, speculation,
the simulated clock — is scheduler policy and stays in the engine,
identical across backends.

One layer up, the continuous mining service (``launch.serve``) leans on
exactly this seam: it coalesces identical tenant requests and routes
every execution through whichever backend its runtime carries, so a
multi-tenant burst of same-shape mining queries reaches the device as
the fused dispatches this module implements.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

from repro.workflow.dag import DAG, Job, TimedResult

BACKENDS = ("inline", "batched", "multihost")


def ready_wave(dag: DAG, results: dict, skip=()) -> list[Job]:
    """The ready wave as a backend sees it mid-run: every job that has
    not yet executed (``status != "done"`` and not already pre-executed
    into ``skip``) whose dependency results are ALL available.

    This is the wave-grouping hook shared by the dispatch-fusing
    backends: because both engine schedulers invoke ``call`` in an order
    that is deterministic on (dag, model, seeds, measured times), every
    process of a distributed run computes the identical wave at the
    identical ``call`` — which is what lets the multihost backend ship a
    whole wave in one collective.  Insertion (scheduler) order.
    """
    return [
        j
        for j in dag.jobs.values()
        if j.status != "done"
        and j.name not in skip
        and all(d in results for d in j.deps)
    ]


def group_wave(wave: list[Job]) -> list[list[Job]]:
    """Split a ready wave into fused-dispatch groups: jobs sharing a
    ``batch_key`` (with a ``batched_fn``) form one group — ONE vmapped
    dispatch covers them — and every other job is its own singleton
    group.  Group order follows each group's first member (insertion
    order), so grouping is deterministic everywhere."""
    groups: dict[Any, list[Job]] = {}
    for j in wave:
        key = ("batch", j.batch_key) if j.batch_key is not None and j.batched_fn is not None else ("solo", j.name)
        groups.setdefault(key, []).append(j)
    return list(groups.values())


@dataclass(frozen=True)
class Partition:
    """How a distributed backend splits one DAG over its processes.

    ``owned`` names the jobs THIS process executes; ``owner_of`` maps
    every job to its owning process id.  The engine still schedules the
    whole DAG locally — placement, the simulated clock and the ledger
    are global state and must stay identical on every process — but only
    owned jobs' callables run here; the rest arrive as owner-measured
    shipped results through ``ExecutionBackend.call``.
    """

    owned: frozenset[str]
    owner_of: dict[str, int]
    n_processes: int
    process_index: int
    owned_sites: tuple[int, ...]


class ExecutionBackend:
    """Executes job callables for the workflow engine.

    ``begin_run`` is called once per ``Engine.run`` with the DAG and the
    shared results dict (the backend may inspect both to find co-batchable
    peers); ``call`` replaces the engine's direct ``job.fn(*args)``.
    Whatever ``call`` returns flows through the engine's TimedResult
    handling unchanged.

    ``partition`` (called once per run, after ``begin_run``) lets a
    distributed backend declare per-process job ownership: return a
    :class:`Partition` and the engine will require every non-owned job's
    ``call`` to return an owner-measured ``TimedResult`` (a host-side
    bracket around a job that executed elsewhere would poison the
    globally-consistent clock).  The default — every job local — returns
    None.
    """

    name = "?"

    def begin_run(self, dag: DAG, results: dict) -> None:
        return None

    def partition(self, dag: DAG, model=None) -> Partition | None:
        return None

    def ledger(self) -> dict | None:
        """Per-run collective/shipment ledger (distributed backends):
        ``{"shipments", "collective_rounds", "shipped_results"}`` counts
        accumulated since ``begin_run``.  The engine copies a non-None
        ledger onto ``RunReport`` so the O(jobs) -> O(waves) collective
        reduction is measurable per run, not asserted by hand.  Local
        backends return None (no collectives to count)."""
        return None

    def call(self, job: Job, args: list) -> Any:
        raise NotImplementedError


class InlineBackend(ExecutionBackend):
    """The sequential host loop: one dispatch per job, in scheduler
    order — the engine's original behavior, kept as the default and the
    baseline every other backend is gated against (bit-for-bit)."""

    name = "inline"

    def call(self, job: Job, args: list) -> Any:
        return job.fn(*args)


class BatchedBackend(ExecutionBackend):
    """Fused site-compute: when a job carries a ``batch_key`` and a
    ``batched_fn``, every not-yet-executed job with the same key whose
    dependencies are all available is executed in ONE fused call, and
    the results are cached for the peers' turns.

    The group's ``batched_fn`` receives ``(names, batch_args, argss)``
    (one entry per member, scheduler order) and returns one
    ``TimedResult`` per member — the ``sitejob.timed_batch`` helper
    measures the fused call once and apportions the wall time equally,
    which is the honest per-site calibration for shape-identical jobs
    (a vmapped fan-out does the same total work as the serial loop, so
    one member's share IS one site's cost).

    Correctness notes:
      * peers are only pre-executed when every dependency result is
        already available, so dependency order is preserved exactly;
      * a group smaller than ``min_batch`` (default 2) falls back to the
        jobs' own ``fn`` — no vmap-of-one overhead; ``min_batch=1``
        forces even singletons through ``batched_fn`` (profiling the
        fused path);
      * DAGMan fault injection happens in the engine BEFORE ``call``,
        so an injected retry simply consumes the cached result on the
        next attempt (batched_fn never re-executes).
    """

    name = "batched"

    def __init__(self, min_batch: int = 2):
        if min_batch < 1:
            raise ValueError(f"min_batch must be >= 1, got {min_batch}")
        self.min_batch = min_batch
        self._dag: DAG | None = None
        self._results: dict | None = None
        self._cache: dict[str, Any] = {}

    def begin_run(self, dag: DAG, results: dict) -> None:
        self._dag = dag
        self._results = results
        self._cache.clear()

    def _peers(self, job: Job) -> list[Job]:
        """The co-batchable group: same batch_key, not yet executed, all
        dependency results available — i.e. this job's group within the
        current ready wave (``ready_wave``/``group_wave``).  Scheduler
        (insertion) order — deterministic."""
        assert self._dag is not None and self._results is not None
        wave = ready_wave(self._dag, self._results, skip=self._cache)
        for group in group_wave(wave):
            if any(j.name == job.name for j in group):
                return group
        return [job]  # pragma: no cover - the requested job is always in the wave

    def call(self, job: Job, args: list) -> Any:
        if job.name in self._cache:
            return self._cache.pop(job.name)
        if job.batch_key is None or job.batched_fn is None or self._dag is None:
            return job.fn(*args)
        batch = self._peers(job)
        if len(batch) < self.min_batch:
            return job.fn(*args)
        assert self._results is not None
        argss = [[self._results[d] for d in j.deps] for j in batch]
        outs = job.batched_fn([j.name for j in batch], [j.batch_arg for j in batch], argss)
        if len(outs) != len(batch):
            raise RuntimeError(
                f"batched_fn for {job.batch_key!r} returned {len(outs)} results "
                f"for {len(batch)} jobs"
            )
        for j, out in zip(batch, outs):
            self._cache[j.name] = out
        return self._cache.pop(job.name)


def resolve_backend(backend: str | ExecutionBackend | None) -> ExecutionBackend:
    """Resolve a backend name (or pass through an instance).  Unknown
    names raise with the valid set, mirroring the engine's schedule and
    placement validation.  ``multihost`` imports lazily from
    ``repro.runtime.backends`` (the scaffold pulls in jax)."""
    if backend is None:
        return InlineBackend()
    if isinstance(backend, ExecutionBackend):
        return backend
    if backend == "inline":
        return InlineBackend()
    if backend == "batched":
        return BatchedBackend()
    if backend == "multihost":
        from repro.runtime.backends import MultiHostBackend  # import cycle guard

        return MultiHostBackend()
    raise ValueError(
        f"unknown backend {backend!r}; expected one of {BACKENDS} or an ExecutionBackend"
    )


__all__ = [
    "BACKENDS",
    "BatchedBackend",
    "ExecutionBackend",
    "InlineBackend",
    "Partition",
    "TimedResult",
    "group_wave",
    "ready_wave",
    "resolve_backend",
]
