"""Kernel-level microbenchmarks: the two compute hot-spots the paper's
algorithms spend their time in.  On this CPU container we time the jnp
oracle (the Pallas kernels target TPU and run here only under the
interpreter); the derived column reports achieved GB/s / GFLOP/s so the
roofline context is visible.

``--out`` writes the rows as JSON (``{"kernels": [{name, seconds, ...}]}``)
— the committed ``BENCH_kernels_baseline.json`` is this file's output, and
``compare_baseline --kernels-baseline/--kernels-candidate`` gates fresh
runs against it so a kernel regression is caught even when scheduler
noise hides it in end-to-end wall time.
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit


def run(out: str | None = None) -> dict:
    from repro.core.apriori import pack_bool_matrix, pack_itemsets
    from repro.kernels.ref import kmeans_assign_ref, support_count_ref

    rng = np.random.default_rng(0)
    cells: list[dict] = []

    # kmeans assignment: N x K distance + argmin
    n, d, k = 65_536, 32, 64
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    f = jax.jit(kmeans_assign_ref)
    jax.block_until_ready(f(x, c))
    dt = timeit(lambda: jax.block_until_ready(f(x, c)))
    flops = 2 * n * d * k
    row("kmeans_assign_jnp", dt, f"gflops={flops / dt / 1e9:.1f};N={n};D={d};K={k}")
    cells.append({"name": "kmeans_assign_jnp", "seconds": dt, "gflops": flops / dt / 1e9})

    # support counting: bitmap AND+match over (tx x candidates)
    ntx, items, cands = 32_768, 128, 512
    dense = rng.random((ntx, items)) < 0.2
    tx = jnp.asarray(pack_bool_matrix(dense))
    sets = [tuple(sorted(rng.choice(items, size=3, replace=False).tolist())) for _ in range(cands)]
    masks = jnp.asarray(pack_itemsets(sets, items))
    g = jax.jit(support_count_ref)
    jax.block_until_ready(g(tx, masks))
    dt = timeit(lambda: jax.block_until_ready(g(tx, masks)))
    gcells = ntx * cands * tx.shape[1]
    row("support_count_jnp", dt, f"gcells={gcells / dt / 1e9:.2f};tx={ntx};cands={cands}")
    cells.append({"name": "support_count_jnp", "seconds": dt, "gcells": gcells / dt / 1e9})

    # Pallas kernels (interpret mode — correctness surface, not speed)
    from repro.kernels import ops

    dt = timeit(lambda: jax.block_until_ready(ops.kmeans_assign(x[:4096], c)), repeats=1, warmup=1)
    row("kmeans_assign_pallas_interpret", dt, "interpret=True (CPU correctness mode)")
    cells.append({"name": "kmeans_assign_pallas_interpret", "seconds": dt})

    result = {"kernels": cells}
    if out:
        with open(out, "w") as fh:
            json.dump(result, fh, indent=2, sort_keys=True)
        print(f"# wrote {out}")
    return result


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="write rows as JSON here")
    args = ap.parse_args()
    run(out=args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
