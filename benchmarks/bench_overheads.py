"""Paper Table 3 — 'Results summary': calculated (grid) vs estimated
(analytical) times and the overhead percentage, reproduced through the
workflow engine with the paper's own constants (295 s DAGMan prep, per-job
submit latency, Table 2 link matrix).

Paper values:  V-Clustering 1050 s vs 19.52 s => 98%;
               GFM 521 min vs 424 min => 18.6%;  FDM 687 vs 518 => 24.6%.

The engine runs the same DAG shapes at the paper's scale (simulated
compute durations — see Job.sim_compute_s) and we assert the paper's
qualitative findings: (1) the cheap-parallel clustering workflow is
overhead-dominated (≈98%), (2) compute-heavy mining amortises prep,
(3) FDM's k sync levels cost it more overhead than GFM's single phase.
"""

from __future__ import annotations

from benchmarks.common import row
from repro.workflow.dag import DAG
from repro.workflow.engine import Engine
from repro.workflow.overhead import GridModel, estimate_stages, overhead_pct

N_PROCS = 200  # the paper's process count


def build_clustering_dag():
    """5e7 points / 200 procs of K-Means: est 19.52 s total (paper)."""
    dag = DAG("vcluster")
    for i in range(N_PROCS):
        dag.job(
            f"cluster_{i}", lambda *a: 0, site=i % 5,
            sim_compute_s=19.0, input_bytes=10**6, output_bytes=4096,
        )
    dag.job(
        "merge", lambda *a: 0, deps=[f"cluster_{i}" for i in range(N_PROCS)],
        sim_compute_s=0.5, input_bytes=4096 * N_PROCS,
    )
    return dag


def build_mining_dag(levels: int, per_level_s: float, xfer_bytes: int):
    dag = DAG("mining")
    prev: list[str] = []
    for lv in range(levels):
        cur = []
        for i in range(N_PROCS):
            name = f"mine_l{lv}_s{i}"
            dag.job(
                name, lambda *a: 0, deps=prev, site=i % 5,
                sim_compute_s=per_level_s, input_bytes=xfer_bytes, output_bytes=xfer_bytes,
            )
            cur.append(name)
        sync = f"sync_l{lv}"
        dag.job(sync, lambda *a: 0, deps=cur, sim_compute_s=1.0)
        prev = [sync]
    return dag


def run():
    model = GridModel()

    # --- V-Clustering: cheap parallel jobs (paper: 1050 s vs 19.52 s) ---
    rep_c = Engine(model=model).run(build_clustering_dag())
    est_c = estimate_stages(
        [[(19.0, 10**6, 4096, i % 5) for i in range(N_PROCS)], [(0.5, 4096 * N_PROCS, 0, 0)]],
        model,
    )
    ovh_c = overhead_pct(rep_c.wall_s, est_c)
    row("table3_vclustering_measured", rep_c.wall_s, f"estimated={est_c:.2f}s;overhead={ovh_c:.1f}pct;paper=98pct")

    # --- GFM: heavy local mining, ONE global phase (paper: 18.6%) ---
    gfm_total = 424 * 60.0  # paper's estimated compute
    rep_g = Engine(model=model).run(build_mining_dag(1, gfm_total, 4 * 10**8))
    est_g = estimate_stages(
        [[(gfm_total, 4 * 10**8, 4 * 10**8, i % 5) for i in range(N_PROCS)]], model
    )
    ovh_g = overhead_pct(rep_g.wall_s, est_g)
    row("table3_gfm_measured", rep_g.wall_s, f"estimated={est_g:.2f}s;overhead={ovh_g:.1f}pct;paper=18.6pct")

    # --- FDM: same compute split over k=4 sync levels (paper: 24.6%) ---
    fdm_total = 518 * 60.0
    rep_f = Engine(model=model).run(build_mining_dag(4, fdm_total / 4, 10**8))
    est_f = estimate_stages(
        [[(fdm_total / 4, 10**8, 10**8, i % 5) for i in range(N_PROCS)] for _ in range(4)], model
    )
    ovh_f = overhead_pct(rep_f.wall_s, est_f)
    row("table3_fdm_measured", rep_f.wall_s, f"estimated={est_f:.2f}s;overhead={ovh_f:.1f}pct;paper=24.6pct")

    assert ovh_c > 90.0, "clustering must be overhead-dominated (paper: 98%)"
    assert ovh_f > ovh_g, "FDM's k sync levels must cost more overhead than GFM"

    # --- beyond-paper: overlapped prep + pipelined submission ---
    rep_c2 = Engine(model=model, overlap_prep=True).run(build_clustering_dag())
    row(
        "table3_vclustering_overlapped", rep_c2.wall_s,
        f"overhead={overhead_pct(rep_c2.wall_s, est_c):.1f}pct;fix=overlap prep+pipelined submit",
    )
    return ovh_c, ovh_g, ovh_f


if __name__ == "__main__":
    run()
