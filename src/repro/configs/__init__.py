"""Architecture registry: one module per assigned architecture.

``get(name)`` returns the full published config; ``reduced(get(name))``
gives the CPU-smoke-test version.  ``input_specs(cfg, shape)`` builds the
ShapeAxes stand-ins for every model input of the (arch x shape) cell.
"""

from __future__ import annotations

import importlib

from repro.configs.shapes import SHAPES, cell_is_supported, input_specs, skip_reason  # noqa: F401
from repro.models.config import ModelConfig, reduced  # noqa: F401

ARCHS = [
    "phi-3-vision-4.2b",
    "phi3-mini-3.8b",
    "granite-20b",
    "stablelm-1.6b",
    "gemma2-2b",
    "zamba2-1.2b",
    "mixtral-8x22b",
    "deepseek-moe-16b",
    "xlstm-1.3b",
    "seamless-m4t-large-v2",
]

_MOD = {
    "phi-3-vision-4.2b": "phi3_vision",
    "phi3-mini-3.8b": "phi3_mini",
    "granite-20b": "granite",
    "stablelm-1.6b": "stablelm",
    "gemma2-2b": "gemma2",
    "zamba2-1.2b": "zamba2",
    "mixtral-8x22b": "mixtral",
    "deepseek-moe-16b": "deepseek_moe",
    "xlstm-1.3b": "xlstm_1b",
    "seamless-m4t-large-v2": "seamless",
}


def get(name: str) -> ModelConfig:
    if name not in _MOD:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MOD[name]}")
    return mod.CONFIG
