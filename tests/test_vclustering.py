"""Algorithm 1 (variance-based distributed clustering) behaviour tests."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kmeans import gap_statistic, kmeans
from repro.core.vclustering import (
    VClusterConfig,
    vcluster_pooled,
)
from repro.data.synthetic import gaussian_mixture, split_sites


def planted(seed=0, n_comp=4, n=2000, d=2, spread=12.0, sigma=0.5):
    pts, lab = gaussian_mixture(seed, n, d, n_comp, spread=spread, sigma=sigma)
    return pts, lab


class TestKMeans:
    def test_recovers_separated_clusters(self):
        pts, _ = planted(seed=3)
        res = kmeans(jax.random.PRNGKey(0), jnp.asarray(pts), 4, iters=25)
        # every true component maps to exactly one center
        assert float(res.inertia) < 2 * pts.shape[0] * 0.5**2 * 2

    def test_fixed_iters_deterministic(self):
        pts, _ = planted(seed=4)
        r1 = kmeans(jax.random.PRNGKey(1), jnp.asarray(pts), 5)
        r2 = kmeans(jax.random.PRNGKey(1), jnp.asarray(pts), 5)
        assert np.array_equal(np.asarray(r1.assign), np.asarray(r2.assign))

    def test_gap_statistic_finds_k(self):
        pts, _ = planted(seed=5, n=600)
        k_hat, _ = gap_statistic(jax.random.PRNGKey(0), jnp.asarray(pts), 6, n_ref=2, iters=10)
        assert k_hat == 4


class TestDistributedClustering:
    def test_recovers_planted_structure_across_sites(self):
        pts, _ = planted(seed=0, n=2000)
        xs = split_sites(pts, 4, seed=1)
        cfg = VClusterConfig(k_local=8, kmeans_iters=20, border_candidates=4)
        res = vcluster_pooled(jax.random.PRNGKey(0), jnp.asarray(xs), cfg)
        assert int(res.merged.n_global) == 4
        # purity: points near each true center share one global label
        labels = np.asarray(res.labels).reshape(-1)
        flat = xs.reshape(-1, 2)
        rng_centers = np.random.default_rng(0).uniform(-12, 12, (4, 2))
        for c in rng_centers:
            near = np.linalg.norm(flat - c, axis=1) < 2.5
            if near.sum() < 10:
                continue
            near_labels = labels[near]
            purity = (near_labels == np.bincount(near_labels).argmax()).mean()
            assert purity > 0.95, (c, purity)

    def test_comm_is_stats_only(self):
        """The ONLY communication is s*k stat triples — KB not MB."""
        pts, _ = planted(seed=0, n=20_000, d=8)
        xs = split_sites(pts, 4, seed=1)
        cfg = VClusterConfig(k_local=10, kmeans_iters=10)
        res = vcluster_pooled(jax.random.PRNGKey(0), jnp.asarray(xs), cfg)
        data_bytes = xs.size * 4
        assert int(res.comm_bytes) < data_bytes / 100, "stats must be ≪ data"
        # and the ratio improves with n: comm is O(s*k*d), data O(n*d)

    def test_merge_is_deterministic_logical_labeling(self):
        """Any site computing the merge gets identical labels (paper's
        'logical merging at any site')."""
        pts, _ = planted(seed=7, n=1000)
        xs = split_sites(pts, 4, seed=2)
        cfg = VClusterConfig(k_local=6, kmeans_iters=15)
        r1 = vcluster_pooled(jax.random.PRNGKey(3), jnp.asarray(xs), cfg)
        r2 = vcluster_pooled(jax.random.PRNGKey(3), jnp.asarray(xs), cfg)
        assert np.array_equal(np.asarray(r1.merged.labels), np.asarray(r2.merged.labels))

    def test_perturbation_does_not_increase_sse(self):
        pts, _ = planted(seed=8, n=1000, sigma=1.2, spread=6.0)
        xs = split_sites(pts, 2, seed=0)
        cfg0 = VClusterConfig(k_local=8, kmeans_iters=15, border_candidates=0)
        cfg1 = cfg0._replace(border_candidates=8)
        # run with and without perturbation; global SSE (recomputed from
        # final labels) must not be worse with perturbation
        def sse_of(res, xs):
            labels = np.asarray(res.labels).reshape(-1)
            flat = np.asarray(xs).reshape(-1, xs.shape[-1])
            tot = 0.0
            for lbl in np.unique(labels):
                pts_l = flat[labels == lbl]
                tot += ((pts_l - pts_l.mean(0)) ** 2).sum()
            return tot

        r0 = vcluster_pooled(jax.random.PRNGKey(0), jnp.asarray(xs), cfg0)
        r1 = vcluster_pooled(jax.random.PRNGKey(0), jnp.asarray(xs), cfg1)
        assert sse_of(r1, xs) <= sse_of(r0, xs) * 1.001


SHARD_MAP_EQUIV = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "SRC")
import jax, jax.numpy as jnp, numpy as np
from repro.core.vclustering import VClusterConfig, vcluster_pooled, vcluster_shard_map
from repro.data.synthetic import gaussian_mixture, split_sites

pts, _ = gaussian_mixture(0, 2000, 2, 4, spread=12.0, sigma=0.5)
xs = split_sites(pts, 4, seed=1)
cfg = VClusterConfig(k_local=6, kmeans_iters=15, border_candidates=4)
key = jax.random.PRNGKey(0)
ref = vcluster_pooled(key, jnp.asarray(xs), cfg)

mesh = jax.make_mesh((4,), ("sites",))
fn = vcluster_shard_map(mesh, "sites", cfg)
keys = jax.random.split(key, 4)
labels, merged = fn(keys, jnp.asarray(xs.reshape(-1, 2)))
# the distributed path must produce the identical global structure
assert int(merged.n_global) == int(ref.merged.n_global), (merged.n_global, ref.merged.n_global)
assert np.array_equal(np.asarray(merged.labels), np.asarray(ref.merged.labels))
assert np.array_equal(np.asarray(labels).reshape(-1), np.asarray(ref.labels).reshape(-1))
print("SHARD_MAP_EQUIV_OK")
"""


class TestShardMapDriver:
    def test_shard_map_equals_pooled_reference(self, tmp_path):
        """The mesh-distributed driver (all_gather of stats + redundant
        logical merge) is bit-identical to the pooled oracle.  Runs in a
        subprocess with 4 host devices."""
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        script = SHARD_MAP_EQUIV.replace("SRC", os.path.abspath(src))
        p = subprocess.run(
            [sys.executable, "-c", script], capture_output=True, text=True, timeout=600,
            env={**os.environ, "JAX_PLATFORMS": "cpu"},
        )
        assert "SHARD_MAP_EQUIV_OK" in p.stdout, p.stdout + p.stderr
