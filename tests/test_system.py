"""End-to-end behaviour tests for the paper's system: the full
grid-mining pipeline through the workflow engine, the paper's headline
claims as assertions, and the dry-run machinery on a small mesh."""

import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


class TestPaperClaims:
    """The paper's quantitative claims, validated on scaled instances."""

    def test_gfm_beats_fdm_in_sync_rounds(self):
        from repro.core.apriori import TransactionDB
        from repro.core.fdm import fdm_mine
        from repro.core.gfm import gfm_mine
        from repro.data.synthetic import ibm_transactions, split_transactions

        dense = ibm_transactions(seed=11, n_tx=3000, n_items=48, avg_tx_len=8, n_patterns=12)
        sites = [TransactionDB.from_dense(s) for s in split_transactions(dense, 5, seed=0)]
        g = gfm_mine(sites, 4, 0.08)
        f = fdm_mine(sites, 4, 0.08)
        assert g.frequent == f.frequent
        assert (g.comm.rounds, f.comm.rounds) == (2, 4)  # paper: "2 (instead of 4)"

    def test_clustering_comm_well_under_1pct_of_data(self):
        from repro.core.vclustering import VClusterConfig, vcluster_pooled
        from repro.data.synthetic import gaussian_mixture, split_sites

        pts, _ = gaussian_mixture(0, 40_000, 4, 6, spread=15.0, sigma=0.6)
        xs = split_sites(pts, 8, seed=0)
        res = vcluster_pooled(jax.random.PRNGKey(0), jnp.asarray(xs), VClusterConfig(k_local=12, kmeans_iters=12))
        # comm is O(s*k*d) regardless of n — at the paper's 5e7-sample scale
        # this ratio is ~1e-6; at this CPU-test scale it is still < 0.5%
        assert int(res.comm_bytes) / (xs.size * 4) < 5e-3

    def test_overhead_ordering_matches_table3(self):
        from benchmarks.bench_overheads import run

        ovh_c, ovh_g, ovh_f = run()
        assert ovh_c > 90
        assert ovh_f > ovh_g


class TestGridMiningPipeline:
    def test_pipeline_with_faults_and_stragglers(self, tmp_path):
        """Full DAG (clustering + mining branches) with injected failures
        completes correctly via retries; rescue file written."""
        env = {**os.environ, "PYTHONPATH": SRC, "JAX_PLATFORMS": "cpu"}
        p = subprocess.run(
            [sys.executable, os.path.join(os.path.dirname(__file__), "..", "examples", "grid_mining_pipeline.py")],
            capture_output=True, text=True, timeout=900, env=env,
        )
        assert "pipeline result: 4 global clusters" in p.stdout, p.stdout + p.stderr
        assert "retries after injected faults: 2" in p.stdout


DRYRUN_SMALL = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "SRC")
import jax, jax.numpy as jnp, json
import repro.configs as C
from repro.models.config import reduced
from repro.models import transformer as T
from repro.train import steps as steps_mod
from repro.sharding import BASELINE, activate, specs_to_shardings, specs_to_structs
from repro.models.layers import spec
from repro.roofline.hlo_costs import analyze_hlo

cfg = reduced(C.get("gemma2-2b"))
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
state_specs = steps_mod.train_state_specs(cfg)
batch_specs = {
    "tokens": spec((8, 32), ("batch", "seq"), "int32"),
    "labels": spec((8, 32), ("batch", "seq"), "int32"),
}
with activate(mesh, BASELINE):
    fn = steps_mod.make_train_step(cfg)
    st_sh = specs_to_shardings(state_specs, BASELINE, mesh)
    b_sh = specs_to_shardings(batch_specs, BASELINE, mesh)
    lowered = jax.jit(fn, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None), donate_argnums=0).lower(
        specs_to_structs(state_specs, BASELINE, mesh), specs_to_structs(batch_specs, BASELINE, mesh))
    compiled = lowered.compile()
mem = compiled.memory_analysis()
costs = analyze_hlo(compiled.as_text(), chips_per_pod=4)
assert costs.flops > 0
assert costs.coll_bytes_total > 0  # grads all-reduce at minimum
print("DRYRUN_SMALL_OK flops=%.3e coll=%.3e" % (costs.flops, costs.coll_bytes_total))
"""


class TestDryRunMachinery:
    def test_small_mesh_lower_compile_analyze(self):
        """The dry-run path (lower+compile+memory+collective analysis)
        works end-to-end on a small 2x2x2 mesh in a subprocess."""
        script = DRYRUN_SMALL.replace("SRC", SRC)
        p = subprocess.run(
            [sys.executable, "-c", script], capture_output=True, text=True, timeout=900,
            env={**os.environ, "JAX_PLATFORMS": "cpu"},
        )
        assert "DRYRUN_SMALL_OK" in p.stdout, p.stdout[-2000:] + p.stderr[-2000:]

    def test_recorded_cells_complete(self):
        """All 40 assigned (arch x shape) cells are recorded for BOTH
        production meshes: OK with roofline terms, or a documented SKIP."""
        d = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
        if not d.exists():
            pytest.skip("dry-run sweep not yet executed")
        import repro.configs as C
        from repro.configs.shapes import SHAPES

        for mesh in ("16x16", "2x16x16"):
            n_ok = n_skip = 0
            for arch in C.ARCHS:
                for shape in SHAPES:
                    f = d / f"{arch}__{shape}__{mesh}.json"
                    assert f.exists(), f"missing dry-run cell {f.name}"
                    rec = json.loads(f.read_text())
                    if rec["status"] == "OK":
                        n_ok += 1
                        assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
                        assert rec["hlo_flops_per_device"] > 0
                    else:
                        n_skip += 1
                        assert "full-attention" in rec["reason"]
            assert n_ok == 34 and n_skip == 6, (mesh, n_ok, n_skip)
