"""Count-distribution Apriori — the classic distributed Apriori variant
from the companion performance study ("Performance Study of Distributed
Apriori-like Frequent Itemsets Mining", arXiv:1903.03008; originally
Agrawal & Shafer's Count Distribution).

Protocol, level-synchronous like FDM but deliberately simpler: at every
level l = 1..k

  1. ONE candidate set is generated from the globally frequent (l-1)-sets
     — identical on every site, no per-site pruning and therefore no
     remote-support phase at all (the step FDM pays ~13% of its compute
     for);
  2. every site counts ALL candidates over its local shard;
  3. one exchange sums the per-site count vectors — the globally frequent
     l-sets fall out of the totals directly.

⇒ k communication rounds like FDM, but each round moves the full count
vector (|C_l| counts per site) instead of FDM's pruned announcements:
count distribution trades bandwidth for zero redundant counting and a
trivially balanced computation.  Counting runs on the same backends as
GFM/FDM (``count_supports`` / the Pallas ``support_count`` kernel), so
the three protocols differ only in what they communicate.

The per-site local passes are served through :class:`DeltaApriori`
(seeded from the site shard via ``from_db``): each level's candidates go
through ``counts_for``/``fold_exact``, so anything the site has already
measured — the singleton seed pass, or any earlier query against the
same state — is served from the cumulative cache instead of re-counted.

This module is registered through the workload plugin registry
(``workflow.registry``) ONLY — nothing hand-wires it into the runtime or
the serving layer, which is the point: it is the proof that the registry
seam carries a whole new mining app.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.core.apriori import (
    DeltaApriori,
    Itemset,
    TransactionDB,
    apriori_join,
    fused_count_sites,
)
from repro.core.gfm import CommLog, _itemset_bytes


@dataclass
class CDAprioriResult:
    frequent: dict[Itemset, int]  # globally frequent -> exact global count
    comm: CommLog
    per_level_candidates: list[int]
    n_total_tx: int


def _level_candidates(level: int, n_items: int, prev_global: list[Itemset]) -> list[Itemset]:
    """The ONE candidate set of level ``level`` — a pure function of the
    globally frequent (l-1)-sets, so every site derives it identically."""
    if level == 1:
        return [(i,) for i in range(n_items)]
    return apriori_join(prev_global)


def cd_mine(
    sites: list[TransactionDB],
    k: int,
    minsup: float,
    backend: str = "jnp",
) -> CDAprioriResult:
    """In-process count-distribution driver — the oracle the SiteJob
    decomposition must match bit-for-bit (same frequents, counts, and
    CommLog)."""
    s = len(sites)
    n_total = sum(db.n_tx for db in sites)
    g_min = int(np.ceil(minsup * n_total))
    comm = CommLog()
    frequent: dict[Itemset, int] = {}
    per_level: list[int] = []
    states = [DeltaApriori.from_db(db, backend=backend) for db in sites]
    comm.count_calls += s  # the singleton seed pass, one per site

    prev_global: list[Itemset] = []
    for level in range(1, k + 1):
        cands = _level_candidates(level, sites[0].n_items, prev_global)
        per_level.append(len(cands))
        if not cands:
            break
        totals: dict[Itemset, int] = dict.fromkeys(cands, 0)
        for st in states:
            fresh = st.uncached(cands)
            cnt = st.counts_for(cands)
            if fresh:
                comm.count_calls += 1
            for its in cands:
                totals[its] += cnt[its]
        # the round: every site broadcasts its FULL count vector
        comm.add_round(len(cands) * s, _itemset_bytes(level), s)
        glob = [(its, c) for its, c in totals.items() if c >= g_min]
        frequent.update(dict(glob))
        prev_global = [its for its, _ in glob]
        if not prev_global:
            break

    return CDAprioriResult(
        frequent=frequent,
        comm=comm,
        per_level_candidates=per_level,
        n_total_tx=n_total,
    )


# ---------------------------------------------------------------------------
# SiteJob decomposition (level-synchronous CD through the one scheduler)
# ---------------------------------------------------------------------------


def cd_site_jobs(
    sites: list[TransactionDB],
    k: int,
    minsup: float,
    backend: str = "jnp",
    measured: dict | None = None,
) -> list:
    """Decompose count-distribution Apriori into
    ``workflow.sitejob.SiteJob``s: per level l, ``count_l_i`` (every site
    counts the whole candidate set) -> ``reduce_l`` (one global sum +
    threshold, one ledgered round).  All k levels are laid out
    statically; levels past exhaustion no-op.  The terminal ``collect``
    job's result is a ``CDAprioriResult`` equal to ``cd_mine``'s.

    Same multihost discipline as ``fdm_site_jobs``: per-site jobs are
    closure-pure toward the SHARED ledger (their device-pass flags and
    timings travel in their results; only the sync jobs fold into the
    CommLog).  Each site's per-level ``DeltaApriori`` state is mutated
    only by that site's own count jobs, which the ownership map pins to
    one process for the whole run.  Run without fault injection (a
    retried sync job would ledger twice).

    The ``count_l_*`` fan-out carries ``batch_key``/``batched_fn``: under
    the ``batched`` backend each level's never-seen candidates count as
    ONE fused site-axis dispatch (``fused_count_sites`` folded back via
    ``DeltaApriori.fold_exact``) — result- and ledger-identical to the
    per-site loop.
    """
    from repro.workflow.sitejob import SiteJob, timed, timed_batch

    s = len(sites)
    n_items = sites[0].n_items
    n_total = sum(db.n_tx for db in sites)
    g_min = int(np.ceil(minsup * n_total))
    comm = CommLog()
    per_level: list[int] = []
    jobs: list[SiteJob] = []
    # per-site local-pass state, created by that site's level-1 count job
    # (on its OWNING process under multihost) and reused every level
    states: list[DeltaApriori | None] = [None] * s

    def _state(i: int) -> DeltaApriori:
        if states[i] is None:
            states[i] = DeltaApriori.from_db(sites[i], backend=backend)
        return states[i]

    def count_fn(level, i):
        def fn(prev=None):
            if level > 1 and (prev is None or not prev["global"]):
                return None  # search exhausted at an earlier level
            cands = _level_candidates(level, n_items, prev["global"] if prev else [])
            t0 = time.perf_counter()
            st = _state(i)
            # passes: device invocations this level, as cd_mine ledgers
            # them — the level-1 singleton seed, or one pass over the
            # never-seen candidates
            passes = 1 if level == 1 else (1 if st.uncached(cands) else 0)
            cnt = st.counts_for(cands)
            return {"cands": cands, "cnt": cnt, "t": time.perf_counter() - t0,
                    "passes": passes}

        return fn

    def count_batched(level):
        def fused(bargs, argss):
            # bargs carry (site, state_accessor): each member's
            # DeltaApriori belongs to ITS OWN request's build closure —
            # in a cross-request merged wave (service fusion) serving one
            # request's counts from another's cumulative cache would
            # corrupt the ledgered pass counts.  Candidates and
            # exhaustion are per member too (each member's prev dep is
            # its own request's reduce, and requests with different
            # minsup exhaust at different levels); within one engine run
            # all members share one reduce dep, which degenerates to the
            # old all-or-nothing early-out exactly.
            prevs = [args[0] if args else None for args in argss]
            live = [
                j for j in range(len(bargs))
                if level == 1 or (prevs[j] is not None and prevs[j]["global"])
            ]
            outs: list[dict | None] = [None] * len(bargs)
            if not live:
                return outs
            t0 = time.perf_counter()
            cands_by = [
                _level_candidates(level, n_items, prevs[j]["global"] if prevs[j] else [])
                for j in live
            ]
            sts = [bargs[j][1](bargs[j][0]) for j in live]
            missing_by = [st.uncached(cands) for st, cands in zip(sts, cands_by)]
            if any(missing_by):
                sups = fused_count_sites(
                    [st.stream() for st in sts], missing_by, backend=backend
                )
                for st, missing, sup in zip(sts, missing_by, sups):
                    st.fold_exact(missing, sup)
            share = (time.perf_counter() - t0) / max(len(live), 1)
            for j, st, cands, missing in zip(live, sts, cands_by, missing_by):
                passes = 1 if level == 1 else (1 if missing else 0)
                outs[j] = {"cands": cands, "cnt": st.counts_for(cands),
                           "t": share, "passes": passes}
            return outs

        return fused

    def reduce_fn(level):
        def fn(*outs):
            if any(o is None for o in outs):
                return None  # search exhausted (all-or-nothing per level)
            cands = outs[0]["cands"]
            per_level.append(len(cands))
            if not cands:
                return None
            comm.count_calls += sum(o["passes"] for o in outs)
            comm.add_round(len(cands) * s, _itemset_bytes(level), s)
            totals = {its: sum(o["cnt"][its] for o in outs) for its in cands}
            glob = [(its, c) for its, c in totals.items() if c >= g_min]
            return {"global": [its for its, _ in glob], "frequent": dict(glob)}

        return fn

    for level in range(1, k + 1):
        prev_dep = [f"reduce_{level - 1}"] if level > 1 else []
        count_batched_fn = timed_batch(count_batched(level), measured)
        for i in range(s):
            jobs.append(
                SiteJob(
                    name=f"count_{level}_{i}",
                    fn=timed(count_fn(level, i), measured, f"count_{level}_{i}"),
                    deps=list(prev_dep),
                    site=i,
                    batch_key=f"count_{level}",
                    batched_fn=count_batched_fn,
                    batch_arg=(i, _state),
                )
            )
        jobs.append(
            SiteJob(
                name=f"reduce_{level}",
                fn=timed(reduce_fn(level), measured, f"reduce_{level}"),
                deps=[f"count_{level}_{i}" for i in range(s)],
            )
        )

    def collect_fn(*decisions):
        frequent: dict[Itemset, int] = {}
        for dec in decisions:
            if dec is not None:
                frequent.update(dec["frequent"])
        return CDAprioriResult(
            frequent=frequent,
            comm=comm,
            per_level_candidates=per_level,
            n_total_tx=n_total,
        )

    jobs.append(
        SiteJob(
            name="collect",
            fn=timed(collect_fn, measured, "collect"),
            deps=[f"reduce_{level}" for level in range(1, k + 1)],
        )
    )
    return jobs
