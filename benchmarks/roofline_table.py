"""Aggregate experiments/dryrun/*.json into the §Roofline table.

Usage:
    PYTHONPATH=src python -m benchmarks.roofline_table            # markdown
    PYTHONPATH=src python -m benchmarks.roofline_table --csv
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

ARCH_ORDER = [
    "phi-3-vision-4.2b", "phi3-mini-3.8b", "granite-20b", "stablelm-1.6b",
    "gemma2-2b", "zamba2-1.2b", "mixtral-8x22b", "deepseek-moe-16b",
    "xlstm-1.3b", "seamless-m4t-large-v2",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str = "16x16", suffix: str = "") -> list[dict]:
    out = []
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            p = DRYRUN / f"{arch}__{shape}__{mesh}{suffix}.json"
            if p.exists():
                out.append(json.loads(p.read_text()))
    return out


def fmt_row(r: dict, csv: bool = False) -> str:
    sep = "," if csv else " | "
    if r.get("status") == "SKIP":
        cells = [r["arch"], r["shape"], "SKIP", "", "", "", "", "", ""]
    else:
        t = r["roofline"]
        cells = [
            r["arch"], r["shape"], r["kind"],
            f"{t['t_compute_s']:.4g}", f"{t['t_memory_s']:.4g}", f"{t['t_collective_s']:.4g}",
            t["dominant"], f"{t['roofline_fraction']:.3f}",
            f"{r['model_vs_hlo_flops']:.2f}",
        ]
    return sep.join(cells) if csv else "| " + " | ".join(cells) + " |"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args(argv)

    rows = load(args.mesh)
    hdr = ["arch", "shape", "kind", "t_compute_s", "t_memory_s", "t_collective_s", "dominant", "roofline_frac", "model/hlo_flops"]
    if args.csv:
        print(",".join(hdr))
    else:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
    for r in rows:
        print(fmt_row(r, args.csv))
    ok = sum(1 for r in rows if r.get("status") == "OK")
    skip = sum(1 for r in rows if r.get("status") == "SKIP")
    print(f"\n{ok} OK, {skip} SKIP (of {len(rows)} recorded cells, mesh {args.mesh})")


if __name__ == "__main__":
    main()
