"""Deterministic fallback for the slice of the hypothesis API the test
suite uses (``given``/``settings``/``strategies.{integers,floats,booleans,
sampled_from}``).

The real ``hypothesis`` (declared in the ``[test]`` extra and installed in
CI) is always preferred — tests import it and fall back here only on
ImportError, so hermetic containers without network access can still run
the full tier-1 suite.  The fallback draws ``max_examples`` pseudo-random
examples from a seed fixed per test (reproducible across runs and
machines); there is no shrinking and no example database.
"""

from __future__ import annotations

import functools
import random
from collections.abc import Callable
from typing import Any


class _Strategy:
    """A draw function over a ``random.Random``; mirrors hypothesis's
    SearchStrategy only as far as the shim needs."""

    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def example_from(self, rng: random.Random) -> Any:
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        pool = list(elements)
        return _Strategy(lambda rng: pool[rng.randrange(len(pool))])


def settings(max_examples: int = 100, deadline=None, **_ignored):
    """Records ``max_examples`` for ``given``; other knobs are no-ops."""

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*arg_strategies: _Strategy, **kw_strategies: _Strategy):
    """Run the test once per drawn example (seeded by the test's qualname,
    so failures reproduce).  Works above or below ``settings``."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples", None) or getattr(
                fn, "_fallback_max_examples", 25
            )
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for _ in range(n):
                drawn = tuple(s.example_from(rng) for s in arg_strategies)
                drawn_kw = {k: s.example_from(rng) for k, s in kw_strategies.items()}
                fn(*args, *drawn, **kwargs, **drawn_kw)

        # pytest must not see the strategy parameters as fixtures
        del wrapper.__wrapped__
        return wrapper

    return deco
