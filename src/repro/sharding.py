"""Logical-axis sharding: MaxText-style rules mapping logical tensor axes
to mesh axes.  Models annotate every parameter/activation with logical
axis names; a rules table (swappable — this is the hillclimbing surface)
maps them to PartitionSpecs.  Divisibility is checked per-dim: a rule that
does not divide the dimension is dropped rather than erroring, so one
rules table serves all 10 architectures.
"""

from __future__ import annotations

import math
from collections.abc import Mapping, Sequence
from dataclasses import dataclass, field

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# Mesh axis sets for supported rule values
AxisVal = tuple[str, ...] | str | None


def _as_tuple(v: AxisVal) -> tuple[str, ...]:
    if v is None:
        return ()
    if isinstance(v, str):
        return (v,)
    return tuple(v)


@dataclass(frozen=True)
class Rules:
    """logical axis name -> mesh axes (in sharding order)."""

    table: Mapping[str, AxisVal]
    name: str = "rules"

    def lookup(self, logical: str) -> tuple[str, ...]:
        return _as_tuple(self.table.get(logical))


# ---------------------------------------------------------------------------
# Baseline rule tables
# ---------------------------------------------------------------------------

# Single-pod baseline: DP over `data` + FSDP over `data` for weights,
# TP over `model` for heads / mlp / vocab / experts.
BASELINE = Rules(
    name="baseline",
    table={
        "batch": ("pod", "data"),
        "embed": ("data",),  # FSDP: shard d_model dim of weights
        "embed_act": (),  # activations keep d_model replicated
        "heads": ("model",),
        "kv_heads": ("model",),
        "mlp": ("model",),
        "vocab": ("model",),
        "experts": ("model",),
        "expert_mlp": ("model",),  # fallback TP dim inside experts
        "kv_seq": ("data",),  # long-context KV cache sequence dim
        "seq": (),
        "head_dim": (),
        "state": (),
        "layers": (),
        "conv": (),
        "frontend": (),
        # MoE dispatch internals
        "expert_cap": ("data",),
        "expert_group": ("data",),
        "flat_tokens": ("pod", "data"),
        # SSM / xLSTM inner dims
        "ssm_inner": ("model",),
        "ssm_heads": ("model",),
        "ssm_state": (),
        "mlstm_inner": ("model",),
        "mlstm_qk": ("model",),
        "mlstm_p": (),
        "slstm_p": (),
    },
)

# GridLocal: identical to baseline but the batch does NOT shard over `pod`
# (each pod is an independent "site"); parameters gain a leading `grid`
# logical axis sharded over `pod`.
GRIDLOCAL = Rules(
    name="gridlocal",
    table={**BASELINE.table, "batch": ("data",), "grid": ("pod",)},
)


def mesh_axis_size(mesh: Mesh, axes: Sequence[str]) -> int:
    return math.prod(mesh.shape[a] for a in axes if a in mesh.shape)


def logical_to_pspec(
    logical_axes: Sequence[str | None],
    shape: Sequence[int],
    rules: Rules,
    mesh: Mesh,
) -> P:
    """Build a PartitionSpec for a tensor with the given logical axes.

    Per-dim: drop mesh axes that are absent from the mesh, already used by
    an earlier dim, or whose product does not divide the dim size.
    """
    assert len(logical_axes) == len(shape), (logical_axes, shape)
    used: set[str] = set()
    parts = []
    for ax, dim in zip(logical_axes, shape):
        cand = [a for a in (rules.lookup(ax) if ax else ()) if a in mesh.shape and a not in used]
        # greedily keep the longest divisible prefix
        keep: list[str] = []
        prod = 1
        for a in cand:
            if dim % (prod * mesh.shape[a]) == 0:
                keep.append(a)
                prod *= mesh.shape[a]
        used.update(keep)
        if not keep:
            parts.append(None)
        elif len(keep) == 1:
            parts.append(keep[0])
        else:
            parts.append(tuple(keep))
    # trim trailing Nones (cosmetic)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def tree_pspecs(axes_tree, shape_tree, rules: Rules, mesh: Mesh):
    """Map logical_to_pspec over parallel pytrees of axes-tuples and shapes."""
    return jax.tree.map(
        lambda ax, shp: logical_to_pspec(ax, shp, rules, mesh),
        axes_tree,
        shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )


def tree_shardings(axes_tree, shape_tree, rules: Rules, mesh: Mesh):
    specs = tree_pspecs(axes_tree, shape_tree, rules, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P))


@dataclass
class ShapeAxes:
    """A (shape, dtype, logical_axes) leaf used to describe parameters and
    inputs without materialising them."""

    shape: tuple[int, ...]
    dtype: str
    axes: tuple[str | None, ...] = field(default=())

    def __post_init__(self):
        if not self.axes:
            object.__setattr__(self, "axes", (None,) * len(self.shape))
        assert len(self.axes) == len(self.shape), (self.shape, self.axes)

    def struct(self, rules: Rules | None = None, mesh: Mesh | None = None) -> jax.ShapeDtypeStruct:
        if rules is None or mesh is None:
            return jax.ShapeDtypeStruct(self.shape, self.dtype)
        sh = NamedSharding(mesh, logical_to_pspec(self.axes, self.shape, rules, mesh))
        return jax.ShapeDtypeStruct(self.shape, self.dtype, sharding=sh)


def is_shape_axes(x) -> bool:
    return isinstance(x, ShapeAxes)


def specs_to_structs(tree, rules: Rules | None = None, mesh: Mesh | None = None):
    return jax.tree.map(lambda s: s.struct(rules, mesh), tree, is_leaf=is_shape_axes)


def specs_to_shardings(tree, rules: Rules, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, logical_to_pspec(s.axes, s.shape, rules, mesh)),
        tree,
        is_leaf=is_shape_axes,
    )


# ---------------------------------------------------------------------------
# Activation-constraint context (used inside model code; identity when no
# mesh is active, e.g. in CPU smoke tests)
# ---------------------------------------------------------------------------

import contextlib

_ACTIVE: list[tuple[Mesh, Rules]] = []


@contextlib.contextmanager
def activate(mesh: Mesh, rules: Rules):
    """Make (mesh, rules) available to ``constrain`` during tracing.  Wrap
    the ``jit(...).lower(...)`` call (constraints bake in at trace time)."""
    _ACTIVE.append((mesh, rules))
    try:
        yield
    finally:
        _ACTIVE.pop()


def constrain(x: jax.Array, logical_axes: Sequence[str | None]) -> jax.Array:
    """with_sharding_constraint by logical axes; identity outside activate().

    Inside shard_map bodies (e.g. the GridLocal per-pod step) the context
    mesh marks the manual axes — constraints must be expressed on that
    abstract mesh with manual axes stripped from the spec."""
    if not _ACTIVE:
        return x
    mesh, rules = _ACTIVE[-1]
    from repro.compat import get_abstract_mesh

    pspec = logical_to_pspec(logical_axes, x.shape, rules, mesh)
    am = get_abstract_mesh()
    if am is not None and am.shape:
        manual = {
            name
            for name, ty in zip(am.axis_names, am.axis_types)
            if "manual" in str(ty).lower()
        }
        if manual:
            def strip(entry):
                if entry is None:
                    return None
                if isinstance(entry, str):
                    return None if entry in manual else entry
                kept = tuple(a for a in entry if a not in manual)
                return kept if kept else None
            pspec = P(*[strip(e) for e in pspec])
        return jax.lax.with_sharding_constraint(x, NamedSharding(am, pspec))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, pspec))
