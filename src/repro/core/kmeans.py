"""Local K-Means (Lloyd) + kmeans++ init + Gap-statistic model selection.

This is the per-site "local clustering" stage of the paper's Algorithm 1.
The assignment step (pairwise distance + argmin) is the compute hot-spot;
``repro.kernels.ops.kmeans_assign`` provides the Pallas TPU kernel and this
module falls back to the pure-jnp oracle on hosts without Mosaic.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.stats import SuffStats, pairwise_sq_dists, stats_from_assignment


class KMeansResult(NamedTuple):
    centers: jax.Array  # (k, D)
    assign: jax.Array  # (N,) int32
    inertia: jax.Array  # () total SSE
    stats: SuffStats  # per-cluster sufficient statistics


def _assign(x: jax.Array, centers: jax.Array, use_kernel: bool) -> tuple[jax.Array, jax.Array]:
    """Nearest-center assignment; returns (assign (N,), min_d2 (N,))."""
    if use_kernel:
        from repro.kernels import ops

        return ops.kmeans_assign(x, centers)
    d2 = pairwise_sq_dists(x, centers)
    return jnp.argmin(d2, axis=-1).astype(jnp.int32), jnp.min(d2, axis=-1)


def kmeans_plus_plus_init(key: jax.Array, x: jax.Array, k: int) -> jax.Array:
    """k-means++ seeding (Arthur & Vassilvitskii) with fixed-shape loops."""
    n, d = x.shape
    first = jax.random.randint(key, (), 0, n)
    centers0 = jnp.zeros((k, d), x.dtype).at[0].set(x[first])

    def body(i, carry):
        centers, key = carry
        key, sub = jax.random.split(key)
        # distance to the nearest already-chosen center (mask unchosen slots)
        d2 = pairwise_sq_dists(x, centers)  # (n, k)
        valid = jnp.arange(k) < i
        d2 = jnp.where(valid[None, :], d2, jnp.inf)
        mind2 = jnp.min(d2, axis=-1)
        probs = mind2 / jnp.maximum(jnp.sum(mind2), 1e-30)
        idx = jax.random.choice(sub, n, p=probs)
        return centers.at[i].set(x[idx]), key

    centers, _ = jax.lax.fori_loop(1, k, body, (centers0, key))
    return centers


@functools.partial(jax.jit, static_argnames=("k", "iters", "use_kernel", "init"))
def kmeans(
    key: jax.Array,
    x: jax.Array,
    k: int,
    iters: int = 25,
    use_kernel: bool = False,
    init: str = "kmeans++",
) -> KMeansResult:
    """Lloyd's algorithm with fixed iteration count (grid-friendly: no
    data-dependent termination, identical work on every site).

    Empty clusters are re-seeded at the point farthest from its center
    (standard Lloyd repair), keeping k live clusters where possible.
    """
    n, d = x.shape
    x = x.astype(jnp.float32)
    if init == "kmeans++":
        centers = kmeans_plus_plus_init(key, x, k)
    else:
        idx = jax.random.choice(key, n, shape=(k,), replace=False)
        centers = x[idx]

    def step(carry, _):
        centers = carry
        assign, mind2 = _assign(x, centers, use_kernel)
        sizes = jax.ops.segment_sum(jnp.ones((n,), jnp.float32), assign, num_segments=k)
        sums = jax.ops.segment_sum(x, assign, num_segments=k)
        new_centers = sums / jnp.maximum(sizes, 1.0)[:, None]
        # keep old center for empty clusters, then re-seed them at the
        # globally farthest point (one at most per iteration — cheap repair)
        new_centers = jnp.where((sizes > 0)[:, None], new_centers, centers)
        far = jnp.argmax(mind2)
        empty = sizes == 0
        any_empty = jnp.any(empty)
        first_empty = jnp.argmax(empty)  # first True, 0 if none
        new_centers = jnp.where(
            any_empty,
            new_centers.at[first_empty].set(x[far]),
            new_centers,
        )
        return new_centers, None

    centers, _ = jax.lax.scan(step, centers, None, length=iters)
    assign, mind2 = _assign(x, centers, use_kernel)
    stats = stats_from_assignment(x, assign, k)
    return KMeansResult(centers=stats.centers, assign=assign, inertia=jnp.sum(mind2), stats=stats)


@functools.partial(jax.jit, static_argnames=("iters", "use_kernel"))
def kmeans_warm(
    x: jax.Array,
    centers0: jax.Array,
    iters: int = 25,
    use_kernel: bool = False,
) -> KMeansResult:
    """Lloyd's algorithm warm-started from explicit initial centers —
    the serving layer's incremental-clustering entry point: on drifting
    data the previous query's centroids are a near-converged seed, so a
    handful of refinement iterations replace a full seeded run.

    Exactly the ``kmeans`` iteration (same empty-cluster repair, same
    statistics), minus the seeding: ``kmeans_warm(x, prev.centers,
    iters=n)`` continues where the previous fit stopped, and on identical
    data reproduces ``kmeans``'s fixed point (idempotent once converged).

    This history dependence is why the ``kmeans`` workload is the ONE
    app with no ``exec_batch_key`` hook: a fused wave builds every
    member's callable before any member's finalize writes centroids
    back, so fusing two same-``k`` queries would silently turn the
    second's warm start into a cold one.  Serial per-group execution
    keeps the query-order semantics observable and deterministic.
    """
    n, d = x.shape
    x = x.astype(jnp.float32)
    centers = jnp.asarray(centers0, jnp.float32)
    k = centers.shape[0]

    def step(carry, _):
        centers = carry
        assign, mind2 = _assign(x, centers, use_kernel)
        sizes = jax.ops.segment_sum(jnp.ones((n,), jnp.float32), assign, num_segments=k)
        sums = jax.ops.segment_sum(x, assign, num_segments=k)
        new_centers = sums / jnp.maximum(sizes, 1.0)[:, None]
        new_centers = jnp.where((sizes > 0)[:, None], new_centers, centers)
        far = jnp.argmax(mind2)
        empty = sizes == 0
        new_centers = jnp.where(
            jnp.any(empty),
            new_centers.at[jnp.argmax(empty)].set(x[far]),
            new_centers,
        )
        return new_centers, None

    centers, _ = jax.lax.scan(step, centers, None, length=iters)
    assign, mind2 = _assign(x, centers, use_kernel)
    stats = stats_from_assignment(x, assign, k)
    return KMeansResult(centers=stats.centers, assign=assign, inertia=jnp.sum(mind2), stats=stats)


def _pooled_inertia(key, x, k, iters):
    return kmeans(key, x, k, iters=iters).inertia


def gap_statistic(
    key: jax.Array,
    x: jax.Array,
    k_max: int,
    n_ref: int = 4,
    iters: int = 15,
) -> tuple[int, jax.Array]:
    """Gap statistic (Tibshirani et al.) for choosing k — the paper's
    "approximation technique" for picking the number of sub-clusters.

    Returns (k_hat, gaps[1..k_max]).  Reference sets are uniform over the
    bounding box.  k_hat = smallest k with gap(k) >= gap(k+1) - s(k+1).
    """
    n, d = x.shape
    lo = jnp.min(x, axis=0)
    hi = jnp.max(x, axis=0)

    ks = list(range(1, k_max + 1))
    gaps = []
    sks = []
    for k in ks:
        key, kd, kr = jax.random.split(key, 3)
        wk = _pooled_inertia(kd, x, k, iters)
        ref_keys = jax.random.split(kr, n_ref)

        def one_ref(rk):
            ku, kc = jax.random.split(rk)
            ref = jax.random.uniform(ku, (n, d), minval=lo, maxval=hi)
            return jnp.log(jnp.maximum(_pooled_inertia(kc, ref, k, iters), 1e-12))

        logs = jnp.stack([one_ref(rk) for rk in ref_keys])
        gap = jnp.mean(logs) - jnp.log(jnp.maximum(wk, 1e-12))
        sk = jnp.std(logs) * jnp.sqrt(1.0 + 1.0 / n_ref)
        gaps.append(gap)
        sks.append(sk)

    gaps_arr = jnp.stack(gaps)
    sks_arr = jnp.stack(sks)
    k_hat = k_max
    for i in range(k_max - 1):
        if bool(gaps_arr[i] >= gaps_arr[i + 1] - sks_arr[i + 1]):
            k_hat = i + 1
            break
    return k_hat, gaps_arr
