"""Multi-device grid-mining runtime.

Bridges the repo's two halves: the paper-faithful mining algorithms
(``repro.core``) and the DAGMan-analog grid workflow model
(``repro.workflow``).  ``GridRuntime`` executes both applications
end-to-end through ``workflow.engine.Engine`` on a real JAX device mesh,
with measured kernel time calibrating the simulated grid clock.
"""

from repro.runtime.backends import MultiHostBackend
from repro.runtime.gridruntime import GridRuntime, RuntimeRun

__all__ = ["GridRuntime", "MultiHostBackend", "RuntimeRun"]
