"""State-space / linear-recurrence mixers.

Core primitive: the chunked *gated outer-product scan*

    h_t = exp(log_a_t) · h_{t-1} + g_t · k_t v_tᵀ        (state: (n, p) per head)
    y_t = q_t · h_t                                       (contract n)

which is simultaneously Mamba-2's SSD recurrence (a=exp(Δ·A), g=Δ, k=B,
v=x, q=C) and the mLSTM matrix-memory recurrence (a=σ_f, g=i-gate, k/v/q
from projections, with the normaliser tracked as an extra v-channel).  The
chunked evaluation (intra-chunk quadratic + inter-chunk state scan) is the
TPU-native adaptation: the intra-chunk einsums are MXU matmuls and the
sequential dependency collapses from S steps to S/chunk steps.

All decay/log quantities stay ≤ 0 so every exp() here is ≤ 1 — the chunked
form is numerically stable in fp32 without extra stabilisers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm, spec
from repro.sharding import constrain


# ---------------------------------------------------------------------------
# Generic chunked scan
# ---------------------------------------------------------------------------


def gated_outer_scan(
    log_a: jax.Array,  # (B, S, H) ≤ 0
    gate: jax.Array,  # (B, S, H)
    k: jax.Array,  # (B, S, H, N)
    v: jax.Array,  # (B, S, H, P)
    q: jax.Array,  # (B, S, H, N)
    h0: jax.Array | None = None,  # (B, H, N, P)
    chunk: int = 128,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B, S, H, P), h_final (B, H, N, P))."""
    b, s, h = log_a.shape
    n, p = k.shape[-1], v.shape[-1]
    chunk = min(chunk, s)
    orig_s = s
    if s % chunk:  # pad tail steps with identity transitions (log_a=0,
        # gate=0): outputs for pads are discarded, the state is unchanged
        pad = chunk - s % chunk
        z2 = ((0, 0), (0, pad), (0, 0))
        log_a = jnp.pad(log_a, z2)
        gate = jnp.pad(gate, z2)
        k = jnp.pad(k, z2 + ((0, 0),))
        v = jnp.pad(v, z2 + ((0, 0),))
        q = jnp.pad(q, z2 + ((0, 0),))
        s += pad
    nc = s // chunk

    f32 = jnp.float32
    la = log_a.astype(f32).reshape(b, nc, chunk, h)
    g = gate.astype(f32).reshape(b, nc, chunk, h)
    kc = k.reshape(b, nc, chunk, h, n)
    vc = v.reshape(b, nc, chunk, h, p)
    qc = q.reshape(b, nc, chunk, h, n)

    lcum = jnp.cumsum(la, axis=2)  # (B, NC, L, H) ≤ 0 within chunk
    ltot = lcum[:, :, -1, :]  # (B, NC, H)

    # --- intra-chunk (computed for all chunks in parallel) ---
    # S[t, s'] = exp(lcum_t - lcum_s') * g_s' * (q_t · k_s'),  s' ≤ t
    qk = jnp.einsum("bclhn,bcmhn->bchlm", qc, kc)  # (B,NC,H,L,L)
    dec = lcum[:, :, :, None, :] - lcum[:, :, None, :, :]  # (B,NC,L,L,H) t,s'
    dec = jnp.transpose(dec, (0, 1, 4, 2, 3))  # (B,NC,H,L,L)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    w = jnp.where(tri, jnp.exp(jnp.minimum(dec, 0.0)), 0.0) * qk
    w = w * jnp.transpose(g, (0, 1, 3, 2))[:, :, :, None, :]  # gate at s'
    y_intra = jnp.einsum("bchlm,bcmhp->bclhp", w.astype(v.dtype), vc)

    # --- inter-chunk scan over NC carrying h (B, H, N, P).  The state
    # injection AND the q·h readout live INSIDE the body so no stacked
    # (NC, ..., N, P) state tensor ever materialises — per-chunk h is a
    # transient.  (§Perf iteration: this took the xlstm-1.3b train memory
    # term down ~an order of magnitude vs emitting states per chunk.) ---
    if h0 is None:
        h0 = jnp.zeros((b, h, n, p), v.dtype)

    inj_w = (jnp.exp(ltot[:, :, None, :] - lcum) * g).astype(v.dtype)  # (B,NC,L,H)
    q_dec = (jnp.exp(lcum)[..., None] * qc.astype(f32)).astype(v.dtype)  # (B,NC,L,H,N)

    def body(hprev, inp):
        ltot_c, injw_c, kc_c, vc_c, qd_c = inp  # (B,H),(B,L,H),(B,L,H,N),(B,L,H,P),(B,L,H,N)
        y_inter_c = jnp.einsum("blhn,bhnp->blhp", qd_c, hprev)
        inj_c = jnp.einsum("blh,blhn,blhp->bhnp", injw_c, kc_c, vc_c)
        hnew = jnp.exp(ltot_c)[..., None, None].astype(hprev.dtype) * hprev + inj_c
        return hnew, y_inter_c

    xs = tuple(
        jnp.moveaxis(t, 1, 0)
        for t in (ltot, inj_w, kc, vc, q_dec)
    )
    h_final, y_inter = jax.lax.scan(body, h0, xs)
    y_inter = jnp.moveaxis(y_inter, 0, 1)  # (B, NC, L, H, P)

    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y[:, :orig_s], h_final


def gated_outer_step(
    log_a: jax.Array,  # (B, H)
    gate: jax.Array,  # (B, H)
    k: jax.Array,  # (B, H, N)
    v: jax.Array,  # (B, H, P)
    q: jax.Array,  # (B, H, N)
    h: jax.Array,  # (B, H, N, P)
) -> tuple[jax.Array, jax.Array]:
    """Single decode step of the same recurrence."""
    hnew = jnp.exp(log_a.astype(jnp.float32))[..., None, None].astype(h.dtype) * h + (
        gate[..., None, None].astype(h.dtype) * k[..., :, None] * v[..., None, :]
    )
    y = jnp.einsum("bhn,bhnp->bhp", q, hnew)
    return y, hnew


# ---------------------------------------------------------------------------
# Causal depthwise conv (mamba's local conv)
# ---------------------------------------------------------------------------


def causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """x (B, S, C), w (W, C) depthwise causal conv."""
    wlen = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (wlen - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(wlen):  # static tiny loop (W=4)
        out = out + pad[:, i : i + x.shape[1], :] * w[i][None, None, :]
    return out


def causal_conv_step(x_new: jax.Array, state: jax.Array, w: jax.Array):
    """x_new (B, C); state (B, W-1, C) past inputs; returns (y (B, C), state')."""
    full = jnp.concatenate([state, x_new[:, None, :]], axis=1)  # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", full, w)
    return y, full[:, 1:, :]


# ---------------------------------------------------------------------------
# Mamba-2 mixer block
# ---------------------------------------------------------------------------


def mamba2_spec(cfg) -> dict:
    ss = cfg.ssm
    d = cfg.d_model
    d_in = ss.expand * d
    h = d_in // ss.head_dim
    gn = ss.d_state  # n_groups = 1
    return {
        "w_z": spec((d, d_in), ("embed", "ssm_inner")),
        "w_x": spec((d, d_in), ("embed", "ssm_inner")),
        "w_B": spec((d, gn), ("embed", "ssm_state")),
        "w_C": spec((d, gn), ("embed", "ssm_state")),
        "w_dt": spec((d, h), ("embed", "ssm_heads")),
        "conv_x": spec((ss.d_conv, d_in), ("conv", "ssm_inner")),
        "conv_B": spec((ss.d_conv, gn), ("conv", "ssm_state")),
        "conv_C": spec((ss.d_conv, gn), ("conv", "ssm_state")),
        "A_log": spec((h,), ("ssm_heads",)),
        "D": spec((h,), ("ssm_heads",)),
        "dt_bias": spec((h,), ("ssm_heads",)),
        "out_norm": {"scale": spec((d_in,), ("norm_scale",))},
        "w_out": spec((d_in, d), ("ssm_inner", "embed")),
    }


def _mamba2_core(cfg, p, x):
    ss = cfg.ssm
    b, s, d = x.shape
    d_in = ss.expand * d
    h = d_in // ss.head_dim
    dt_ = x.dtype
    z = constrain(x @ p["w_z"].astype(dt_), ("batch", "seq", "ssm_inner"))
    xi = causal_conv(constrain(x @ p["w_x"].astype(dt_), ("batch", "seq", "ssm_inner")), p["conv_x"].astype(dt_))
    xi = jax.nn.silu(xi)
    Bm = jax.nn.silu(causal_conv(x @ p["w_B"].astype(dt_), p["conv_B"].astype(dt_)))
    Cm = jax.nn.silu(causal_conv(x @ p["w_C"].astype(dt_), p["conv_C"].astype(dt_)))
    dt = jax.nn.softplus(
        (x @ p["w_dt"].astype(dt_)).astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )  # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H,) < 0
    log_a = dt * A[None, None, :]
    xh = xi.reshape(b, s, h, ss.head_dim)
    kb = jnp.broadcast_to(Bm[:, :, None, :], (b, s, h, ss.d_state))
    qc = jnp.broadcast_to(Cm[:, :, None, :], (b, s, h, ss.d_state))
    return z, xh, kb, qc, dt, log_a


def apply_mamba2(cfg, p: dict, x: jax.Array, h0=None):
    """Full-sequence mamba2 mixer.  Returns (y (B,S,D), cache)."""
    ss = cfg.ssm
    b, s, d = x.shape
    z, xh, kb, qc, dt, log_a = _mamba2_core(cfg, p, x)
    y, h_fin = gated_outer_scan(log_a, dt, kb, xh, qc, h0=h0, chunk=ss.chunk)
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(b, s, -1)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"]["scale"])
    out = y @ p["w_out"].astype(x.dtype)
    # decode cache: final state + conv tails
    d_in = ss.expand * d
    cache = {
        "h": h_fin,
        "conv_x": (x @ p["w_x"].astype(x.dtype))[:, -(ss.d_conv - 1) :, :],
        "conv_B": (x @ p["w_B"].astype(x.dtype))[:, -(ss.d_conv - 1) :, :],
        "conv_C": (x @ p["w_C"].astype(x.dtype))[:, -(ss.d_conv - 1) :, :],
    }
    return out, cache


def mamba2_decode(cfg, p: dict, x: jax.Array, cache: dict):
    """x (B, 1, D) single-token step; returns (y (B,1,D), cache')."""
    ss = cfg.ssm
    b, _, d = x.shape
    d_in = ss.expand * d
    h = d_in // ss.head_dim
    dt_ = x.dtype
    xt = x[:, 0, :]
    z = xt @ p["w_z"].astype(dt_)
    xc, st_x = causal_conv_step(xt @ p["w_x"].astype(dt_), cache["conv_x"], p["conv_x"].astype(dt_))
    Bc, st_B = causal_conv_step(xt @ p["w_B"].astype(dt_), cache["conv_B"], p["conv_B"].astype(dt_))
    Cc, st_C = causal_conv_step(xt @ p["w_C"].astype(dt_), cache["conv_C"], p["conv_C"].astype(dt_))
    xi = jax.nn.silu(xc).reshape(b, h, ss.head_dim)
    Bm = jnp.broadcast_to(jax.nn.silu(Bc)[:, None, :], (b, h, ss.d_state))
    Cm = jnp.broadcast_to(jax.nn.silu(Cc)[:, None, :], (b, h, ss.d_state))
    dt = jax.nn.softplus(
        (xt @ p["w_dt"].astype(dt_)).astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )  # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, hnew = gated_outer_step(dt * A[None, :], dt, Bm, xi, Cm, cache["h"])
    y = y + p["D"].astype(y.dtype)[None, :, None] * xi
    y = y.reshape(b, -1)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"]["scale"])
    out = (y @ p["w_out"].astype(x.dtype))[:, None, :]
    return out, {"h": hnew, "conv_x": st_x, "conv_B": st_B, "conv_C": st_C}


def mamba2_cache_spec(cfg, batch: int) -> dict:
    ss = cfg.ssm
    d_in = ss.expand * cfg.d_model
    h = d_in // ss.head_dim
    dt = cfg.dtype
    return {
        "h": spec((batch, h, ss.d_state, ss.head_dim), ("batch", "ssm_heads", "ssm_state", None), dt),
        "conv_x": spec((batch, ss.d_conv - 1, d_in), ("batch", None, "ssm_inner"), dt),
        "conv_B": spec((batch, ss.d_conv - 1, ss.d_state), ("batch", None, "ssm_state"), dt),
        "conv_C": spec((batch, ss.d_conv - 1, ss.d_state), ("batch", None, "ssm_state"), dt),
    }
