"""DAG job model — the Condor/DAGMan analogue the paper evaluates against.

A Job is a Python callable plus metadata (inputs/outputs in bytes, the
site it runs on).  The DAG enforces ordering; the engine (engine.py)
executes it with a simulated grid clock, fault injection and rescue
semantics.
"""

from __future__ import annotations

from collections.abc import Callable
from dataclasses import dataclass, field
from typing import Any, NamedTuple


class TimedResult(NamedTuple):
    """A job result carrying its own device-measured compute time.

    When a job's ``fn`` returns one of these, the engine advances the
    simulated grid clock by ``compute_s`` (the caller's measurement — e.g.
    wall time around ``jax.block_until_ready``) instead of its own
    perf_counter bracket, and dependents receive the unwrapped ``value``.
    This is how the runtime layer calibrates the paper's overhead model
    with real kernel timings.
    """

    value: Any
    compute_s: float


@dataclass
class Job:
    name: str
    fn: Callable[..., Any]
    deps: list[str] = field(default_factory=list)
    site: int = 0  # grid site executing this job (overhead model: link matrix)
    input_bytes: int = 0  # data staged in from the submit node
    output_bytes: int = 0  # data staged back
    retries: int = 2  # DAGMan-style automatic retry budget
    sim_compute_s: float = 0.0  # simulated compute (paper-scale what-if
    # studies); added to the simulated clock WITHOUT real sleeping
    # execution-backend batching hooks (workflow.executor.BatchedBackend):
    # jobs sharing a batch_key form one shape-identical fan-out group;
    # batched_fn(names, batch_args, argss) executes the whole group in
    # one fused call; batch_arg is this job's member payload (site index)
    batch_key: str | None = None
    batched_fn: Callable[..., Any] | None = None
    batch_arg: Any = None

    # filled by the engine
    status: str = "pending"  # pending | running | done | failed
    attempts: int = 0
    result: Any = None
    t_submit: float = 0.0
    t_start: float = 0.0
    t_end: float = 0.0


class DAG:
    def __init__(self, name: str = "dag"):
        self.name = name
        self.jobs: dict[str, Job] = {}

    def add(self, job: Job) -> Job:
        if job.name in self.jobs:
            raise ValueError(
                f"duplicate job {job.name!r} in DAG {self.name!r}: job names must be unique"
            )
        if job.name in job.deps:
            raise ValueError(f"job {job.name!r} depends on itself (cycle: {job.name} -> {job.name})")
        for d in job.deps:
            if d not in self.jobs:
                raise ValueError(
                    f"job {job.name!r} depends on unknown {d!r} "
                    f"(jobs must be added in topological order)"
                )
        self.jobs[job.name] = job
        return job

    def job(self, name: str, fn: Callable, deps: list[str] | None = None, **kw) -> Job:
        return self.add(Job(name=name, fn=fn, deps=deps or [], **kw))

    def ready(self) -> list[Job]:
        out = []
        for j in self.jobs.values():
            if j.status == "pending" and all(self.jobs[d].status == "done" for d in j.deps):
                out.append(j)
        return out

    def done(self) -> bool:
        return all(j.status == "done" for j in self.jobs.values())

    def failed(self) -> list[Job]:
        return [j for j in self.jobs.values() if j.status == "failed"]

    def validate_acyclic(self) -> None:
        """Reject cyclic dependency graphs with the offending cycle
        spelled out (``cycle: a -> b -> a``), and unknown dependency
        names with the job that references them.  Iterative DFS — a
        10k-job chain must not hit the recursion limit."""
        seen: dict[str, int] = {}  # 0/absent = white, 1 = on path, 2 = done
        for root in self.jobs:
            if seen.get(root) == 2:
                continue
            path: list[str] = []
            stack: list[tuple[str, bool]] = [(root, False)]
            while stack:
                n, leaving = stack.pop()
                if leaving:
                    seen[n] = 2
                    path.pop()
                    continue
                st = seen.get(n, 0)
                if st == 2:
                    continue
                if st == 1:
                    cycle = path[path.index(n):] + [n]
                    raise ValueError(f"dependency cycle in DAG {self.name!r}: {' -> '.join(cycle)}")
                seen[n] = 1
                path.append(n)
                stack.append((n, True))
                for d in self.jobs[n].deps:
                    if d not in self.jobs:
                        raise ValueError(f"job {n!r} depends on unknown {d!r}")
                    if seen.get(d, 0) != 2:
                        stack.append((d, False))
