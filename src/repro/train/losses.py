"""Losses.  The CE is computed CHUNKED over the sequence so the full
(B, S, V) logits tensor never materialises — required for the 256k-vocab
architectures (gemma2, seamless) at 4k..32k sequence lengths."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.transformer import logits_from
from repro.sharding import constrain


def chunked_softmax_ce(cfg, params, hidden, labels, chunk: int = 512):
    """hidden (B, S, D); labels (B, S) int32 with -1 = ignore.

    Returns (mean_ce, n_tokens).  Scans over S/chunk chunks; each chunk's
    logits are formed, reduced and discarded (remat-friendly).
    """
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    if s % chunk:  # pad with ignored labels
        pad = chunk - s % chunk
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        s += pad
    nc = s // chunk
    hs = jnp.moveaxis(hidden.reshape(b, nc, chunk, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)

    @jax.checkpoint  # logits recomputed in backward: never stored per-chunk
    def chunk_ce(h, lab):
        h = constrain(h, ("batch", None, None))
        lg = logits_from(cfg, params, h)  # (B, C, Vp) f32, padded ids masked
        lg = constrain(lg, ("batch", None, "vocab"))
        mask = lab >= 0
        lab_safe = jnp.maximum(lab, 0)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, lab_safe[..., None], axis=-1)[..., 0]
        ce = jnp.where(mask, lse - gold, 0.0)
        return jnp.sum(ce), jnp.sum(mask)

    def body(carry, inp):
        tot, cnt = carry
        h, lab = inp
        ce, n = chunk_ce(h, lab)
        return (tot + ce, cnt + n), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.int32(0)), (hs, ls))
    return tot / jnp.maximum(cnt.astype(jnp.float32), 1.0), cnt
