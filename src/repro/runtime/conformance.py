"""Cross-backend conformance harness — the contract every execution
backend must satisfy, and the multi-process audit trail that proves true
site ownership.

The contract: execution backends change HOW job callables run (inline
host loop, fused vmapped dispatch, site-partitioned multi-host with
result shipping) — never WHAT the scheduler decides or WHAT the mining
computes.  For any (app, schedule) cell this module can produce

  * a **result digest** — the mining outputs themselves (cluster labels,
    frequent itemsets with exact counts, the CommLog) in canonical
    JSON-able form; backends must match BIT-FOR-BIT;
  * a **scheduling fingerprint** — the simulated-clock quantities that
    are deterministic under fixed placement (prep/submit/transfer,
    placements, retries, job set); backends must match exactly.

Run as a module it is the multi-host conformance CHILD: each
``jax.distributed`` process executes every cell through
``MultiHostBackend`` *and* through the inline backend in the same
process, then prints one JSON report (digests, fingerprints, per-process
execution logs, ownership) for the parent harness — pytest
(``tests/test_backend_conformance.py``) or the CI matrix job — to cross
check:

    python -m repro.runtime.conformance --pid 0 --nprocs 3 \\
        --port 12345 --sites 4

The execution logs are the acceptance check for true distribution: each
site's jobs must appear in EXACTLY ONE process's ``executed`` list.
"""

from __future__ import annotations

import argparse
import json

import jax

from repro.core.apriori import TransactionDB
from repro.core.vclustering import VClusterConfig
from repro.data.synthetic import (
    gaussian_mixture,
    ibm_transactions,
    split_sites,
    split_transactions,
)
from repro.runtime.gridruntime import GridRuntime
from repro.workflow.engine import Engine, RunReport
from repro.workflow.faults import FaultInjector
from repro.workflow.overhead import GridModel
from repro.workflow.registry import RunContext, conformance_apps, get_workload

# every registered grid workload that opted into the conformance matrix —
# registering a new app through workflow.registry extends this suite (and
# tests/test_backend_conformance.py, and the benches) automatically
APPS = conformance_apps()
SCHEDULES = ("staged", "async")

# small-but-nontrivial canonical inputs: enough structure that the mining
# produces real itemsets/clusters, small enough that a 3-process CPU
# conformance run stays in CI smoke budget
_N_POINTS_PER_SITE = 60
_N_TX = 160
_N_ITEMS = 12
_K_ITEMSETS = 3
_MINSUP = 0.15


def make_inputs(n_sites: int, seed: int = 0):
    """Deterministic synthetic inputs for one conformance cell: per-site
    point sets for clustering and per-site TransactionDBs for mining.
    Every process derives the identical inputs from the seed."""
    pts, _ = gaussian_mixture(seed, _N_POINTS_PER_SITE * n_sites, 2, 3, spread=9.0, sigma=0.8)
    xs = split_sites(pts, n_sites, seed=seed + 1)
    dense = ibm_transactions(
        seed=seed + 2, n_tx=_N_TX, n_items=_N_ITEMS, avg_tx_len=5, n_patterns=4
    )
    dbs = [TransactionDB.from_dense(d) for d in split_transactions(dense, n_sites, seed=seed)]
    return xs, dbs


def _cfg() -> VClusterConfig:
    return VClusterConfig(k_local=3, kmeans_iters=5, use_kernel=False)


def _conf_params(app: str, seed: int = 0) -> dict:
    """The canonical params of one conformance cell, by dataset kind."""
    if get_workload(app).dataset_kind == "points":
        return {"key": jax.random.PRNGKey(seed), "cfg": _cfg()}
    return {"k": _K_ITEMSETS, "minsup": _MINSUP}


def run_app(
    app: str,
    n_sites: int,
    schedule: str,
    backend,
    *,
    faults=None,
    seed: int = 0,
    count_backend: str = "jnp",
    use_kernel: bool = False,
    block: str | None = None,
):
    """Execute one registered app through the generic GridRuntime.run on
    the given execution backend (name or instance); returns the
    RuntimeRun.

    ``count_backend``/``use_kernel`` select the compute path exactly as
    ``GridRuntime`` does (the default jnp oracle keeps the CI matrix
    cheap); ``block="auto"`` additionally flips the kernel wrappers'
    block mode for the duration of the run, so the conformance digests
    can be checked with autotuned tile shapes active — the autotuner's
    never-changes-results contract, proven on the real apps."""
    xs, dbs = make_inputs(n_sites, seed)
    engine = Engine(
        model=GridModel(),
        faults=faults,
        overlap_prep=True,
        schedule=schedule,
        backend=backend,
    )
    rt = GridRuntime(
        engine=engine, sync="pooled", use_kernel=use_kernel, count_backend=count_backend
    )
    data = xs if get_workload(app).dataset_kind == "points" else dbs
    if block is None:
        return rt.run(app, data, _conf_params(app, seed))
    from repro.kernels import ops

    prev = ops.set_default_block(block)
    try:
        return rt.run(app, data, _conf_params(app, seed))
    finally:
        ops.set_default_block(prev)


def result_digest(app: str, run) -> dict:
    """The mining output in canonical JSON-able form — the thing that must
    be bit-for-bit identical across backends and processes.  The digest
    shape is the registered WorkloadSpec's, not this module's."""
    return get_workload(app).digest(run.result)


def schedule_fingerprint(rep: RunReport) -> dict:
    """What the scheduler decided, independent of measured compute and of
    the executing backend: identical across backends under fixed
    placement, and identical across the processes of one multi-host run
    (the globally-consistent clock/ledger invariant)."""
    return {
        "schedule": rep.schedule,
        "placement": rep.placement,
        "placements": {k: int(v) for k, v in sorted(rep.placements.items())},
        "prep_s": rep.prep_s,
        "submit_s": rep.submit_s,
        "transfer_s": rep.transfer_s,
        "retries": int(rep.retries),
        "speculative": int(rep.speculative),
        "jobs": sorted(rep.job_times),
    }


def conformance_cell(
    app: str,
    n_sites: int,
    schedule: str,
    backend,
    *,
    count_backend: str = "jnp",
    use_kernel: bool = False,
    block: str | None = None,
) -> dict:
    """One (app, schedule) cell on one backend: digest + fingerprint."""
    run = run_app(
        app,
        n_sites,
        schedule,
        backend,
        count_backend=count_backend,
        use_kernel=use_kernel,
        block=block,
    )
    return {
        "app": app,
        "schedule": schedule,
        "backend": run.backend,
        "digest": result_digest(app, run),
        "fingerprint": schedule_fingerprint(run.report),
    }


def job_sites(app: str, n_sites: int) -> dict[str, int]:
    """job name -> pre-assigned site for one app's DAG (the ownership
    audit needs it to check each SITE's jobs land on one process)."""
    spec = get_workload(app)
    xs, dbs = make_inputs(n_sites)
    data = xs if spec.dataset_kind == "points" else dbs
    ctx = RunContext(measured={}, count_backend="jnp", use_kernel=False, cluster_sync=None)
    jobs, _ = spec.build_jobs(data, spec.resolve(_conf_params(app)), ctx)
    return {j.name: int(j.site) for j in jobs}


# ---------------------------------------------------------------------------
# Multi-host conformance child (one jax.distributed process)
# ---------------------------------------------------------------------------

MARKER = "MULTIHOST_CONFORMANCE "


def child_main(argv=None) -> dict:  # pragma: no cover - runs in the
    # jax.distributed subprocesses of the conformance harness, where
    # in-process coverage cannot see it (tests/test_backend_conformance.py
    # exercises every line through 2- and 3-process groups)
    """Run every conformance cell through the multihost backend AND the
    inline baseline in THIS process; print one JSON report."""
    from repro.runtime.backends import MultiHostBackend

    ap = argparse.ArgumentParser()
    ap.add_argument("--pid", type=int, required=True)
    ap.add_argument("--nprocs", type=int, required=True)
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--sites", type=int, required=True)
    ap.add_argument("--apps", default=",".join(APPS))
    ap.add_argument("--schedules", default=",".join(SCHEDULES))
    # --fuse 1 (default) = wave-fused shipping (one collective per ready
    # wave); --fuse 0 = the PR-5 per-job shipment rounds.  Both modes must
    # produce bit-identical digests — the CI matrix runs each.
    ap.add_argument("--fuse", type=int, default=1, choices=(0, 1))
    # compute-path knobs: --count-backend kernel + --block auto runs the
    # matrix with the Pallas kernels and autotuned tile shapes active
    ap.add_argument("--count-backend", default="jnp", choices=("jnp", "kernel"))
    ap.add_argument("--block", default=None, choices=(None, "default", "auto"))
    args = ap.parse_args(argv)

    be = MultiHostBackend(
        coordinator_address=f"127.0.0.1:{args.port}",
        num_processes=args.nprocs,
        process_id=args.pid,
        fuse_waves=bool(args.fuse),
    )
    report = {
        "pid": args.pid,
        "n_sites": args.sites,
        "fuse_waves": bool(args.fuse),
        "topology": be.describe(),
        "cells": [],
    }
    knobs = {
        "count_backend": args.count_backend,
        "use_kernel": args.count_backend == "kernel",
        "block": args.block,
    }
    for app in args.apps.split(","):
        for schedule in args.schedules.split(","):
            mh = conformance_cell(app, args.sites, schedule, be, **knobs)
            mh["executed"] = list(be.executed_log)
            mh["shipped"] = sorted(be.shipped_log)
            mh["owned_sites"] = list(
                be._partition.owned_sites if be._partition is not None else []
            )
            mh["job_sites"] = job_sites(app, args.sites)
            # the collective/shipment ledger for this cell: under wave
            # fusion shipments must equal waves (O(waves) collectives);
            # per-job mode ships once per executed job
            mh["ledger"] = dict(be.ledger(), waves=int(be.waves))
            inline = conformance_cell(app, args.sites, schedule, "inline", **knobs)
            report["cells"].append({"multihost": mh, "inline": inline})

    # fault-injection under true distribution: a seeded injected failure
    # retries identically on every process, the shipment collectives stay
    # in lockstep, and the result still matches the inline run under the
    # same faults
    fault = {"cluster_1": 1}
    run_mh = run_app("vclustering", args.sites, "staged", be, faults=FaultInjector(fail=fault))
    run_in = run_app(
        "vclustering", args.sites, "staged", "inline", faults=FaultInjector(fail=fault)
    )
    report["fault_cell"] = {
        "retries_mh": int(run_mh.report.retries),
        "retries_inline": int(run_in.report.retries),
        "digest_mh": result_digest("vclustering", run_mh),
        "digest_inline": result_digest("vclustering", run_in),
        "executed": list(be.executed_log),
        "n_processes": int(run_mh.n_processes),
        "owned_sites": list(run_mh.owned_sites or []),
    }
    print(MARKER + json.dumps(report), flush=True)
    return report


if __name__ == "__main__":  # pragma: no cover - subprocess entry
    child_main()
