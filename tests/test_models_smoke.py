"""Per-architecture smoke tests (reduced same-family configs on CPU):
one forward/train step + prefill/decode, asserting output shapes, finite
values, and decode-path parity with the full forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import transformer as T
from repro.models.config import reduced
from repro.sharding import ShapeAxes

B, S = 2, 32


def _make(cfg):
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    f = cfg.frontend_len
    s_tok = S - (f if (cfg.frontend != "none" and not cfg.is_encdec) else 0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, s_tok), dtype=np.int32))
    fe = None
    if cfg.frontend != "none":
        fe = jnp.asarray(rng.normal(size=(B, f, cfg.d_model)).astype(np.float32))
    return params, toks, fe, s_tok


def _zeros_cache(cfg):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        T.cache_specs(cfg, B, S),
        is_leaf=lambda x: isinstance(x, ShapeAxes),
    )


@pytest.mark.parametrize("arch", C.ARCHS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = reduced(C.get(arch))
        params, toks, fe, s_tok = _make(cfg)
        logits, aux = T.forward_train(cfg, params, toks, fe, chunk=16)
        assert logits.shape == (B, s_tok, cfg.vocab_padded)
        assert bool(jnp.all(jnp.isfinite(logits)))
        assert bool(jnp.isfinite(aux["aux_loss"]))

    def test_prefill_decode_parity(self, arch):
        """decode(prefill(tokens[:-1]), tokens[-1]) must equal the full
        forward's last-position logits — validates every cache path
        (KV, ssm state, conv tails, mLSTM matrix memory, cross-attn)."""
        cfg = reduced(C.get(arch))
        params, toks, fe, s_tok = _make(cfg)
        full, _ = T.forward_train(cfg, params, toks, fe, chunk=16)

        cache = _zeros_cache(cfg)
        _, cache = T.prefill(cfg, params, toks[:, :-1], cache, fe, chunk=16)
        pos = s_tok - 1
        if cfg.frontend != "none" and not cfg.is_encdec:
            pos = S - 1  # positions include the frontend prefix
        lg, _ = T.decode_step(cfg, params, toks[:, -1:], jnp.int32(pos), cache)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full[:, -1]), rtol=3e-2, atol=3e-2
        )

    def test_train_step_reduces_loss(self, arch):
        from repro.optim.adamw import AdamWConfig
        from repro.train.steps import make_train_step, materialize_state

        cfg = reduced(C.get(arch))
        params, toks, fe, s_tok = _make(cfg)
        state = materialize_state(cfg, jax.random.PRNGKey(0))
        step = jax.jit(
            make_train_step(cfg, AdamWConfig(lr=5e-3, warmup=0, decay_steps=10**9), loss_chunk=16)
        )
        batch = {"tokens": toks, "labels": toks}  # memorise: loss must drop
        if fe is not None:
            batch["frontend"] = fe
        losses = []
        for _ in range(8):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
        assert all(np.isfinite(losses)), losses
        assert losses[-1] < losses[0], losses


class TestConfigIntegrity:
    @pytest.mark.parametrize("arch", C.ARCHS)
    def test_full_config_matches_assignment(self, arch):
        cfg = C.get(arch)
        expected = {
            "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
            "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
            "granite-20b": (52, 6144, 48, 1, 24576, 49152),
            "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
            "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
            "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
            "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
            "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
            "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
            "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        }[arch]
        dff = cfg.moe.expert_d_ff if arch in ("mixtral-8x22b", "deepseek-moe-16b") else cfg.d_ff
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, dff, cfg.vocab)
        assert got == expected

    def test_moe_configs(self):
        mx = C.get("mixtral-8x22b").moe
        assert (mx.n_experts, mx.top_k) == (8, 2)
        ds = C.get("deepseek-moe-16b").moe
        assert (ds.n_experts, ds.n_shared_experts, ds.top_k) == (64, 2, 6)

    def test_param_counts_in_band(self):
        """Total parameter counts should be near the advertised sizes."""
        bands = {
            "phi3-mini-3.8b": (3.0e9, 4.6e9),
            "granite-20b": (17e9, 24e9),
            "stablelm-1.6b": (1.2e9, 2.1e9),
            "gemma2-2b": (2.0e9, 3.4e9),
            "zamba2-1.2b": (0.9e9, 1.7e9),
            "mixtral-8x22b": (120e9, 150e9),
            "deepseek-moe-16b": (14e9, 19e9),
            "xlstm-1.3b": (0.9e9, 1.8e9),
        }
        for arch, (lo, hi) in bands.items():
            n = T.param_count(C.get(arch))
            assert lo <= n <= hi, (arch, n)


class TestSLSTMKernelPath:
    def test_xlstm_forward_parity_with_kernel(self):
        """cfg.slstm_kernel=True routes the recurrence through the Pallas
        kernel (interpret on CPU) — logits must match the XLA path."""
        cfg0 = reduced(C.get("xlstm-1.3b"))
        cfg1 = cfg0.scaled(slstm_kernel=True)
        params = T.init_params(cfg0, jax.random.PRNGKey(0))
        toks = jnp.asarray(np.random.default_rng(0).integers(0, cfg0.vocab, (2, 32), dtype=np.int32))
        l0, _ = T.forward_train(cfg0, params, toks, chunk=16)
        l1, _ = T.forward_train(cfg1, params, toks, chunk=16)
        np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), rtol=5e-4, atol=5e-4)


class TestFlashKernelPath:
    @pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "gemma2-2b", "mixtral-8x22b"])
    def test_forward_parity_with_flash_kernel(self, arch):
        """cfg.flash_kernel=True routes full-sequence attention through
        the Pallas flash kernel — logits must match the chunked-jnp oracle
        (covers GQA, logit softcap, alternating SWA, MoE blocks)."""
        cfg0 = reduced(C.get(arch))
        cfg1 = cfg0.scaled(flash_kernel=True)
        params = T.init_params(cfg0, jax.random.PRNGKey(0))
        toks = jnp.asarray(np.random.default_rng(0).integers(0, cfg0.vocab, (2, 32), dtype=np.int32))
        l0, _ = T.forward_train(cfg0, params, toks, chunk=16)
        l1, _ = T.forward_train(cfg1, params, toks, chunk=16)
        np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), rtol=1e-3, atol=1e-3)
