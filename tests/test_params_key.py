"""Property tests for ``runtime.cache.params_key`` — the canonicalization
that coalescing and result-cache keying stand on.

Properties pinned here:
  * **totality** over JSON-ish values — nested dicts/lists/tuples/sets,
    bools, strings, ints, and floats INCLUDING ``inf``/``-inf``/``nan``
    (the pre-fix ``_canon`` crashed with OverflowError/ValueError on
    them, which let one malformed request kill the service dispatch
    loop);
  * **canonical equality** — logically identical params (reordered dict
    keys, list vs tuple spelling, integral floats vs ints, any nan
    object) always map to EQUAL, hashable keys;
  * **determinism** — the same value canonicalizes identically across
    calls (set iteration order does not leak into the key).

Runs under real hypothesis when installed (CI) and under the
deterministic ``repro.testing`` fallback otherwise: the random structure
is derived from a drawn integer seed, so both paths exercise the same
generator.
"""

from __future__ import annotations

import math

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from repro.testing import given, settings, strategies as st

from repro.runtime.cache import ResultCache, params_key

_SPECIALS = (math.inf, -math.inf, math.nan)


def _rand_value(rng: np.random.Generator, depth: int = 0):
    """One random JSON-ish value, with non-finite floats in the mix."""
    kinds = 8 if depth < 3 else 5  # cap nesting
    k = int(rng.integers(kinds))
    if k == 0:
        return int(rng.integers(-10_000, 10_000))
    if k == 1:
        return float(rng.normal() * 10)
    if k == 2:
        return _SPECIALS[int(rng.integers(3))]
    if k == 3:
        return bool(rng.integers(2))
    if k == 4:
        return f"s{int(rng.integers(50))}"
    if k == 5:
        return [_rand_value(rng, depth + 1) for _ in range(int(rng.integers(4)))]
    if k == 6:
        return {f"k{i}": _rand_value(rng, depth + 1) for i in range(int(rng.integers(4)))}
    return {int(rng.integers(20)) for _ in range(int(rng.integers(4)))}


def _rand_params(rng: np.random.Generator) -> dict:
    return {f"p{i}": _rand_value(rng) for i in range(int(rng.integers(1, 6)))}


def _respell(v, rng: np.random.Generator):
    """A logically-identical respelling: reordered dict keys, list<->tuple,
    small exact ints as floats, fresh nan objects, reshuffled sets."""
    if isinstance(v, dict):
        keys = list(v)
        rng.shuffle(keys)
        return {k: _respell(v[k], rng) for k in keys}
    if isinstance(v, list):
        return tuple(_respell(x, rng) for x in v)
    if isinstance(v, tuple):
        return [_respell(x, rng) for x in v]
    if isinstance(v, (set, frozenset)):
        items = list(v)
        rng.shuffle(items)
        return frozenset(items) if isinstance(v, set) else set(items)
    if isinstance(v, float) and math.isnan(v):
        return float("nan")  # a DIFFERENT nan object, same meaning
    if isinstance(v, bool):
        return v
    if isinstance(v, int) and abs(v) < 2**52:
        return float(v)  # exact as a double; canonicalizes back to int
    return v


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(min_value=0, max_value=100_000))
def test_params_key_total_hashable_deterministic(seed):
    rng = np.random.default_rng(seed)
    params = _rand_params(rng)
    key = params_key(params)  # must never raise, non-finite floats included
    hash(key)  # and must be usable as a cache/coalescing key
    assert key == params_key(params)  # deterministic across calls
    # usable in the real cache key path too
    hash(ResultCache.key("ds", 1, "app", params))


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(min_value=0, max_value=100_000))
def test_logically_identical_params_map_to_equal_keys(seed):
    rng = np.random.default_rng(seed)
    params = _rand_params(rng)
    respelled = {k: _respell(v, np.random.default_rng(seed + 1)) for k, v in params.items()}
    assert params_key(params) == params_key(respelled)


def test_nonfinite_regression():
    """The exact crashes from the issue: inf raised OverflowError, nan
    raised ValueError, either killing the dispatch loop."""
    assert params_key({"minsup": float("inf")}) == params_key({"minsup": math.inf})
    assert params_key({"minsup": float("nan")}) == params_key({"minsup": math.nan})
    assert params_key({"a": math.inf}) != params_key({"a": -math.inf})
    assert params_key({"a": math.inf}) != params_key({"a": math.nan})
    hash(params_key({"x": [math.nan, {math.inf}, {"y": -math.inf}]}))


def test_spelling_equivalences():
    assert params_key({"k": 3}) == params_key({"k": 3.0})
    assert params_key({"a": 1, "b": 2}) == params_key({"b": 2, "a": 1})
    assert params_key({"xs": [1, 2]}) == params_key({"xs": (1, 2)})
    assert params_key({"s": {3, 1, 2}}) == params_key({"s": frozenset({2, 3, 1})})
    assert params_key({"k": 3}) != params_key({"k": 3.5})
    assert params_key(None) == params_key({})
    # bools stay distinct from ints where Python hashes collide
    assert params_key({"flag": True}) == params_key({"flag": True})
