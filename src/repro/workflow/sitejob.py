"""SiteJob — the shared unit of site-local mining work.

Both of the paper's applications (variance-based clustering and GFM/FDM
itemset mining) decompose into the same shape: a stage of per-site compute
jobs, a synchronization job over their outputs, and optionally more
per-site work.  ``SiteJob`` is that contract: the core algorithm modules
(`core.vclustering`, `core.gfm`, `core.fdm`) emit lists of SiteJobs, and
one scheduler — ``workflow.engine.Engine`` — executes any of them through
the same DAGMan-analog grid model.

``timed`` wraps a site job's callable so the engine's simulated clock is
fed the *measured* device compute time (blocking on all jax outputs)
rather than a host-side bracket that would include tracing overhead noise.
"""

from __future__ import annotations

import functools
import time
import warnings
from collections.abc import Callable
from dataclasses import dataclass, field
from typing import Any

import jax

from repro.workflow.dag import DAG, Job, TimedResult
from repro.workflow.overhead import JobSpec


class MissingJobTimeWarning(UserWarning):
    """A job fed to ``job_specs`` has no measured time — its analytical
    compute defaults to 0.0, which silently miscalibrates estimates."""


@dataclass
class SiteJob:
    """One unit of site-local (or synchronization) work.

    ``fn`` receives the results of ``deps`` in order and does the real
    compute; ``site`` indexes into the grid model's link matrix for the
    staging-cost simulation; byte counts size the staged transfers.
    """

    name: str
    fn: Callable[..., Any]
    deps: list[str] = field(default_factory=list)
    site: int = 0
    input_bytes: int = 0
    output_bytes: int = 0
    retries: int = 2
    # fused-execution hooks (``workflow.executor.BatchedBackend``): jobs
    # sharing a ``batch_key`` are one shape-identical fan-out group;
    # ``batched_fn(names, batch_args, argss)`` executes the whole group
    # in one fused (vmapped) call and returns one TimedResult per member
    # (see ``timed_batch``); ``batch_arg`` is this member's payload —
    # for the site-job builders, the site index
    batch_key: str | None = None
    batched_fn: Callable[..., Any] | None = None
    batch_arg: Any = None

    def to_job(self) -> Job:
        return Job(
            name=self.name,
            fn=self.fn,
            deps=list(self.deps),
            site=self.site,
            input_bytes=self.input_bytes,
            output_bytes=self.output_bytes,
            retries=self.retries,
            batch_key=self.batch_key,
            batched_fn=self.batched_fn,
            batch_arg=self.batch_arg,
        )


def timed(fn: Callable[..., Any], record: dict[str, float] | None = None, name: str = "") -> Callable[..., Any]:
    """Wrap ``fn`` to return a TimedResult with device-measured compute.

    Blocks until every jax array in the output is ready, so asynchronous
    dispatch cannot hide compute from the clock.  When ``record`` is given
    the measurement is also stored under ``name`` — the runtime uses this
    to cross-check the engine's ledger.
    """

    @functools.wraps(fn)
    def wrapper(*args):
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(*args))
        dt = time.perf_counter() - t0
        if record is not None:
            record[name or getattr(fn, "__name__", "job")] = dt
        return TimedResult(out, dt)

    return wrapper


def timed_batch(
    fused_fn: Callable[..., list],
    record: dict[str, float] | None = None,
    owned: Callable[[str], bool] | None = None,
) -> Callable[..., list]:
    """Wrap a fused group executor into a ``batched_fn`` for the batched
    execution backend.

    ``fused_fn(batch_args, argss) -> list`` computes every member's
    result in one call (one vmapped dispatch across the site axis).
    The wrapper measures the fused call ONCE (blocking on all jax
    outputs, like ``timed``) and apportions the wall time equally across
    the members — the honest per-site calibration for shape-identical
    fan-out jobs, since the fused call does the same total work the
    serial per-site loop would.  Each member's share is recorded in
    ``record`` (the runtime's cross-check ledger) and returned as its
    ``TimedResult``, so the engine's simulated clock, job_times, and
    the analytical estimators see per-job times exactly as they do on
    the inline backend.

    ``owned`` enforces OWNER-ONLY timing for multi-process execution:
    when given, only member names it accepts are recorded — a fused group
    that (redundantly) covers jobs owned by another process must not
    write process-local shares for them, or the record would diverge from
    the owner-measured times the engine's global ledger carries.  The
    returned TimedResults are unaffected (the execution backend decides
    which of them ship).
    """

    def batched(names: list[str], batch_args: list, argss: list) -> list:
        t0 = time.perf_counter()
        outs = jax.block_until_ready(fused_fn(batch_args, argss))
        share = (time.perf_counter() - t0) / max(len(names), 1)
        if record is not None:
            for name in names:
                if owned is None or owned(name):
                    record[name] = share
        return [TimedResult(out, share) for out in outs]

    return batched


def merge_owner_times(
    measured: dict[str, float],
    job_times: dict[str, float],
    owned: tuple | frozenset | list | None,
) -> dict[str, float]:
    """Normalize a per-process ``measured`` record against the engine's
    globally-consistent ledger for a partitioned (multi-host) run.

    Under true site ownership a process only executes — and therefore
    only records — its OWNED jobs; every other job's time exists solely
    as the owner-measured value shipped with its result, which the engine
    ledgers in ``RunReport.job_times``.  Feeding the partial local record
    straight into ``job_specs(strict=True)`` would raise on every
    non-owned job, so this helper completes it from the ledger — and, for
    jobs that WERE recorded locally, keeps the local measurement only if
    it is actually this process's own (``owned``; stale entries for jobs
    owned elsewhere — the redundant-execution hazard — are overwritten
    with the authoritative shipped times).

    An ``owned`` entry naming a job the ledger has never heard of is a
    caller bug (a stale partition, a typo'd name) that would otherwise
    pass silently — so it raises, naming the stray entries.
    """
    owned_set = set(owned) if owned is not None else None
    if owned_set is not None:
        stray = sorted(str(n) for n in owned_set - set(job_times))
        if stray:
            raise ValueError(
                f"merge_owner_times: {len(stray)} owned job name(s) not in the "
                f"job_times ledger: {', '.join(stray[:5])}"
                + ("..." if len(stray) > 5 else "")
            )
    out = dict(measured)
    for name, dt in job_times.items():
        if name not in out or (owned_set is not None and name not in owned_set):
            out[name] = dt
    return out


def build_dag(site_jobs: list[SiteJob], name: str = "site-jobs") -> DAG:
    """Assemble SiteJobs into an executable DAG (insertion order must be
    topological, as with ``DAG.add``).  Duplicate job names and unknown
    or self dependencies are rejected by ``DAG.add`` with the offending
    job named — which also makes a cycle unconstructible here; cycles
    introduced by later mutation are caught by ``DAG.validate_acyclic``
    at run time."""
    dag = DAG(name)
    for sj in site_jobs:
        dag.add(sj.to_job())
    return dag


def replay_dag(specs: list[JobSpec], job_times: dict[str, float] | None = None) -> DAG:
    """Rebuild a workflow topology as a pure-simulation DAG: trivial jobs
    whose simulated compute is the recorded measurement (``job_times``,
    falling back to each spec's ``compute_s``).  Replaying the same specs
    and times through different engine schedules or link matrices isolates
    the scheduling policy — identical DAG/model/times, zero timing noise —
    which is how the sweep benchmark compares staged vs async fairly."""
    times = job_times or {}
    dag = DAG("replay")
    for sp in specs:
        sim = float(times.get(sp.name, sp.compute_s))
        dag.job(
            sp.name,
            lambda *a: TimedResult(None, 0.0),
            deps=list(sp.deps),
            site=sp.site,
            input_bytes=sp.input_bytes,
            output_bytes=sp.output_bytes,
            sim_compute_s=sim,
        )
    return dag


def job_specs(
    site_jobs: list[SiteJob],
    job_times: dict[str, float] | None = None,
    strict: bool = False,
) -> list[JobSpec]:
    """Strip SiteJobs down to the analytical ``overhead.JobSpec`` view,
    with compute times taken from a run's measured ``RunReport.job_times``
    — the inputs to ``estimate_dag`` / ``estimate_stages_from_specs``, so
    the paper's measured-vs-estimated comparison is calibrated by the same
    kernel timings that fed the simulated clock.

    A job name with no measured time silently feeding ``compute_s=0.0``
    into the estimators is exactly how a calibration goes quietly wrong,
    so missing entries are loud: when ``job_times`` is given but lacks a
    job, a ``MissingJobTimeWarning`` is emitted (or, with
    ``strict=True``, a ``KeyError`` raised — also when ``job_times`` is
    None entirely).  Passing ``job_times=None`` without ``strict`` keeps
    the explicit "no calibration, zero-compute topology view" behavior,
    warning-free."""
    if strict and job_times is None:
        raise KeyError("job_specs(strict=True) requires measured job_times, got None")
    missing = [sj.name for sj in site_jobs if job_times is not None and sj.name not in job_times]
    if missing:
        msg = (
            f"{len(missing)} job(s) have no measured time and default to compute_s=0.0 "
            f"(miscalibrated estimate): {', '.join(missing[:5])}"
            + ("..." if len(missing) > 5 else "")
        )
        if strict:
            raise KeyError(msg)
        warnings.warn(msg, MissingJobTimeWarning, stacklevel=2)
    times = job_times or {}
    return [
        JobSpec(
            name=sj.name,
            deps=tuple(sj.deps),
            compute_s=float(times.get(sj.name, 0.0)),
            input_bytes=sj.input_bytes,
            output_bytes=sj.output_bytes,
            site=sj.site,
        )
        for sj in site_jobs
    ]
