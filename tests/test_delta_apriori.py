"""Delta-Apriori correctness contract: incremental maintenance over an
append-only stream is BIT-IDENTICAL to from-scratch Apriori over the
concatenated data (property-tested over random append histories), and
the warm-started k-means entry point continues a previous fit.
"""

from __future__ import annotations

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from repro.testing import given, settings, strategies as st

from repro.core.apriori import DeltaApriori, concat_dbs, local_apriori
from repro.core.kmeans import kmeans, kmeans_warm
from repro.data.synthetic import gaussian_mixture


def _random_batches(rng: np.random.Generator, n_batches: int, n_items: int):
    """Random dense bool transaction batches (each with >=1 transaction)."""
    return [
        rng.random((int(rng.integers(3, 25)), n_items)) < rng.uniform(0.2, 0.7)
        for _ in range(n_batches)
    ]


def _assert_bitidentical(delta_res, scratch_res):
    assert delta_res.counts == scratch_res.counts
    assert delta_res.frequent == scratch_res.frequent
    assert delta_res.candidates_counted == scratch_res.candidates_counted


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    n_batches=st.integers(min_value=1, max_value=4),
    n_items=st.integers(min_value=4, max_value=9),
    k_max=st.integers(min_value=1, max_value=4),
)
def test_delta_query_bitidentical_to_scratch(seed, n_batches, n_items, k_max):
    """query(k, t) == local_apriori(concat(batches), k, t) for every
    random append history and threshold — same counts, same frequents."""
    rng = np.random.default_rng(seed)
    batches = _random_batches(rng, n_batches, n_items)
    state = DeltaApriori(n_items)
    for b in batches:
        state.append(b)
    total = state.n_tx
    min_count = int(rng.integers(1, max(total // 2, 1) + 1))
    scratch = local_apriori(concat_dbs(state._batches), k_max, min_count)
    _assert_bitidentical(state.query(k_max, min_count), scratch)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_delta_bitidentical_at_every_version(seed):
    """Interleaved appends and queries: the identity holds at EVERY
    version, not just the final one."""
    rng = np.random.default_rng(seed)
    n_items = 6
    state = DeltaApriori(n_items)
    for b in _random_batches(rng, 3, n_items):
        state.append(b)
        min_count = max(1, state.n_tx // 4)
        scratch = local_apriori(concat_dbs(state._batches), 3, min_count)
        _assert_bitidentical(state.query(3, min_count), scratch)


def test_repeat_query_costs_zero_device_passes():
    rng = np.random.default_rng(0)
    state = DeltaApriori(8)
    for b in _random_batches(rng, 2, 8):
        state.append(b)
    first = state.query(3, max(1, state.n_tx // 5))
    again = state.query(3, max(1, state.n_tx // 5))
    assert first.count_calls >= 0
    assert again.count_calls == 0  # every candidate already cached
    _assert_bitidentical(again, first)


def test_delta_query_cheaper_than_scratch():
    """The point of the delta path: a query after appends runs no more
    device count passes than the from-scratch equivalent (and strictly
    fewer once a previous query populated the cache)."""
    rng = np.random.default_rng(1)
    state = DeltaApriori(8)
    state.append(_random_batches(rng, 1, 8)[0])
    min_count = max(1, state.n_tx // 5)
    state.query(3, min_count)
    state.append(_random_batches(rng, 1, 8)[0])
    min_count = max(1, state.n_tx // 5)
    scratch = local_apriori(concat_dbs(state._batches), 3, min_count)
    delta_res = state.query(3, min_count)
    _assert_bitidentical(delta_res, scratch)
    assert delta_res.count_calls <= scratch.count_calls


def test_version_bumps_per_append():
    state = DeltaApriori(5)
    assert state.version == 0
    assert state.append(np.ones((4, 5), dtype=bool)) == 1
    assert state.append(np.zeros((2, 5), dtype=bool)) == 2
    assert state.n_tx == 6


def test_append_rejects_wrong_universe():
    state = DeltaApriori(5)
    with pytest.raises(ValueError, match="items"):
        state.append(np.ones((3, 7), dtype=bool))


def test_query_before_any_append_raises():
    with pytest.raises(RuntimeError, match="append"):
        DeltaApriori(4).query(2, 1)


def test_concat_dbs_rejects_mismatched_universes():
    from repro.core.apriori import TransactionDB

    a = TransactionDB.from_dense(np.ones((2, 4), dtype=bool))
    b = TransactionDB.from_dense(np.ones((2, 6), dtype=bool))
    with pytest.raises(ValueError, match="universes"):
        concat_dbs([a, b])
    with pytest.raises(ValueError, match="at least one"):
        concat_dbs([])


# -- warm-started k-means ----------------------------------------------------


def test_kmeans_warm_continues_converged_fit():
    """Warm-starting from a converged fit's centers reproduces its fixed
    point on identical data."""
    x, _ = gaussian_mixture(0, 200, 2, 3)
    cold = kmeans(jax.random.PRNGKey(0), x, 3, iters=40)
    warm = kmeans_warm(x, cold.centers, iters=5)
    np.testing.assert_allclose(np.asarray(warm.centers), np.asarray(cold.centers),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(warm.inertia), float(cold.inertia),
                               rtol=1e-4, atol=1e-3)


def test_kmeans_warm_does_not_regress_on_drifted_data():
    """On appended (drifted) data, Lloyd refinement from the previous
    centroids can only improve on assigning the new data to them as-is."""
    x0, _ = gaussian_mixture(1, 150, 2, 3)
    cold = kmeans(jax.random.PRNGKey(0), x0, 3, iters=30)
    x1, _ = gaussian_mixture(2, 80, 2, 3, spread=11.0)
    x = np.concatenate([x0, x1], axis=0)
    prev = np.asarray(cold.centers)
    d2 = ((x[:, None, :] - prev[None, :, :]) ** 2).sum(-1)
    inertia_at_prev = float(d2.min(axis=1).sum())
    warm = kmeans_warm(x, prev, iters=10)
    assert float(warm.inertia) <= inertia_at_prev + 1e-3
    assert warm.centers.shape == (3, 2)
    assert warm.assign.shape == (len(x),)
