"""Kernel-level microbenchmarks: the two compute hot-spots the paper's
algorithms spend their time in.  On this CPU container we time the jnp
oracle (the Pallas kernels target TPU and run here only under the
interpreter); the derived column reports achieved GB/s / GFLOP/s so the
roofline context is visible.

Every row records the block config it ran (``block``), so the committed
baseline pins not just the time but the tile shape that produced it.
``--autotune`` runs the block-size search (``repro.kernels.autotune``)
and appends ``*_autotune`` rows carrying both ``seconds_default`` and
``seconds_tuned`` — ``compare_baseline`` gates ``tuned <= default``
within a noise band on exactly those rows.  ``--smoke`` shrinks the
search lattice to the CI-sized one; ``--tuned-out`` persists the tuned
table JSON (the bench-smoke artifact).

``--out`` writes the rows as JSON (``{"kernels": [{name, seconds, ...}]}``)
— the committed ``BENCH_kernels_baseline.json`` is this file's output, and
``compare_baseline --kernels-baseline/--kernels-candidate`` gates fresh
runs against it so a kernel regression is caught even when scheduler
noise hides it in end-to-end wall time.
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit


def run(out: str | None = None, autotune: bool = False, tuned_out: str | None = None) -> dict:
    from repro.core.apriori import pack_bool_matrix, pack_itemsets
    from repro.kernels import autotune as at
    from repro.kernels import ops
    from repro.kernels.ref import kmeans_assign_ref, support_count_ref

    rng = np.random.default_rng(0)
    cells: list[dict] = []

    # kmeans assignment: N x K distance + argmin
    n, d, k = 65_536, 32, 64
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    f = jax.jit(kmeans_assign_ref)
    jax.block_until_ready(f(x, c))
    dt = timeit(lambda: jax.block_until_ready(f(x, c)))
    flops = 2 * n * d * k
    row("kmeans_assign_jnp", dt, f"gflops={flops / dt / 1e9:.1f};N={n};D={d};K={k}")
    cells.append({"name": "kmeans_assign_jnp", "seconds": dt, "gflops": flops / dt / 1e9})

    # support counting: bitmap AND+match over (tx x candidates)
    ntx, items, cands = 32_768, 128, 512
    dense = rng.random((ntx, items)) < 0.2
    tx = jnp.asarray(pack_bool_matrix(dense))
    sets = [tuple(sorted(rng.choice(items, size=3, replace=False).tolist())) for _ in range(cands)]
    masks = jnp.asarray(pack_itemsets(sets, items))
    g = jax.jit(support_count_ref)
    jax.block_until_ready(g(tx, masks))
    dt = timeit(lambda: jax.block_until_ready(g(tx, masks)))
    gcells = ntx * cands * tx.shape[1]
    row("support_count_jnp", dt, f"gcells={gcells / dt / 1e9:.2f};tx={ntx};cands={cands}")
    cells.append({"name": "support_count_jnp", "seconds": dt, "gcells": gcells / dt / 1e9})

    # Pallas kernels (interpret mode — correctness surface, not speed).
    # Small slices: the interpreter is the correctness path, so these rows
    # gate "did the kernel wrapper get slower", not device throughput.
    km_block = at.DEFAULT_KMEANS_BLOCK
    dt = timeit(
        lambda: jax.block_until_ready(ops.kmeans_assign(x[:4096], c, block_n=km_block)),
        repeats=1,
        warmup=1,
    )
    row("kmeans_assign_pallas_interpret", dt, f"interpret=True;block={km_block}")
    cells.append({"name": "kmeans_assign_pallas_interpret", "seconds": dt, "block": km_block})

    sc_block = list(at.DEFAULT_SUPPORT_BLOCKS)
    dt = timeit(
        lambda: jax.block_until_ready(
            ops.support_count(tx[:4096], masks, block=tuple(sc_block))
        ),
        repeats=1,
        warmup=1,
    )
    row("support_count_pallas_interpret", dt, f"interpret=True;block={tuple(sc_block)}")
    cells.append({"name": "support_count_pallas_interpret", "seconds": dt, "block": sc_block})

    # prune-fused variant: count + threshold in one pass — same tiles, so
    # its cost should track the plain row (the fusion is the win upstream:
    # no separate host threshold sweep per Apriori level)
    dt = timeit(
        lambda: jax.block_until_ready(
            ops.support_count_prune(tx[:4096], masks, 100, block=tuple(sc_block))
        ),
        repeats=1,
        warmup=1,
    )
    row("support_count_prune_interpret", dt, f"interpret=True;block={tuple(sc_block)}")
    cells.append({"name": "support_count_prune_interpret", "seconds": dt, "block": sc_block})

    if autotune:
        # block-size search on the interpret-mode shapes above; _pick
        # keeps the default unless a candidate wins beyond the noise
        # margin, so tuned <= default holds by construction and the
        # compare_baseline gate enforces it stayed that way
        tx_t = jax.lax.bitcast_convert_type(tx[:4096].astype(jnp.uint32), jnp.int32).T
        mk_t = jax.lax.bitcast_convert_type(masks.astype(jnp.uint32), jnp.int32).T
        ent = at.tune_support_count(tx_t, mk_t, interpret=True)
        row(
            "support_count_autotune",
            ent["seconds_tuned"],
            f"default={ent['seconds_default']:.4f}s;block={tuple(ent['config'])}",
        )
        cells.append(
            {
                "name": "support_count_autotune",
                "seconds": ent["seconds_tuned"],
                "seconds_tuned": ent["seconds_tuned"],
                "seconds_default": ent["seconds_default"],
                "block": list(ent["config"]),
            }
        )
        from repro.kernels import pad_to
        from repro.kernels.kmeans_assign import BIG

        xs = x[:4096]
        dp, kp = pad_to(max(d, 128), 128), pad_to(max(k, 128), 128)
        xp = jnp.zeros((xs.shape[0], dp), jnp.float32).at[:, :d].set(xs)
        cp = jnp.full((kp, dp), 0.0, jnp.float32)
        cp = cp.at[:, :d].set(jnp.full((kp, d), BIG, jnp.float32))
        cp = cp.at[:k, :d].set(c)
        ent = at.tune_kmeans_assign(xp, cp, interpret=True)
        row(
            "kmeans_assign_autotune",
            ent["seconds_tuned"],
            f"default={ent['seconds_default']:.4f}s;block={ent['config']}",
        )
        cells.append(
            {
                "name": "kmeans_assign_autotune",
                "seconds": ent["seconds_tuned"],
                "seconds_tuned": ent["seconds_tuned"],
                "seconds_default": ent["seconds_default"],
                "block": ent["config"],
            }
        )
        if tuned_out:
            n_ent = at.save_table(tuned_out)
            print(f"# wrote {tuned_out} ({n_ent} tuned entries)")

    result = {"kernels": cells}
    if out:
        with open(out, "w") as fh:
            json.dump(result, fh, indent=2, sort_keys=True)
        print(f"# wrote {out}")
    return result


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="write rows as JSON here")
    ap.add_argument(
        "--autotune",
        action="store_true",
        help="run the block-size search and append tuned-vs-default rows",
    )
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny autotune lattice (CI-sized search, same code path)",
    )
    ap.add_argument(
        "--tuned-out", default=None, help="persist the tuned table JSON here"
    )
    args = ap.parse_args()
    from repro.launch.mesh import tuned_platform

    tuned_platform()  # apply the tuned XLA flag set (GPU) before first use
    if args.smoke:
        from repro.kernels import autotune as at

        at.set_smoke(True)
    run(out=args.out, autotune=args.autotune, tuned_out=args.tuned_out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
