"""GQA attention: chunked (flash-style, online-softmax) for train/prefill,
cached single-token decode, sliding-window + logit-softcap variants, and
cross-attention for the enc-dec architecture.

Memory discipline: scores are never materialised at (Sq, Skv) — the KV axis
is processed in chunks under ``lax.scan`` with running (max, denom, acc),
which is what lets the 32k-prefill shapes fit the dry-run memory budget.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import apply_norm, apply_rope, norm_spec, softcap, spec
from repro.sharding import constrain

NEG = -1e30
PAD_POS = 1 << 29  # sentinel position for padded KV slots (always masked)


def attn_spec(cfg, cross: bool = False) -> dict:
    d = cfg.d_model
    p = {
        "wq": spec((d, cfg.n_heads, cfg.head_dim), ("embed", "heads", "head_dim")),
        "wk": spec((d, cfg.n_kv_heads, cfg.head_dim), ("embed", "kv_heads", "head_dim")),
        "wv": spec((d, cfg.n_kv_heads, cfg.head_dim), ("embed", "kv_heads", "head_dim")),
        "wo": spec((cfg.n_heads, cfg.head_dim, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = norm_spec(cfg, cfg.head_dim)
        p["k_norm"] = norm_spec(cfg, cfg.head_dim)
    return p


def _project_qkv(cfg, p, x, kv_x):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"].astype(dt))
    q = constrain(q, ("batch", "seq", "heads", None))
    k = constrain(k, ("batch", "seq", "kv_heads", None))
    v = constrain(v, ("batch", "seq", "kv_heads", None))
    return q, k, v


def _grouped(q, n_kv: int):
    """(B, S, H, Dh) -> (B, S, Kv, G, Dh) splitting query heads into KV groups."""
    b, s, h, dh = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, dh)


def chunked_attention(
    q: jax.Array,  # (B, Sq, Kv, G, Dh) — grouped query heads
    k: jax.Array,  # (B, Sk, Kv, Dh)
    v: jax.Array,  # (B, Sk, Kv, Dh)
    q_pos: jax.Array,  # (Sq,) int32
    k_pos: jax.Array,  # (Sk,) int32
    causal: bool,
    window: int = 0,
    cap: float = 0.0,
    chunk: int = 1024,
) -> jax.Array:
    """Online-softmax attention, scanning the KV axis in chunks."""
    b, sq, kvh, g, dh = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(dh)
    chunk = min(chunk, sk)
    if sk % chunk:  # pad KV to a chunk multiple; sentinel positions mask out
        pad = chunk - sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.concatenate([k_pos, jnp.full((pad,), PAD_POS, jnp.int32)])
        sk += pad
    n_chunks = sk // chunk

    qf = (q * scale).astype(q.dtype)
    ks = k.reshape(b, n_chunks, chunk, kvh, dh).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, n_chunks, chunk, kvh, dh).transpose(1, 0, 2, 3, 4)
    kps = k_pos.reshape(n_chunks, chunk)

    # statically-redundant mask terms are dropped (a window >= kv-length
    # masks nothing beyond causality).  §Perf iterations: probabilities go
    # to the compute dtype immediately (halves the flash intermediate
    # traffic) and the full (Sq, C) "fully-masked row" where() is replaced
    # by a per-ROW validity vector — for rows with any valid key,
    # exp(NEG - m_new) already underflows to exactly 0.0.
    use_window = bool(window) and window < sk

    @jax.checkpoint  # flash-style backward: scores/probs recomputed per
    # chunk from (q, kc, vc) — never stored across the KV scan (this is
    # what keeps train/prefill memory linear in S instead of quadratic)
    def body(carry, inp):
        m, den, acc = carry
        kc, vc, kp = inp  # (B, C, Kv, Dh), (B, C, Kv, Dh), (C,)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kc.astype(qf.dtype))  # (B,Kv,G,Sq,C)
        s = s.astype(jnp.float32)
        if cap:
            s = softcap(s, cap)
        mask = kp[None, :] < PAD_POS  # padded KV slots never attend
        mask = jnp.broadcast_to(mask, (sq, kp.shape[0]))
        if causal:
            mask &= q_pos[:, None] >= kp[None, :]
        if use_window:
            mask &= q_pos[:, None] - kp[None, :] < window
        s = jnp.where(mask[None, None, None], s, NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None]).astype(vc.dtype)
        # per-row guard against fully-masked chunks (future causal chunks,
        # all-pad chunks, out-of-window chunks): (Sq,) instead of (Sq, C)
        kp_max_real = jnp.max(jnp.where(kp < PAD_POS, kp, -1))
        row_valid = jnp.broadcast_to(kp[0] < PAD_POS, (sq,))
        if causal:
            row_valid &= q_pos >= kp[0]
        if use_window:
            row_valid &= q_pos - kp_max_real < window
        p = p * row_valid[None, None, None, :, None].astype(p.dtype)
        corr = jnp.exp(m - m_new)
        den = den * corr + jnp.sum(p.astype(jnp.float32), axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vc)
        acc = acc * corr[..., None].astype(acc.dtype) + pv
        return (m_new, den, acc), None

    m0 = jnp.full((b, kvh, g, sq), NEG, jnp.float32)
    den0 = jnp.zeros((b, kvh, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, sq, dh), q.dtype)
    (m, den, acc), _ = jax.lax.scan(body, (m0, den0, a0), (ks, vs, kps))
    out = acc / jnp.maximum(den, 1e-30)[..., None].astype(acc.dtype)
    # (B, Kv, G, Sq, Dh) -> (B, Sq, Kv, G, Dh)
    return out.transpose(0, 3, 1, 2, 4)


def attention(
    cfg,
    p: dict,
    x: jax.Array,  # (B, Sq, D)
    q_pos: jax.Array,  # (Sq,)
    *,
    causal: bool = True,
    window: int = 0,
    kv_x: jax.Array | None = None,  # cross-attention memory (B, Sk, D)
    kv_pos: jax.Array | None = None,
    rope: bool = True,
    chunk: int = 1024,
) -> jax.Array:
    """Full-sequence attention (train / prefill / encoder / cross)."""
    kv_in = x if kv_x is None else kv_x
    q, k, v = _project_qkv(cfg, p, x, kv_in)
    if cfg.qk_norm and "q_norm" in p:
        q = apply_norm(cfg, p["q_norm"], q)
        k = apply_norm(cfg, p["k_norm"], k)
    kp = q_pos if kv_pos is None else kv_pos
    if rope:
        q = apply_rope(q, q_pos[None, :], cfg.rope_theta, cfg.rope_pct)
        k = apply_rope(k, kp[None, :], cfg.rope_theta, cfg.rope_pct)
    b, s = x.shape[:2]
    if getattr(cfg, "flash_kernel", False):
        # Pallas flash kernel: scores never leave VMEM (TPU; interpret on
        # CPU).  Positions must be contiguous-from-0 on this path.
        from repro.kernels import ops as kops

        out = kops.flash_attention(q, k, v, causal=causal, window=window, cap=cfg.attn_softcap)
    else:
        qg = _grouped(q, cfg.n_kv_heads)
        out = chunked_attention(
            qg, k, v, q_pos, kp, causal=causal, window=window, cap=cfg.attn_softcap, chunk=chunk
        )
        out = out.reshape(b, s, cfg.n_heads, cfg.head_dim)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def attention_with_cache(
    cfg,
    p: dict,
    x: jax.Array,  # (B, Sq, D)
    q_pos: jax.Array,  # (Sq,)
    cache: dict | None,
    *,
    window: int = 0,
    rope: bool = True,
    chunk: int = 1024,
):
    """Prefill: computes full attention AND returns the populated KV cache."""
    kv_in = x
    q, k, v = _project_qkv(cfg, p, x, kv_in)
    if cfg.qk_norm and "q_norm" in p:
        q = apply_norm(cfg, p["q_norm"], q)
        k = apply_norm(cfg, p["k_norm"], k)
    if rope:
        q = apply_rope(q, q_pos[None, :], cfg.rope_theta, cfg.rope_pct)
        k = apply_rope(k, q_pos[None, :], cfg.rope_theta, cfg.rope_pct)
    qg = _grouped(q, cfg.n_kv_heads)
    out = chunked_attention(
        qg, k, v, q_pos, q_pos, causal=True, window=window, cap=cfg.attn_softcap, chunk=chunk
    )
    b, s = x.shape[:2]
    out = out.reshape(b, s, cfg.n_heads, cfg.head_dim)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, {"k": k, "v": v}


def decode_attention(
    cfg,
    p: dict,
    x: jax.Array,  # (B, 1, D)
    pos: jax.Array,  # () int32 — current position (cache entries < pos are live)
    cache: dict,  # {"k","v"}: (B, S, Kv, Dh)
    *,
    window: int = 0,
    rope: bool = True,
):
    """Single-token decode against a pre-allocated cache; returns
    (out (B,1,D), updated cache)."""
    b, _, d = x.shape
    s_max = cache["k"].shape[1]
    q, k_new, v_new = _project_qkv(cfg, p, x, x)
    if cfg.qk_norm and "q_norm" in p:
        q = apply_norm(cfg, p["q_norm"], q)
        k_new = apply_norm(cfg, p["k_norm"], k_new)
    pos_arr = jnp.full((1,), pos, jnp.int32)
    if rope:
        q = apply_rope(q, pos_arr[None, :], cfg.rope_theta, cfg.rope_pct)
        k_new = apply_rope(k_new, pos_arr[None, :], cfg.rope_theta, cfg.rope_pct)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), pos, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), pos, axis=1)

    qg = _grouped(q, cfg.n_kv_heads)  # (B, 1, Kv, G, Dh)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", (qg * scale).astype(qg.dtype), k)
    s = s.astype(jnp.float32)
    if cfg.attn_softcap:
        s = softcap(s, cfg.attn_softcap)
    kpos = jnp.arange(s_max, dtype=jnp.int32)
    mask = kpos[None, :] <= pos
    if window:
        mask &= pos - kpos[None, :] < window
    s = jnp.where(mask[None, None, None], s, NEG)
    pr = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bhgqd", pr.astype(v.dtype), v)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, 1, cfg.n_heads, cfg.head_dim)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, {"k": k, "v": v}
