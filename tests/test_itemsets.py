"""Algorithm 2 (GFM) + FDM baseline: exactness vs brute force, round
counts (the paper's 2-vs-k claim), and communication accounting."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic env: deterministic shim, no shrinking
    from repro.testing import given, settings, strategies as st

from repro.core.apriori import (
    TransactionDB,
    apriori_join,
    bruteforce_frequent,
    count_supports,
    local_apriori,
)
from repro.core.fdm import fdm_mine
from repro.core.gfm import gfm_mine
from repro.data.synthetic import ibm_transactions, split_transactions


def make_sites(seed=1, n_tx=2000, n_items=50, n_sites=4, **kw):
    dense = ibm_transactions(seed=seed, n_tx=n_tx, n_items=n_items, **kw)
    shards = split_transactions(dense, n_sites, seed=0)
    return dense, [TransactionDB.from_dense(s) for s in shards]


class TestApriori:
    def test_pack_roundtrip_supports(self):
        rng = np.random.default_rng(0)
        dense = rng.random((100, 40)) < 0.3
        db = TransactionDB.from_dense(dense)
        sets = [(0,), (1, 3), (2, 5, 7)]
        got = count_supports(db, sets)
        want = [dense[:, list(s)].all(axis=1).sum() for s in sets]
        assert list(got) == want

    def test_apriori_join_prefix_semantics(self):
        prev = [(0, 1), (0, 2), (1, 2), (1, 3)]
        cands = apriori_join(prev)
        assert (0, 1, 2) in cands  # all subsets frequent
        assert (1, 2, 3) not in cands  # (2,3) missing

    def test_local_apriori_counts_match_bruteforce(self):
        dense, sites = make_sites(n_sites=1)
        res = local_apriori(sites[0], 3, min_count=int(0.1 * len(dense)))
        oracle = bruteforce_frequent(dense, 3, int(0.1 * len(dense)))
        got = {its: res.counts[its] for lv in (1, 2, 3) for its in res.frequent[lv]}
        assert got == oracle


class TestGFMvsFDMvsOracle:
    @pytest.mark.parametrize("seed", [1, 2, 3])
    def test_exactness(self, seed):
        dense, sites = make_sites(seed=seed)
        minsup, k = 0.08, 4
        oracle = bruteforce_frequent(dense, k, int(np.ceil(minsup * len(dense))))
        g = gfm_mine(sites, k, minsup)
        f = fdm_mine(sites, k, minsup)
        assert g.frequent == oracle
        assert f.frequent == oracle

    def test_round_counts_paper_claim(self):
        """GFM: single sync = 2 passes; FDM: one per level = k (paper:
        'only 2 communication passes (instead of 4) were required')."""
        dense, sites = make_sites(seed=5)
        g = gfm_mine(sites, 4, 0.08)
        f = fdm_mine(sites, 4, 0.08)
        assert g.comm.rounds == 2
        assert f.comm.rounds == 4
        assert g.comm.rounds < f.comm.rounds

    def test_fdm_remote_support_cost_positive(self):
        """The paper measures FDM's remote-support computation at ~13% of
        its compute; ours must be a nonzero share."""
        dense, sites = make_sites(seed=6)
        f = fdm_mine(sites, 4, 0.08)
        assert f.remote_count_time > 0
        assert f.remote_count_time < f.total_count_time

    @given(
        st.integers(0, 10_000),
        st.integers(2, 4),
        st.sampled_from([0.1, 0.15, 0.25]),
    )
    @settings(max_examples=15, deadline=None)
    def test_property_random_dbs(self, seed, n_sites, minsup):
        """Property: for ANY random transaction DB and site split, GFM and
        FDM return exactly the brute-force frequent itemsets."""
        rng = np.random.default_rng(seed)
        dense = rng.random((rng.integers(50, 300), rng.integers(8, 24))) < rng.uniform(0.1, 0.4)
        shards = split_transactions(dense, n_sites, seed=seed)
        shards = [s for s in shards if len(s)]
        sites = [TransactionDB.from_dense(s) for s in shards]
        k = 3
        oracle = bruteforce_frequent(dense, k, int(np.ceil(minsup * len(dense))))
        g = gfm_mine(sites, k, minsup)
        f = fdm_mine(sites, k, minsup)
        assert g.frequent == oracle
        assert f.frequent == oracle

    def test_gfm_nonuniform_local_threshold_falls_back_to_more_rounds(self):
        """With a LOOSER local threshold the lemma still holds; with a
        TIGHTER one GFM may descend (extra rounds) but stays exact only
        when the lemma applies — we assert exactness for looser."""
        dense, sites = make_sites(seed=9)
        minsup = 0.1
        oracle = bruteforce_frequent(dense, 4, int(np.ceil(minsup * len(dense))))
        g = gfm_mine(sites, 4, minsup, local_minsup=minsup * 0.6)
        assert g.frequent == oracle


class TestCommAccounting:
    def test_gfm_bytes_scale_with_pool(self):
        dense, sites = make_sites(seed=2)
        g = gfm_mine(sites, 4, 0.08)
        assert g.comm.bytes_sent > 0
        assert g.comm.per_round_bytes[0] > 0
        assert len(g.comm.per_round_bytes) == g.comm.rounds

    def test_kernel_backend_equivalence(self):
        dense, sites = make_sites(seed=3, n_tx=500)
        g1 = gfm_mine(sites, 3, 0.1, backend="jnp")
        g2 = gfm_mine(sites, 3, 0.1, backend="kernel")
        assert g1.frequent == g2.frequent
