"""Continuous mining service — a long-lived, multi-tenant serving layer
over the grid runtime.

Everything below ``launch`` runs ONE application's DAG and reports; real
grid load ("Mining the Workload of Real Grid Computing Systems",
arXiv:1412.2673) is a bursty stream of arrivals from many users.
:class:`MiningService` closes that gap in-process (no network):

  * **submit/poll/result** — tenants submit mining requests (app +
    dataset + params) and poll for completion; admission control rejects
    into bounded per-tenant queues (``workflow.requests.TenantQueues``),
    and a deterministic weighted round-robin picker keeps tenants fair.
  * **incremental per-dataset state** — appended transaction batches
    fold into a ``core.apriori.DeltaApriori`` (queries are bit-identical
    to from-scratch Apriori over the concatenation, at O(|delta|) device
    cost per append); k-means queries warm-start from the previous
    version's centroids (``core.kmeans.kmeans_warm``) on drifting data.
  * **coalescing + batched dispatch** — concurrent identical requests
    (same dataset version, app, canonical params) become ONE execution,
    and every execution runs through the engine's execution backends
    (``batched`` by default: shape-identical fan-out jobs fuse into one
    vmapped dispatch; ``multihost`` partitions sites across processes).
  * **cross-request batching** — execution groups in the same wave whose
    workloads report a compatible batch signature
    (``WorkloadSpec.exec_batch_key``: same app, dataset, version, and
    signature tuple — e.g. two ``fdm`` queries differing only in minsup)
    run as ONE fused device dispatch (``GridRuntime.run_many`` merges
    their DAGs under shared ``batch_key``s), digest-identical to serial
    per-group execution, with measured device time apportioned per
    request; the ledger reports ``exec_groups`` / ``fused_requests`` /
    ``device_dispatches`` per wave.
  * **versioned result cache** — completed results are cached under
    ``(dataset, dataset_version, app, params)``
    (``runtime.cache.ResultCache``); any append bumps the version, so a
    stale result is unreachable by key construction.
  * **ledger** — per-request and per-tenant records (queue wait, compute
    share, cache hit, backend used) in the same spirit as the engine's
    ``RunReport``, JSON-serializable for the CI smoke's artifact.

CLI driver (bursty synthetic multi-tenant trace; ``--check`` gates the
fairness bound, cache hits and coalescing for CI)::

    PYTHONPATH=src python -m repro.launch.serve --requests 50 --tenants 3 \
        --backend batched --check --ledger-out service_ledger.json
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.core.apriori import DeltaApriori
from repro.data.synthetic import gaussian_mixture, ibm_transactions
from repro.runtime.cache import ResultCache, params_key
from repro.runtime.gridruntime import GridRuntime
from repro.workflow.registry import app_names, get_workload, workloads
from repro.workflow.requests import (
    MiningRequest,
    QueueFullError,
    TenantQueues,
    coalesce,
    request_ids,
)
from repro.workflow.sitejob import SiteJob, timed, timed_batch

# the ONE source of truth for the app family is the workload registry;
# this module adds no app knowledge of its own
APPS = app_names()


@dataclass
class _Dataset:
    """Per-dataset incremental state the service maintains across appends."""

    name: str
    kind: str  # "transactions" | "points"
    version: int = 0
    # transactions: the appended dense batches plus the delta-Apriori state
    n_items: int | None = None
    delta: DeltaApriori | None = None
    tx_batches: list = field(default_factory=list)
    # points: appended (n, dim) batches plus per-k warm-start centroids
    dim: int | None = None
    pt_batches: list = field(default_factory=list)
    warm_centers: dict = field(default_factory=dict)  # k -> np.ndarray (k, dim)

    def pooled_points(self) -> np.ndarray:
        return np.concatenate(self.pt_batches, axis=0)

    def pooled_dense(self) -> np.ndarray:
        return np.concatenate(self.tx_batches, axis=0)


class MiningService:
    """In-process multi-tenant mining service over :class:`GridRuntime`.

    One instance owns the datasets, the tenant queues, the result cache
    and the runtime; :meth:`step` is the scheduler tick — a fair pick of
    queued requests, coalesced by execution key, served from cache or
    executed through the engine's execution backend.
    """

    def __init__(
        self,
        runtime: GridRuntime | None = None,
        backend: str = "batched",
        n_sites: int = 4,
        max_depth: int = 64,
        weights: dict[str, float] | None = None,
        cache_capacity: int | None = 256,
        count_backend: str = "jnp",
        use_kernel: bool = False,
        clock=time.monotonic,
        fuse_requests: bool = True,
        failure_memo_capacity: int = 128,
    ):
        if runtime is None:
            runtime = GridRuntime(
                backend=backend,
                sync="pooled",
                use_kernel=use_kernel,
                count_backend=count_backend,
            )
        self.runtime = runtime
        self.backend_name = runtime.engine.backend.name
        self.n_sites = int(n_sites)
        self.use_kernel = use_kernel
        self.count_backend = count_backend
        self.queues = TenantQueues(max_depth=max_depth, weights=weights)
        self.cache = ResultCache(cache_capacity)
        self._ids = request_ids()
        self._requests: dict[int, MiningRequest] = {}
        self._results: dict[int, Any] = {}
        self._datasets: dict[str, _Dataset] = {}
        self._clock = clock
        self.executions = 0  # execution groups actually run (fused or not)
        self.coalesced = 0  # requests served by another request's run
        self.invalid = 0  # submissions rejected by param validation
        self.rejected_full = 0  # submissions rejected by a full tenant queue
        # cross-request batching ledger: distinct execution groups that
        # reached the dispatch stage, requests served by a fused
        # multi-group dispatch, and engine invocations actually made
        # (fusion drives device_dispatches < executions)
        self.fuse_requests = bool(fuse_requests)
        self.exec_groups = 0
        self.fused_requests = 0
        self.device_dispatches = 0
        # failed-execution ledger: real failed attempts, plus the
        # short-circuits served from the failure memo — a bounded map
        # keyed by the full execution key (dataset VERSION included, so
        # any append invalidates the memo by key construction: TTL = the
        # dataset version)
        self.failures = 0
        self.failure_memo_hits = 0
        self._failure_memo: OrderedDict[tuple, str] = OrderedDict()
        self._failure_memo_cap = int(failure_memo_capacity)
        # tenant pick order, for the fairness audit (CI gates a prefix
        # bound on this while every tenant stays backlogged)
        self.pick_log: list[str] = []

    # -- datasets -------------------------------------------------------------

    def register_dataset(
        self, name: str, kind: str = "transactions", *, n_items: int | None = None,
        dim: int | None = None,
    ) -> None:
        if kind not in ("transactions", "points"):
            raise ValueError(f"unknown dataset kind {kind!r}")
        if name in self._datasets:
            raise ValueError(f"dataset {name!r} already registered")
        if kind == "transactions":
            if n_items is None:
                raise ValueError("transactions dataset needs n_items")
            ds = _Dataset(name=name, kind=kind, n_items=int(n_items),
                          delta=DeltaApriori(int(n_items), backend=self.count_backend))
        else:
            if dim is None:
                raise ValueError("points dataset needs dim")
            ds = _Dataset(name=name, kind=kind, dim=int(dim))
        self._datasets[name] = ds

    def _dataset(self, name: str) -> _Dataset:
        try:
            return self._datasets[name]
        except KeyError:
            raise KeyError(f"unknown dataset {name!r}; register_dataset first") from None

    def append_transactions(self, name: str, dense_batch: np.ndarray) -> int:
        """Append one dense bool (n_tx, n_items) batch; folds into the
        delta-Apriori state and bumps ``version``.  Returns the version."""
        ds = self._dataset(name)
        if ds.kind != "transactions":
            raise ValueError(f"dataset {name!r} holds points, not transactions")
        dense = np.asarray(dense_batch, dtype=bool)
        ds.delta.append(dense)
        ds.tx_batches.append(dense)
        ds.version = ds.delta.version
        return ds.version

    def append_points(self, name: str, points: np.ndarray) -> int:
        """Append one (n, dim) point batch; bumps ``version``.  Previous
        per-k centroids are KEPT — they seed the next warm-started fit."""
        ds = self._dataset(name)
        if ds.kind != "points":
            raise ValueError(f"dataset {name!r} holds transactions, not points")
        pts = np.asarray(points, dtype=np.float32)
        if pts.ndim != 2 or pts.shape[1] != ds.dim:
            raise ValueError(f"expected (n, {ds.dim}) points, got {pts.shape}")
        ds.pt_batches.append(pts)
        ds.version += 1
        return ds.version

    def dataset_version(self, name: str) -> int:
        return self._dataset(name).version

    # -- request lifecycle ----------------------------------------------------

    def submit(self, tenant: str, app: str, dataset: str, params: dict | None = None) -> int:
        """Admit one request; returns its id.  Raises ``QueueFullError``
        when the tenant's queue is at capacity (the rejected request stays
        in the ledger) and ``ValueError`` on app/dataset mismatch or
        malformed params.  App names, dataset-kind checks and param
        validation all derive from the workload registry — a malformed
        request (unknown param, non-finite float) becomes a LEDGERED
        rejection here, never a crash in the dispatch loop."""
        spec = get_workload(app)  # ValueError: unknown app
        ds = self._dataset(dataset)
        if ds.kind != spec.dataset_kind:
            raise ValueError(
                f"app {app!r} needs a {spec.dataset_kind} dataset; "
                f"{dataset!r} is {ds.kind}"
            )
        req = MiningRequest(
            request_id=next(self._ids),
            tenant=str(tenant),
            app=app,
            dataset=dataset,
            params=dict(params or {}),
            submitted_at=self._clock(),
        )
        try:
            req.params = spec.validate_submitted(params)
        except ValueError as e:
            req.status = "rejected"
            req.error = f"{type(e).__name__}: {e}"
            req.finished_at = self._clock()
            self._requests[req.request_id] = req
            self.invalid += 1
            raise
        self._requests[req.request_id] = req
        try:
            self.queues.push(req)  # marks req rejected on a full queue
        except QueueFullError as e:
            # unify with the param-rejection path: a queue-full rejection
            # is a LEDGERED terminal state too — reason and finish time
            # set, counted service-level (it would otherwise report
            # service_s == 0.0 with no error and no counter)
            req.error = f"{type(e).__name__}: {e}"
            req.finished_at = self._clock()
            self.rejected_full += 1
            raise
        return req.request_id

    def poll(self, request_id: int) -> str:
        return self._requests[request_id].status

    def result(self, request_id: int) -> Any:
        req = self._requests[request_id]
        if req.status == "done":
            return self._results[request_id]
        if req.status == "failed":
            raise RuntimeError(f"request {request_id} failed: {req.error}")
        raise RuntimeError(f"request {request_id} is {req.status}, not done")

    def request(self, request_id: int) -> MiningRequest:
        return self._requests[request_id]

    # -- the scheduler tick ---------------------------------------------------

    def _exec_key(self, req: MiningRequest) -> tuple:
        return (req.dataset, req.dataset_version, req.app, params_key(req.params))

    def step(self, max_requests: int = 8) -> list[int]:
        """One dispatch wave: fair-pick up to ``max_requests`` queued
        requests, coalesce identical ones, serve from cache (or the
        failure memo), then bucket the remaining execution groups by
        their workload's cross-request batch signature — same-signature
        groups run as ONE fused device dispatch, everything else runs
        serially per group.  Returns the ids completed (done or failed)
        this wave."""
        batch = self.queues.pick_batch(max_requests)
        now = self._clock()
        for req in batch:
            req.status = "running"
            req.started_at = now
            req.dataset_version = self._datasets[req.dataset].version
            self.pick_log.append(req.tenant)
        finished: list[int] = []
        pending: list[tuple[tuple, tuple, list[MiningRequest]]] = []
        for ekey, reqs in coalesce(batch, self._exec_key).items():
            rep = reqs[0]
            for other in reqs[1:]:
                other.coalesced_into = rep.request_id
            self.coalesced += len(reqs) - 1
            ckey = ResultCache.key(rep.dataset, rep.dataset_version, rep.app, rep.params)
            value = self.cache.get(ckey)
            if value is not None:
                self._finish(reqs, value, compute_s=0.0, backend="cache", cache_hit=True)
                finished.extend(r.request_id for r in reqs)
                continue
            memo_err = self._failure_memo.get(ekey)
            if memo_err is not None:
                # a deterministically-failing request resubmitted by a
                # polling tenant short-circuits here instead of paying a
                # full grid run every wave; the memo key includes the
                # dataset version, so any append retries for real
                self.failure_memo_hits += 1
                self._fail(reqs, memo_err, backend="failure-memo")
                finished.extend(r.request_id for r in reqs)
                continue
            pending.append((ekey, ckey, reqs))
        self.exec_groups += len(pending)
        for bucket in self._fuse_buckets(pending):
            finished.extend(self._run_bucket(bucket))
        return finished

    def drain(self, max_requests: int = 8, max_steps: int | None = None) -> list[int]:
        """Step until every queue is empty (or ``max_steps``); returns all
        ids completed."""
        done: list[int] = []
        steps = 0
        while self.queues.pending():
            done.extend(self.step(max_requests))
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return done

    def _finish(
        self, reqs, value, *, compute_s: float, backend: str, cache_hit: bool,
        fused: bool = False,
    ) -> None:
        tf = self._clock()
        share = compute_s / len(reqs)
        for req in reqs:
            req.status = "done"
            req.finished_at = tf
            req.cache_hit = cache_hit
            req.backend = backend
            req.compute_s = share
            req.fused = fused
            self._results[req.request_id] = value

    def _fail(
        self, reqs, err: str, *, backend: str | None = None, attempt_s: float = 0.0,
    ) -> None:
        """Terminal failure for one execution group — the attempt is
        LEDGERED like a completion: reason, finish time, the backend that
        ran (or "failure-memo" for short-circuits) and the attempt's wall
        time apportioned as the group's compute share."""
        tf = self._clock()
        share = attempt_s / max(len(reqs), 1)
        for req in reqs:
            req.status = "failed"
            req.error = err
            req.finished_at = tf
            if backend is not None:
                req.backend = backend
            req.compute_s = share

    def _memo_failure(self, ekey: tuple, err: str) -> None:
        self.failures += 1
        self._failure_memo[ekey] = err
        while len(self._failure_memo) > self._failure_memo_cap:
            self._failure_memo.popitem(last=False)

    # -- execution ------------------------------------------------------------

    def _fuse_signature(self, rep: MiningRequest):
        """The workload's cross-request batch signature for one execution
        group's representative, or None when the group must run solo
        (fusion disabled, no ``exec_batch_key`` hook, or the hook opted
        this param point out)."""
        if not self.fuse_requests:
            return None
        spec = get_workload(rep.app)
        if spec.exec_batch_key is None:
            return None
        p = spec.resolve(rep.params)
        if "n_sites" in p and p["n_sites"] is None:
            p = {**p, "n_sites": self.n_sites}
        return spec.exec_batch_key(self._datasets[rep.dataset], p)

    def _fuse_buckets(self, pending) -> list[list]:
        """Bucket the wave's pending execution groups: groups sharing
        (app, dataset, version, exec_batch_key signature) fuse into one
        dispatch; signature-None groups each get their own bucket.
        First-seen order — deterministic given the pick order."""
        buckets: OrderedDict[Any, list] = OrderedDict()
        for ekey, ckey, reqs in pending:
            rep = reqs[0]
            try:
                sig = self._fuse_signature(rep)
            except Exception:  # noqa: BLE001 — a bad signature hook must not kill the wave
                sig = None
            if sig is None:
                bkey = ("solo", rep.request_id)
            else:
                bkey = (rep.app, rep.dataset, rep.dataset_version, sig)
            buckets.setdefault(bkey, []).append((ekey, ckey, reqs))
        return list(buckets.values())

    def _run_bucket(self, bucket: list) -> list[int]:
        """Execute one bucket of same-signature execution groups: >= 2
        groups attempt ONE fused dispatch (falling back to serial
        per-group execution if the fused attempt throws — fusion is an
        optimization, never a correctness dependency); solo groups run
        the serial path directly."""
        if len(bucket) >= 2:
            try:
                return self._execute_fused(bucket)
            except Exception:  # noqa: BLE001 — fall back to per-group serial
                pass
        finished: list[int] = []
        for ekey, ckey, reqs in bucket:
            rep = reqs[0]
            if rep.status == "done":
                # a fused attempt that threw mid-completion (e.g. in a
                # finalize hook) may have finished earlier groups already
                finished.extend(r.request_id for r in reqs)
                continue
            t0 = self._clock()
            self.device_dispatches += 1
            try:
                value, compute_s, backend = self._execute(rep)
            except Exception as e:  # noqa: BLE001 — one bad request must not kill the service
                err = f"{type(e).__name__}: {e}"
                self._memo_failure(ekey, err)
                self._fail(reqs, err, backend=self.backend_name,
                           attempt_s=self._clock() - t0)
                finished.extend(r.request_id for r in reqs)
                continue
            self._complete_group(ckey, reqs, value, compute_s, backend, fused=False)
            finished.extend(r.request_id for r in reqs)
        return finished

    def _complete_group(
        self, ckey, reqs, value, compute_s: float, backend: str, *, fused: bool,
    ) -> None:
        rep = reqs[0]
        spec = get_workload(rep.app)
        if fused and spec.finalize is not None:
            # serial execution finalizes inside _execute; the fused path
            # folds state back here, per group in wave order
            spec.finalize(self._datasets[rep.dataset], spec.resolve(rep.params), value)
        self.cache.put(ckey, value)
        self.executions += 1
        if fused:
            self.fused_requests += len(reqs)
        self._finish(reqs, value, compute_s=compute_s, backend=backend,
                     cache_hit=False, fused=fused)

    def _execute_fused(self, bucket: list) -> list[int]:
        """ONE device dispatch for >= 2 same-signature execution groups.
        Grid workloads merge their SiteJob DAGs through
        ``GridRuntime.run_many`` (shared ``batch_key``s fuse the fan-outs
        across requests); local workloads run their per-group callables
        as one merged engine run.  Measured device time is apportioned
        per request exactly like ``timed_batch`` does per job."""
        reps = [reqs[0] for _, _, reqs in bucket]
        spec = get_workload(reps[0].app)
        ds = self._datasets[reps[0].dataset]
        self.device_dispatches += 1
        if spec.runner == "grid":
            datas, plists = [], []
            for rep in reps:
                p = spec.resolve(rep.params)
                datas.append(spec.site_split(ds, p, self))
                plists.append(spec.grid_params(p, self))
            runs = self.runtime.run_many(reps[0].app, datas, plists)
            values = [(r.result, r.compute_s, r.backend) for r in runs]
        else:
            values = self._run_many_local(reps, spec, ds)
        finished: list[int] = []
        for (_ekey, ckey, reqs), (value, compute_s, backend) in zip(bucket, values):
            self._complete_group(ckey, reqs, value, compute_s, backend, fused=True)
            finished.extend(r.request_id for r in reqs)
        return finished

    def _run_many_local(self, reps, spec, ds) -> list[tuple[Any, float, str]]:
        """Merged engine run for >= 2 local (delta-served) execution
        groups: one single-job DAG per group, all sharing a ``batch_key``
        so the batched backend serves the whole wave in one call (the
        fused fn just invokes each group's callable — the win is one
        engine invocation, and the delta state serves every member from
        one warm cache)."""
        measured: dict[str, float] = {}

        def fused(bargs, argss):
            return [fn() for fn in bargs]

        bfn = timed_batch(fused, measured)
        jobs = []
        for j, rep in enumerate(reps):
            p = spec.resolve(rep.params)
            fn = spec.local_fn(ds, p, self)
            name = f"r{j}/{rep.app}"
            jobs.append(SiteJob(name=name, fn=timed(fn, measured, name),
                                batch_key="local", batched_fn=bfn, batch_arg=fn))
        rep_, results = self.runtime.engine.run_site_jobs(
            jobs, name=f"serve-{reps[0].app}-fused{len(reps)}")
        return [
            (results[f"r{j}/{r.app}"], rep_.job_times.get(f"r{j}/{r.app}", 0.0),
             rep_.backend)
            for j, r in enumerate(reps)
        ]

    def _execute(self, req: MiningRequest) -> tuple[Any, float, str]:
        """Run one representative request; returns (result, measured
        device compute seconds, backend name).  Entirely table-driven off
        the workload registry: local (delta-served) workloads run their
        ``local_fn`` as a single ledgered job, grid workloads split the
        dataset with the spec's ``site_split`` and go through the generic
        ``GridRuntime.run`` — no per-app branches, so a registered app
        can NEVER reach an "unknown app" dead end here (submit already
        proved it is registered)."""
        spec = get_workload(req.app)
        ds = self._datasets[req.dataset]
        p = spec.resolve(req.params)
        if spec.runner == "local":
            fn = spec.local_fn(ds, p, self)
            value, compute_s, backend = self._run_single(req, fn)
            if spec.finalize is not None:
                spec.finalize(ds, p, value)
            return value, compute_s, backend
        data = spec.site_split(ds, p, self)
        run = self.runtime.run(req.app, data, spec.grid_params(p, self))
        return run.result, run.report.compute_s, run.backend

    def _run_single(self, req: MiningRequest, fn) -> tuple[Any, float, str]:
        """Execute a single-job DAG through the engine so the request is
        ledgered exactly like any grid run (RunReport, backend, measured
        compute feeding the simulated clock)."""
        name = f"{req.app}"
        measured: dict[str, float] = {}
        jobs = [SiteJob(name=name, fn=timed(fn, measured, name))]
        rep, results = self.runtime.engine.run_site_jobs(
            jobs, name=f"serve-{req.app}-{req.request_id}")
        return results[name], rep.compute_s, rep.backend

    # -- ledger ---------------------------------------------------------------

    def ledger(self) -> dict:
        """Service-level + per-request + per-tenant ledger, JSON-ready."""
        requests = [self._record(r) for r in sorted(self._requests.values(),
                                                    key=lambda r: r.request_id)]
        return {
            "backend": self.backend_name,
            "executions": self.executions,
            "coalesced": self.coalesced,
            "exec_groups": self.exec_groups,
            "fused_requests": self.fused_requests,
            "device_dispatches": self.device_dispatches,
            "failures": self.failures,
            "failure_memo_hits": self.failure_memo_hits,
            "rejected": self.queues.rejected + self.invalid,
            "rejected_full": self.rejected_full,
            "rejected_invalid": self.invalid,
            "cache": {
                "hits": self.cache.stats.hits,
                "misses": self.cache.stats.misses,
                "evictions": self.cache.stats.evictions,
                "hit_rate": self.cache.stats.hit_rate(),
                "entries": len(self.cache),
            },
            "per_tenant": self.tenant_ledger(),
            "requests": requests,
        }

    def tenant_ledger(self) -> dict[str, dict]:
        out: dict[str, dict] = {}
        for req in self._requests.values():
            t = out.setdefault(req.tenant, {
                "submitted": 0, "done": 0, "failed": 0, "rejected": 0,
                "cache_hits": 0, "coalesced": 0, "fused": 0,
                "queue_wait_s": 0.0, "compute_s": 0.0, "service_s": 0.0,
            })
            t["submitted"] += 1
            if req.status in ("done", "failed", "rejected"):
                t[req.status] += 1
            if req.cache_hit:
                t["cache_hits"] += 1
            if req.coalesced_into is not None:
                t["coalesced"] += 1
            if req.fused:
                t["fused"] += 1
            t["queue_wait_s"] += req.queue_wait_s
            t["compute_s"] += req.compute_s
            t["service_s"] += req.service_s
        return out

    @staticmethod
    def _record(req: MiningRequest) -> dict:
        return {
            "request_id": req.request_id,
            "tenant": req.tenant,
            "app": req.app,
            "dataset": req.dataset,
            "dataset_version": req.dataset_version,
            "params": {str(k): v for k, v in req.params.items()},
            "status": req.status,
            "cache_hit": req.cache_hit,
            "coalesced_into": req.coalesced_into,
            "backend": req.backend,
            "fused": req.fused,
            "queue_wait_s": req.queue_wait_s,
            "compute_s": req.compute_s,
            "service_s": req.service_s,
            "error": req.error,
        }


# ---------------------------------------------------------------------------
# Fairness audit
# ---------------------------------------------------------------------------


def fairness_violations(pick_log: list[str], tenants: list[str], window: int) -> list[str]:
    """Audit the round-robin bound on a pick-log prefix during which every
    tenant was backlogged: with uniform weights, after any prefix of the
    first ``window`` picks the per-tenant pick counts differ by at most
    one.  Returns human-readable violations (empty = fair)."""
    counts = dict.fromkeys(tenants, 0)
    bad: list[str] = []
    for i, tenant in enumerate(pick_log[:window]):
        if tenant in counts:
            counts[tenant] += 1
        spread = max(counts.values()) - min(counts.values())
        if spread > 1:
            bad.append(f"after pick {i + 1}: per-tenant counts {counts} spread {spread} > 1")
    return bad


# ---------------------------------------------------------------------------
# CLI driver: bursty synthetic multi-tenant trace
# ---------------------------------------------------------------------------


def _build_service(args) -> MiningService:
    svc = MiningService(
        backend=args.backend,
        n_sites=args.n_sites,
        max_depth=args.max_depth,
        count_backend="jnp",
        use_kernel=False,
        fuse_requests=not getattr(args, "no_fuse", False),
    )
    svc.register_dataset("tx", "transactions", n_items=args.n_items)
    svc.register_dataset("pts", "points", dim=2)
    svc.append_transactions("tx", ibm_transactions(args.seed, 240, args.n_items))
    pts, _ = gaussian_mixture(args.seed, 240, 2, 3)
    svc.append_points("pts", pts)
    return svc


def _trace_bursts(args, rng: np.random.Generator) -> list[list[tuple[str, str, str, dict]]]:
    """A bursty multi-tenant trace: each burst opens with one request all
    tenants share (coalescing fodder) and — when the pool has one — a
    same-app different-params SIBLING of it (cross-request fusion
    fodder: the two land in the same dispatch wave with a shared batch
    signature), then per-tenant draws from a SMALL param pool, so
    repeats within a dataset version become cache hits.  The pool is the
    registry's smoke params — EVERY registered workload (the
    registry-added ones included) is in the trace for free."""
    tenants = [f"tenant{i}" for i in range(args.tenants)]
    pool = []
    for spec in workloads():
        dsname = "tx" if spec.dataset_kind == "transactions" else "pts"
        for smoke in spec.smoke_params:
            params = dict(smoke)
            if spec.runner == "grid":
                params.setdefault("n_sites", args.n_sites)
            pool.append((spec.name, dsname, params))
    bursts: list[list[tuple[str, str, str, dict]]] = []
    remaining = args.requests
    while remaining > 0:
        burst: list[tuple[str, str, str, dict]] = []
        shared = pool[int(rng.integers(len(pool)))]
        for t in tenants:  # the burst's shared query — first in every queue
            burst.append((t, *shared))
        siblings = [e for e in pool if e[0] == shared[0] and e[2] != shared[2]]
        if siblings:
            sib = siblings[int(rng.integers(len(siblings)))]
            for t in tenants:  # same wave as the shared query → fuses
                burst.append((t, *sib))
        per_tenant = max(1, min(args.burst, remaining // max(len(tenants), 1)) - 1)
        for t in tenants:
            for _ in range(per_tenant):
                app, dataset, params = pool[int(rng.integers(len(pool)))]
                burst.append((t, app, dataset, params))
        bursts.append(burst)
        remaining -= len(burst)
    return bursts


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=50, help="total requests in the trace")
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--burst", type=int, default=4, help="max requests per tenant per burst")
    ap.add_argument("--backend", default="batched", choices=("inline", "batched", "multihost"))
    ap.add_argument("--n-sites", type=int, default=4)
    ap.add_argument("--n-items", type=int, default=12)
    ap.add_argument("--max-depth", type=int, default=64)
    ap.add_argument("--max-per-step", type=int, default=8)
    ap.add_argument("--append-every", type=int, default=2,
                    help="append fresh data every N bursts (version bump)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-fuse", action="store_true",
                    help="disable cross-request batching (the serial baseline)")
    ap.add_argument("--ledger-out", default=None, help="write the JSON ledger here")
    ap.add_argument("--check", action="store_true",
                    help="assert fairness bound, cache hits, coalescing and "
                         "cross-request fusion (CI gate)")
    args = ap.parse_args(argv)

    rng = np.random.default_rng(args.seed)
    svc = _build_service(args)
    tenants = [f"tenant{i}" for i in range(args.tenants)]
    bursts = _trace_bursts(args, rng)

    fairness_ok = True
    fairness_detail: list[str] = []
    rejected = 0
    t0 = time.perf_counter()
    for b, burst in enumerate(bursts):
        for tenant, app, dataset, params in burst:
            try:
                svc.submit(tenant, app, dataset, params)
            except QueueFullError:
                rejected += 1
        # every tenant is backlogged right now: audit the fairness bound
        # over the picks that drain this burst's guaranteed backlog
        window = len(svc.pick_log) + min(svc.queues.depth(t) for t in tenants) * len(tenants)
        svc.drain(max_requests=args.max_per_step)
        viol = fairness_violations(svc.pick_log[:window], tenants, window)
        if viol:
            fairness_ok = False
            fairness_detail.extend(f"burst {b}: {v}" for v in viol[:3])
        if args.append_every and (b + 1) % args.append_every == 0:
            svc.append_transactions("tx", ibm_transactions(args.seed + b + 1, 60, args.n_items))
            pts, _ = gaussian_mixture(args.seed + b + 1, 60, 2, 3)
            svc.append_points("pts", pts)
    wall = time.perf_counter() - t0

    led = svc.ledger()
    done = [r for r in led["requests"] if r["status"] == "done"]
    failed = [r for r in led["requests"] if r["status"] == "failed"]
    lat = np.array([r["service_s"] for r in done]) if done else np.zeros(1)
    print(f"[serve] backend={led['backend']} requests={len(led['requests'])} "
          f"done={len(done)} failed={len(failed)} rejected={led['rejected']}")
    print(f"[serve] executions={led['executions']} coalesced={led['coalesced']} "
          f"cache hits={led['cache']['hits']} misses={led['cache']['misses']} "
          f"hit_rate={led['cache']['hit_rate']:.2f}")
    print(f"[serve] exec_groups={led['exec_groups']} "
          f"device_dispatches={led['device_dispatches']} "
          f"fused_requests={led['fused_requests']} "
          f"failures={led['failures']} memo_hits={led['failure_memo_hits']}")
    print(f"[serve] throughput={len(done) / max(wall, 1e-9):.1f} req/s "
          f"latency p50={np.percentile(lat, 50) * 1e3:.1f}ms "
          f"p95={np.percentile(lat, 95) * 1e3:.1f}ms")
    for tenant, t in sorted(led["per_tenant"].items()):
        print(f"[serve]   {tenant}: submitted={t['submitted']} done={t['done']} "
              f"cache_hits={t['cache_hits']} coalesced={t['coalesced']} "
              f"queue_wait={t['queue_wait_s']:.3f}s compute={t['compute_s']:.3f}s")
    print(f"[serve] fairness bound (round-robin, spread<=1): "
          f"{'OK' if fairness_ok else 'VIOLATED'}")

    if args.ledger_out:
        with open(args.ledger_out, "w") as f:
            json.dump(led, f, indent=2, default=float)
        print(f"[serve] ledger -> {args.ledger_out}")

    if args.check:
        problems: list[str] = []
        if failed:
            problems.append(f"{len(failed)} requests failed: {failed[0]['error']}")
        if led["cache"]["hits"] < 1:
            problems.append("expected cache hits on repeated queries, got 0")
        if led["coalesced"] < 1:
            problems.append("expected coalesced identical requests, got 0")
        if not fairness_ok:
            problems.append("fairness bound violated: " + "; ".join(fairness_detail))
        if not args.no_fuse and led["device_dispatches"] >= led["executions"]:
            problems.append(
                "expected cross-request fusion to drop device dispatches below "
                f"executions, got {led['device_dispatches']} >= {led['executions']}"
            )
        if problems:
            for p in problems:
                print(f"[serve] CHECK FAILED: {p}", file=sys.stderr)
            return 1
        print("[serve] checks passed: fairness bound, cache hits, coalescing, "
              "cross-request fusion")
    return 0


if __name__ == "__main__":
    sys.exit(main())
