"""repro — grid-based distributed data mining on multi-pod JAX.

Reproduction + extension of:
  Aouad, Le-Khac, Kechadi, "Grid-based Approaches for Distributed Data
  Mining Applications" (2017).

Lazy public API: submodules import jax at first use so that launch-time
environment flags (XLA_FLAGS device-count overrides) can be set before
any repro import triggers jax initialisation.
"""

__version__ = "0.1.0"

_LAZY = {
    "SuffStats": "repro.core.stats",
    "merge_cost": "repro.core.stats",
    "merge_stats": "repro.core.stats",
    "kmeans": "repro.core.kmeans",
    "kmeans_plus_plus_init": "repro.core.kmeans",
    "gap_statistic": "repro.core.kmeans",
    "VClusterConfig": "repro.core.vclustering",
    "vcluster_pooled": "repro.core.vclustering",
    "merge_subclusters": "repro.core.vclustering",
    "gfm_mine": "repro.core.gfm",
    "fdm_mine": "repro.core.fdm",
}


def __getattr__(name):
    if name in _LAZY:
        import importlib

        mod = importlib.import_module(_LAZY[name])
        return getattr(mod, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
