"""Model assembly: heterogeneous layer stacks under `lax.scan`.

Layers are grouped as  [prefix (static)] + [G groups x P pattern slots
(scanned)] + [tail (static)].  Per-slot parameters are stacked on a
leading G axis so the HLO contains ONE trace of each distinct block kind
regardless of depth — essential for CPU-side compile times of 26..56-layer
configs and for keeping the dry-run HLO small.

Covers: dense/GQA attention (full / sliding-window / alternating),
logit softcaps, pre+post norms, MoE FFNs, Mamba-2 and xLSTM mixers,
zamba2-style weight-shared attention blocks interleaved between scan
groups, and the seamless-style encoder-decoder with cross-attention.

Three entry points per architecture:
  forward_train  — full-sequence logits (+ aux losses)
  prefill        — full-sequence forward that also builds the decode cache
  decode_step    — single-token step against the cache
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_ffn,
    apply_norm,
    ffn_spec,
    init_from_specs,
    norm_spec,
    softcap,
    spec,
)
from repro.sharding import constrain

# ---------------------------------------------------------------------------
# Structure helpers
# ---------------------------------------------------------------------------


def _layout(cfg: ModelConfig):
    """(prefix kinds, pattern, G, tail kinds)."""
    blocks = cfg.blocks()
    n_prefix = len(cfg.prefix_pattern)
    body = blocks[n_prefix:]
    p = cfg.pattern_period
    g = len(body) // p
    tail = body[g * p :]
    return blocks[:n_prefix], cfg.layer_pattern, g, tail


def _is_attn(kind: str) -> bool:
    return kind in ("full", "swa", "full_dense", "swa_dense")


def _window(cfg, kind: str) -> int:
    return cfg.window if kind.startswith("swa") else 0


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


def block_spec(cfg: ModelConfig, kind: str, cross: bool = False) -> dict:
    if _is_attn(kind):
        p: dict[str, Any] = {"ln1": norm_spec(cfg), "attn": attn.attn_spec(cfg)}
        if cfg.post_norm:
            p["ln1_post"] = norm_spec(cfg)
        if cross:
            p["ln_cross"] = norm_spec(cfg)
            p["cross"] = attn.attn_spec(cfg, cross=True)
        p["ln2"] = norm_spec(cfg)
        if cfg.moe is not None and not kind.endswith("_dense"):
            p["moe"] = moe_mod.moe_spec(cfg)
        elif cfg.d_ff:
            p["ffn"] = ffn_spec(cfg)
        if cfg.post_norm:
            p["ln2_post"] = norm_spec(cfg)
        return p
    if kind == "mamba2":
        return {"ln1": norm_spec(cfg), "mixer": ssm_mod.mamba2_spec(cfg)}
    if kind == "mlstm":
        return {"ln1": norm_spec(cfg), "mixer": xlstm_mod.mlstm_spec(cfg)}
    if kind == "slstm":
        return {"ln1": norm_spec(cfg), "mixer": xlstm_mod.slstm_spec(cfg)}
    raise ValueError(f"unknown block kind {kind!r}")


def _stack_specs(s, g: int):
    """Prepend a stacked 'layers' axis of size g to every ShapeAxes leaf."""
    return jax.tree.map(
        lambda leaf: spec((g, *leaf.shape), ("layers", *leaf.axes), leaf.dtype),
        s,
        is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "shape"),
    )


def param_specs(cfg: ModelConfig) -> dict:
    prefix, pattern, g, tail = _layout(cfg)
    cross = cfg.is_encdec
    p: dict[str, Any] = {
        "embed": spec((cfg.vocab_padded, cfg.d_model), ("vocab", "embed")),
        "final_norm": norm_spec(cfg),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = spec((cfg.d_model, cfg.vocab_padded), ("embed", "vocab"))
    if prefix:
        p["prefix"] = [block_spec(cfg, k, cross) for k in prefix]
    if g:
        p["groups"] = {
            str(slot): _stack_specs(block_spec(cfg, pattern[slot], cross), g)
            for slot in range(len(pattern))
        }
    if tail:
        p["tail"] = [block_spec(cfg, k, cross) for k in tail]
    if cfg.shared_attn_every:
        shared_cfg = cfg
        p["shared_attn"] = {
            "ln1": norm_spec(cfg),
            "attn": attn.attn_spec(cfg),
            "ln2": norm_spec(cfg),
            "ffn": ffn_spec(cfg),
        }
    if cfg.is_encdec:
        p["encoder"] = {
            "blocks": _stack_specs(
                {
                    "ln1": norm_spec(cfg),
                    "attn": attn.attn_spec(cfg),
                    "ln2": norm_spec(cfg),
                    "ffn": ffn_spec(cfg),
                },
                cfg.n_enc_layers,
            ),
            "final_norm": norm_spec(cfg),
        }
    return p


def init_params(cfg: ModelConfig, key: jax.Array):
    return init_from_specs(key, param_specs(cfg))


def param_count(cfg: ModelConfig) -> int:
    leaves = jax.tree.leaves(
        param_specs(cfg), is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "shape")
    )
    return sum(math.prod(leaf.shape) for leaf in leaves)


def active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: shared + top_k of routed)."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    per_expert = 3 * cfg.d_model * m.expert_d_ff
    prefix, pattern, g, tail = _layout(cfg)
    n_moe = sum(
        1 for k in (list(prefix) + list(pattern) * g + list(tail)) if _is_attn(k) and not k.endswith("_dense")
    )
    inactive = n_moe * (m.n_experts - m.top_k) * per_expert
    return total - inactive


# ---------------------------------------------------------------------------
# Cache specs
# ---------------------------------------------------------------------------


def _attn_cache_spec(cfg, batch: int, seq: int, cross_len: int = 0) -> dict:
    c = {
        "k": spec((batch, seq, cfg.n_kv_heads, cfg.head_dim), ("batch", "kv_seq", "kv_heads", None), cfg.dtype),
        "v": spec((batch, seq, cfg.n_kv_heads, cfg.head_dim), ("batch", "kv_seq", "kv_heads", None), cfg.dtype),
    }
    if cross_len:
        c["ck"] = spec((batch, cross_len, cfg.n_kv_heads, cfg.head_dim), ("batch", None, "kv_heads", None), cfg.dtype)
        c["cv"] = spec((batch, cross_len, cfg.n_kv_heads, cfg.head_dim), ("batch", None, "kv_heads", None), cfg.dtype)
    return c


def _kind_cache_spec(cfg, kind: str, batch: int, seq: int, cross_len: int):
    if _is_attn(kind):
        return _attn_cache_spec(cfg, batch, seq, cross_len)
    if kind == "mamba2":
        return ssm_mod.mamba2_cache_spec(cfg, batch)
    if kind == "mlstm":
        return xlstm_mod.mlstm_cache_spec(cfg, batch)
    if kind == "slstm":
        return xlstm_mod.slstm_cache_spec(cfg, batch)
    raise ValueError(kind)


def cache_specs(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """ShapeAxes tree describing the decode cache for (batch, max_seq)."""
    prefix, pattern, g, tail = _layout(cfg)
    cross = cfg.frontend_len if cfg.is_encdec else 0
    c: dict[str, Any] = {}
    if prefix:
        c["prefix"] = [_kind_cache_spec(cfg, k, batch, seq, cross) for k in prefix]
    if g:
        c["groups"] = {
            str(slot): _stack_specs(_kind_cache_spec(cfg, pattern[slot], batch, seq, cross), g)
            for slot in range(len(pattern))
        }
    if tail:
        c["tail"] = [_kind_cache_spec(cfg, k, batch, seq, cross) for k in tail]
    if cfg.shared_attn_every and g:
        c["shared"] = _stack_specs(_attn_cache_spec(cfg, batch, seq), g)
    return c


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------


def _apply_ffn_part(cfg, p, x, aux):
    h = apply_norm(cfg, p["ln2"], x)
    if "moe" in p:
        y, a = moe_mod.apply_moe(cfg, p["moe"], h)
        aux = {k: aux[k] + a[k] for k in aux}
    elif "ffn" in p:
        y = apply_ffn(cfg, p["ffn"], h)
    else:
        return x, aux
    if cfg.post_norm:
        y = apply_norm(cfg, p["ln2_post"], y)
    return x + y, aux


def apply_block(
    cfg: ModelConfig,
    kind: str,
    p: dict,
    x: jax.Array,
    q_pos: jax.Array,
    *,
    mode: str,  # 'train' | 'prefill' | 'decode'
    cache: dict | None = None,
    pos=None,  # decode position scalar
    memory: jax.Array | None = None,  # encoder output for cross-attn
    aux: dict,
    chunk: int = 1024,
):
    """Returns (x, new_cache, aux)."""
    x = constrain(x, ("batch", "seq", None))
    new_cache = cache
    if _is_attn(kind):
        h = apply_norm(cfg, p["ln1"], x)
        window = _window(cfg, kind)
        if mode == "train":
            y = attn.attention(cfg, p["attn"], h, q_pos, causal=True, window=window, chunk=chunk)
            kv = None
        elif mode == "prefill":
            y, kv = attn.attention_with_cache(cfg, p["attn"], h, q_pos, None, window=window, chunk=chunk)
            # pad K/V out to the cache length
            s_max = cache["k"].shape[1]
            pad = s_max - kv["k"].shape[1]
            kv = {
                "k": jnp.pad(kv["k"], ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cache["k"].dtype),
                "v": jnp.pad(kv["v"], ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cache["v"].dtype),
            }
        else:  # decode
            y, kv = attn.decode_attention(cfg, p["attn"], h, pos, {"k": cache["k"], "v": cache["v"]}, window=window)
        if cfg.post_norm:
            y = apply_norm(cfg, p["ln1_post"], y)
        x = x + y

        if "cross" in p:
            hc = apply_norm(cfg, p["ln_cross"], x)
            if mode == "decode":
                y = _cross_decode(cfg, p["cross"], hc, cache["ck"], cache["cv"])
                kv = {**kv, "ck": cache["ck"], "cv": cache["cv"]}
            else:
                kp = jnp.arange(memory.shape[1], dtype=jnp.int32)
                y = attn.attention(
                    cfg, p["cross"], hc, q_pos, causal=False, kv_x=memory, kv_pos=kp, rope=False, chunk=chunk
                )
                if mode == "prefill":
                    dt = cache["ck"].dtype
                    kv = {
                        **kv,
                        "ck": jnp.einsum("bsd,dhk->bshk", memory, p["cross"]["wk"].astype(x.dtype)).astype(dt),
                        "cv": jnp.einsum("bsd,dhk->bshk", memory, p["cross"]["wv"].astype(x.dtype)).astype(dt),
                    }
            x = x + y

        x, aux = _apply_ffn_part(cfg, p, x, aux)
        if mode in ("prefill", "decode"):
            new_cache = kv
        return x, new_cache, aux

    # --- recurrent mixers ---
    h = apply_norm(cfg, p["ln1"], x)
    if kind == "mamba2":
        if mode == "decode":
            y, new_cache = ssm_mod.mamba2_decode(cfg, p["mixer"], h, cache)
        else:
            y, new_cache = ssm_mod.apply_mamba2(cfg, p["mixer"], h)
    elif kind == "mlstm":
        if mode == "decode":
            y, new_cache = xlstm_mod.mlstm_decode(cfg, p["mixer"], h, cache)
        else:
            y, new_cache = xlstm_mod.apply_mlstm(cfg, p["mixer"], h)
    elif kind == "slstm":
        if mode == "decode":
            y, new_cache = xlstm_mod.slstm_decode(cfg, p["mixer"], h, cache)
        else:
            y, new_cache = xlstm_mod.apply_slstm(cfg, p["mixer"], h)
    else:
        raise ValueError(kind)
    if mode == "train":
        new_cache = None
    return x + y, new_cache, aux


def _cross_decode(cfg, p, x, ck, cv):
    """Single-token cross-attention against precomputed encoder K/V."""
    b = x.shape[0]
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    qg = attn._grouped(q, cfg.n_kv_heads)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg / math.sqrt(cfg.head_dim), ck.astype(dt))
    pr = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bhgqd", pr.astype(dt), cv.astype(dt))
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, 1, cfg.n_heads, cfg.head_dim)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------


def ZERO_AUX():
    return {"aux_loss": jnp.float32(0.0), "z_loss": jnp.float32(0.0)}


def _shared_attn_block(cfg, p, x, q_pos, mode, cache, pos, aux, chunk):
    """zamba2-style weight-shared attention+FFN block (applied per group)."""
    h = apply_norm(cfg, p["ln1"], x)
    if mode == "train":
        y = attn.attention(cfg, p["attn"], h, q_pos, causal=True, chunk=chunk)
        kv = None
    elif mode == "prefill":
        y, kv = attn.attention_with_cache(cfg, p["attn"], h, q_pos, None, chunk=chunk)
        s_max = cache["k"].shape[1]
        pad = s_max - kv["k"].shape[1]
        kv = {
            "k": jnp.pad(kv["k"], ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cache["k"].dtype),
            "v": jnp.pad(kv["v"], ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cache["v"].dtype),
        }
    else:
        y, kv = attn.decode_attention(cfg, p["attn"], h, pos, cache)
    x = x + y
    h2 = apply_norm(cfg, p["ln2"], x)
    x = x + apply_ffn(cfg, p["ffn"], h2)
    return x, kv, aux


def _run_stack(
    cfg: ModelConfig,
    params: dict,
    x: jax.Array,
    q_pos: jax.Array,
    *,
    mode: str,
    cache: dict | None,
    pos=None,
    memory=None,
    chunk: int = 1024,
):
    """Apply prefix + scanned groups + tail.  Returns (x, new_cache, aux)."""
    prefix, pattern, g, tail = _layout(cfg)
    aux = ZERO_AUX()
    new_cache: dict[str, Any] = {}

    if prefix:
        pc = []
        for i, kind in enumerate(prefix):
            c_i = cache["prefix"][i] if cache else None
            x, nc, aux = apply_block(
                cfg, kind, params["prefix"][i], x, q_pos, mode=mode, cache=c_i, pos=pos, memory=memory, aux=aux, chunk=chunk
            )
            pc.append(nc)
        if mode != "train":
            new_cache["prefix"] = pc

    if g:
        p_slots = params["groups"]
        c_slots = cache["groups"] if cache else None
        shared_p = params.get("shared_attn")
        c_shared = cache.get("shared") if cache else None

        def group_body(carry, inp):
            x, aux = carry
            p_slice, c_slice, sh_c = inp
            out_c: dict[str, Any] = {}
            sh_out = None
            if shared_p is not None:
                x, sh_out, aux = _shared_attn_block(cfg, shared_p, x, q_pos, mode, sh_c, pos, aux, chunk)
            for slot in range(len(pattern)):
                kind = pattern[slot]
                cc = c_slice[str(slot)] if c_slice is not None else None
                x, nc, aux = apply_block(
                    cfg, kind, p_slice[str(slot)], x, q_pos, mode=mode, cache=cc, pos=pos, memory=memory, aux=aux, chunk=chunk
                )
                out_c[str(slot)] = nc
            return (x, aux), (out_c if mode != "train" else None, sh_out if mode != "train" else None)

        body = group_body
        if mode == "train" and cfg.remat != "none":
            policy = None if cfg.remat == "full" else jax.checkpoint_policies.checkpoint_dots
            body = jax.checkpoint(group_body, policy=policy)

        xs = (p_slots, c_slots, c_shared)
        (x, aux), (gc, sc) = jax.lax.scan(body, (x, aux), xs)
        if mode != "train":
            new_cache["groups"] = gc
            if sc is not None and shared_p is not None:
                new_cache["shared"] = sc

    if tail:
        tc = []
        for i, kind in enumerate(tail):
            c_i = cache["tail"][i] if cache else None
            x, nc, aux = apply_block(
                cfg, kind, params["tail"][i], x, q_pos, mode=mode, cache=c_i, pos=pos, memory=memory, aux=aux, chunk=chunk
            )
            tc.append(nc)
        if mode != "train":
            new_cache["tail"] = tc

    return x, (new_cache if mode != "train" else None), aux


# ---------------------------------------------------------------------------
# Embedding / logits / encoder
# ---------------------------------------------------------------------------


def embed_tokens(cfg, params, tokens, frontend_embeds=None):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    if cfg.embed_scale:
        x = x * math.sqrt(cfg.d_model)
    if frontend_embeds is not None and not cfg.is_encdec:
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
    return constrain(x, ("batch", "seq", None))


def logits_from(cfg, params, x):
    h = apply_norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        lg = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype))
    else:
        lg = jnp.einsum("bsd,dv->bsv", h, params["lm_head"].astype(h.dtype))
    lg = lg.astype(jnp.float32)
    if cfg.final_softcap:
        lg = softcap(lg, cfg.final_softcap)
    if cfg.vocab_padded > cfg.vocab:
        # mask padded vocabulary ids so they never win sampling / CE mass
        ids = jnp.arange(cfg.vocab_padded)
        lg = jnp.where(ids < cfg.vocab, lg, -1e30)
    return lg


def encode(cfg, params, frames: jax.Array, chunk: int = 1024):
    """Encoder stack over stub frame embeddings (B, Senc, D)."""
    enc = params["encoder"]
    x = frames.astype(cfg.dtype)
    q_pos = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(x, p):
        h = apply_norm(cfg, p["ln1"], x)
        y = attn.attention(cfg, p["attn"], h, q_pos, causal=False, chunk=chunk)
        x = x + y
        h2 = apply_norm(cfg, p["ln2"], x)
        return x + apply_ffn(cfg, p["ffn"], h2), None

    x, _ = jax.lax.scan(body, x, enc["blocks"])
    return apply_norm(cfg, enc["final_norm"], x)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def forward_train(
    cfg: ModelConfig, params, tokens, frontend_embeds=None, chunk: int = 1024, return_hidden: bool = False
):
    """Returns (logits over TOKEN positions (B, S_tok, V), aux); with
    return_hidden=True returns the pre-logits hidden states instead of
    logits (the chunked-CE train loss computes logits chunk-wise)."""
    memory = None
    if cfg.is_encdec:
        memory = encode(cfg, params, frontend_embeds, chunk=chunk)
        x = embed_tokens(cfg, params, tokens)
    else:
        x = embed_tokens(cfg, params, tokens, frontend_embeds)
    q_pos = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, _, aux = _run_stack(cfg, params, x, q_pos, mode="train", cache=None, memory=memory, chunk=chunk)
    if frontend_embeds is not None and not cfg.is_encdec:
        x = x[:, frontend_embeds.shape[1] :, :]
    if return_hidden:
        return x, aux
    return logits_from(cfg, params, x), aux


def prefill(cfg: ModelConfig, params, tokens, cache, frontend_embeds=None, chunk: int = 1024):
    """Full forward building the decode cache.  Returns (logits, cache)."""
    memory = None
    if cfg.is_encdec:
        memory = encode(cfg, params, frontend_embeds, chunk=chunk)
        x = embed_tokens(cfg, params, tokens)
    else:
        x = embed_tokens(cfg, params, tokens, frontend_embeds)
    q_pos = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, new_cache, _ = _run_stack(cfg, params, x, q_pos, mode="prefill", cache=cache, memory=memory, chunk=chunk)
    if frontend_embeds is not None and not cfg.is_encdec:
        x = x[:, frontend_embeds.shape[1] :, :]
    return logits_from(cfg, params, x[:, -1:, :]), new_cache


def decode_step(cfg: ModelConfig, params, token, pos, cache):
    """token (B, 1) int32; pos () int32; returns (logits (B,1,V), cache')."""
    x = embed_tokens(cfg, params, token)
    q_pos = jnp.full((1,), pos, jnp.int32)
    x, new_cache, _ = _run_stack(cfg, params, x, q_pos, mode="decode", cache=cache, pos=pos)
    return logits_from(cfg, params, x), new_cache
