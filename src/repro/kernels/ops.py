"""Jitted public wrappers around the Pallas kernels.

Handle padding/layout so callers pass natural shapes; select interpret
mode automatically off-TPU (this container is CPU-only — Mosaic kernels
are VALIDATED via the interpreter and TARGET TPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import pad_to, ref
from repro.kernels.kmeans_assign import BIG, kmeans_assign_pallas
from repro.kernels.support_count import support_count_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def kmeans_assign(x: jax.Array, centers: jax.Array, block_n: int = 256) -> tuple[jax.Array, jax.Array]:
    """Nearest-center assignment.  x (N, D), centers (K, D) ->
    (assign (N,) int32, min_d2 (N,) f32).  Pads D and K to the 128-lane
    boundary per the kernel contract (the kernel auto-pads N itself)."""
    n, d = x.shape
    k, _ = centers.shape
    dp = pad_to(max(d, 128), 128)
    kp = pad_to(max(k, 128), 128)
    xp = jnp.zeros((n, dp), jnp.float32).at[:, :d].set(x.astype(jnp.float32))
    # padded center rows sit at +BIG so they never win the argmin;
    # padded D columns are zero in both operands (distance unchanged)
    cp = jnp.full((kp, dp), 0.0, jnp.float32)
    cp = cp.at[:, :d].set(jnp.full((kp, d), BIG, jnp.float32))
    cp = cp.at[:k, :d].set(centers.astype(jnp.float32))
    return kmeans_assign_pallas(xp, cp, block_n=block_n, interpret=not _on_tpu())


def support_count(tx_packed: jax.Array, masks: jax.Array, block_n: int = 512, block_c: int = 512) -> jax.Array:
    """Support counts.  tx_packed (N, W) uint32, masks (C, W) uint32 ->
    (C,) int32.  Transposes to the kernel's (W, ·) lane layout; the
    kernel auto-pads N/C to its blocks (padded transactions count zero
    support, padded candidate outputs are sliced away)."""
    n, w = tx_packed.shape
    c, w2 = masks.shape
    assert w == w2
    tx_t = jax.lax.bitcast_convert_type(tx_packed.astype(jnp.uint32), jnp.int32).T
    mk_t = jax.lax.bitcast_convert_type(masks.astype(jnp.uint32), jnp.int32).T
    return support_count_pallas(tx_t, mk_t, block_n=block_n, block_c=block_c, interpret=not _on_tpu())


def flash_attention(
    q: jax.Array,  # (B, Sq, H, Dh)
    k: jax.Array,  # (B, Skv, Kv, Dh)
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    cap: float = 0.0,
    block_q: int = 128,
    block_k: int = 256,
) -> jax.Array:
    """Flash attention with GQA; returns (B, Sq, H, Dh).

    Flattens (batch, heads) into the kernel's leading grid dim; the KV
    index map folds the GQA group so K/V are never repeated.  Pads Sq/Skv
    to the block sizes (padded keys sit behind an out-of-range causal/pad
    mask because padded q/k positions extend past the real length and the
    kernel's positional mask plus the final slice discard them)."""
    from repro.kernels.flash_attention import flash_attention_pallas

    b, sq, h, dh = q.shape
    _, skv, kvh, _ = k.shape
    tq = min(block_q, pad_to(sq, 8))
    tk = min(block_k, pad_to(skv, 8))
    sqp, skp = pad_to(sq, tq), pad_to(skv, tk)
    # padded keys are masked by causality (k_pos >= skv > any real q_pos);
    # without causality there is no mask to hide them
    assert causal or skp == skv, "non-causal flash requires Skv % block_k == 0"
    qf = jnp.pad(q, ((0, 0), (0, sqp - sq), (0, 0), (0, 0)))
    kf = jnp.pad(k, ((0, 0), (0, skp - skv), (0, 0), (0, 0)))
    vf = jnp.pad(v, ((0, 0), (0, skp - skv), (0, 0), (0, 0)))
    # (B, S, H, D) -> (B*H, S, D) with heads grouped per batch
    qf = qf.transpose(0, 2, 1, 3).reshape(b * h, sqp, dh)
    kf = kf.transpose(0, 2, 1, 3).reshape(b * kvh, skp, dh)
    vf = vf.transpose(0, 2, 1, 3).reshape(b * kvh, skp, dh)
    out = flash_attention_pallas(
        qf, kf, vf, causal=causal, window=window, cap=cap,
        block_q=tq, block_k=tk, interpret=not _on_tpu(),
    )
    out = out.reshape(b, h, sqp, dh).transpose(0, 2, 1, 3)
    return out[:, :sq]


def slstm_scan(wx: jax.Array, r: jax.Array, bias: jax.Array, state0, t_chunk: int = 16):
    """sLSTM sequence scan with VMEM-resident recurrent weights.

    wx (B, S, H, 4P) batch-major; state0 = (c, n, hid) each (B, H, P).
    Returns (hids (B, S, H, P), (cT, nT, hT)).  Pads S to the time-chunk
    (identity steps would corrupt state, so padding uses zero wx and the
    final state is captured from the real tail by re-running the remainder
    — instead we simply require S % t_chunk == 0 by choosing a divisor)."""
    from repro.kernels.slstm_cell import slstm_scan_pallas

    b, s, h, p4 = wx.shape
    tc = t_chunk
    while s % tc:
        tc //= 2
    tc = max(tc, 1)
    c0, n0, h0 = state0
    hids, cT, nT, hT = slstm_scan_pallas(
        jnp.moveaxis(wx, 1, 0), r, bias, c0, n0, h0, t_chunk=tc, interpret=not _on_tpu()
    )
    return jnp.moveaxis(hids, 0, 1), (cT, nT, hT)


def support_count_sites(tx_packed_s: jax.Array, masks_s: jax.Array) -> jax.Array:
    """Fused site-axis support counting: ONE dispatch for S sites.

    tx_packed_s (S, N, W) uint32, masks_s (S, C, W) uint32 -> (S, C)
    int32 — the vmapped form of :func:`support_count` (vmap lifts the
    Pallas grid by one site dimension, so the whole fan-out runs as a
    single kernel launch instead of S host-loop dispatches).  Per-site
    padding semantics are unchanged.
    """
    return jax.vmap(support_count)(tx_packed_s, masks_s)


def kmeans_assign_sites(
    xs: jax.Array, centers_s: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Fused site-axis K-Means assignment: ONE dispatch for S sites.

    xs (S, N, D), centers_s (S, K, D) -> (assign (S, N) int32,
    min_d2 (S, N) f32) — the vmapped form of :func:`kmeans_assign`.
    """
    return jax.vmap(kmeans_assign)(xs, centers_s)


# re-export oracles for convenience
kmeans_assign_ref = ref.kmeans_assign_ref
support_count_ref = ref.support_count_ref
