"""xlstm-1.3b [ssm] — mLSTM blocks with sLSTM every 8th (7:1)
[arXiv:2405.04517].  d_ff=0: blocks carry their own projections."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab=50304,
    layer_pattern=("mlstm",) * 7 + ("slstm",),
    norm="rmsnorm",
    subquadratic=True,
)
