"""Scheduler invariants across all placement policies, property-tested
over random DAGs (hypothesis, with the deterministic repro.testing
fallback for hermetic environments):

  * per-site worker slots are never oversubscribed
    (``site_busy <= workers_per_site`` at every trace record);
  * the event clock is monotone non-decreasing;
  * every job reaches a terminal state (and is placed exactly once);
  * dependency order is never violated (a job starts only after every
    dependency finished);
plus the determinism regression: identical ``RunReport`` — placement
decisions and speculation outcomes included — across repeated runs with
the same seed, for both schedule modes x both mining apps x each policy,
and the fixed policy reproducing the pre-placement engine bit-for-bit.
"""

import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic container: deterministic fallback
    from repro.testing import given, settings, strategies as st

from repro.workflow.dag import DAG, TimedResult
from repro.workflow.engine import SCHEDULES, Engine
from repro.workflow.overhead import GridModel
from repro.workflow.placement import POLICIES

N_SITES = 5


def sim(value=None):
    """A job fn whose measured compute is exactly 0 (TimedResult), so the
    simulated clock advances by sim_compute_s alone — deterministic."""
    return lambda *a: TimedResult(value, 0.0)


def random_dag(seed: int, n_jobs: int) -> DAG:
    """A random topology: each job depends on a subset of earlier jobs,
    with random sites, staging sizes and simulated compute."""
    rng = random.Random(seed)
    dag = DAG(f"rand-{seed}")
    names = [f"j{i}" for i in range(n_jobs)]
    for i, name in enumerate(names):
        deps = [d for d in names[:i] if rng.random() < 0.3][:3]
        dag.job(
            name,
            sim(),
            deps=deps,
            site=rng.randrange(N_SITES),
            input_bytes=rng.randrange(0, 10**6),
            output_bytes=rng.randrange(0, 10**5),
            sim_compute_s=round(rng.uniform(0.0, 3.0), 3),
        )
    return dag


def run_traced(dag: DAG, policy: str, schedule: str, workers: int, straggler: float):
    trace: list = []
    eng = Engine(
        model=GridModel(
            prep_latency_s=0.0, submit_latency_s=0.5, workers_per_site=workers
        ),
        schedule=schedule,
        placement=policy,
        straggler_factor=straggler,
        trace=trace,
    )
    rep = eng.run(dag)
    return rep, trace


class TestSchedulerInvariants:
    """Trace records are (t, kind, job, site, site_busy_after).  The
    "speculate" record is future-dated to the detection instant (it is
    pushed while processing an earlier event), so clock monotonicity is
    asserted over the event records only."""

    @settings(max_examples=40, deadline=None)
    @given(
        st.integers(min_value=0, max_value=10**9),
        st.integers(min_value=1, max_value=14),
        st.sampled_from(POLICIES),
        st.sampled_from(SCHEDULES),
        st.integers(min_value=1, max_value=3),
        st.sampled_from([0.0, 2.5]),
    )
    def test_invariants(self, seed, n_jobs, policy, schedule, workers, straggler):
        dag = random_dag(seed, n_jobs)
        fixed_sites = {name: job.site for name, job in dag.jobs.items()}
        rep, trace = run_traced(dag, policy, schedule, workers, straggler)

        # every job reaches a terminal state and was placed exactly once
        assert all(j.status == "done" for j in dag.jobs.values())
        assert set(rep.placements) == set(dag.jobs)
        assert rep.placement == policy

        # placements land on real sites; fixed echoes the a-priori ones
        if policy == "fixed":
            assert rep.placements == fixed_sites
        else:
            assert all(0 <= s < N_SITES for s in rep.placements.values())

        # worker slots are never oversubscribed, and releases never go
        # negative (async traces busy after start/finish/speculate)
        for t, kind, job, site, busy in trace:
            if schedule == "async":
                assert 0 <= busy <= workers, (kind, job, site, busy)
            assert rep.wall_s >= t - 1e-9

        # the event clock is monotone non-decreasing
        times = [t for t, kind, *_ in trace if kind != "speculate"]
        assert all(t1 >= t0 - 1e-9 for t0, t1 in zip(times, times[1:]))

        # dependency order is never violated: a job starts only after
        # every one of its dependencies finished
        starts = {job: t for t, kind, job, *_ in trace if kind == "start"}
        finishes = {job: t for t, kind, job, *_ in trace if kind == "finish"}
        assert set(starts) == set(dag.jobs) and set(finishes) == set(dag.jobs)
        for name, job in dag.jobs.items():
            for dep in job.deps:
                assert starts[name] >= finishes[dep] - 1e-9, (name, dep, policy, schedule)

        # the schedule's wall covers the whole trace and the accounting
        # identity holds
        assert rep.wall_s >= rep.critical_path_s - 1e-9
        assert 0.0 <= rep.overhead_pct() <= 100.0


def app_specs():
    """Both mining applications' real DAG topologies (builders only — no
    kernel execution), stripped to analytical specs."""
    import jax

    from repro.core.apriori import TransactionDB
    from repro.core.gfm import gfm_site_jobs
    from repro.core.vclustering import VClusterConfig, vcluster_site_jobs
    from repro.data.synthetic import (
        gaussian_mixture,
        ibm_transactions,
        split_sites,
        split_transactions,
    )
    from repro.workflow.sitejob import job_specs

    pts, _ = gaussian_mixture(0, 400, 2, 4, spread=12.0, sigma=0.5)
    xs = split_sites(pts, 4, seed=1)
    vjobs = vcluster_site_jobs(
        jax.random.PRNGKey(0), xs, VClusterConfig(k_local=4, kmeans_iters=5)
    )

    dense = ibm_transactions(seed=2, n_tx=200, n_items=16, avg_tx_len=5, n_patterns=4)
    sites = [TransactionDB.from_dense(s) for s in split_transactions(dense, 4, seed=0)]
    gjobs = gfm_site_jobs(sites, 2, 0.1)
    return {"vclustering": job_specs(vjobs), "gfm": job_specs(gjobs)}


def report_fingerprint(rep):
    """Everything observable about a simulated run, placement decisions
    and speculation outcomes included."""
    return (
        rep.wall_s,
        rep.compute_s,
        rep.critical_compute_s,
        rep.critical_transfer_s,
        rep.prep_s,
        rep.submit_s,
        rep.transfer_s,
        rep.retries,
        rep.speculative,
        rep.schedule,
        rep.placement,
        tuple(sorted(rep.placements.items())),
        tuple(sorted(rep.job_times.items())),
    )


class TestDeterminism:
    """Identical (DAG, model, times, seed) must replay identically under
    every schedule mode x mining app x placement policy."""

    @pytest.fixture(scope="class")
    def specs_by_app(self):
        return app_specs()

    @pytest.mark.parametrize("schedule", SCHEDULES)
    @pytest.mark.parametrize("policy", POLICIES)
    def test_repeated_runs_identical(self, specs_by_app, schedule, policy):
        from repro.workflow.sitejob import replay_dag

        model = GridModel.skewed(workers_per_site=2)
        for app, specs in specs_by_app.items():
            times = {sp.name: 0.05 * (i % 3 + 1) for i, sp in enumerate(specs)}
            prints = []
            for _ in range(2):
                eng = Engine(
                    model=model, schedule=schedule, placement=policy, straggler_factor=2.5
                )
                prints.append(report_fingerprint(eng.run(replay_dag(specs, times))))
            assert prints[0] == prints[1], (app, schedule, policy)

    @pytest.mark.parametrize("schedule", SCHEDULES)
    def test_fixed_policy_reproduces_pre_placement_engine(self, specs_by_app, schedule):
        """placement="fixed" (and the default) must be bit-for-bit the
        engine that honored job.site a priori — same wall, same critical
        path, same submit/transfer ledger."""
        from repro.workflow.sitejob import replay_dag

        for app, specs in specs_by_app.items():
            times = {sp.name: 0.05 * (i % 3 + 1) for i, sp in enumerate(specs)}
            default = Engine(model=GridModel(), schedule=schedule).run(
                replay_dag(specs, times)
            )
            explicit = Engine(model=GridModel(), schedule=schedule, placement="fixed").run(
                replay_dag(specs, times)
            )
            assert report_fingerprint(default) == report_fingerprint(explicit), (app, schedule)
            assert default.placements == {sp.name: sp.site for sp in specs}

    def test_greedy_eta_beats_fixed_on_skewed_grid(self, specs_by_app):
        """The acceptance invariant behind the CI sweep gate, asserted on
        the applications' own topologies: on the heterogeneous grid,
        adaptive matchmaking never loses to a-priori pinning."""
        from repro.workflow.sitejob import replay_dag

        model = GridModel.skewed()
        for app, specs in specs_by_app.items():
            times = {sp.name: 0.2 * (i % 3 + 1) for i, sp in enumerate(specs)}
            walls = {}
            for policy in ("fixed", "greedy_eta"):
                eng = Engine(model=model, schedule="async", placement=policy)
                walls[policy] = eng.run(replay_dag(specs, times)).wall_s
            assert walls["greedy_eta"] <= walls["fixed"] + 1e-9, (app, walls)
