"""DAG job model — the Condor/DAGMan analogue the paper evaluates against.

A Job is a Python callable plus metadata (inputs/outputs in bytes, the
site it runs on).  The DAG enforces ordering; the engine (engine.py)
executes it with a simulated grid clock, fault injection and rescue
semantics.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, NamedTuple


class TimedResult(NamedTuple):
    """A job result carrying its own device-measured compute time.

    When a job's ``fn`` returns one of these, the engine advances the
    simulated grid clock by ``compute_s`` (the caller's measurement — e.g.
    wall time around ``jax.block_until_ready``) instead of its own
    perf_counter bracket, and dependents receive the unwrapped ``value``.
    This is how the runtime layer calibrates the paper's overhead model
    with real kernel timings.
    """

    value: Any
    compute_s: float


@dataclass
class Job:
    name: str
    fn: Callable[..., Any]
    deps: list[str] = field(default_factory=list)
    site: int = 0  # grid site executing this job (overhead model: link matrix)
    input_bytes: int = 0  # data staged in from the submit node
    output_bytes: int = 0  # data staged back
    retries: int = 2  # DAGMan-style automatic retry budget
    sim_compute_s: float = 0.0  # simulated compute (paper-scale what-if
    # studies); added to the simulated clock WITHOUT real sleeping

    # filled by the engine
    status: str = "pending"  # pending | running | done | failed
    attempts: int = 0
    result: Any = None
    t_submit: float = 0.0
    t_start: float = 0.0
    t_end: float = 0.0


class DAG:
    def __init__(self, name: str = "dag"):
        self.name = name
        self.jobs: dict[str, Job] = {}

    def add(self, job: Job) -> Job:
        if job.name in self.jobs:
            raise ValueError(f"duplicate job {job.name!r}")
        for d in job.deps:
            if d not in self.jobs:
                raise ValueError(f"job {job.name!r} depends on unknown {d!r}")
        self.jobs[job.name] = job
        return job

    def job(self, name: str, fn: Callable, deps: list[str] | None = None, **kw) -> Job:
        return self.add(Job(name=name, fn=fn, deps=deps or [], **kw))

    def ready(self) -> list[Job]:
        out = []
        for j in self.jobs.values():
            if j.status == "pending" and all(self.jobs[d].status == "done" for d in j.deps):
                out.append(j)
        return out

    def done(self) -> bool:
        return all(j.status == "done" for j in self.jobs.values())

    def failed(self) -> list[Job]:
        return [j for j in self.jobs.values() if j.status == "failed"]

    def validate_acyclic(self) -> None:
        seen: dict[str, int] = {}

        def visit(n: str):
            st = seen.get(n, 0)
            if st == 1:
                raise ValueError(f"cycle through {n!r}")
            if st == 2:
                return
            seen[n] = 1
            for d in self.jobs[n].deps:
                visit(d)
            seen[n] = 2

        for n in self.jobs:
            visit(n)
