"""Analytical overhead model — the paper's §5.2.2.

estimated_time(workflow) = Σ over stages of max over parallel jobs of
(compute + transfer), with transfer times from a measured link matrix.
The paper compares this "ideal" bound against grid execution and finds
98% overhead for the cheap clustering workflow (Table 3); the engine
reproduces the measured side with its simulated job-prep latencies.

Two estimators:
  * ``estimate_stages`` — the paper's stage-barrier formula (matches the
    engine's ``schedule="staged"`` mode);
  * ``estimate_dag`` — the per-job critical-path bound (matches
    ``schedule="async"``, where a job starts the moment its dependencies
    complete; the paper's "partly overlapped by computations in the DAG").

``GridModel`` reproduces the paper's Table 2 (Mb/s - ms) exactly with
``links="grid5000"``; ``links="lan"`` models every pair as the local
cluster link (the overhead-free comparison point); ``links="skewed"``
degrades the Table 2 matrix per-site (the heterogeneous-WAN scenario of
arXiv:1412.2673's grid-workload study, where adaptive placement pays
off); ``bw_scale`` / ``lat_scale`` degrade or improve the matrix
uniformly for sweeps.  ``site_speed`` adds per-site compute speed
factors (None = homogeneous, preserving pre-placement numbers exactly).

Both estimators accept ``placement=`` to bound a workflow under a
placement policy: the specs are statically re-sited by
``placement.plan_specs`` (contention-free matchmaking) before the bound
is evaluated.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

# Table 2: average bandwidths (Mb/s) and latencies (ms) among the sites.
# Order: Orsay, Toulouse, Rennes, Nancy, Sophia.  None on the diagonal.
SITES = ["Orsay", "Toulouse", "Rennes", "Nancy", "Sophia"]
BW_MBPS = [
    [None, 16.15, 57.73, 90.77, 17.63],
    [38.97, None, 26.08, 28.89, 35.74],
    [66.33, 12.71, None, 44.63, 26.96],
    [106.63, 14.13, 44.54, None, 30.01],
    [21.45, 17.41, 26.93, 30.14, None],
]
LAT_MS = [
    [None, 15, 8, 5, 28],
    [15, None, 19, 17, 14],
    [8, 19, None, 11, 19],
    [5, 17, 11, None, 17],
    [28, 14, 19, 17, None],
]
LOCAL_BW_MBPS = 941.0
LOCAL_LAT_MS = 0.07

# links="skewed": per-site degradation of the Table 2 matrix — a link
# divides its bandwidth by (and multiplies its latency by) the product of
# its endpoints' factors.  Sites 1 (Toulouse) and 4 (Sophia) get
# congested-WAN treatment, site 3 (Nancy) an upgraded backbone — the
# heterogeneous-link regime of arXiv:1412.2673 where matchmaking
# placement dominates the schedule.
SKEW_LINK_FACTOR = (1.0, 6.0, 1.0, 0.5, 10.0)
# the matching per-site compute heterogeneity (GridModel.skewed()):
# speed >1 = faster site; 1.0 keeps the site at the homogeneous baseline
SKEW_SITE_SPEED = (1.0, 0.5, 1.0, 1.5, 0.25)
LINKS = ("grid5000", "lan", "skewed")

# §5.3: measured Condor/DAGMan workflow preparation latency (a 2-job DAG
# on a laptop) — "about 295 seconds ... the interval between the workflow
# launching and the first job submission".
DAGMAN_PREP_S = 295.0


@dataclass(frozen=True)
class GridModel:
    prep_latency_s: float = DAGMAN_PREP_S
    submit_latency_s: float = 3.0  # per-job scheduling/matchmaking cost
    n_sites: int = 5
    # per-site worker slots for the async scheduler's contention model
    # (a speculative duplicate needs a second free slot somewhere)
    workers_per_site: int = 2
    # link matrix: "grid5000" = the paper's Table 2; "lan" = every pair at
    # local-cluster quality (the no-WAN comparison point for sweeps);
    # "skewed" = Table 2 degraded per-site by SKEW_LINK_FACTOR
    links: str = "grid5000"
    bw_scale: float = 1.0  # uniform bandwidth multiplier (>1 = faster)
    lat_scale: float = 1.0  # uniform latency multiplier (<1 = faster)
    # per-site compute speed factors (>1 = faster site); None models the
    # homogeneous grid the pre-placement engine assumed — site_compute_s
    # is then the identity, so old numbers reproduce bit-for-bit
    site_speed: tuple | None = None

    def __post_init__(self):
        if self.links not in LINKS:
            raise ValueError(f"unknown links {self.links!r}; expected one of {LINKS}")
        if self.site_speed is not None:
            speeds = tuple(float(s) for s in self.site_speed)
            if not speeds or any(s <= 0 for s in speeds):
                raise ValueError(f"site_speed factors must be positive, got {self.site_speed!r}")
            object.__setattr__(self, "site_speed", speeds)  # frozen dataclass

    @classmethod
    def skewed(cls, **kw) -> "GridModel":
        """The canonical heterogeneous grid: skewed links AND skewed
        per-site compute speeds — the sweep point where adaptive
        placement is gated against fixed."""
        kw.setdefault("links", "skewed")
        kw.setdefault("site_speed", SKEW_SITE_SPEED)
        return cls(**kw)

    def speed(self, site: int) -> float:
        """Compute speed factor of ``site`` (1.0 on the homogeneous
        grid); out-of-range indices wrap like the link matrix."""
        if self.site_speed is None:
            return 1.0
        return self.site_speed[site % len(self.site_speed)]

    def site_compute_s(self, site: int, compute_s: float) -> float:
        """Scheduled duration of ``compute_s`` worth of baseline compute
        at ``site``.  Identity when the grid is homogeneous (site_speed
        None) — not merely "divide by 1.0" — so pre-placement numbers
        reproduce exactly."""
        if self.site_speed is None:
            return compute_s
        return compute_s / self.speed(site)

    def transfer_s(self, src: int, dst: int, nbytes: int) -> float:
        """Transfer time for nbytes between sites (Table 2 units)."""
        if nbytes <= 0:
            return 0.0
        if src == dst or self.links == "lan":
            bw, lat = LOCAL_BW_MBPS, LOCAL_LAT_MS
        else:
            i, j = src % len(SITES), dst % len(SITES)
            bw = BW_MBPS[i][j] or LOCAL_BW_MBPS
            lat = LAT_MS[i][j] or LOCAL_LAT_MS
            if self.links == "skewed":
                factor = SKEW_LINK_FACTOR[i] * SKEW_LINK_FACTOR[j]
                bw /= factor
                lat *= factor
        bw *= self.bw_scale
        lat *= self.lat_scale
        return lat / 1e3 + (nbytes * 8) / (bw * 1e6)

    def worst_transfer_s(self, nbytes: int) -> float:
        worst = 0.0
        for i in range(len(SITES)):
            for j in range(len(SITES)):
                if i != j:
                    worst = max(worst, self.transfer_s(i, j, nbytes))
        return worst


def estimate_stages(stages: list[list[tuple[float, int, int, int]]], model: GridModel) -> float:
    """Ideal (analytical) execution time of a staged workflow.

    stages: list of stages; each stage is a list of parallel jobs
    (compute_s, input_bytes, output_bytes, site).  Per the paper: overall
    time = Σ_stage max_job (transfer_in + compute + transfer_out),
    transfers measured against the submit site (site 0) and compute
    scaled by the site's speed factor.
    """
    total = 0.0
    for stage in stages:
        worst = 0.0
        for compute_s, in_b, out_b, site in stage:
            t = (
                model.transfer_s(0, site, in_b)
                + model.site_compute_s(site, compute_s)
                + model.transfer_s(site, 0, out_b)
            )
            worst = max(worst, t)
        total += worst
    return total


class JobSpec(NamedTuple):
    """One job of an analytical workflow estimate: the metadata the ideal
    bound needs and nothing else (no callable, no status)."""

    name: str
    deps: tuple[str, ...] = ()
    compute_s: float = 0.0
    input_bytes: int = 0
    output_bytes: int = 0
    site: int = 0


def _topo_fold(specs: list[JobSpec], fold) -> dict:
    """Resolve every spec after its dependencies (iterative DFS — specs
    from the SiteJob builders are topological, but don't rely on it) and
    reduce with ``fold(spec, dep_values) -> value``."""
    by_name = {s.name: s for s in specs}
    out: dict = {}
    for s in specs:
        stack = [s.name]
        while stack:
            n = stack[-1]
            if n in out:
                stack.pop()
                continue
            spec = by_name[n]
            pending = [d for d in spec.deps if d not in out]
            if pending:
                stack.extend(pending)
                continue
            out[n] = fold(spec, [out[d] for d in spec.deps])
            stack.pop()
    return out


def _place_specs(specs: list[JobSpec], model: GridModel, placement) -> list[JobSpec]:
    """Re-site specs under a placement policy (contention-free static
    matchmaking); ``None`` keeps the pre-assigned sites untouched."""
    if placement is None:
        return specs
    from repro.workflow.placement import plan_specs  # import cycle guard

    return plan_specs(specs, model, placement)


def estimate_dag(specs: list[JobSpec], model: GridModel, placement=None) -> float:
    """Ideal (analytical) execution time of a DAG workflow under per-job
    overlap — the async counterpart of ``estimate_stages``.

    Each job costs transfer_in + compute + transfer_out (transfers against
    the submit site, as in the paper; compute scaled by the site's speed
    factor) and starts the instant its last dependency finishes; no
    preparation, submission or slot-contention cost.  The result is the
    critical-path length — a lower bound on any schedule, and the
    baseline against which async-mode recovered overhead is measured.
    With ``placement`` the specs are first re-sited by the policy's
    contention-free plan (placement-aware bound).
    """
    specs = _place_specs(specs, model, placement)

    def finish(spec: JobSpec, dep_finishes: list[float]) -> float:
        ideal = (
            model.transfer_s(0, spec.site, spec.input_bytes)
            + model.site_compute_s(spec.site, spec.compute_s)
            + model.transfer_s(spec.site, 0, spec.output_bytes)
        )
        return max(dep_finishes, default=0.0) + ideal

    return max(_topo_fold(specs, finish).values(), default=0.0)


def estimate_stages_from_specs(specs: list[JobSpec], model: GridModel, placement=None) -> float:
    """The paper's stage-barrier estimate applied to a DAG: jobs are
    grouped into topological waves (longest-path depth) and each wave is a
    stage of ``estimate_stages``.  This is the analytical counterpart of
    the engine's ``schedule="staged"`` mode; the gap to ``estimate_dag``
    is the overhead the barrier itself adds."""
    specs = _place_specs(specs, model, placement)
    depth = _topo_fold(specs, lambda spec, dep_depths: 1 + max(dep_depths, default=-1))
    waves: dict[int, list[tuple[float, int, int, int]]] = {}
    for s in specs:
        waves.setdefault(depth[s.name], []).append(
            (s.compute_s, s.input_bytes, s.output_bytes, s.site)
        )
    return estimate_stages([waves[w] for w in sorted(waves)], model)


def overhead_pct(measured_s: float, estimated_s: float) -> float:
    """Table 3's 'Estimated overhead' column."""
    if measured_s <= 0:
        return 0.0
    return 100.0 * (measured_s - estimated_s) / measured_s
