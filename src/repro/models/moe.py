"""Mixture-of-Experts FFN: top-k routing with capacity-bounded, sort-free
dispatch (per-expert top-C token selection) + optional always-on shared
experts (deepseek-style fine-grained MoE).

Dispatch strategy (TPU-friendly, no ragged ops):
  router probs (T, E) → top-k per token → per-expert token weights (E, T)
  → per-expert top-C token gather into (E, C, D) buffers → batched expert
  einsum → weighted scatter-add back to (T, D).

The (E, C, D) buffer is the unit of expert parallelism: when E divides the
`model` mesh axis the buffer and expert weights shard over experts (true
EP — deepseek 64/16); otherwise expert weights shard over their FFN dim
(TP-within-expert — mixtral 8 on 16).  Both are expressed purely through
the logical-axis rules; the compute code is identical.

Tokens beyond an expert's capacity are dropped (standard capacity-factor
semantics); the router aux/z losses are returned for the train loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import spec
from repro.sharding import constrain


def moe_spec(cfg) -> dict:
    m = cfg.moe
    d = cfg.d_model
    p = {
        "router": spec((d, m.n_experts), ("embed", "experts")),
        "w_gate": spec((m.n_experts, d, m.expert_d_ff), ("experts", "embed", "expert_mlp")),
        "w_up": spec((m.n_experts, d, m.expert_d_ff), ("experts", "embed", "expert_mlp")),
        "w_down": spec((m.n_experts, m.expert_d_ff, d), ("experts", "expert_mlp", "embed")),
    }
    if m.n_shared_experts:
        dsh = m.expert_d_ff * m.n_shared_experts
        p["shared"] = {
            "w_gate": spec((d, dsh), ("embed", "mlp")),
            "w_up": spec((d, dsh), ("embed", "mlp")),
            "w_down": spec((dsh, d), ("mlp", "embed")),
        }
    return p


def _capacity(t: int, m) -> int:
    c = int(t * m.top_k * m.capacity_factor / m.n_experts)
    return min(t, max(8, (c + 7) // 8 * 8))


def apply_moe(cfg, p: dict, x: jax.Array) -> tuple[jax.Array, dict]:
    """x: (B, S, D) -> (B, S, D), aux metrics {aux_loss, z_loss}."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    dt = x.dtype

    logits = (xt.astype(jnp.float32)) @ p["router"].astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, m.top_k)  # (T, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # per-expert token weights (E, T)
    onehot = jax.nn.one_hot(top_i, m.n_experts, dtype=jnp.float32)  # (T, k, E)
    w_te = jnp.einsum("tke,tk->te", onehot, top_p)  # (T, E)
    w_et = w_te.T  # (E, T)

    # aux losses (Switch-style load balancing + router z-loss)
    frac_tokens = jnp.mean(jnp.sum(onehot, axis=1), axis=0)  # (E,)
    frac_probs = jnp.mean(probs, axis=0)
    aux = m.n_experts * jnp.sum(frac_tokens * frac_probs) * m.aux_loss
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * m.router_z_loss

    groups = getattr(cfg, "moe_dispatch_groups", 0)
    if groups and groups > 1 and t % groups == 0:
        # LOCAL dispatch (§Perf): top-C within each token group; groups are
        # aligned with the `data` shards so the gather/scatter is
        # device-local and cross-device movement is only the EP all-to-all.
        tl = t // groups
        cap = _capacity(tl, m)
        w_egt = w_et.reshape(m.n_experts, groups, tl)
        sel_w, sel_idx = jax.lax.top_k(w_egt, cap)  # (E, G, Cl)
        sel_idx = constrain(sel_idx, ("experts", "expert_group", None))
        xt_g = xt.reshape(groups, tl, d)

        take = jax.vmap(lambda xs, ix: jnp.take(xs, ix, axis=0), in_axes=(0, 1), out_axes=1)
        xg = take(xt_g, sel_idx)  # (E, G, Cl, D)
        xg = constrain(xg, ("experts", "expert_group", None, None))

        h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xg, p["w_gate"].astype(dt))) * jnp.einsum(
            "egcd,edf->egcf", xg, p["w_up"].astype(dt)
        )
        ye = jnp.einsum("egcf,efd->egcd", h, p["w_down"].astype(dt))
        ye = constrain(ye, ("experts", "expert_group", None, None))
        ye = ye * sel_w[..., None].astype(dt)

        def scat(ix, val):  # (E, Cl), (E, Cl, D) -> (Tl, D)
            return jnp.zeros((tl, d), dt).at[ix.reshape(-1)].add(val.reshape(-1, d))

        out_g = jax.vmap(scat, in_axes=(1, 1))(sel_idx, ye)  # (G, Tl, D)
        out = constrain(out_g.reshape(t, d), ("flat_tokens", None))
    else:
        # GLOBAL dispatch (baseline): per-expert top-C over all tokens.
        # The (E, C, D) buffer is the EP unit: experts shard over
        # `model`/`expert` (when divisible), capacity over `data`.
        cap = _capacity(t, m)
        sel_w, sel_idx = jax.lax.top_k(w_et, cap)  # (E, C)
        sel_idx = constrain(sel_idx, ("experts", "expert_cap"))
        xg = jnp.take(xt, sel_idx, axis=0)  # (E, C, D)
        xg = constrain(xg, ("experts", "expert_cap", None))

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, p["w_gate"].astype(dt))) * jnp.einsum(
            "ecd,edf->ecf", xg, p["w_up"].astype(dt)
        )
        ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt))  # (E, C, D)
        ye = constrain(ye, ("experts", "expert_cap", None))
        ye = ye * sel_w[..., None].astype(dt)

        out = jnp.zeros((t, d), dt)
        out = out.at[sel_idx.reshape(-1)].add(ye.reshape(-1, d))
        out = constrain(out, ("flat_tokens", None))

    if m.n_shared_experts:
        sh = p["shared"]
        hs = jax.nn.silu(xt @ sh["w_gate"].astype(dt)) * (xt @ sh["w_up"].astype(dt))
        out = out + hs @ sh["w_down"].astype(dt)

    return out.reshape(b, s, d), {"aux_loss": aux, "z_loss": z}
