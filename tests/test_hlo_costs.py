"""HLO cost parser: trip-count multiplication, dot flops, collective
attribution — validated against XLA's own cost_analysis on loop-free
modules and against hand-computed values on scanned ones."""

import jax
import jax.numpy as jnp
import pytest

from repro.compat import cost_analysis_dict
from repro.roofline.analyze import roofline_terms
from repro.roofline.hlo_costs import _parse_replica_groups, analyze_hlo


def compile_text(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return c, c.as_text()


class TestDotFlops:
    def test_single_matmul_matches_xla(self):
        x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        w = jax.ShapeDtypeStruct((128, 32), jnp.float32)
        c, txt = compile_text(lambda a, b: a @ b, x, w)
        res = analyze_hlo(txt)
        assert res.flops == pytest.approx(cost_analysis_dict(c)["flops"], rel=0.01)
        assert res.flops == pytest.approx(2 * 64 * 128 * 32)

    def test_scan_multiplies_by_trip_count(self):
        def scanned(x, ws):
            def body(c, w):
                return c @ w, None
            y, _ = jax.lax.scan(body, x, ws)
            return y

        x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
        ws = jax.ShapeDtypeStruct((10, 64, 64), jnp.float32)
        _, txt = compile_text(scanned, x, ws)
        res = analyze_hlo(txt)
        assert res.flops == pytest.approx(10 * 2 * 32 * 64 * 64, rel=0.05)

    def test_nested_scan_multiplies_product(self):
        def nested(x, ws):
            def outer(c, wpair):
                def inner(c2, w):
                    return c2 @ w, None
                c, _ = jax.lax.scan(inner, c, wpair)
                return c, None
            y, _ = jax.lax.scan(outer, x, ws)
            return y

        x = jax.ShapeDtypeStruct((16, 32), jnp.float32)
        ws = jax.ShapeDtypeStruct((4, 3, 32, 32), jnp.float32)
        _, txt = compile_text(nested, x, ws)
        res = analyze_hlo(txt)
        assert res.flops == pytest.approx(12 * 2 * 16 * 32 * 32, rel=0.05)

    def test_batched_dot_contracting_dims(self):
        def f(a, b):
            return jnp.einsum("bij,bjk->bik", a, b)

        a = jax.ShapeDtypeStruct((8, 16, 32), jnp.float32)
        b = jax.ShapeDtypeStruct((8, 32, 24), jnp.float32)
        c, txt = compile_text(f, a, b)
        res = analyze_hlo(txt)
        assert res.flops == pytest.approx(2 * 8 * 16 * 32 * 24, rel=0.05)


class TestReplicaGroups:
    def test_explicit_braces(self):
        g = _parse_replica_groups("all-reduce(...), replica_groups={{0,1},{2,3}}, x")
        assert g == [[0, 1], [2, 3]]

    def test_iota_form(self):
        g = _parse_replica_groups("all-gather(...), replica_groups=[4,4]<=[16], y")
        assert len(g) == 4 and g[0] == [0, 1, 2, 3]

    def test_iota_transposed(self):
        g = _parse_replica_groups("all-reduce(...), replica_groups=[4,4]<=[4,4]T(1,0), z")
        assert len(g) == 4
        assert g[0] == [0, 4, 8, 12]


class TestRooflineTerms:
    def test_dominant_selection(self):
        hw = {"peak_flops_bf16": 100.0, "hbm_bw": 10.0, "ici_bw": 1.0}
        t = roofline_terms(flops=1000.0, hlo_bytes=10.0, coll_bytes=0.0, chips=1, hw=hw)
        assert t["dominant"] == "compute"
        assert t["roofline_fraction"] == pytest.approx(1.0)
        t2 = roofline_terms(flops=10.0, hlo_bytes=1000.0, coll_bytes=0.0, chips=1, hw=hw)
        assert t2["dominant"] == "memory"
        assert t2["roofline_fraction"] < 0.01
