#!/usr/bin/env python
"""CI gate: every registered workload must be fully specified.

Runs ``repro.workflow.registry.validate_registry`` — param schema with
docs, result schema, digest, runner wiring (grid builders or local_fn),
and valid smoke params for every registered ``WorkloadSpec`` — so an
under-specified workload plugin fails the build instead of a tenant
request.  ``--table`` prints the registry-generated markdown app table
(the README/docs tables are regenerated from it, never hand-edited).

    PYTHONPATH=src python tools/check_registry.py
"""

from __future__ import annotations

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.workflow.registry import (  # noqa: E402 — after sys.path setup
    app_names,
    app_table_markdown,
    conformance_apps,
    validate_registry,
    workloads,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--table", action="store_true",
                    help="print the registry's markdown app table and exit")
    args = ap.parse_args(argv)

    if args.table:
        print(app_table_markdown())
        return 0

    problems = validate_registry()
    for p in problems:
        print(f"check_registry: {p}", file=sys.stderr)
    if problems:
        print(f"check_registry: {len(problems)} problem(s)", file=sys.stderr)
        return 1
    n = len(workloads())
    print(
        f"check_registry: {n} workloads fully specified "
        f"({', '.join(app_names())}); conformance matrix: "
        f"{', '.join(conformance_apps())}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
