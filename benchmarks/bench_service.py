"""Service-level throughput/latency bench: a bursty multi-tenant arrival
trace through the continuous mining service (``repro.launch.serve``).

Where the sweep benches measure ONE application's DAG, this measures the
serving layer itself: request throughput, tenant-visible latency
percentiles (admission to completion, queue wait included), the
versioned cache's hit rate across bursts and data appends, how many
identical concurrent requests coalesced into shared executions, and the
round-robin fairness bound over the pick log.  The trace is the same
seeded burst generator the service CLI drives (shared query per burst ->
coalescing; small param pool -> repeats within a dataset version ->
cache hits; periodic appends -> version bumps -> honest misses).

    PYTHONPATH=src python -m benchmarks.bench_service --smoke --out BENCH_service.json
"""

from __future__ import annotations

import argparse
import json
import time
from types import SimpleNamespace

import numpy as np

from repro.launch.serve import _build_service, _trace_bursts, fairness_violations
from repro.workflow.requests import QueueFullError


def run(
    backend: str = "batched",
    requests: int = 50,
    tenants: int = 3,
    burst: int = 4,
    n_sites: int = 4,
    n_items: int = 12,
    append_every: int = 2,
    max_per_step: int = 8,
    seed: int = 0,
    out: str | None = None,
) -> dict:
    args = SimpleNamespace(
        backend=backend, requests=requests, tenants=tenants, burst=burst,
        n_sites=n_sites, n_items=n_items, seed=seed, max_depth=256,
    )
    rng = np.random.default_rng(seed)
    svc = _build_service(args)
    tenant_names = [f"tenant{i}" for i in range(tenants)]
    bursts = _trace_bursts(args, rng)

    from repro.data.synthetic import gaussian_mixture, ibm_transactions

    rejected = 0
    t0 = time.perf_counter()
    for b, burst_reqs in enumerate(bursts):
        for tenant, app, dataset, params in burst_reqs:
            try:
                svc.submit(tenant, app, dataset, params)
            except QueueFullError:
                rejected += 1
        svc.drain(max_requests=max_per_step)
        if append_every and (b + 1) % append_every == 0:
            svc.append_transactions("tx", ibm_transactions(seed + b + 1, 60, n_items))
            pts, _ = gaussian_mixture(seed + b + 1, 60, 2, 3)
            svc.append_points("pts", pts)
    wall = time.perf_counter() - t0

    led = svc.ledger()
    done = [r for r in led["requests"] if r["status"] == "done"]
    lat = np.array([r["service_s"] for r in done]) if done else np.zeros(1)
    waits = np.array([r["queue_wait_s"] for r in done]) if done else np.zeros(1)
    fairness_ok = not fairness_violations(
        svc.pick_log, tenant_names, len(tenant_names) * min(
            sum(1 for r in led["requests"] if r["tenant"] == t) for t in tenant_names))

    report = {
        "backend": led["backend"],
        "n_sites": n_sites,
        "tenants": tenants,
        "requests": len(led["requests"]),
        "done": len(done),
        "failed": sum(1 for r in led["requests"] if r["status"] == "failed"),
        "rejected": led["rejected"] + rejected,
        "wall_s": wall,
        "throughput_rps": len(done) / max(wall, 1e-9),
        "latency_ms": {
            "p50": float(np.percentile(lat, 50) * 1e3),
            "p90": float(np.percentile(lat, 90) * 1e3),
            "p95": float(np.percentile(lat, 95) * 1e3),
            "max": float(lat.max() * 1e3),
        },
        "queue_wait_ms_mean": float(waits.mean() * 1e3),
        "cache": led["cache"],
        "executions": led["executions"],
        "coalesced": led["coalesced"],
        "fairness_ok": bool(fairness_ok),
        "per_tenant": led["per_tenant"],
    }

    print(f"# mining service, {tenants} tenants x bursty trace, backend={report['backend']}")
    print("requests,done,throughput_rps,p50_ms,p95_ms,hit_rate,coalesced,fair")
    print(
        f"{report['requests']},{report['done']},{report['throughput_rps']:.2f},"
        f"{report['latency_ms']['p50']:.0f},{report['latency_ms']['p95']:.0f},"
        f"{report['cache']['hit_rate']:.2f},{report['coalesced']},"
        f"{'yes' if fairness_ok else 'NO'}"
    )
    if out:
        with open(out, "w") as fh:
            json.dump(report, fh, indent=2, sort_keys=True, default=float)
        print(f"# wrote {out}")
    return report


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="batched", choices=("inline", "batched", "multihost"))
    ap.add_argument("--requests", type=int, default=50)
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--burst", type=int, default=4)
    ap.add_argument("--n-sites", type=int, default=4)
    ap.add_argument("--n-items", type=int, default=12)
    ap.add_argument("--append-every", type=int, default=2)
    ap.add_argument("--max-per-step", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="small trace for CI (fewer requests, tiny data)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    kw = dict(
        backend=args.backend, requests=args.requests, tenants=args.tenants,
        burst=args.burst, n_sites=args.n_sites, n_items=args.n_items,
        append_every=args.append_every, max_per_step=args.max_per_step,
        seed=args.seed, out=args.out,
    )
    if args.smoke:
        # one dataset version across the whole trace (append_every=3 >
        # burst count) so the repeated param pool demonstrably hits
        kw.update(requests=18, n_sites=2, n_items=10, burst=3, append_every=3)
    run(**kw)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
