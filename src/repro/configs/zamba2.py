"""zamba2-1.2b [hybrid] — Mamba2 backbone + weight-shared attention block
interleaved every 6 layers [arXiv:2411.15242].

Layout: 38 mamba2 layers = 6 scan groups of 6 (each preceded by the shared
attention+FFN block) + 2 static tail layers.
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=32000,
    layer_pattern=("mamba2",) * 6,
    shared_attn_every=6,
    ssm=SSMConfig(d_state=64, expand=2, d_conv=4, head_dim=64, chunk=128),
    norm="rmsnorm",
    act="swiglu",
    subquadratic=True,
)
