"""xLSTM blocks: mLSTM (matrix memory — chunked linear recurrence on the
same gated-outer-scan primitive as Mamba-2) and sLSTM (scalar memory with
recurrent gate connections — inherently sequential, evaluated with
``lax.scan`` over time).

Numerics note (recorded in DESIGN.md): the original xLSTM uses exponential
input gates with max-stabiliser bookkeeping; we use sigmoid input gates +
the mLSTM normaliser channel, which keeps every exp() ≤ 1 (fp32-stable in
the chunked form) while preserving the structure, parameter count and FLOP
profile.  The normaliser n_t = f·n_{t-1} + i·k_t is carried as one extra
v-channel of the same outer-product recurrence, so y = (q·C)/max(|q·n|,1)
costs a single augmented scan.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm, spec
from repro.models.ssm import gated_outer_scan, gated_outer_step
from repro.sharding import constrain


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def _mlstm_dims(cfg):
    d_in = 2 * cfg.d_model  # proj factor 2
    h = cfg.n_heads
    p = d_in // h  # value head dim
    n = max(p // 2, 8)  # qk head dim (xLSTM: qk = v/2)
    return d_in, h, p, n


def mlstm_spec(cfg) -> dict:
    """Parameters per mLSTM block (matches the published 1.3B budget):
    a fused up-projection d -> 2*d_in (x_in and gate z) and BLOCK-DIAGONAL
    per-head q/k/v over the inner heads (xLSTM's block-diagonal qkv)."""
    d = cfg.d_model
    d_in, h, p, n = _mlstm_dims(cfg)
    return {
        "w_in": spec((d, 2 * d_in), ("embed", "mlstm_inner")),
        "w_q": spec((h, p, n), ("heads", "mlstm_p", None)),
        "w_k": spec((h, p, n), ("heads", "mlstm_p", None)),
        "w_v": spec((h, p, p), ("heads", "mlstm_p", None)),
        "w_if": spec((d_in, h, 2), ("mlstm_inner", "heads", None)),
        "if_bias": spec((h, 2), ("heads", None)),
        "out_norm": {"scale": spec((d_in,), ("norm_scale",))},
        "w_out": spec((d_in, d), ("mlstm_inner", "embed")),
    }


def _mlstm_qkvg(cfg, p_, x):
    dt = x.dtype
    b, s, _ = x.shape
    d_in, h, p, n = _mlstm_dims(cfg)
    up = constrain(x @ p_["w_in"].astype(dt), ("batch", "seq", "mlstm_inner"))  # (B,S,2*d_in)
    xi, z = up[..., :d_in], up[..., d_in:]
    xh = xi.reshape(b, s, h, p)  # per-head view for block-diagonal qkv
    q = jnp.einsum("bshp,hpn->bshn", xh, p_["w_q"].astype(dt)) / jnp.sqrt(float(n))
    k = jnp.einsum("bshp,hpn->bshn", xh, p_["w_k"].astype(dt)) / jnp.sqrt(float(n))
    v = jnp.einsum("bshp,hpq->bshq", xh, p_["w_v"].astype(dt))
    gates = jnp.einsum("bsd,dhg->bshg", xi, p_["w_if"].astype(dt)).astype(jnp.float32)
    gates = gates + p_["if_bias"].astype(jnp.float32)[None, None]
    i_gate = jax.nn.sigmoid(gates[..., 0])  # (B,S,H)
    log_f = jax.nn.log_sigmoid(gates[..., 1])  # ≤ 0
    return z, q, k, v, i_gate, log_f


def _mlstm_readout(cfg, p_, y_aug, z, b, s):
    # y_aug: (B,S,H,P+1) — last channel is the normaliser q·n
    y = y_aug[..., :-1]
    denom = jnp.maximum(jnp.abs(y_aug[..., -1:]), 1.0)
    y = (y / denom).reshape(b, s, -1)
    y = rms_norm(y, p_["out_norm"]["scale"]) * jax.nn.silu(z)
    return y @ p_["w_out"].astype(z.dtype)


def apply_mlstm(cfg, p_: dict, x: jax.Array, h0=None, chunk: int = 128):
    """Full-sequence mLSTM mixer.  Returns (y (B,S,D), cache {h})."""
    b, s, d = x.shape
    z, q, k, v, i_gate, log_f = _mlstm_qkvg(cfg, p_, x)
    ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
    v_aug = jnp.concatenate([v, ones], axis=-1)  # normaliser channel
    y_aug, h_fin = gated_outer_scan(log_f, i_gate, k, v_aug, q, h0=h0, chunk=chunk)
    return _mlstm_readout(cfg, p_, y_aug, z, b, s), {"h": h_fin}


def mlstm_decode(cfg, p_: dict, x: jax.Array, cache: dict):
    b, _, d = x.shape
    z, q, k, v, i_gate, log_f = _mlstm_qkvg(cfg, p_, x)
    ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
    v_aug = jnp.concatenate([v, ones], axis=-1)
    y_aug, hnew = gated_outer_step(
        log_f[:, 0], i_gate[:, 0], k[:, 0], v_aug[:, 0], q[:, 0], cache["h"]
    )
    out = _mlstm_readout(cfg, p_, y_aug[:, None], z, b, 1)
    return out, {"h": hnew}


def mlstm_cache_spec(cfg, batch: int) -> dict:
    d_in, h, p, n = _mlstm_dims(cfg)
    return {
        "h": spec((batch, h, n, p + 1), ("batch", "heads", "mlstm_qk", None), cfg.dtype),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_spec(cfg) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    p = d // h
    return {
        "w": spec((d, h, 4 * p), ("embed", "heads", None)),  # z,i,f,o stacked
        "r": spec((h, p, 4 * p), ("heads", "slstm_p", None)),  # block-diag recurrence
        "bias": spec((h, 4 * p), ("heads", None)),
        "out_norm": {"scale": spec((d,), ("norm_scale",))},
        "w_out": spec((d, d), ("embed", "embed")),
    }


def _slstm_cell(p_, wx_t, state):
    """One timestep.  wx_t: (B,H,4P) pre-computed input projection."""
    c, n, hid = state  # each (B,H,P)
    rec = jnp.einsum("bhp,hpq->bhq", hid, p_["r"].astype(hid.dtype))
    g = (wx_t + rec + p_["bias"].astype(wx_t.dtype)[None]).astype(jnp.float32)
    pdim = g.shape[-1] // 4
    z = jnp.tanh(g[..., :pdim])
    i = jax.nn.sigmoid(g[..., pdim : 2 * pdim])
    f = jax.nn.sigmoid(g[..., 2 * pdim : 3 * pdim])
    o = jax.nn.sigmoid(g[..., 3 * pdim :])
    c = f * c.astype(jnp.float32) + i * z
    n = f * n.astype(jnp.float32) + i
    hid_new = o * c / jnp.maximum(n, 1.0)
    dt = wx_t.dtype
    return (c.astype(dt), n.astype(dt), hid_new.astype(dt))


def apply_slstm(cfg, p_: dict, x: jax.Array, state0=None):
    """Sequential sLSTM over the sequence.  Returns (y (B,S,D), cache).

    With cfg.slstm_kernel=True the recurrence runs in the Pallas kernel
    (`kernels/slstm_cell.py`) that pins R in VMEM across timesteps —
    ~170x less HBM traffic than the XLA per-step path (§Perf); off by
    default because Mosaic cannot lower in the CPU dry-run."""
    b, s, d = x.shape
    h = cfg.n_heads
    pdim = d // h
    wx = jnp.einsum("bsd,dhq->bshq", x, p_["w"].astype(x.dtype))  # (B,S,H,4P)
    if state0 is None:
        zero = jnp.zeros((b, h, pdim), x.dtype)
        state0 = (zero, zero, zero)

    if getattr(cfg, "slstm_kernel", False):
        from repro.kernels import ops

        hids_bshp, state = ops.slstm_scan(wx, p_["r"], p_["bias"], state0)
        y = hids_bshp.reshape(b, s, d)
    else:
        def body(st, wx_t):
            new = _slstm_cell(p_, wx_t, st)
            return new, new[2]

        state, hids = jax.lax.scan(body, state0, jnp.moveaxis(wx, 1, 0))
        y = jnp.moveaxis(hids, 0, 1).reshape(b, s, d)
    y = rms_norm(y, p_["out_norm"]["scale"])
    out = y @ p_["w_out"].astype(x.dtype)
    return out, {"c": state[0], "n": state[1], "hid": state[2]}


def slstm_decode(cfg, p_: dict, x: jax.Array, cache: dict):
    b, _, d = x.shape
    wx = jnp.einsum("bsd,dhq->bshq", x, p_["w"].astype(x.dtype))[:, 0]
    state = (cache["c"], cache["n"], cache["hid"])
    c, n, hid = _slstm_cell(p_, wx, state)
    y = rms_norm(hid.reshape(b, 1, d), p_["out_norm"]["scale"])
    out = y @ p_["w_out"].astype(x.dtype)
    return out, {"c": c, "n": n, "hid": hid}


def slstm_cache_spec(cfg, batch: int) -> dict:
    h = cfg.n_heads
    pdim = cfg.d_model // h
    ax = ("batch", "heads", None)
    return {
        "c": spec((batch, h, pdim), ax, cfg.dtype),
        "n": spec((batch, h, pdim), ax, cfg.dtype),
        "hid": spec((batch, h, pdim), ax, cfg.dtype),
    }
