"""Version compatibility for the jax API surface this repo rides on.

The repo targets current jax (``jax.shard_map``, ``AbstractMesh(axis_sizes,
axis_names)``, dict-returning ``Compiled.cost_analysis``) but must also run
on the 0.4.x line baked into the CI/dev containers, where those entry
points live elsewhere or return different shapes.  Everything
version-sensitive is funnelled through here so the rest of the codebase
stays on the modern spelling.
"""

from __future__ import annotations

import pickle
from typing import Any

import jax
import numpy as np


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` (new) / ``jax.experimental.shard_map`` (0.4.x).

    The replication-checking kwarg was renamed check_rep -> check_vma; we
    accept the new name and translate.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )


def get_abstract_mesh():
    """``jax.sharding.get_abstract_mesh`` where it exists, else None.

    Callers treat None as "no abstract-mesh tracking" and fall back to the
    concrete context mesh (the pre-abstract-mesh behaviour).
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    return fn() if fn is not None else None


def abstract_mesh(axis_sizes: tuple[int, ...], axis_names: tuple[str, ...]):
    """``AbstractMesh(axis_sizes, axis_names)``; 0.4.x wants one tuple of
    (name, size) pairs."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def _leaf_to_host(leaf: Any) -> Any:
    """Pytree-leaf normalization for the cross-process wire: committed
    jax Arrays become host numpy (device/sharding state does not survive a
    pickle across ``jax.distributed`` processes on every jax line this repo
    rides — and the receiver wants host data anyway); every other leaf
    (ints, dicts-as-leaves, dataclasses) passes through to pickle."""
    if isinstance(leaf, jax.Array):
        return np.asarray(leaf)
    return leaf


def pack_payload(obj: Any) -> bytes:
    """Serialize an arbitrary SiteJob result for ``process_allgather``
    shipping: jax array leaves are pulled to host numpy via ``tree_map``
    (NamedTuples like SuffStats/MergeResult/TimedResult and ordinary
    list/tuple/dict containers are traversed; unregistered objects such as
    itemset-count dicts inside LocalMineResult are pickled whole), then the
    whole tree is pickled.  The inverse is :func:`unpack_payload`.

    Note dict keys are re-ordered by jax's tree flattening (sorted) — all
    consumers in this repo are key-lookup/sort-before-iterate, so the
    round-trip is value-identical.
    """
    host = jax.tree_util.tree_map(_leaf_to_host, obj)
    return pickle.dumps(host, protocol=pickle.HIGHEST_PROTOCOL)


def unpack_payload(data: bytes) -> Any:
    """Deserialize a :func:`pack_payload` wire payload.  Array leaves come
    back as host numpy — bit-identical values; downstream jnp ops accept
    them transparently."""
    return pickle.loads(data)


def cost_analysis_dict(compiled) -> dict[str, Any]:
    """``Compiled.cost_analysis()`` as a flat dict.

    Old jaxlib returns a one-element list of dicts (one per computation);
    new jax returns the dict directly; either may be empty/None.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})
