"""compare_baseline: the CI perf-regression gate's decision logic on
synthetic sweep payloads (no jax, no benchmark run)."""

import copy
import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))
from benchmarks.compare_baseline import compare  # noqa: E402


def payload():
    cell = {
        "app": "gfm",
        "n_sites": 4,
        "links": "grid5000",
        "compute_scale": 1,
        "schedule": "staged",
        "wall_s": 325.0,
        "overhead_pct": 99.9,
        "prep_s": 295.0,
        "submit_s": 30.0,
        "transfer_s": 1.5,
    }
    acell = dict(cell, schedule="async", wall_s=307.0, submit_s=30.0)
    return {
        "cells": [cell, acell],
        "comparisons": [
            {
                "app": "gfm",
                "n_sites": 4,
                "links": "grid5000",
                "compute_scale": 1,
                "wall_staged_s": 325.0,
                "wall_async_s": 307.0,
            }
        ],
    }


class TestCompare:
    def test_identical_passes(self):
        failures, notes = compare(payload(), payload())
        assert failures == [] and notes == []

    def test_simulated_component_regression_fails(self):
        cand = payload()
        cand["cells"][0]["submit_s"] *= 1.10  # > 1% on a simulated component
        failures, _ = compare(payload(), cand)
        assert any("submit_s" in f for f in failures)

    def test_wall_within_band_passes(self):
        cand = payload()
        cand["cells"][0]["wall_s"] *= 1.10  # within the 30% wall band
        failures, _ = compare(payload(), cand)
        assert failures == []

    def test_wall_regression_fails(self):
        cand = payload()
        cand["cells"][0]["wall_s"] *= 1.50
        failures, _ = compare(payload(), cand)
        assert any("wall_s" in f for f in failures)

    def test_improvement_is_note_not_failure(self):
        cand = payload()
        cand["cells"][0]["wall_s"] *= 0.5
        cand["cells"][0]["submit_s"] *= 0.5
        failures, notes = compare(payload(), cand)
        assert failures == []
        assert any("refresh the baseline" in n for n in notes)

    def test_missing_cell_fails(self):
        cand = copy.deepcopy(payload())
        cand["cells"] = cand["cells"][:1]
        failures, _ = compare(payload(), cand)
        assert any("missing" in f for f in failures)

    def test_async_invariant_violation_fails(self):
        cand = payload()
        cand["comparisons"][0]["wall_async_s"] = 340.0
        failures, _ = compare(payload(), cand)
        assert any("invariant" in f for f in failures)

    def test_missing_comparisons_fail(self):
        """A candidate that silently drops its comparison rows must not
        pass with the invariant untested."""
        cand = payload()
        cand["comparisons"] = []
        failures, _ = compare(payload(), cand)
        assert any("comparison row missing" in f for f in failures)

    def test_overhead_pct_band(self):
        cand = payload()
        cand["cells"][0]["overhead_pct"] = 99.9 + 6.0  # beyond 5-point band
        failures, _ = compare(payload(), cand)
        assert any("overhead_pct" in f for f in failures)

    def test_overhead_pct_not_gated_at_scaled_cells(self):
        """Compute-scale multipliers amplify calibration noise in
        overhead_pct; only the x1 cells are banded."""
        base, cand = payload(), payload()
        for p in (base, cand):
            for cell in p["cells"]:
                cell["compute_scale"] = 50
            p["comparisons"][0]["compute_scale"] = 50
        cand["cells"][0]["overhead_pct"] = 99.9 + 6.0
        failures, _ = compare(base, cand)
        assert failures == []
