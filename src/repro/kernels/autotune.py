"""Deterministic block-size autotuner for the Pallas mining kernels.

The two hot kernels — ``support_count_pallas`` (grid over N x C tiles)
and ``kmeans_assign_pallas`` (grid over N tiles) — ship with block sizes
that are educated guesses (512x512 and 256).  The right tile depends on
the padded input shape, the dtype, and the platform actually executing
(TPU Mosaic vs the CPU interpreter), none of which the call site knows.
This module closes that gap:

  * a small **candidate lattice** per kernel, filtered to VMEM-feasible
    configs for the given shape (the kernels' documented per-program
    footprint formulas, against a conservative half-VMEM budget) and to
    blocks that do not grossly over-pad the real extent;
  * each surviving config is **timed with the benchmark discipline**
    (median of ``repeats`` after ``warmup`` discarding compile, exactly
    ``benchmarks.common.timeit``'s shape) on the real padded inputs;
  * the winner is **memoized in-process** keyed by ``(kernel, padded
    shape, dtype, platform)`` — padded to the 128-lane granularity, so
    every shape that tiles identically shares one search;
  * the table can be **persisted/loaded as JSON** so CI and the serving
    layer reuse tuning instead of re-searching.

Determinism + safety contract: candidates are enumerated in a fixed
order, the DEFAULT config is always searched, and it stays the winner
unless a candidate beats it by more than ``MARGIN`` (2%) — so a tuned
config is never a noise artifact that loses to the default.  Block size
never changes *results* (the padding semantics are part of each kernel's
contract, property-tested in ``tests/test_autotune.py``), so autotuning
changes speed and nothing else.

The :mod:`repro.kernels.ops` wrappers consult this module when called
with ``block="auto"`` (or when the module default is flipped via
``ops.set_default_block`` / ``REPRO_KERNEL_BLOCKS=auto``).  Under a jit
trace timing is impossible, so tracing callers get the memoized winner
when one exists and the default config otherwise — tune eagerly (or load
a persisted table) first to feed jitted paths like ``core.kmeans``.
"""

from __future__ import annotations

import json
import os
import time
from collections.abc import Callable

import jax

from repro.kernels import pad_to

# the hard-coded guesses the kernels shipped with — always searched, and
# kept unless a candidate is a real (beyond-noise) improvement
DEFAULT_SUPPORT_BLOCKS = (512, 512)
DEFAULT_KMEANS_BLOCK = 256

# conservative per-program VMEM budget: half the ~16 MB core so the
# pipelined double-buffering of the next block always has headroom
VMEM_BUDGET_BYTES = 8 * 1024 * 1024

# full candidate lattice (lane-aligned; the min-tile rules keep every
# entry a multiple of the 128 lane width)
_LATTICE = (128, 256, 512, 1024)
# tiny lattice for --smoke / CI: default + one alternative per axis, so
# the search path is exercised every PR without costing a real sweep
_SMOKE_LATTICE = (256, 512)

MARGIN = 0.02  # a candidate must beat the default by > 2% to replace it

_smoke_default = os.environ.get("REPRO_AUTOTUNE_SMOKE", "") not in ("", "0")

# in-process memo: key tuple -> entry dict (see _entry below)
_cache: dict[tuple, dict] = {}
_hits = 0
_misses = 0


def set_smoke(on: bool) -> bool:
    """Flip the module-wide tiny-lattice mode (returns the previous
    value).  Also settable via ``REPRO_AUTOTUNE_SMOKE=1``."""
    global _smoke_default
    prev = _smoke_default
    _smoke_default = bool(on)
    return prev


def clear_cache() -> None:
    """Drop every memoized winner (tests / fresh searches)."""
    global _hits, _misses
    _cache.clear()
    _hits = 0
    _misses = 0


def cache_stats() -> dict:
    """{'entries': n, 'hits': h, 'misses': m} for the in-process memo."""
    return {"entries": len(_cache), "hits": _hits, "misses": _misses}


def _platform(interpret: bool) -> str:
    return jax.default_backend() + ("+interpret" if interpret else "")


def _timeit(fn: Callable[[], object], repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds — the ``benchmarks.common.timeit`` discipline
    (warmup runs absorb compilation; the median damps host noise)."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


# ---------------------------------------------------------------------------
# Candidate lattices (VMEM feasibility from the kernels' footprint docs)
# ---------------------------------------------------------------------------


def support_count_vmem(w: int, block_n: int, block_c: int) -> int:
    """Per-program bytes of ``support_count_pallas``: the (W, TN) tx
    block + (W, TC) mask block + the (TN, TC) hit tile, all 4-byte."""
    return 4 * (w * (block_n + block_c) + block_n * block_c)


def kmeans_assign_vmem(d: int, k: int, block_n: int) -> int:
    """Per-program bytes of ``kmeans_assign_pallas``: the (TN, D) point
    block + full (K, D) center set + the (TN, K) distance tile (f32)."""
    return 4 * (block_n * d + k * d + block_n * k)


def _axis_candidates(extent: int, lattice: tuple[int, ...]) -> list[int]:
    """Lattice values that do not grossly over-pad ``extent``: a block
    must not more than double the 128-padded extent (the smallest
    lattice value is always kept so every shape has a candidate)."""
    ceil = pad_to(max(extent, 1), 128)
    keep = [b for b in lattice if b < 2 * ceil]
    return keep or [min(lattice)]


def support_count_candidates(
    w: int, n: int, c: int, smoke: bool | None = None
) -> list[tuple[int, int]]:
    """Deterministically-ordered (block_n, block_c) candidates for one
    padded support-count shape: default first, then the VMEM-feasible,
    non-over-padding lattice points in fixed order."""
    lattice = _SMOKE_LATTICE if (smoke if smoke is not None else _smoke_default) else _LATTICE
    out = [DEFAULT_SUPPORT_BLOCKS]
    for bn in _axis_candidates(n, lattice):
        for bc in _axis_candidates(c, lattice):
            cfg = (bn, bc)
            if cfg in out:
                continue
            if support_count_vmem(w, bn, bc) <= VMEM_BUDGET_BYTES:
                out.append(cfg)
    return out


def kmeans_assign_candidates(
    n: int, d: int, k: int, smoke: bool | None = None
) -> list[int]:
    """Deterministically-ordered block_n candidates for one padded
    kmeans-assign shape (default first)."""
    lattice = _SMOKE_LATTICE if (smoke if smoke is not None else _smoke_default) else _LATTICE
    out = [DEFAULT_KMEANS_BLOCK]
    for bn in _axis_candidates(n, lattice):
        if bn not in out and kmeans_assign_vmem(d, k, bn) <= VMEM_BUDGET_BYTES:
            out.append(bn)
    return out


# ---------------------------------------------------------------------------
# Keys + the search itself
# ---------------------------------------------------------------------------


def support_count_key(w: int, n: int, c: int, dtype, interpret: bool) -> tuple:
    """Memo key for a support-count shape.  N/C are padded to the 128
    granularity: every lattice block is a multiple of 128, so two shapes
    sharing this key pad to identical extents under EVERY candidate and
    therefore share one performance profile."""
    return (
        "support_count",
        (int(w), pad_to(max(int(n), 1), 128), pad_to(max(int(c), 1), 128)),
        str(dtype),
        _platform(interpret),
    )


def kmeans_assign_key(n: int, d: int, k: int, dtype, interpret: bool) -> tuple:
    """Memo key for a kmeans-assign shape (D/K arrive lane-padded from
    the ops wrapper; N is padded to the 128 granularity here)."""
    return (
        "kmeans_assign",
        (pad_to(max(int(n), 1), 128), int(d), int(k)),
        str(dtype),
        _platform(interpret),
    )


def _entry(kernel: str, key: tuple, config, timings: dict) -> dict:
    """One tuned-table entry.  ``config`` is the winner; ``timings`` maps
    the stringified config to its median seconds (default included)."""
    default = DEFAULT_SUPPORT_BLOCKS if kernel == "support_count" else DEFAULT_KMEANS_BLOCK
    return {
        "kernel": kernel,
        "shape": list(key[1]),
        "dtype": key[2],
        "platform": key[3],
        "config": list(config) if isinstance(config, tuple) else config,
        "config_default": list(default) if isinstance(default, tuple) else default,
        "seconds_tuned": timings[str(config)],
        "seconds_default": timings[str(default)],
        "timings": timings,
    }


def _pick(timed: list[tuple[object, float]]) -> object:
    """The winner of one search: the fastest config, except the default
    (always ``timed[0]``) is kept unless a candidate beats it by more
    than ``MARGIN`` — ties and noise never dethrone the default."""
    default_cfg, default_t = timed[0]
    best_cfg, best_t = min(timed, key=lambda ct: ct[1])
    if best_t >= default_t * (1.0 - MARGIN):
        return default_cfg
    return best_cfg


def lookup(key: tuple):
    """The memoized winner for ``key`` or None — the only autotune entry
    point legal under a jit trace (no timing, just the table)."""
    ent = _cache.get(key)
    return None if ent is None else _config_of(ent)


def _config_of(ent: dict):
    cfg = ent["config"]
    return tuple(cfg) if isinstance(cfg, list) else cfg


def tune_support_count(
    tx_t: jax.Array,  # (W, N) int32 — the kernel-layout transactions
    masks_t: jax.Array,  # (W, C) int32
    interpret: bool = False,
    smoke: bool | None = None,
) -> dict:
    """Search (block_n, block_c) for this support-count shape; returns
    the full tuned-table entry (``entry['config']`` is the winner).
    Memoized: the second call with an equivalently-padded shape is a
    cache hit and runs nothing."""
    global _hits, _misses
    from repro.kernels.support_count import support_count_pallas

    w, n = tx_t.shape
    _, c = masks_t.shape
    key = support_count_key(w, n, c, tx_t.dtype, interpret)
    if key in _cache:
        _hits += 1
        return _cache[key]
    _misses += 1
    timings: dict[str, float] = {}
    timed: list[tuple[tuple[int, int], float]] = []
    for bn, bc in support_count_candidates(w, n, c, smoke=smoke):
        t = _timeit(
            lambda bn=bn, bc=bc: jax.block_until_ready(
                support_count_pallas(tx_t, masks_t, block_n=bn, block_c=bc, interpret=interpret)
            )
        )
        timings[str((bn, bc))] = t
        timed.append(((bn, bc), t))
    ent = _entry("support_count", key, _pick(timed), timings)
    _cache[key] = ent
    return ent


def tune_kmeans_assign(
    x: jax.Array,  # (N, D) f32, D lane-padded
    centers: jax.Array,  # (K, D) f32, K lane-padded + BIG sentinel rows
    interpret: bool = False,
    smoke: bool | None = None,
) -> dict:
    """Search block_n for this kmeans-assign shape; returns the full
    tuned-table entry.  Memoized like :func:`tune_support_count`."""
    global _hits, _misses
    from repro.kernels.kmeans_assign import kmeans_assign_pallas

    n, d = x.shape
    k, _ = centers.shape
    key = kmeans_assign_key(n, d, k, x.dtype, interpret)
    if key in _cache:
        _hits += 1
        return _cache[key]
    _misses += 1
    timings: dict[str, float] = {}
    timed: list[tuple[int, float]] = []
    for bn in kmeans_assign_candidates(n, d, k, smoke=smoke):
        t = _timeit(
            lambda bn=bn: jax.block_until_ready(
                kmeans_assign_pallas(x, centers, block_n=bn, interpret=interpret)
            )
        )
        timings[str(bn)] = t
        timed.append((bn, t))
    ent = _entry("kmeans_assign", key, _pick(timed), timings)
    _cache[key] = ent
    return ent


# ---------------------------------------------------------------------------
# Persisted tuned tables (JSON) — CI artifacts + serving reuse
# ---------------------------------------------------------------------------


def _key_of(ent: dict) -> tuple:
    return (ent["kernel"], tuple(ent["shape"]), ent["dtype"], ent["platform"])


def save_table(path: str) -> int:
    """Write every memoized entry as a JSON tuned table; returns the
    entry count.  The file is the CI artifact and the reuse seam: load
    it at process start and every covered shape skips its search."""
    entries = [_cache[k] for k in sorted(_cache)]
    with open(path, "w") as fh:
        json.dump({"version": 1, "entries": entries}, fh, indent=2, sort_keys=True)
    return len(entries)


def load_table(path: str, replace: bool = False) -> int:
    """Merge (or, with ``replace=True``, reset to) a persisted tuned
    table; returns the number of entries loaded.  Entries round-trip
    exactly — ``save_table`` then ``load_table`` reproduces the memo."""
    with open(path) as fh:
        data = json.load(fh)
    if replace:
        clear_cache()
    n = 0
    for ent in data.get("entries", []):
        _cache[_key_of(ent)] = ent
        n += 1
    return n
