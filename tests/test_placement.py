"""Placement policies (the Condor matchmaking analogue): unit behavior of
each policy, threading through Engine/GridRuntime, the placement-aware
analytical bounds, and the GridModel heterogeneity knobs they rely on
(per-site speed factors, skewed links, transfer edge cases)."""

import pytest

from repro.workflow.dag import DAG, TimedResult
from repro.workflow.engine import Engine
from repro.workflow.overhead import (
    SKEW_SITE_SPEED,
    GridModel,
    JobSpec,
    estimate_dag,
    estimate_stages_from_specs,
)
from repro.workflow.placement import (
    POLICIES,
    FixedPlacement,
    GreedyEtaPlacement,
    PlacementRequest,
    RandomPlacement,
    RoundRobinPlacement,
    plan_specs,
    resolve_placement,
)

ZERO = dict(prep_latency_s=0, submit_latency_s=0)


def sim(value=None):
    return lambda *a: TimedResult(value, 0.0)


def request(model=None, site=3, **kw):
    kw.setdefault("name", "j")
    kw.setdefault("fixed_site", site)
    kw.setdefault("input_bytes", 0)
    kw.setdefault("output_bytes", 0)
    kw.setdefault("expected_compute_s", 1.0)
    kw.setdefault("now", 0.0)
    kw.setdefault("model", model or GridModel(**ZERO))
    kw.setdefault("sites", list(range(5)))
    kw.setdefault("workers", 2)
    return PlacementRequest(**kw)


class TestPolicies:
    def test_resolve_by_name_and_instance(self):
        for name in POLICIES:
            assert resolve_placement(name).name == name
        pol = RandomPlacement(seed=7)
        assert resolve_placement(pol) is pol
        assert resolve_placement(None).name == "fixed"

    def test_unknown_policy_rejected(self):
        with pytest.raises(ValueError, match="unknown placement"):
            resolve_placement("best_effort")
        with pytest.raises(ValueError, match="unknown placement"):
            Engine(placement="best_effort")

    def test_fixed_echoes_preassigned_site(self):
        assert FixedPlacement().place(request(site=4)) == 4
        # fixed keeps exactly the pre-assigned site universe
        assert FixedPlacement().candidate_sites([2, 2, 0, 2], GridModel(**ZERO)) == [2, 0]

    def test_round_robin_cycles_and_resets(self):
        pol = RoundRobinPlacement()
        got = [pol.place(request(sites=[0, 1, 2])) for _ in range(5)]
        assert got == [0, 1, 2, 0, 1]
        pol.reset()
        assert pol.place(request(sites=[0, 1, 2])) == 0

    def test_random_is_seeded_and_in_range(self):
        a = RandomPlacement(seed=3)
        b = RandomPlacement(seed=3)
        sites = list(range(5))
        got_a = [a.place(request(sites=sites)) for _ in range(20)]
        got_b = [b.place(request(sites=sites)) for _ in range(20)]
        assert got_a == got_b
        assert all(s in sites for s in got_a)
        a.reset()
        assert [a.place(request(sites=sites)) for _ in range(20)] == got_a

    def test_greedy_prefers_fast_site(self):
        # site 3 computes 1.5x faster on the skewed grid; with no load or
        # staging the ETA is pure compute
        model = GridModel(**ZERO, links="lan", site_speed=SKEW_SITE_SPEED)
        assert GreedyEtaPlacement().place(request(model=model)) == 3

    def test_greedy_avoids_busy_site(self):
        # all slots at the otherwise-best site are busy far into the
        # future -> the matchmaker goes elsewhere
        model = GridModel(**ZERO, links="lan", site_speed=SKEW_SITE_SPEED)
        req = request(
            model=model,
            site_busy={3: 2},
            busy_until={3: [100.0, 200.0]},
            service_est_s=1.0,
        )
        assert GreedyEtaPlacement().place(req) != 3

    def test_greedy_queue_wait_prices_fifo_depth(self):
        req = request(site_busy={0: 2}, queue_depth={0: 3}, busy_until={0: [4.0, 9.0]},
                      service_est_s=2.0)
        # first release at t=4, three queued ahead beyond it (2+3-2=3)
        assert req.queue_wait_s(0) == pytest.approx(4.0 + 3 * 2.0)
        assert req.queue_wait_s(1) == 0.0


class TestEnginePlacement:
    def mk(self, n=4):
        dag = DAG()
        for i in range(n):
            dag.job(f"j{i}", sim(), site=0, sim_compute_s=1.0)
        return dag

    def test_round_robin_spreads_jobs(self):
        rep = Engine(model=GridModel(**ZERO), schedule="async", placement="round_robin").run(
            self.mk(5)
        )
        assert sorted(rep.placements.values()) == [0, 1, 2, 3, 4]

    def test_run_placement_override(self):
        eng = Engine(model=GridModel(**ZERO), schedule="async")
        rep = eng.run(self.mk(), placement="round_robin")
        assert rep.placement == "round_robin"
        assert eng.run(self.mk()).placement == "fixed"  # engine default intact

    def test_adaptive_relieves_contention(self):
        """4 one-second jobs pinned to one 1-slot site serialize under
        fixed placement; any adaptive policy spreads them out."""
        model = GridModel(**ZERO, workers_per_site=1)
        fixed = Engine(model=model, schedule="async", placement="fixed").run(self.mk())
        spread = Engine(model=model, schedule="async", placement="round_robin").run(self.mk())
        greedy = Engine(model=model, schedule="async", placement="greedy_eta").run(self.mk())
        assert fixed.wall_s == pytest.approx(4.0)
        assert spread.wall_s == pytest.approx(1.0)
        assert greedy.wall_s <= fixed.wall_s + 1e-9

    def test_staged_placement_places_per_stage(self):
        rep = Engine(model=GridModel(**ZERO), schedule="staged", placement="round_robin").run(
            self.mk(5)
        )
        assert rep.placement == "round_robin"
        assert sorted(rep.placements.values()) == [0, 1, 2, 3, 4]

    def test_speculation_survives_adaptive_placement(self):
        """Rescue/retry/speculation semantics hold in every policy: the
        straggler still gets a winning duplicate under greedy placement."""
        dag = DAG()
        dag.job("straggler", sim(), site=3, sim_compute_s=10.0)
        for i in range(3):
            dag.job(f"fast{i}", sim(), site=i, sim_compute_s=1.0)
        for policy in POLICIES:
            rep = Engine(
                model=GridModel(**ZERO), schedule="async",
                placement=policy, straggler_factor=3.0,
            ).run(dag_copy(dag))
            assert rep.speculative >= 1, policy
            assert rep.wall_s < 10.0, policy

    def test_retries_and_rescue_with_placement(self, tmp_path):
        from repro.workflow.faults import FaultInjector

        rescue = tmp_path / "rescue.json"
        calls = []

        def mk():
            dag = DAG()
            dag.job("a", lambda: calls.append("a") or 1)
            dag.job("flaky", lambda a: calls.append("flaky") or a + 1, deps=["a"], retries=3)
            return dag

        eng = Engine(
            model=GridModel(**ZERO),
            schedule="async",
            placement="greedy_eta",
            faults=FaultInjector(fail={"flaky": 2}),
            rescue_path=rescue,
        )
        results = {}
        rep = eng.run(mk(), results=results)
        assert results["flaky"] == 2
        assert rep.retries == 2
        assert rescue.exists()


def dag_copy(dag: DAG) -> DAG:
    out = DAG(dag.name)
    for j in dag.jobs.values():
        out.job(
            j.name, j.fn, deps=list(j.deps), site=j.site,
            input_bytes=j.input_bytes, output_bytes=j.output_bytes,
            sim_compute_s=j.sim_compute_s,
        )
    return out


class TestPlacementAwareBounds:
    SPECS = [
        JobSpec("a", (), 2.0, 10**6, 0, 1),
        JobSpec("b", ("a",), 2.0, 0, 10**5, 4),
    ]

    def test_plan_specs_fixed_is_identity(self):
        model = GridModel(**ZERO)
        assert [sp.site for sp in plan_specs(self.SPECS, model, "fixed")] == [1, 4]

    def test_plan_specs_greedy_rewrites_sites(self):
        model = GridModel.skewed(**ZERO)
        planned = plan_specs(self.SPECS, model, "greedy_eta")
        # sites 1 and 4 are the penalized ones; greedy must leave them
        assert all(sp.site not in (1, 4) for sp in planned)

    def test_estimate_dag_placement_aware(self):
        model = GridModel.skewed(**ZERO)
        fixed = estimate_dag(self.SPECS, model)
        greedy = estimate_dag(self.SPECS, model, placement="greedy_eta")
        assert greedy < fixed
        assert estimate_dag(self.SPECS, model, placement="fixed") == pytest.approx(fixed)

    def test_estimate_stages_placement_aware(self):
        model = GridModel.skewed(**ZERO)
        fixed = estimate_stages_from_specs(self.SPECS, model)
        greedy = estimate_stages_from_specs(self.SPECS, model, placement="greedy_eta")
        assert greedy < fixed

    def test_engine_wall_lower_bounded_by_placed_estimate(self):
        """The bound priced at the actually-chosen sites stays a true
        lower bound on the async engine's wall."""
        from repro.workflow.sitejob import replay_dag

        model = GridModel.skewed()
        rep = Engine(model=model, schedule="async", placement="greedy_eta").run(
            replay_dag(self.SPECS)
        )
        placed = [sp._replace(site=rep.placements[sp.name]) for sp in self.SPECS]
        assert rep.wall_s >= estimate_dag(placed, model) - 1e-9


class TestGridModelHeterogeneity:
    def test_zero_and_negative_bytes_cost_nothing(self):
        m = GridModel()
        assert m.transfer_s(0, 3, 0) == 0.0
        assert m.transfer_s(0, 3, -10) == 0.0
        assert m.transfer_s(2, 2, 0) == 0.0

    def test_link_matrix_is_asymmetric(self):
        m = GridModel()
        # Table 2: Nancy->Orsay 106.63 Mb/s vs Orsay->Nancy 90.77 Mb/s
        assert m.transfer_s(3, 0, 10**7) != m.transfer_s(0, 3, 10**7)

    def test_unknown_site_index_wraps_like_link_matrix(self):
        m = GridModel()
        assert m.transfer_s(7, 0, 10**6) == pytest.approx(m.transfer_s(2, 0, 10**6))
        assert m.transfer_s(0, 9, 10**6) == pytest.approx(m.transfer_s(0, 4, 10**6))
        sped = GridModel(site_speed=(1.0, 2.0))
        assert sped.speed(5) == sped.speed(1) == 2.0

    def test_default_speeds_are_homogeneous_identity(self):
        """site_speed=None is the pre-placement engine: site_compute_s is
        the identity (bit-for-bit, not merely 'divide by 1.0')."""
        m = GridModel()
        assert m.site_speed is None
        val = 0.123456789
        assert m.site_compute_s(3, val) is val
        assert m.speed(2) == 1.0

    def test_speed_factors_scale_compute(self):
        m = GridModel(site_speed=(1.0, 2.0, 0.5))
        assert m.site_compute_s(1, 3.0) == pytest.approx(1.5)
        assert m.site_compute_s(2, 3.0) == pytest.approx(6.0)
        assert m.site_compute_s(0, 3.0) == pytest.approx(3.0)

    def test_invalid_speed_and_links_rejected(self):
        with pytest.raises(ValueError, match="site_speed"):
            GridModel(site_speed=(1.0, 0.0))
        with pytest.raises(ValueError, match="site_speed"):
            GridModel(site_speed=())
        with pytest.raises(ValueError, match="unknown links"):
            GridModel(links="wan")

    def test_skewed_links_penalize_per_site(self):
        base, skew = GridModel(), GridModel(links="skewed")
        # links touching penalized sites (1, 4) degrade...
        assert skew.transfer_s(0, 1, 10**7) > base.transfer_s(0, 1, 10**7)
        assert skew.transfer_s(4, 0, 10**7) > base.transfer_s(4, 0, 10**7)
        # ...the upgraded backbone (site 3) improves
        assert skew.transfer_s(0, 3, 10**7) < base.transfer_s(0, 3, 10**7)

    def test_skewed_classmethod_bundles_speeds(self):
        m = GridModel.skewed()
        assert m.links == "skewed"
        assert m.site_speed == SKEW_SITE_SPEED
        assert GridModel.skewed(links="lan").links == "lan"


class TestRuntimePlacementThreading:
    def test_runtime_threads_placement_into_engine(self):
        from repro.runtime import GridRuntime

        rt = GridRuntime(sync="pooled", schedule="async", placement="greedy_eta")
        assert resolve_placement(rt.engine.placement).name == "greedy_eta"
        assert rt.engine.schedule == "async"

    def test_runtime_rebuilds_supplied_engine_on_mismatch(self):
        from repro.runtime import GridRuntime

        eng = Engine(model=GridModel(**ZERO), schedule="async")
        rt = GridRuntime(engine=eng, sync="pooled", placement="round_robin")
        assert eng.placement == "fixed"  # caller's engine never mutated
        assert resolve_placement(rt.engine.placement).name == "round_robin"
        assert rt.engine.model is eng.model

    def test_runtime_keeps_matching_engine(self):
        from repro.runtime import GridRuntime

        eng = Engine(model=GridModel(**ZERO), schedule="async", placement="random")
        rt = GridRuntime(engine=eng, sync="pooled", schedule="async", placement="random")
        assert rt.engine is eng
