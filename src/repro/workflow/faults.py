"""Deterministic fault injection for workflow/fault-tolerance tests.

Failures are keyed on (job name, attempt) so tests reproduce exactly:
``FaultInjector(fail={"cluster_3": 2})`` makes job cluster_3 fail its
first two attempts and succeed on the third (if the retry budget allows).
A rate-based mode drives soak tests with a seeded RNG.
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field


@dataclass
class FaultInjector:
    fail: dict[str, int] = field(default_factory=dict)  # name -> #attempts to fail
    rate: float = 0.0  # random failure probability per attempt
    seed: int = 0

    def __post_init__(self):
        self._rng = random.Random(self.seed)

    def should_fail(self, job_name: str, attempt: int) -> bool:
        if self.fail.get(job_name, 0) >= attempt:
            return True
        if self.rate > 0.0:
            return self._rng.random() < self.rate
        return False
