"""Pure-jnp oracles for every Pallas kernel in this package.

These define the semantics; kernels are validated against them with
``interpret=True`` across shape/dtype sweeps (see tests/test_kernels_*).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def kmeans_assign_ref(x: jax.Array, centers: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x (N, D), centers (K, D) -> (assign (N,) int32, min_d2 (N,) f32)."""
    x = x.astype(jnp.float32)
    c = centers.astype(jnp.float32)
    d2 = (
        jnp.sum(x**2, axis=-1)[:, None]
        + jnp.sum(c**2, axis=-1)[None, :]
        - 2.0 * x @ c.T
    )
    d2 = jnp.maximum(d2, 0.0)
    return jnp.argmin(d2, axis=-1).astype(jnp.int32), jnp.min(d2, axis=-1)


def support_count_ref(tx: jax.Array, masks: jax.Array) -> jax.Array:
    """tx (N, W) uint32/int32, masks (C, W) -> (C,) int32 supports."""
    tx = tx.astype(jnp.uint32)
    masks = masks.astype(jnp.uint32)
    hit = (tx[:, None, :] & masks[None, :, :]) == masks[None, :, :]  # (N, C, W)
    return jnp.sum(jnp.all(hit, axis=-1), axis=0).astype(jnp.int32)
