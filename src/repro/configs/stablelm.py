"""stablelm-1.6b [dense] — partial rotary (25%), LayerNorm
[hf:stabilityai/stablelm-2-1_6b]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab=100352,
    rope_theta=10_000.0,
    rope_pct=0.25,
    layer_pattern=("full",),
    norm="layernorm",
    act="swiglu",
    tie_embeddings=False,
    subquadratic=False,
)
