import os
import sys

# tests run single-device (the dry-run sets its own device count in
# SUBPROCESSES; setting it here would poison every other test's jit cache)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
