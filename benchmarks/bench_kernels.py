"""Kernel-level microbenchmarks: the two compute hot-spots the paper's
algorithms spend their time in.  On this CPU container we time the jnp
oracle (the Pallas kernels target TPU and run here only under the
interpreter); the derived column reports achieved GB/s / GFLOP/s so the
roofline context is visible."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core.apriori import pack_bool_matrix, pack_itemsets
from repro.kernels.ref import kmeans_assign_ref, support_count_ref


def run():
    rng = np.random.default_rng(0)

    # kmeans assignment: N x K distance + argmin
    n, d, k = 65_536, 32, 64
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    f = jax.jit(kmeans_assign_ref)
    jax.block_until_ready(f(x, c))
    dt = timeit(lambda: jax.block_until_ready(f(x, c)))
    flops = 2 * n * d * k
    row("kmeans_assign_jnp", dt, f"gflops={flops / dt / 1e9:.1f};N={n};D={d};K={k}")

    # support counting: bitmap AND+match over (tx x candidates)
    ntx, items, cands = 32_768, 128, 512
    dense = rng.random((ntx, items)) < 0.2
    tx = jnp.asarray(pack_bool_matrix(dense))
    sets = [tuple(sorted(rng.choice(items, size=3, replace=False).tolist())) for _ in range(cands)]
    masks = jnp.asarray(pack_itemsets(sets, items))
    g = jax.jit(support_count_ref)
    jax.block_until_ready(g(tx, masks))
    dt = timeit(lambda: jax.block_until_ready(g(tx, masks)))
    cells = ntx * cands * tx.shape[1]
    row("support_count_jnp", dt, f"gcells={cells / dt / 1e9:.2f};tx={ntx};cands={cands}")

    # Pallas kernels (interpret mode — correctness surface, not speed)
    from repro.kernels import ops

    dt = timeit(lambda: jax.block_until_ready(ops.kmeans_assign(x[:4096], c)), repeats=1, warmup=1)
    row("kmeans_assign_pallas_interpret", dt, "interpret=True (CPU correctness mode)")


if __name__ == "__main__":
    run()
