"""Service-level throughput/latency bench: a bursty multi-tenant arrival
trace through the continuous mining service (``repro.launch.serve``).

Where the sweep benches measure ONE application's DAG, this measures the
serving layer itself: request throughput, tenant-visible latency
percentiles (admission to completion, queue wait included) overall and
PER TENANT, the versioned cache's hit rate across bursts and data
appends, how many identical concurrent requests coalesced into shared
executions, how many execution groups the cross-request batcher fused
into shared device dispatches, and the round-robin fairness bound over
the pick log.  The trace is the same seeded burst generator the service
CLI drives (shared query per burst -> coalescing; a same-app sibling
query per burst -> cross-request fusion; small param pool -> repeats
within a dataset version -> cache hits; periodic appends -> version
bumps -> honest misses).

``--slo BENCH_service_slo.json`` turns the bench into a gate: the report
is checked against committed latency bands (p50/p95 overall and per
tenant), the fairness bound, the fusion invariant
(``device_dispatches < executions``), and — because the gate first
replays the SAME trace with fusion disabled — the wall-time invariant
that fused execution is never slower than serial beyond a tolerance.
The serial pass runs FIRST, so jit warm-up is charged to it, not to the
fused pass being gated.

    PYTHONPATH=src python -m benchmarks.bench_service --smoke --out BENCH_service.json
    PYTHONPATH=src python -m benchmarks.bench_service --smoke --slo BENCH_service_slo.json
"""

from __future__ import annotations

import argparse
import json
import time
from types import SimpleNamespace

import numpy as np

from repro.launch.serve import _build_service, _trace_bursts, fairness_violations
from repro.workflow.requests import QueueFullError


def _latency_ms(values) -> dict:
    arr = np.array(values) if len(values) else np.zeros(1)
    return {
        "p50": float(np.percentile(arr, 50) * 1e3),
        "p90": float(np.percentile(arr, 90) * 1e3),
        "p95": float(np.percentile(arr, 95) * 1e3),
        "max": float(arr.max() * 1e3),
    }


def run(
    backend: str = "batched",
    requests: int = 50,
    tenants: int = 3,
    burst: int = 4,
    n_sites: int = 4,
    n_items: int = 12,
    append_every: int = 2,
    max_per_step: int = 8,
    seed: int = 0,
    fuse: bool = True,
    out: str | None = None,
) -> dict:
    args = SimpleNamespace(
        backend=backend, requests=requests, tenants=tenants, burst=burst,
        n_sites=n_sites, n_items=n_items, seed=seed, max_depth=256,
        no_fuse=not fuse,
    )
    rng = np.random.default_rng(seed)
    svc = _build_service(args)
    tenant_names = [f"tenant{i}" for i in range(tenants)]
    bursts = _trace_bursts(args, rng)

    from repro.data.synthetic import gaussian_mixture, ibm_transactions

    rejected = 0
    t0 = time.perf_counter()
    for b, burst_reqs in enumerate(bursts):
        for tenant, app, dataset, params in burst_reqs:
            try:
                svc.submit(tenant, app, dataset, params)
            except QueueFullError:
                rejected += 1
        svc.drain(max_requests=max_per_step)
        if append_every and (b + 1) % append_every == 0:
            svc.append_transactions("tx", ibm_transactions(seed + b + 1, 60, n_items))
            pts, _ = gaussian_mixture(seed + b + 1, 60, 2, 3)
            svc.append_points("pts", pts)
    wall = time.perf_counter() - t0

    led = svc.ledger()
    done = [r for r in led["requests"] if r["status"] == "done"]
    waits = np.array([r["queue_wait_s"] for r in done]) if done else np.zeros(1)
    fairness_ok = not fairness_violations(
        svc.pick_log, tenant_names, len(tenant_names) * min(
            sum(1 for r in led["requests"] if r["tenant"] == t) for t in tenant_names))

    report = {
        "backend": led["backend"],
        "fuse_requests": bool(fuse),
        "n_sites": n_sites,
        "tenants": tenants,
        "requests": len(led["requests"]),
        "done": len(done),
        "failed": sum(1 for r in led["requests"] if r["status"] == "failed"),
        "rejected": led["rejected"] + rejected,
        "wall_s": wall,
        "throughput_rps": len(done) / max(wall, 1e-9),
        "latency_ms": _latency_ms([r["service_s"] for r in done]),
        "per_tenant_latency_ms": {
            t: _latency_ms([r["service_s"] for r in done if r["tenant"] == t])
            for t in tenant_names
        },
        "queue_wait_ms_mean": float(waits.mean() * 1e3),
        "cache": led["cache"],
        "executions": led["executions"],
        "coalesced": led["coalesced"],
        "exec_groups": led["exec_groups"],
        "fused_requests": led["fused_requests"],
        "device_dispatches": led["device_dispatches"],
        "fairness_ok": bool(fairness_ok),
        "per_tenant": led["per_tenant"],
    }

    print(f"# mining service, {tenants} tenants x bursty trace, "
          f"backend={report['backend']}, fuse={'on' if fuse else 'off'}")
    print("requests,done,throughput_rps,p50_ms,p95_ms,hit_rate,coalesced,dispatches,fair")
    print(
        f"{report['requests']},{report['done']},{report['throughput_rps']:.2f},"
        f"{report['latency_ms']['p50']:.0f},{report['latency_ms']['p95']:.0f},"
        f"{report['cache']['hit_rate']:.2f},{report['coalesced']},"
        f"{report['device_dispatches']}/{report['executions']},"
        f"{'yes' if fairness_ok else 'NO'}"
    )
    if out:
        with open(out, "w") as fh:
            json.dump(report, fh, indent=2, sort_keys=True, default=float)
        print(f"# wrote {out}")
    return report


def check_slo(report: dict, slo: dict, serial_report: dict | None = None) -> list[str]:
    """SLO bands for one bench report; returns the violations (empty =
    pass).  Band keys (all optional): ``p50_ms_max`` / ``p95_ms_max``
    (overall), ``per_tenant_p95_ms_max`` (every tenant), ``min_done``,
    ``require_fairness``, ``require_fusion`` (device_dispatches <
    executions), and — when a fusion-disabled replay of the same trace
    is supplied — ``fused_vs_serial_tol``: fused wall time must be
    within ``serial * (1 + tol)``."""
    problems: list[str] = []
    lat = report["latency_ms"]
    if "p50_ms_max" in slo and lat["p50"] > slo["p50_ms_max"]:
        problems.append(f"p50 {lat['p50']:.0f}ms > band {slo['p50_ms_max']}ms")
    if "p95_ms_max" in slo and lat["p95"] > slo["p95_ms_max"]:
        problems.append(f"p95 {lat['p95']:.0f}ms > band {slo['p95_ms_max']}ms")
    cap = slo.get("per_tenant_p95_ms_max")
    if cap is not None:
        for t, pl in report["per_tenant_latency_ms"].items():
            if pl["p95"] > cap:
                problems.append(f"tenant {t} p95 {pl['p95']:.0f}ms > band {cap}ms")
    if "min_done" in slo and report["done"] < slo["min_done"]:
        problems.append(f"done {report['done']} < band {slo['min_done']}")
    if slo.get("require_fairness", True) and not report["fairness_ok"]:
        problems.append("fairness bound violated")
    if slo.get("require_fusion", False) and (
        report["device_dispatches"] >= report["executions"]
    ):
        problems.append(
            f"no cross-request fusion: device_dispatches "
            f"{report['device_dispatches']} >= executions {report['executions']}"
        )
    if serial_report is not None:
        tol = float(slo.get("fused_vs_serial_tol", 0.25))
        bound = serial_report["wall_s"] * (1.0 + tol)
        if report["wall_s"] > bound:
            problems.append(
                f"fused wall {report['wall_s']:.2f}s > serial "
                f"{serial_report['wall_s']:.2f}s * (1 + {tol}) = {bound:.2f}s"
            )
    return problems


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="batched", choices=("inline", "batched", "multihost"))
    ap.add_argument("--requests", type=int, default=50)
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--burst", type=int, default=4)
    ap.add_argument("--n-sites", type=int, default=4)
    ap.add_argument("--n-items", type=int, default=12)
    ap.add_argument("--append-every", type=int, default=2)
    ap.add_argument("--max-per-step", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-fuse", action="store_true",
                    help="disable cross-request batched execution")
    ap.add_argument("--smoke", action="store_true",
                    help="small trace for CI (fewer requests, tiny data)")
    ap.add_argument("--slo", default=None, metavar="BANDS_JSON",
                    help="gate the report against committed SLO bands; also "
                         "replays the trace fusion-disabled (FIRST, so jit "
                         "warm-up is charged to the serial pass) and gates "
                         "fused wall time against it")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    kw = dict(
        backend=args.backend, requests=args.requests, tenants=args.tenants,
        burst=args.burst, n_sites=args.n_sites, n_items=args.n_items,
        append_every=args.append_every, max_per_step=args.max_per_step,
        seed=args.seed,
    )
    if args.smoke:
        # one dataset version across the whole trace (append_every=3 >
        # burst count) so the repeated param pool demonstrably hits
        kw.update(requests=18, n_sites=2, n_items=10, burst=3, append_every=3)
    if args.slo:
        with open(args.slo) as fh:
            slo = json.load(fh)
        serial = run(**kw, fuse=False, out=None)
        report = run(**kw, fuse=not args.no_fuse, out=args.out)
        problems = check_slo(report, slo, serial_report=serial)
        if problems:
            print("# SLO gate FAILED:")
            for p in problems:
                print(f"#   - {p}")
            return 1
        print(f"# SLO gate passed ({args.slo}): p50/p95 bands, fairness, "
              "fusion, fused<=serial wall")
        return 0
    run(**kw, fuse=not args.no_fuse, out=args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
