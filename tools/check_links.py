"""Docs link check: dead RELATIVE links in README.md / docs/ fail CI.

Scans markdown files for inline links and images (``[text](target)``),
skips absolute URLs (http/https/mailto) and pure in-page anchors, and
verifies every remaining target resolves to an existing file or
directory relative to the markdown file that references it (fragments
after ``#`` are stripped — existence of the file is what is checked).

Dependency-free by design (stdlib only) so the CI step needs nothing
installed:

    python tools/check_links.py            # checks README.md + docs/**.md
    python tools/check_links.py FILE...    # explicit file list
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

# inline markdown links/images: [text](target) — greedy enough for docs,
# ignores fenced code because targets there rarely parse as paths anyway
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def check_file(md: Path, root: Path) -> list[str]:
    errors: list[str] = []
    text = md.read_text(encoding="utf-8")
    for lineno, line in enumerate(text.splitlines(), start=1):
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if target.startswith(SKIP_PREFIXES):
                continue
            path_part = target.split("#", 1)[0]
            if not path_part:
                continue
            resolved = (md.parent / path_part).resolve()
            if not resolved.exists():
                rel = md.relative_to(root)
                errors.append(f"{rel}:{lineno}: dead link -> {target}")
    return errors


def main(argv: list[str]) -> int:
    root = Path(__file__).resolve().parent.parent
    if argv:
        files = [Path(a).resolve() for a in argv]
    else:
        files = [root / "README.md", *sorted((root / "docs").glob("**/*.md"))]
    files = [f for f in files if f.exists()]
    errors: list[str] = []
    for md in files:
        errors.extend(check_file(md, root))
    for e in errors:
        print(f"FAIL  {e}")
    n = len(files)
    if errors:
        print(f"# link check: {len(errors)} dead link(s) across {n} file(s)")
        return 1
    print(f"# link check: OK ({n} file(s), all relative links resolve)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
