"""Checkpointer: roundtrip, atomicity, async, retention, elastic restore."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer


def state_tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)), "b": jnp.zeros((16,))},
        "opt": {"step": jnp.int32(7), "m": {"w": jnp.ones((8, 16))}},
    }


class TestRoundtrip:
    def test_save_restore_exact(self, tmp_path):
        ck = Checkpointer(tmp_path, async_mode=False)
        st = state_tree()
        ck.save(3, st)
        got = ck.restore(st)
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(st)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_step_selection(self, tmp_path):
        ck = Checkpointer(tmp_path, async_mode=False)
        st = state_tree()
        for s in (1, 5, 9):
            ck.save(s, st)
        assert ck.latest_step() == 9
        assert ck.all_steps() == [1, 5, 9]

    def test_restore_specific_step(self, tmp_path):
        ck = Checkpointer(tmp_path, async_mode=False, keep=10)
        st1 = state_tree(0)
        st2 = jax.tree.map(lambda x: x + 1, st1)
        ck.save(1, st1)
        ck.save(2, st2)
        got = ck.restore(st1, step=1)
        np.testing.assert_array_equal(np.asarray(got["params"]["w"]), np.asarray(st1["params"]["w"]))


class TestAtomicity:
    def test_tmp_dirs_never_visible(self, tmp_path):
        ck = Checkpointer(tmp_path, async_mode=False)
        ck.save(1, state_tree())
        assert not list(tmp_path.glob("*.tmp"))

    def test_shape_mismatch_rejected(self, tmp_path):
        ck = Checkpointer(tmp_path, async_mode=False)
        st = state_tree()
        ck.save(1, st)
        bad = {"params": {"w": jnp.zeros((4, 4)), "b": jnp.zeros((16,))}, "opt": st["opt"]}
        with pytest.raises(ValueError, match="shape mismatch"):
            ck.restore(bad)


class TestAsyncAndRetention:
    def test_async_save_then_restore(self, tmp_path):
        ck = Checkpointer(tmp_path, async_mode=True)
        st = state_tree()
        ck.save(4, st)
        ck.wait()
        got = ck.restore(st)
        np.testing.assert_array_equal(np.asarray(got["opt"]["step"]), 7)

    def test_retention_keeps_newest_k(self, tmp_path):
        ck = Checkpointer(tmp_path, async_mode=False, keep=2)
        st = state_tree()
        for s in range(5):
            ck.save(s, st)
        assert ck.all_steps() == [3, 4]

    def test_restart_resumes_training(self, tmp_path):
        """Full fault-tolerance loop: train, checkpoint, 'crash', restore,
        continue — the stream is pure in (seed, step) so the resumed run
        produces the identical state as an uninterrupted one."""
        from repro.data.pipeline import TokenStream
        from repro.models.config import ModelConfig
        from repro.optim.adamw import AdamWConfig
        from repro.train.steps import make_train_step, materialize_state

        cfg = ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                          d_ff=64, vocab=64, dtype="float32", remat="none")
        stream = TokenStream(vocab=cfg.vocab, global_batch=2, seq_len=16, seed=1)
        step_fn = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3, warmup=0), loss_chunk=16))

        def run(n0, n1, state):
            for s in range(n0, n1):
                state, _ = step_fn(state, jax.tree.map(jnp.asarray, stream.batch_at(s)))
            return state

        # uninterrupted reference
        ref = run(0, 6, materialize_state(cfg, jax.random.PRNGKey(0)))

        # interrupted + resumed
        ck = Checkpointer(tmp_path, async_mode=False)
        st = run(0, 3, materialize_state(cfg, jax.random.PRNGKey(0)))
        ck.save(3, st)
        del st  # "crash"
        like = materialize_state(cfg, jax.random.PRNGKey(42))  # fresh process
        restored = jax.tree.map(jnp.asarray, ck.restore(like))
        out = run(3, 6, restored)

        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
