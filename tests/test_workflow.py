"""Workflow engine (DAGMan analogue): ordering, faults/retries, rescue
restart, straggler speculation, and the paper's Table 3 overhead model."""

import pytest

from repro.workflow.dag import DAG
from repro.workflow.engine import Engine
from repro.workflow.faults import FaultInjector
from repro.workflow.overhead import (
    DAGMAN_PREP_S,
    GridModel,
    estimate_stages,
    overhead_pct,
)


def diamond_dag(calls):
    dag = DAG("diamond")
    dag.job("a", lambda: calls.append("a") or 1)
    dag.job("b", lambda a: calls.append("b") or a + 1, deps=["a"])
    dag.job("c", lambda a: calls.append("c") or a + 2, deps=["a"])
    dag.job("d", lambda b, c: calls.append("d") or b + c, deps=["b", "c"])
    return dag


class TestDAG:
    def test_topological_execution(self):
        calls = []
        dag = diamond_dag(calls)
        rep = Engine(model=GridModel(prep_latency_s=0, submit_latency_s=0)).run(dag)
        assert calls[0] == "a" and calls[-1] == "d"
        assert dag.jobs["d"].result == 5
        assert rep.wall_s >= rep.max_stage_compute_s

    def test_cycle_detection(self):
        dag = DAG()
        dag.job("a", lambda: 1)
        dag.job("b", lambda a: 1, deps=["a"])
        dag.jobs["a"].deps = ["b"]  # force a cycle
        with pytest.raises(ValueError, match="cycle"):
            dag.validate_acyclic()

    def test_cycle_error_names_the_cycle(self):
        dag = DAG("wf")
        dag.job("a", lambda: 1)
        dag.job("b", lambda a: 1, deps=["a"])
        dag.job("c", lambda b: 1, deps=["b"])
        dag.jobs["a"].deps = ["c"]  # a -> c -> b -> a
        with pytest.raises(ValueError, match=r"wf.*(a -> c -> b -> a|c -> b -> a -> c|b -> a -> c -> b)"):
            dag.validate_acyclic()

    def test_self_dependency_rejected(self):
        dag = DAG()
        with pytest.raises(ValueError, match="depends on itself"):
            dag.job("a", lambda: 1, deps=["a"])

    def test_duplicate_job_rejected(self):
        dag = DAG("wf")
        dag.job("a", lambda: 1)
        with pytest.raises(ValueError, match="duplicate job 'a' in DAG 'wf'"):
            dag.job("a", lambda: 2)

    def test_build_dag_rejects_duplicates_and_cycles(self):
        from repro.workflow.sitejob import SiteJob, build_dag

        dup = [SiteJob("s", lambda: 1), SiteJob("s", lambda: 2)]
        with pytest.raises(ValueError, match="duplicate job 's'"):
            build_dag(dup)

        jobs = [SiteJob("a", lambda: 1), SiteJob("b", lambda a: 1, deps=["a"])]
        dag = build_dag(jobs)  # valid topology assembles fine
        dag.jobs["a"].deps = ["b"]
        with pytest.raises(ValueError, match="cycle"):
            dag.validate_acyclic()

    def test_deep_chain_validates_without_recursion_limit(self):
        dag = DAG()
        dag.job("j0", lambda: 0)
        for i in range(1, 5000):
            dag.job(f"j{i}", lambda x: x, deps=[f"j{i - 1}"])
        dag.validate_acyclic()  # must not raise RecursionError

    def test_unknown_dep_rejected(self):
        dag = DAG()
        with pytest.raises(ValueError, match="unknown"):
            dag.job("a", lambda: 1, deps=["nope"])
        dag.job("a", lambda: 1)
        dag.jobs["a"].deps = ["ghost"]  # mutated after add
        with pytest.raises(ValueError, match="depends on unknown 'ghost'"):
            dag.validate_acyclic()


class TestFaultTolerance:
    def test_retry_recovers(self):
        dag = DAG()
        dag.job("flaky", lambda: 42, retries=2)
        eng = Engine(
            model=GridModel(prep_latency_s=0, submit_latency_s=0),
            faults=FaultInjector(fail={"flaky": 2}),
        )
        rep = eng.run(dag)
        assert dag.jobs["flaky"].result == 42
        assert dag.jobs["flaky"].attempts == 3
        assert rep.retries == 2

    def test_retry_budget_exhausted(self):
        dag = DAG()
        dag.job("doomed", lambda: 1, retries=1)
        eng = Engine(
            model=GridModel(prep_latency_s=0, submit_latency_s=0),
            faults=FaultInjector(fail={"doomed": 5}),
        )
        with pytest.raises(RuntimeError, match="exhausted"):
            eng.run(dag)

    def test_rescue_resume_skips_done_jobs(self, tmp_path):
        """Crash after 'a' completes; the rescued run must NOT re-run 'a'
        (DAGMan rescue-DAG semantics)."""
        rescue = tmp_path / "rescue.json"
        calls = []
        dag1 = DAG()
        dag1.job("a", lambda: calls.append("a1") or 1)
        dag1.job("boom", lambda a: (_ for _ in ()).throw(RuntimeError("x")), deps=["a"], retries=0)
        eng = Engine(model=GridModel(prep_latency_s=0, submit_latency_s=0), rescue_path=rescue)
        with pytest.raises(Exception):
            eng.run(dag1)
        assert rescue.exists()

        calls2 = []
        dag2 = DAG()
        dag2.job("a", lambda: calls2.append("a2") or 1)
        dag2.job("boom", lambda a=None: 99, deps=["a"], retries=0)
        eng2 = Engine(model=GridModel(prep_latency_s=0, submit_latency_s=0), rescue_path=rescue)
        results = {"a": 1}  # rescued value re-injected by the driver
        rep = eng2.run(dag2, results=results)
        assert "a2" not in calls2, "completed job must not re-execute"
        assert dag2.jobs["boom"].result == 99


class TestStragglers:
    def test_speculation_caps_stage_time(self):
        import time as _t

        dag = DAG()
        for i in range(4):
            dag.job(f"j{i}", lambda: 0)
        dag.job("slow", lambda: _t.sleep(0.5))
        eng = Engine(
            model=GridModel(prep_latency_s=0, submit_latency_s=0), straggler_factor=3.0
        )
        rep = eng.run(dag)
        assert rep.speculative >= 1
        # stage wall uses the speculative (median) time, not the straggler
        assert rep.wall_s < 0.5


class TestOverheadModel:
    def test_table2_asymmetry(self):
        m = GridModel()
        # Nancy->Orsay is the fastest WAN link in Table 2 (106.63 Mb/s)
        fast = m.transfer_s(3, 0, 10**7)
        slow = m.transfer_s(2, 1, 10**7)  # Rennes->Toulouse 12.71 Mb/s
        assert fast < slow

    def test_paper_prep_latency_default(self):
        assert GridModel().prep_latency_s == DAGMAN_PREP_S == 295.0

    def test_clustering_overhead_reproduces_table3_shape(self):
        """Cheap parallel jobs (paper's clustering: est 19.52 s vs 1050 s
        measured => 98% overhead).  With our simulated engine the prep
        latency dominates exactly as in the paper."""
        dag = DAG()
        for i in range(8):
            dag.job(f"cluster_{i}", lambda: sum(range(2000)), site=i % 5)
        dag.job("merge", lambda *a: 0, deps=[f"cluster_{i}" for i in range(8)])
        eng = Engine(model=GridModel())  # full 295 s prep
        rep = eng.run(dag)
        assert rep.overhead_pct() > 90.0

    def test_overlap_prep_reduces_overhead(self):
        """The paper suggests overheads are 'partly overlapped by
        computations in the DAG' for heavier jobs — our overlapped mode
        must strictly reduce wall time."""
        def mk():
            dag = DAG()
            for i in range(8):
                dag.job(f"c{i}", lambda: sum(range(2000)), site=i % 5)
            return dag

        base = Engine(model=GridModel()).run(mk())
        fast = Engine(model=GridModel(), overlap_prep=True).run(mk())
        assert fast.wall_s < base.wall_s * 0.2

    def test_estimate_stages_matches_paper_structure(self):
        m = GridModel()
        stages = [
            [(2.0, 10**6, 10**4, s) for s in range(5)],  # parallel local mining
            [(0.5, 10**4, 0, 0)],  # aggregation
        ]
        est = estimate_stages(stages, m)
        assert est > 2.5  # compute floor
        assert overhead_pct(100.0, est) > 90
