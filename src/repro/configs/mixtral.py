"""mixtral-8x22b [moe] — 8 experts top-2, GQA kv=8, SWA (per assignment)
[arXiv:2401.04088]."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=32768,
    window=4096,
    layer_pattern=("swa",),
    moe=MoEConfig(n_experts=8, n_shared_experts=0, top_k=2, expert_d_ff=16384),
    norm="rmsnorm",
    act="swiglu",
    tie_embeddings=False,
    subquadratic=True,  # sliding-window attention in every layer
)
