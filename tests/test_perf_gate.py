"""compare_baseline: the CI perf-regression gate's decision logic on
synthetic sweep payloads (no jax, no benchmark run)."""

import copy
import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))
from benchmarks.compare_baseline import compare  # noqa: E402


def payload():
    cell = {
        "app": "gfm",
        "n_sites": 4,
        "links": "grid5000",
        "compute_scale": 1,
        "schedule": "staged",
        "placement": "fixed",
        "wall_s": 325.0,
        "overhead_pct": 99.9,
        "prep_s": 295.0,
        "submit_s": 30.0,
        "transfer_s": 1.5,
    }
    acell = dict(cell, schedule="async", wall_s=307.0, submit_s=30.0)
    gcell = dict(acell, placement="greedy_eta", links="skewed", wall_s=306.0)
    fcell = dict(acell, links="skewed", wall_s=309.0)
    bcell = dict(cell, exec_backend="batched", wall_s=324.0)
    return {
        "cells": [cell, acell, gcell, fcell, bcell],
        "comparisons": [
            {
                "app": "gfm",
                "n_sites": 4,
                "links": "grid5000",
                "compute_scale": 1,
                "wall_staged_s": 325.0,
                "wall_async_s": 307.0,
            }
        ],
        "backend_comparisons": [
            {
                "app": "gfm",
                "n_sites": 8,
                "links": "grid5000",
                "schedule": "staged",
                "compute_scale": 50,
                "wall_inline_s": 330.0,
                "wall_batched_s": 326.0,
            },
            {
                # small fan-out: fusion gains are noise-level, not gated —
                # meaningful only because this row is far beyond the band
                "app": "gfm",
                "n_sites": 2,
                "links": "grid5000",
                "schedule": "staged",
                "compute_scale": 50,
                "wall_inline_s": 300.0,
                "wall_batched_s": 400.0,
            },
        ],
        "placement_comparisons": [
            {
                "app": "gfm",
                "n_sites": 4,
                "links": "skewed",
                "compute_scale": 1,
                "wall_fixed_s": 309.0,
                "wall_greedy_eta_s": 306.0,
            },
            {
                # far beyond the gate band — meaningful only because
                # non-skewed rows are not gated at all
                "app": "gfm",
                "n_sites": 4,
                "links": "grid5000",
                "compute_scale": 1,
                "wall_fixed_s": 307.0,
                "wall_greedy_eta_s": 350.0,
            },
        ],
    }


class TestCompare:
    def test_identical_passes(self):
        failures, notes = compare(payload(), payload())
        assert failures == [] and notes == []

    def test_simulated_component_regression_fails(self):
        cand = payload()
        cand["cells"][0]["submit_s"] *= 1.10  # > 1% on a simulated component
        failures, _ = compare(payload(), cand)
        assert any("submit_s" in f for f in failures)

    def test_wall_within_band_passes(self):
        cand = payload()
        cand["cells"][0]["wall_s"] *= 1.10  # within the 30% wall band
        failures, _ = compare(payload(), cand)
        assert failures == []

    def test_wall_regression_fails(self):
        cand = payload()
        cand["cells"][0]["wall_s"] *= 1.50
        failures, _ = compare(payload(), cand)
        assert any("wall_s" in f for f in failures)

    def test_improvement_is_note_not_failure(self):
        cand = payload()
        cand["cells"][0]["wall_s"] *= 0.5
        cand["cells"][0]["submit_s"] *= 0.5
        failures, notes = compare(payload(), cand)
        assert failures == []
        assert any("refresh the baseline" in n for n in notes)

    def test_missing_cell_fails(self):
        cand = copy.deepcopy(payload())
        cand["cells"] = cand["cells"][:1]
        failures, _ = compare(payload(), cand)
        assert any("missing" in f for f in failures)

    def test_async_invariant_violation_fails(self):
        cand = payload()
        cand["comparisons"][0]["wall_async_s"] = 340.0
        failures, _ = compare(payload(), cand)
        assert any("invariant" in f for f in failures)

    def test_missing_comparisons_fail(self):
        """A candidate that silently drops its comparison rows must not
        pass with the invariant untested."""
        cand = payload()
        cand["comparisons"] = []
        failures, _ = compare(payload(), cand)
        assert any("comparison row missing" in f for f in failures)

    def test_overhead_pct_band(self):
        cand = payload()
        cand["cells"][0]["overhead_pct"] = 99.9 + 6.0  # beyond 5-point band
        failures, _ = compare(payload(), cand)
        assert any("overhead_pct" in f for f in failures)

    def test_legacy_baseline_cells_match_fixed_placement(self):
        """Pre-placement baselines carry no placement field; their cells
        must keep gating the candidate's fixed-placement cells."""
        base = payload()
        for cell in base["cells"]:
            cell.pop("placement", None)
        failures, notes = compare(base, payload())
        assert failures == [] and notes == []

    def test_placement_invariant_violation_fails(self):
        cand = payload()
        cand["placement_comparisons"][0]["wall_greedy_eta_s"] = 330.0  # skewed row, >5% band
        failures, _ = compare(payload(), cand)
        assert any("placement invariant" in f for f in failures)

    def test_placement_invariant_not_gated_off_skewed(self):
        """Only skewed rows gate: the payload's grid5000 row has greedy
        losing to fixed by far more than the band and must not fail."""
        failures, notes = compare(payload(), payload())
        assert failures == [] and notes == []

    def test_adaptive_cells_not_strictly_banded(self):
        """Adaptive placement chooses sites from host-calibrated times,
        so its transfer ledger may legitimately drift across hosts —
        only fixed-placement cells carry the 1% simulated-component
        band; adaptive cells stay under the loose wall band."""
        cand = payload()
        greedy_cell = next(c for c in cand["cells"] if c["placement"] == "greedy_eta")
        greedy_cell["transfer_s"] *= 2.0
        failures, _ = compare(payload(), cand)
        assert failures == []
        fixed_cell = next(
            c for c in cand["cells"] if c["placement"] == "fixed" and c["schedule"] == "staged"
        )
        fixed_cell["transfer_s"] *= 2.0
        failures, _ = compare(payload(), cand)
        assert any("transfer_s" in f for f in failures)

    def test_missing_placement_comparisons_fail(self):
        cand = payload()
        cand["placement_comparisons"] = []
        failures, _ = compare(payload(), cand)
        assert any("placement comparison row missing" in f for f in failures)

    def test_backend_invariant_violation_fails(self):
        cand = payload()
        cand["backend_comparisons"][0]["wall_batched_s"] = 350.0  # 8-site row, >5% band
        failures, _ = compare(payload(), cand)
        assert any("backend invariant" in f for f in failures)

    def test_backend_invariant_not_gated_under_8_sites(self):
        """Only fan-out-heavy rows gate: the 2-site row has batched
        losing by far more than the band and must not fail."""
        failures, notes = compare(payload(), payload())
        assert failures == [] and notes == []

    def test_missing_backend_comparisons_fail(self):
        cand = payload()
        cand["backend_comparisons"] = []
        failures, _ = compare(payload(), cand)
        assert any("backend comparison row missing" in f for f in failures)

    def test_legacy_baseline_cells_match_inline_backend(self):
        """Pre-backend baselines carry no exec_backend field; their
        cells must keep gating the candidate's inline cells."""
        base = payload()
        base["cells"] = base["cells"][:-1]  # drop the batched cell
        for cell in base["cells"]:
            cell.pop("exec_backend", None)
        base["backend_comparisons"] = []
        failures, notes = compare(base, payload())
        assert failures == [] and notes == []

    def test_overhead_pct_not_gated_at_scaled_cells(self):
        """Compute-scale multipliers amplify calibration noise in
        overhead_pct; only the x1 cells are banded."""
        base, cand = payload(), payload()
        for p in (base, cand):
            for cell in p["cells"]:
                cell["compute_scale"] = 50
            p["comparisons"][0]["compute_scale"] = 50
        cand["cells"][0]["overhead_pct"] = 99.9 + 6.0
        failures, _ = compare(base, cand)
        assert failures == []
