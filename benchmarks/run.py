"""Benchmark harness entry — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run            # all benches
    PYTHONPATH=src python -m benchmarks.run --only gfm
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="substring filter")
    args = ap.parse_args()

    from benchmarks import (
        bench_clustering,
        bench_gfm_vs_fdm,
        bench_kernels,
        bench_overheads,
        bench_runtime,
        bench_scaling,
    )

    benches = [
        ("gfm_vs_fdm (paper §5.2.1, Table 3 rows 2-3)", bench_gfm_vs_fdm.run),
        ("clustering (paper §5.2.1, Table 3 row 1)", bench_clustering.run),
        ("overheads (paper Table 3 / §5.2.2)", bench_overheads.run),
        ("scaling (grid dimension)", bench_scaling.run),
        ("kernels (hot-spot microbench)", bench_kernels.run),
        ("runtime (end-to-end apps through GridRuntime)", bench_runtime.run),
    ]

    print("name,us_per_call,derived")
    failures = []
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"# FAILED: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
