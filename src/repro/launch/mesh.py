"""Device-mesh construction and multi-process bring-up for the grid
runtime — the hardware seam everything above ``repro.core`` stands on.

What lives here, bottom-up:

  * :func:`make_site_mesh` — the 1-D grid-site mesh (one device per paper
    "site") the single-host runtime's shard_map synchronization runs on;
    returns None when the host has too few devices and callers fall back
    to the bit-identical pooled path.
  * :func:`init_multihost` / :func:`make_multihost_mesh` — bring up
    ``jax.distributed`` (gloo CPU collectives selected BEFORE backend
    init; idempotent) and build the same site mesh over the GLOBAL
    device set, so the identical SiteJob DAGs distribute across hosts.
  * :func:`site_ownership` — the deterministic ``site -> process`` map
    (capacity-proportional greedy) that gives every grid site exactly
    one executing process under ``runtime.backends.MultiHostBackend``.
  * :func:`allgather_bytes` / :func:`allgather_payload` — the shipment
    wire: variable-length bytes (then packed pytrees) gathered across
    processes; the ONLY cross-process traffic the multihost backend
    performs, wave-fused so collectives scale with ready waves.
  * :func:`make_production_mesh` / :func:`make_variant_mesh` /
    :func:`make_test_mesh`, and the ``HW`` roofline constants — the
    scale-out/dry-run harness meshes (16x16-pod shapes) used by the
    roofline table and capacity notes, not by the mining runtime.

Everything is kept as FUNCTIONS (never module-level constants) so
importing this module never touches jax device state — the dry-run must
set XLA_FLAGS=--xla_force_host_platform_device_count BEFORE first jax
init, and ``init_multihost`` must run before the first backend query.
"""

from __future__ import annotations

import jax


# XLA flag set for real-GPU deployments (jax gpu_performance_tips):
# triton softmax fusion + any-shape triton GEMMs cut kernel-launch
# overhead on the mining matmuls; async collectives + the latency-hiding
# scheduler overlap the grid's cross-site synchronization with compute.
GPU_XLA_FLAGS = (
    "--xla_gpu_enable_triton_softmax_fusion=true "
    "--xla_gpu_triton_gemm_any=True "
    "--xla_gpu_enable_async_collectives=true "
    "--xla_gpu_enable_latency_hiding_scheduler=true "
    "--xla_gpu_enable_highest_priority_async_stream=true "
)


def tuned_platform(platform: str | None = None) -> str:
    """Select the jax platform and apply the tuned XLA flag set for it —
    the process-entry companion of the kernel autotuner (blocks tune the
    Pallas tile shapes; this tunes what XLA does around them).

    ``platform=None`` keeps whatever backend jax would pick and only
    applies flags when that backend is GPU.  Like every XLA flag, this
    only takes effect BEFORE the first jax computation/backend query —
    call it first thing in ``main()`` (the benchmark entry points
    ``bench_kernels``/``bench_runtime`` do).  On CPU/TPU it is a no-op
    beyond the optional platform pin, so the benchmarks call it
    unconditionally and real-GPU deployments get the tuned flags for
    free.  Returns the platform name it settled on.
    """
    import os

    if platform is not None:
        if platform not in ("cpu", "gpu", "tpu"):
            raise ValueError(f"unknown platform {platform!r} (want cpu|gpu|tpu)")
        jax.config.update("jax_platform_name", platform)
    if platform == "gpu" or (platform is None and _probable_backend() == "gpu"):
        existing = os.environ.get("XLA_FLAGS", "")
        missing = [f for f in GPU_XLA_FLAGS.split() if f.split("=")[0] not in existing]
        if missing:
            os.environ["XLA_FLAGS"] = (existing + " " + " ".join(missing)).strip()
        return "gpu"
    return platform or _probable_backend()


def _probable_backend() -> str:
    """The backend jax will (or did) pick, WITHOUT forcing backend init
    when the answer is already knowable from the environment — XLA_FLAGS
    applied after init are dead letters, so :func:`tuned_platform` must
    not itself trigger init while probing."""
    import os

    env = os.environ.get("JAX_PLATFORMS", "") or os.environ.get("JAX_PLATFORM_NAME", "")
    if env:
        return env.split(",")[0].strip().lower()
    if os.environ.get("CUDA_VISIBLE_DEVICES") not in (None, "", "-1") or os.path.exists(
        "/dev/nvidia0"
    ):
        return "gpu"
    return jax.default_backend()


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    Axes: `pod` is the DCN-crossing grid-site axis (the paper's "site"),
    `data` is intra-pod DP/FSDP, `model` is TP/EP.  The dry-run environment
    exposes 512 placeholder devices; the single-pod mesh uses the first 256.
    """
    import math

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devs = jax.devices()[:need]
    return jax.make_mesh(shape, axes, devices=devs)


def make_variant_mesh(name: str, *, multi_pod: bool = False):
    """Hillclimbing mesh variants (same chip counts as production).

    'moe2d': (data, expert, model) = (16, 8, 2) — factorises the 256-chip
    pod so coarse-expert MoEs (mixtral: 8 experts) get true expert
    parallelism instead of TP-within-expert (§Perf iteration)."""
    if name == "moe2d":
        shape = (2, 16, 8, 2) if multi_pod else (16, 8, 2)
        axes = ("pod", "data", "expert", "model") if multi_pod else ("data", "expert", "model")
        import math

        devs = jax.devices()[: math.prod(shape)]
        return jax.make_mesh(shape, axes, devices=devs)
    raise KeyError(name)


def init_multihost(
    coordinator_address: str | None = None,
    num_processes: int | None = None,
    process_id: int | None = None,
    cpu_collectives: str = "gloo",
) -> bool:
    """Initialize ``jax.distributed`` for a multi-process (multi-host)
    mesh; returns True when this jax runtime is multi-process afterwards.

    Idempotent: already-initialized runtimes (or single-process calls
    with no coordinator) return without touching jax state.  On CPU the
    cross-process collective implementation is selected BEFORE backend
    init (``gloo`` ships with jaxlib and makes psum/all_gather work
    across host processes — the two-subprocess smoke test exercises it);
    TPU/GPU runtimes ignore the flag.
    """
    if coordinator_address is None and num_processes is None:
        # nothing to initialize: report the launcher-provided topology
        # (safe to touch the backend here — no distributed init follows)
        return jax.process_count() > 1
    # ORDER MATTERS: jax.distributed.initialize must run before ANY jax
    # computation/backend query (jax.devices, jax.process_count, jit),
    # so the collective flag is set first and the backend only queried
    # after init.
    if cpu_collectives:
        try:
            jax.config.update("jax_cpu_collectives_implementation", cpu_collectives)
        except Exception:
            pass  # older jaxlib: collectives stay single-process
    try:
        jax.distributed.initialize(
            coordinator_address=coordinator_address,
            num_processes=num_processes,
            process_id=process_id,
        )
    except RuntimeError as e:
        # idempotence: a runtime initialized by the launcher (or an
        # earlier backend) is fine; anything else is a real error
        if "already" not in str(e):
            raise
    return jax.process_count() > 1


def make_multihost_mesh(n_sites: int | None = None, axis: str = "sites"):
    """1-D grid-site mesh over the GLOBAL device set of a multi-process
    runtime (``init_multihost`` first) — the multi-host counterpart of
    ``make_site_mesh``: every process sees the same mesh spanning every
    host's devices, so the same SiteJob DAGs and shard_map collectives
    distribute across hosts for real.

    ``n_sites=None`` uses every global device; otherwise the first
    ``n_sites`` (None is returned when the global runtime is too small,
    matching ``make_site_mesh``'s fallback contract).
    """
    devs = jax.devices()
    n = len(devs) if n_sites is None else n_sites
    if n < 1 or len(devs) < n:
        return None
    return jax.make_mesh((n,), (axis,), devices=devs[:n])


def site_ownership(
    sites,
    n_processes: int | None = None,
    mesh=None,
    site_weights: dict[int, float] | None = None,
) -> dict[int, int]:
    """Explicit ``site -> process`` ownership map for true multi-host
    execution: every grid site's jobs execute on exactly one process and
    only their RESULTS ship over the collective.

    Assignment is least-relative-load greedy over sorted site ids
    (deterministic; ties break to the lowest process id):

      * ``mesh`` given — the candidate processes and their capacities are
        derived from the global device mesh (capacity = local device
        count), so a process holding more of the mesh owns
        proportionally more sites;
      * otherwise — ``n_processes`` unit-capacity processes.

    ``site_weights`` (site -> load units, e.g. per-site worker slots)
    skews the balance toward lighter owners for heavy sites; UNIFORM
    weights — such as the scalar ``GridModel.workers_per_site`` — cancel
    out and reduce to round-robin, so only genuinely per-site
    heterogeneity changes the map.

    Deterministic on every process by construction — all inputs are
    global state, so every process derives the identical map.
    """
    site_ids = sorted(set(int(s) for s in sites))
    if mesh is not None:
        capacity: dict[int, int] = {}
        for d in mesh.devices.flat:
            capacity[int(d.process_index)] = capacity.get(int(d.process_index), 0) + 1
    else:
        n_proc = int(n_processes if n_processes is not None else jax.process_count())
        if n_proc < 1:
            raise ValueError(f"n_processes must be >= 1, got {n_proc}")
        capacity = dict.fromkeys(range(n_proc), 1)
    load = dict.fromkeys(capacity, 0.0)
    owner: dict[int, int] = {}
    for s in site_ids:
        w = float(site_weights.get(s, 1.0)) if site_weights else 1.0
        pid = min(capacity, key=lambda p: (load[p] / capacity[p], p))
        owner[s] = pid
        load[pid] += max(w, 1e-9)
    return owner


def allgather_bytes(data: bytes) -> list[bytes]:
    """Gather one variable-length bytes payload per process (identity on a
    single-process runtime) — the wire that ships owned-site results.

    Two ``process_allgather`` rounds: payload lengths first, then the
    max-length-padded uint8 buffers; each process's slice is returned in
    process-id order.  This is the ONLY cross-process communication the
    multihost backend performs — one shipment per executed job, i.e. the
    paper's synchronization traffic and nothing else.
    """
    import numpy as np

    if jax.process_count() <= 1:
        return [data]
    from jax.experimental.multihost_utils import process_allgather

    lens = np.asarray(
        process_allgather(np.asarray([len(data)], dtype=np.int64))
    ).reshape(-1)
    cap = max(int(lens.max()), 1)
    buf = np.zeros((cap,), dtype=np.uint8)
    if data:
        buf[: len(data)] = np.frombuffer(data, dtype=np.uint8)
    mat = np.asarray(process_allgather(buf)).reshape(len(lens), cap)
    return [mat[p, : int(lens[p])].tobytes() for p in range(len(lens))]


def allgather_payload(obj) -> list:
    """One-object-per-process shipment over :func:`allgather_bytes`:
    pack an arbitrary pytree payload (``compat.pack_payload`` — jax array
    leaves to host numpy, everything else pickled), gather every
    process's bytes in ONE ``allgather_bytes`` round, and unpack each
    slice.  This is the batched-shipment wire: the multihost backend
    ships a whole ready wave's result dict through one call instead of
    one ``allgather_bytes`` per job, so the collective count scales with
    waves, not jobs."""
    from repro.compat import pack_payload, unpack_payload

    return [unpack_payload(b) for b in allgather_bytes(pack_payload(obj))]


def make_site_mesh(n_sites: int, axis: str = "sites"):
    """1-D grid-site mesh for the mining runtime (one device per paper
    "site"), or None when the host exposes fewer devices than sites —
    callers fall back to the pooled vmap path.  Multi-device CPU tests get
    their devices from xla_force_host_platform_device_count."""
    devs = jax.devices()
    if n_sites < 1 or len(devs) < n_sites:
        return None
    return jax.make_mesh((n_sites,), (axis,), devices=devs[:n_sites])


def make_test_mesh(n_data: int = 2, n_model: int = 2, n_pods: int = 0):
    """Small mesh for multi-device CPU tests (subprocesses set
    xla_force_host_platform_device_count accordingly)."""
    if n_pods:
        return jax.make_mesh((n_pods, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


# TPU v5e hardware model used by the roofline analysis (per chip).
HW = {
    "peak_flops_bf16": 197e12,  # FLOP/s
    "hbm_bw": 819e9,  # B/s
    "ici_bw": 50e9,  # B/s per link (~ per-direction)
    "chips_per_pod": 256,
    "dcn_bw": 6.25e9,  # B/s per host NIC-ish; used for pod-crossing notes
}
