"""Shared benchmark utilities: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (one per paper
table/figure entry) so ``python -m benchmarks.run`` output is machine
readable end-to-end.
"""

from __future__ import annotations

import time


def timeit(fn, *args, repeats: int = 3, warmup: int = 1, **kw) -> float:
    """Median wall seconds of fn(*args)."""
    for _ in range(warmup):
        fn(*args, **kw)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def row(name: str, seconds: float, derived: str = "") -> str:
    line = f"{name},{seconds * 1e6:.1f},{derived}"
    print(line, flush=True)
    return line
