"""Pallas TPU kernel: K-Means assignment (pairwise sq-distance + argmin).

The hot inner loop of the paper's per-site local clustering.  TPU-native
formulation: d²(x,c) = ‖x‖² + ‖c‖² − 2·x·cᵀ so the dominant term is a
(TN×D)·(D×K) matmul that runs on the MXU; the argmin/min run on the VPU.

Tiling: grid over N tiles.  Each program holds one (TN, D) block of points
and the full (K, D) center set in VMEM (K and D are padded to the 128-lane
boundary by ``ops.kmeans_assign``).  VMEM footprint per program:
TN·D + K·D + TN·K floats — e.g. TN=256, D=128, K=128: ~49 KB·f32 ≪ 16 MB.

Padding contract (enforced by the wrapper): padded D columns are zero in
both x and centers (distances unchanged); padded K rows carry +BIG
sentinel centers so they never win the argmin.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import pad_to

BIG = 1e30  # sentinel coordinate for padded center rows


def _kernel(x_ref, c_ref, assign_ref, mind2_ref):
    x = x_ref[...].astype(jnp.float32)  # (TN, D)
    c = c_ref[...].astype(jnp.float32)  # (K, D)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)  # (TN, 1)
    c2 = jnp.sum(c * c, axis=-1)[None, :]  # (1, K)
    # MXU: (TN, D) @ (D, K)
    xc = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (TN, K)
    d2 = x2 + c2 - 2.0 * xc
    assign_ref[...] = jnp.argmin(d2, axis=-1).astype(jnp.int32)
    mind2_ref[...] = jnp.maximum(jnp.min(d2, axis=-1), 0.0)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign_pallas(
    x: jax.Array,  # (N, D) f32 — any N (auto-padded to block_n), D % 128 == 0
    centers: jax.Array,  # (K, D) f32 — K % 128 == 0, padded rows = BIG
    block_n: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Assignment for arbitrary N: points are auto-padded to the block
    multiple with zero rows whose outputs are sliced away before
    returning (padded rows cost compute, never correctness).  Block-
    multiple inputs take the original zero-copy path bit-for-bit.  The
    D/K lane-padding contract (zero columns, +BIG sentinel center rows)
    remains the wrapper's job — see ``ops.kmeans_assign``.

    Zero-size fast path: N=0 points (an empty delta batch) returns empty
    outputs without building a degenerate Pallas grid."""
    n, d = x.shape
    k, d2_ = centers.shape
    assert d == d2_, (x.shape, centers.shape)
    if n == 0:
        return jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.float32)
    np_ = pad_to(max(n, block_n), block_n)
    x_p = x if np_ == n else jnp.zeros((np_, d), x.dtype).at[:n].set(x)
    grid = (np_ // block_n,)
    assign, mind2 = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), jnp.int32),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
        ],
        interpret=interpret,
    )(x_p, centers)
    return assign[:n], mind2[:n]
