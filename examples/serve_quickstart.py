"""Serving-layer quickstart: a small multi-tenant session against the
continuous mining service, end to end.

Walks the full request lifecycle — register datasets, append data,
submit from three tenants, step the scheduler, read results and the
ledger — and prints where the cache hits, where requests coalesce, and
what an append (version bump) changes.

    PYTHONPATH=src python examples/serve_quickstart.py
"""

import numpy as np

from repro.data.synthetic import gaussian_mixture, ibm_transactions
from repro.launch.serve import MiningService

svc = MiningService(backend="batched", n_sites=2, count_backend="jnp")

# -- datasets grow by appends; every append bumps the dataset version
svc.register_dataset("tx", "transactions", n_items=10)
svc.register_dataset("pts", "points", dim=2)
svc.append_transactions("tx", ibm_transactions(0, 200, 10))
pts, _ = gaussian_mixture(0, 200, 2, 3)
svc.append_points("pts", pts)

# -- three tenants submit a burst; two of them ask the SAME query
r1 = svc.submit("alice", "apriori", "tx", {"k": 3, "minsup": 0.25})
r2 = svc.submit("bob", "apriori", "tx", {"minsup": 0.25, "k": 3})  # same, reordered
r3 = svc.submit("carol", "kmeans", "pts", {"k": 3, "iters": 10})
print("queued:", [svc.poll(r) for r in (r1, r2, r3)])

# -- one scheduler tick: fair pick -> coalesce -> execute through the
#    batched backend.  alice and bob's identical requests run ONCE.
svc.step(max_requests=8)
print("after step:", [svc.poll(r) for r in (r1, r2, r3)])
print("bob coalesced into alice's run:",
      svc.request(r2).coalesced_into == r1)

freq = svc.result(r1).frequent
print("frequent pairs:", freq[2][:5], "...")
print("kmeans centers:\n", np.asarray(svc.result(r3).centers).round(2))

# -- a repeat of the same query on unchanged data is a cache hit
r4 = svc.submit("carol", "apriori", "tx", {"k": 3, "minsup": 0.25})
svc.step()
print("repeat served from cache:", svc.request(r4).cache_hit)

# -- appending data bumps the version: the old entry is unreachable,
#    the next query recomputes (delta-Apriori pays only for the delta)
svc.append_transactions("tx", ibm_transactions(1, 50, 10))
r5 = svc.submit("alice", "apriori", "tx", {"k": 3, "minsup": 0.25})
svc.step()
req5 = svc.request(r5)
print(f"after append: version {req5.dataset_version}, "
      f"cache_hit={req5.cache_hit} (recomputed on fresh data)")

# -- the ledger: per-tenant queue wait / compute / cache accounting
led = svc.ledger()
print(f"cache: {led['cache']['hits']} hits / {led['cache']['misses']} misses; "
      f"executions={led['executions']}, coalesced={led['coalesced']}")
for tenant, t in sorted(led["per_tenant"].items()):
    print(f"  {tenant}: submitted={t['submitted']} done={t['done']} "
          f"cache_hits={t['cache_hits']} compute={t['compute_s']:.3f}s")
