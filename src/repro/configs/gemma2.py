"""gemma2-2b [dense] — local+global alternating attention, logit softcaps,
pre+post norms, scaled embeddings [arXiv:2408.00118].

long_500k note: NOT pure full-attention (half the layers are 4096-window
SWA; global layers are decode-linear per step), so the long-context decode
cell runs — see DESIGN.md §Arch-applicability.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab=256_000,
    rope_theta=10_000.0,
    window=4096,
    layer_pattern=("swa", "full"),
    attn_softcap=50.0,
    final_softcap=30.0,
    norm="rmsnorm",
    act="geglu",
    post_norm=True,
    embed_scale=True,
    subquadratic=True,
)
