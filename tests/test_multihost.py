"""Multi-host mesh scaffold: single-process fallback semantics in-process
and the CPU two-subprocess ``jax.distributed`` smoke test.

The subprocess test is the CI guard for ROADMAP follow-on (a): two host
processes bring up one ``jax.distributed`` runtime, agree on the global
device topology, build the same multi-host site mesh, exchange data with
a real cross-process collective (gloo CPU backend), and run a SiteJob
DAG through ``Engine(backend="multihost")`` with identical results on
every process.
"""

import json
import socket
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.runtime.backends import MultiHostBackend
from repro.workflow.dag import DAG
from repro.workflow.engine import Engine
from repro.workflow.overhead import GridModel

SRC = str(Path(__file__).resolve().parent.parent / "src")


class TestSingleProcessFallback:
    """Without a coordinator the backend must degrade to inline
    execution over the local devices — safe everywhere."""

    def test_describe_single_process(self):
        be = MultiHostBackend()
        info = be.describe()
        assert info["is_multiprocess"] is False
        assert info["process_count"] == 1
        assert info["n_global_devices"] >= 1
        assert info["mesh_shape"] == {"sites": info["n_global_devices"]}

    def test_allgather_check_identity(self):
        be = MultiHostBackend()
        out = be.allgather_check(7.0)
        assert out.shape == (1, 1) and float(out[0, 0]) == 7.0

    def test_engine_runs_with_multihost_backend(self):
        dag = DAG("d")
        dag.job("a", lambda: 2)
        dag.job("b", lambda a: a + 3, deps=["a"])
        results = {}
        rep = Engine(model=GridModel(prep_latency_s=0.0), backend="multihost").run(
            dag, results=results
        )
        assert results["b"] == 5
        assert rep.backend == "multihost"


CHILD = textwrap.dedent(
    """
    import json, sys
    sys.path.insert(0, {src!r})
    import os
    os.environ["JAX_PLATFORMS"] = "cpu"
    import jax
    from repro.launch.mesh import init_multihost, make_multihost_mesh
    from repro.runtime.backends import MultiHostBackend
    from repro.workflow.dag import DAG
    from repro.workflow.engine import Engine
    from repro.workflow.overhead import GridModel

    pid = int(sys.argv[1])
    be = MultiHostBackend(
        coordinator_address="127.0.0.1:{port}", num_processes=2, process_id=pid
    )
    info = be.describe()
    gathered = be.allgather_check(float(pid + 1)).reshape(-1).tolist()

    dag = DAG("smoke")
    dag.job("a", lambda: 20)
    dag.job("b", lambda a: a + 22, deps=["a"])
    results = {{}}
    rep = Engine(model=GridModel(prep_latency_s=0.0), backend="multihost").run(
        dag, results=results
    )
    print("MULTIHOST " + json.dumps({{
        "pid": pid,
        "process_count": info["process_count"],
        "n_global_devices": info["n_global_devices"],
        "n_local_devices": info["n_local_devices"],
        "mesh_shape": info["mesh_shape"],
        "is_multiprocess": info["is_multiprocess"],
        "gathered": gathered,
        "result": results["b"],
        "backend": rep.backend,
    }}), flush=True)
    """
)


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def test_two_process_cpu_smoke(tmp_path):
    """Two host processes, one distributed runtime: global topology,
    cross-process all_gather, and identical multihost-backend DAG
    results on both processes."""
    port = _free_port()
    script = tmp_path / "child.py"
    script.write_text(CHILD.format(src=SRC, port=port))
    procs = [
        subprocess.Popen(
            [sys.executable, str(script), str(pid)],
            stdout=subprocess.PIPE,
            stderr=subprocess.PIPE,
            text=True,
        )
        for pid in range(2)
    ]
    outs = []
    for p in procs:
        try:
            out, err = p.communicate(timeout=180)
        except subprocess.TimeoutExpired:
            for q in procs:
                q.kill()
            pytest.fail("multihost smoke subprocess timed out")
        assert p.returncode == 0, f"child failed:\nstdout:\n{out}\nstderr:\n{err}"
        outs.append(out)
    infos = []
    for out in outs:
        lines = [ln for ln in out.splitlines() if ln.startswith("MULTIHOST ")]
        assert lines, f"no smoke marker in child output: {out!r}"
        infos.append(json.loads(lines[0][len("MULTIHOST "):]))
    infos.sort(key=lambda d: d["pid"])
    for info in infos:
        assert info["is_multiprocess"] is True
        assert info["process_count"] == 2
        assert info["n_global_devices"] == 2
        assert info["n_local_devices"] == 1
        assert info["mesh_shape"] == {"sites": 2}
        # the cross-process collective really crossed processes
        assert info["gathered"] == [1.0, 2.0]
        # SPMD-redundant execution: identical results on every process
        assert info["result"] == 42
        assert info["backend"] == "multihost"
