"""MiningService behavior: submit/poll/result lifecycle, versioned cache
(hits never cross a dataset version), request coalescing, admission
control, weighted round-robin fairness, and the per-request/per-tenant
ledger."""

from __future__ import annotations

import json

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from repro.testing import given, settings, strategies as st

from repro.core.apriori import concat_dbs, local_apriori
from repro.launch.serve import MiningService, fairness_violations
from repro.workflow.registry import workloads
from repro.workflow.requests import QueueFullError, TenantQueues


def _tx_batch(seed: int, n_tx: int = 40, n_items: int = 8) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.random((n_tx, n_items)) < 0.45


def _service(**kw) -> MiningService:
    kw.setdefault("count_backend", "jnp")
    kw.setdefault("use_kernel", False)
    kw.setdefault("n_sites", 2)
    svc = MiningService(**kw)
    svc.register_dataset("tx", "transactions", n_items=8)
    svc.append_transactions("tx", _tx_batch(0))
    return svc


def test_submit_poll_result_lifecycle():
    svc = _service()
    rid = svc.submit("alice", "apriori", "tx", {"k": 3, "minsup": 0.2})
    assert svc.poll(rid) == "queued"
    with pytest.raises(RuntimeError, match="queued"):
        svc.result(rid)
    done = svc.step()
    assert done == [rid]
    assert svc.poll(rid) == "done"
    res = svc.result(rid)
    assert res.frequent[1]  # something is frequent at minsup 0.2
    req = svc.request(rid)
    assert req.dataset_version == 1
    assert req.backend == "batched"
    assert not req.cache_hit
    assert req.service_s >= req.queue_wait_s >= 0.0


def test_validation_errors():
    svc = _service()
    with pytest.raises(KeyError, match="register_dataset"):
        svc.submit("a", "apriori", "nope")
    with pytest.raises(ValueError, match="unknown app"):
        svc.submit("a", "word2vec", "tx")
    with pytest.raises(ValueError, match="points dataset"):
        svc.submit("a", "kmeans", "tx")
    with pytest.raises(ValueError, match="already registered"):
        svc.register_dataset("tx", "transactions", n_items=8)


def test_cache_hit_on_repeated_query():
    svc = _service()
    r1 = svc.submit("alice", "apriori", "tx", {"k": 3, "minsup": 0.2})
    svc.step()
    r2 = svc.submit("bob", "apriori", "tx", {"minsup": 0.2, "k": 3})  # reordered params
    svc.step()
    assert svc.cache.stats.hits == 1
    assert svc.executions == 1
    req2 = svc.request(r2)
    assert req2.cache_hit and req2.backend == "cache" and req2.compute_s == 0.0
    assert svc.result(r2) is svc.result(r1)


def test_cache_never_serves_across_versions():
    svc = _service()
    r1 = svc.submit("alice", "apriori", "tx", {"k": 3, "minsup": 0.2})
    svc.step()
    svc.append_transactions("tx", _tx_batch(1))
    r2 = svc.submit("alice", "apriori", "tx", {"k": 3, "minsup": 0.2})
    svc.step()
    req1, req2 = svc.request(r1), svc.request(r2)
    assert (req1.dataset_version, req2.dataset_version) == (1, 2)
    assert not req2.cache_hit  # the append made the old entry unreachable
    assert svc.cache.stats.hits == 0
    assert svc.result(r2).counts != svc.result(r1).counts


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(min_value=0, max_value=5_000))
def test_served_results_always_match_current_version(seed):
    """Interleave appends and repeated queries: every served result —
    cached or computed — equals from-scratch Apriori over the data as of
    the request's dataset_version (i.e. cache hits are never stale)."""
    rng = np.random.default_rng(seed)
    svc = MiningService(count_backend="jnp", use_kernel=False)
    svc.register_dataset("tx", "transactions", n_items=6)
    svc.append_transactions("tx", rng.random((int(rng.integers(5, 20)), 6)) < 0.5)
    for _ in range(4):
        if rng.random() < 0.5:
            svc.append_transactions("tx", rng.random((int(rng.integers(3, 15)), 6)) < 0.5)
        params = {"k": int(rng.integers(1, 4)), "min_count": int(rng.integers(1, 8))}
        rid = svc.submit("t0", "apriori", "tx", params)
        svc.step()
        got = svc.result(rid)
        state = svc._datasets["tx"].delta
        scratch = local_apriori(concat_dbs(state._batches), params["k"], params["min_count"])
        assert got.counts == scratch.counts
        assert got.frequent == scratch.frequent
    assert svc.cache.stats.hits + svc.cache.stats.misses == 4


def test_coalescing_identical_requests_one_execution():
    svc = _service()
    rids = [svc.submit(t, "apriori", "tx", {"k": 2, "minsup": 0.3})
            for t in ("a", "b", "c")]
    done = svc.step(max_requests=8)
    assert sorted(done) == sorted(rids)
    assert svc.executions == 1
    assert svc.coalesced == 2
    rep = svc.request(rids[0])
    assert rep.coalesced_into is None
    for rid in rids[1:]:
        assert svc.request(rid).coalesced_into == rids[0]
        assert svc.result(rid) is svc.result(rids[0])
    # a request with DIFFERENT params must not coalesce
    r4 = svc.submit("a", "apriori", "tx", {"k": 2, "minsup": 0.5})
    r5 = svc.submit("b", "apriori", "tx", {"k": 2, "minsup": 0.3})
    svc.step(max_requests=8)
    assert svc.request(r4).coalesced_into is None
    assert not svc.request(r4).cache_hit
    assert svc.request(r5).cache_hit  # same version+params as the first wave


def test_admission_control_bounded_queues():
    svc = _service(max_depth=2)
    svc.submit("a", "apriori", "tx", {"k": 1, "minsup": 0.9})
    svc.submit("a", "apriori", "tx", {"k": 1, "minsup": 0.8})
    with pytest.raises(QueueFullError, match="full"):
        svc.submit("a", "apriori", "tx", {"k": 1, "minsup": 0.7})
    assert svc.queues.rejected == 1
    led = svc.ledger()
    assert led["rejected"] == 1
    assert led["per_tenant"]["a"]["rejected"] == 1
    # other tenants are unaffected by a's full queue
    svc.submit("b", "apriori", "tx", {"k": 1, "minsup": 0.9})
    assert svc.queues.depth("b") == 1


def test_round_robin_fairness_bound():
    svc = _service()
    tenants = ["t0", "t1", "t2"]
    for i in range(4):
        for t in tenants:
            svc.submit(t, "apriori", "tx", {"k": 1, "min_count": i + 1})
    svc.drain(max_requests=5)
    assert len(svc.pick_log) == 12
    assert fairness_violations(svc.pick_log, tenants, len(svc.pick_log)) == []


def test_weighted_fairness_shares():
    q = TenantQueues(max_depth=32, weights={"big": 2.0, "small": 1.0})
    from repro.workflow.requests import MiningRequest

    for i in range(6):
        q.push(MiningRequest(request_id=i, tenant="big", app="apriori", dataset="d"))
    for i in range(3):
        q.push(MiningRequest(request_id=100 + i, tenant="small", app="apriori", dataset="d"))
    picks = [q.pick().tenant for _ in range(9)]
    assert picks == ["big", "big", "small"] * 3  # 2:1 weighted cycles
    assert q.pick() is None


def test_fractional_weights_honor_ratios():
    """Weights below 1 are normalized at construction (divide by the
    smallest), so {big: 1, small: 0.5} grants the SAME 2:1 shares as
    {big: 2, small: 1} — fractional weights are no longer silently
    rounded up to one pick per cycle."""
    q = TenantQueues(max_depth=32, weights={"big": 1.0, "small": 0.5})
    assert q.weights == {"big": 2.0, "small": 1.0}
    from repro.workflow.requests import MiningRequest

    for i in range(6):
        q.push(MiningRequest(request_id=i, tenant="big", app="apriori", dataset="d"))
    for i in range(3):
        q.push(MiningRequest(request_id=100 + i, tenant="small", app="apriori", dataset="d"))
    picks = [q.pick().tenant for _ in range(9)]
    assert picks == ["big", "big", "small"] * 3
    assert q.pick() is None
    # weights >= 1 are untouched; non-positive weights still rejected
    assert TenantQueues(weights={"a": 3.0, "b": 1.0}).weights == {"a": 3.0, "b": 1.0}
    with pytest.raises(ValueError, match="must be > 0"):
        TenantQueues(weights={"a": 0.0})


def test_failed_request_does_not_kill_service():
    # n_sites=0 passes submit-time validation (a finite int) but blows up
    # at execution when the dataset is split — the "one bad request must
    # not kill the service" guard in _step
    svc = _service()
    bad = svc.submit("a", "gfm", "tx", {"k": 2, "minsup": 0.3, "n_sites": 0})
    ok = svc.submit("b", "apriori", "tx", {"k": 2, "minsup": 0.3})
    done = svc.step(max_requests=4)
    assert sorted(done) == sorted([bad, ok])
    assert svc.poll(bad) == "failed"
    with pytest.raises(RuntimeError, match="failed"):
        svc.result(bad)
    assert svc.poll(ok) == "done"
    assert svc.ledger()["per_tenant"]["a"]["failed"] == 1


def test_malformed_params_rejected_at_submit():
    """Non-finite and uncoercible params are LEDGERED rejections at
    submit — the params_key crash class (inf/nan killing the dispatch
    loop) is unreachable from a tenant request."""
    svc = _service()
    with pytest.raises(ValueError, match="non-finite"):
        svc.submit("a", "apriori", "tx", {"minsup": float("inf")})
    with pytest.raises(ValueError, match="non-finite"):
        svc.submit("a", "apriori", "tx", {"minsup": float("nan")})
    with pytest.raises(ValueError, match="expects int"):
        svc.submit("a", "apriori", "tx", {"min_count": "not-a-number"})
    with pytest.raises(ValueError, match="does not accept param"):
        svc.submit("a", "apriori", "tx", {"bogus": 1})
    led = svc.ledger()
    assert led["rejected"] == 4
    rejected = [r for r in led["requests"] if r["status"] == "rejected"]
    assert len(rejected) == 4 and all(r["error"] for r in rejected)
    assert led["per_tenant"]["a"]["rejected"] == 4
    # the dispatch loop is unharmed: a well-formed request still runs
    ok = svc.submit("a", "apriori", "tx", {"k": 2, "minsup": 0.3})
    assert svc.step() == [ok]
    assert svc.poll(ok) == "done"


def test_kmeans_warm_start_across_versions():
    svc = MiningService(count_backend="jnp", use_kernel=False)
    svc.register_dataset("pts", "points", dim=2)
    rng = np.random.default_rng(0)
    svc.append_points("pts", rng.normal(size=(60, 2)).astype(np.float32))
    r1 = svc.submit("a", "kmeans", "pts", {"k": 3, "iters": 8})
    svc.step()
    assert 3 in svc._datasets["pts"].warm_centers  # centroids retained
    svc.append_points("pts", rng.normal(loc=2.0, size=(30, 2)).astype(np.float32))
    r2 = svc.submit("a", "kmeans", "pts", {"k": 3, "iters": 8})
    svc.step()
    res1, res2 = svc.result(r1), svc.result(r2)
    assert res2.centers.shape == (3, 2)
    assert res2.assign.shape == (90,)
    assert not svc.request(r2).cache_hit  # version bumped between queries
    assert np.isfinite(float(res2.inertia)) and float(res1.inertia) >= 0.0


def _registry_tx_pool(n_sites: int) -> list[tuple[str, dict]]:
    """Every registered transactions workload's smoke params — the mixed
    trace is parametrized off the registry, so a newly registered app is
    exercised here with NO test change."""
    pool: list[tuple[str, dict]] = []
    for spec in workloads():
        if spec.dataset_kind != "transactions":
            continue
        for smoke in spec.smoke_params:
            params = dict(smoke)
            if spec.runner == "grid":
                params["n_sites"] = n_sites
            pool.append((spec.name, params))
    return pool


def test_mixed_tenant_trace_ledger():
    """A small mixed-tenant burst trace end-to-end on the batched
    backend, drawing every registered transactions app from the registry
    smoke params: everything completes, repeats hit the cache, identical
    concurrent requests coalesce, the fairness bound holds, and the
    ledger is JSON-serializable."""
    svc = _service()
    tenants = ["t0", "t1", "t2"]
    pool = _registry_tx_pool(n_sites=2)
    assert {app for app, _ in pool} == {
        s.name for s in workloads() if s.dataset_kind == "transactions"
    }
    rng = np.random.default_rng(7)
    for burst in range(3):
        for t in tenants:
            # bursts 0 and 1 share one query at the same dataset version
            # (the append lands after burst 1): burst 0 executes it,
            # burst 1 coalesces AND cache-hits it deterministically
            app, params = pool[(burst // 2) % len(pool)]
            svc.submit(t, app, "tx", params)
            app, params = pool[int(rng.integers(len(pool)))]
            svc.submit(t, app, "tx", params)
        svc.drain(max_requests=6)
        if burst == 1:
            svc.append_transactions("tx", _tx_batch(burst + 10, n_tx=20))
    led = svc.ledger()
    assert len(led["requests"]) == 18
    assert all(r["status"] == "done" for r in led["requests"])
    assert led["cache"]["hits"] > 0
    assert led["coalesced"] > 0
    assert led["executions"] + led["cache"]["hits"] + led["coalesced"] == 18
    assert fairness_violations(svc.pick_log, tenants, len(svc.pick_log)) == []
    for t in tenants:
        assert led["per_tenant"][t]["submitted"] == 6
        assert led["per_tenant"][t]["done"] == 6
    json.dumps(led)  # the CI artifact must serialize


def test_ledger_records_shape():
    svc = _service()
    rid = svc.submit("a", "apriori", "tx", {"k": 2, "minsup": 0.3})
    svc.step()
    rec = next(r for r in svc.ledger()["requests"] if r["request_id"] == rid)
    for field in ("tenant", "app", "dataset", "dataset_version", "status",
                  "cache_hit", "coalesced_into", "backend", "queue_wait_s",
                  "compute_s", "service_s", "error"):
        assert field in rec
    assert rec["status"] == "done" and rec["error"] is None
