"""Per-kernel validation: Pallas (interpret mode on CPU) vs pure-jnp
oracles, swept over shapes/dtypes with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic env: deterministic shim, no shrinking
    from repro.testing import given, settings, strategies as st

from repro.core.apriori import pack_bool_matrix, pack_itemsets
from repro.kernels import ops
from repro.kernels.kmeans_assign import BIG, kmeans_assign_pallas
from repro.kernels.ref import kmeans_assign_ref, support_count_ref
from repro.kernels.support_count import support_count_pallas, support_count_prune_pallas


class TestKMeansAssignKernel:
    @given(
        n=st.integers(1, 700),
        d=st.integers(1, 160),
        k=st.integers(1, 130),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=25, deadline=None)
    def test_matches_oracle(self, n, d, k, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        c = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
        a_k, d_k = ops.kmeans_assign(x, c)
        a_r, d_r = kmeans_assign_ref(x, c)
        np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r), rtol=1e-3, atol=1e-3)
        # argmin ties can differ only when two centers are equidistant
        diff = np.asarray(a_k) != np.asarray(a_r)
        if diff.any():
            dd = np.asarray(jnp.sum((x[diff, None] - c[None]) ** 2, -1))
            best2 = np.sort(dd, axis=1)[:, :2]
            np.testing.assert_allclose(best2[:, 0], best2[:, 1], rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(128, 16))).astype(dtype)
        c = jnp.asarray(rng.normal(size=(8, 16))).astype(dtype)
        a_k, _ = ops.kmeans_assign(x, c)
        a_r, _ = kmeans_assign_ref(x, c)
        assert (np.asarray(a_k) == np.asarray(a_r)).mean() > 0.97

    @pytest.mark.parametrize("block_n", [64, 128, 512])
    def test_block_shapes(self, block_n):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(300, 24)).astype(np.float32))
        c = jnp.asarray(rng.normal(size=(12, 24)).astype(np.float32))
        a_k, d_k = ops.kmeans_assign(x, c, block_n=block_n)
        a_r, d_r = kmeans_assign_ref(x, c)
        assert np.array_equal(np.asarray(a_k), np.asarray(a_r))

    def test_fused_site_axis(self):
        """ops.kmeans_assign_sites — the vmapped site-axis form — must
        match per-site ops.kmeans_assign calls exactly."""
        rng = np.random.default_rng(4)
        xs = jnp.asarray(rng.normal(size=(3, 70, 5)).astype(np.float32))
        cs = jnp.asarray(rng.normal(size=(3, 6, 5)).astype(np.float32))
        a_s, d_s = ops.kmeans_assign_sites(xs, cs)
        assert a_s.shape == (3, 70) and d_s.shape == (3, 70)
        for i in range(3):
            a_i, d_i = ops.kmeans_assign(xs[i], cs[i])
            assert np.array_equal(np.asarray(a_s[i]), np.asarray(a_i))
            np.testing.assert_allclose(np.asarray(d_s[i]), np.asarray(d_i), rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("n", [1, 5, 129, 255, 256])
    def test_pallas_entry_odd_n(self, n):
        """The kernel entry point itself accepts arbitrary N (auto-pads
        to the block and slices the pad rows away); D/K stay on the
        lane-padding contract (zero columns, +BIG sentinel rows)."""
        rng = np.random.default_rng(n)
        x = jnp.asarray(rng.normal(size=(n, 128)).astype(np.float32))
        c = jnp.full((128, 128), BIG, jnp.float32)
        c = c.at[:9].set(jnp.asarray(rng.normal(size=(9, 128)).astype(np.float32)))
        a_k, d_k = kmeans_assign_pallas(x, c, block_n=128, interpret=True)
        a_r, d_r = kmeans_assign_ref(x, c[:9])
        assert a_k.shape == (n,) and d_k.shape == (n,)
        assert np.array_equal(np.asarray(a_k), np.asarray(a_r))
        np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r), rtol=1e-3, atol=1e-3)


class TestSupportCountKernel:
    @given(
        n=st.integers(1, 1200),
        items=st.integers(1, 200),
        c=st.integers(1, 300),
        density=st.floats(0.05, 0.6),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=25, deadline=None)
    def test_matches_oracle(self, n, items, c, density, seed):
        rng = np.random.default_rng(seed)
        dense = rng.random((n, items)) < density
        tx = jnp.asarray(pack_bool_matrix(dense))
        sets = [
            tuple(sorted(rng.choice(items, size=rng.integers(1, min(5, items) + 1), replace=False).tolist()))
            for _ in range(c)
        ]
        masks = jnp.asarray(pack_itemsets(sets, items))
        got = ops.support_count(tx, masks)
        want = support_count_ref(tx, masks)
        assert np.array_equal(np.asarray(got), np.asarray(want))
        # cross-check against numpy ground truth
        direct = np.array([dense[:, list(s)].all(axis=1).sum() for s in sets])
        np.testing.assert_array_equal(np.asarray(got), direct)

    @pytest.mark.parametrize("blocks", [(128, 128), (512, 512), (256, 1024)])
    def test_block_shapes(self, blocks):
        bn, bc = blocks
        rng = np.random.default_rng(2)
        dense = rng.random((700, 64)) < 0.3
        tx = jnp.asarray(pack_bool_matrix(dense))
        sets = [(0, 1), (5,), (2, 9, 33)] * 50
        masks = jnp.asarray(pack_itemsets(sets, 64))
        got = ops.support_count(tx, masks, block_n=bn, block_c=bc)
        want = support_count_ref(tx, masks)
        assert np.array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("n,c", [(1, 1), (7, 3), (511, 513), (700, 129), (512, 512)])
    def test_pallas_entry_odd_shapes(self, n, c):
        """The kernel entry point itself (not the ops wrapper) accepts
        arbitrary non-block-multiple N/C by auto-padding: padded rows
        count zero support."""
        rng = np.random.default_rng(n * 1000 + c)
        dense = rng.random((n, 40)) < 0.3
        tx = pack_bool_matrix(dense)
        sets = [
            tuple(sorted(rng.choice(40, size=rng.integers(1, 4), replace=False).tolist()))
            for _ in range(c)
        ]
        masks = pack_itemsets(sets, 40)
        tx_t = jnp.asarray(tx.astype(np.int64).astype(np.int32)).T
        mk_t = jnp.asarray(masks.astype(np.int64).astype(np.int32)).T
        got = support_count_pallas(tx_t, mk_t, block_n=128, block_c=128, interpret=True)
        want = support_count_ref(jnp.asarray(tx), jnp.asarray(masks))
        assert got.shape == (c,)
        assert np.array_equal(np.asarray(got), np.asarray(want))

    def test_pallas_entry_empty_mask_pad_correction(self):
        """An all-zero mask matches the zero pad rows; the kernel must
        correct its count back to the true transaction count."""
        rng = np.random.default_rng(0)
        dense = rng.random((130, 32)) < 0.5
        tx_t = jnp.asarray(pack_bool_matrix(dense).astype(np.int64).astype(np.int32)).T
        mk_t = jnp.zeros((tx_t.shape[0], 2), jnp.int32)  # two empty itemsets
        got = support_count_pallas(tx_t, mk_t, block_n=128, block_c=128, interpret=True)
        np.testing.assert_array_equal(np.asarray(got), [130, 130])

    def test_wide_item_universe(self):
        """> 32 words (1024+ items) exercises the W loop."""
        rng = np.random.default_rng(3)
        dense = rng.random((200, 1100)) < 0.1
        tx = jnp.asarray(pack_bool_matrix(dense))
        sets = [tuple(sorted(rng.choice(1100, size=2, replace=False).tolist())) for _ in range(40)]
        masks = jnp.asarray(pack_itemsets(sets, 1100))
        got = ops.support_count(tx, masks)
        direct = np.array([dense[:, list(s)].all(axis=1).sum() for s in sets])
        np.testing.assert_array_equal(np.asarray(got), direct)


class TestZeroSizeEdges:
    """C=0 candidates (a dried-up Apriori level) and N=0 transactions/
    points (an empty delta batch) must return empty results instead of
    building a degenerate Pallas grid — both shapes are reachable from
    ``DeltaApriori.append`` and the level loop."""

    def test_support_count_zero_candidates(self):
        rng = np.random.default_rng(0)
        tx = jnp.asarray(pack_bool_matrix(rng.random((50, 32)) < 0.4))
        out = ops.support_count(tx, jnp.zeros((0, 1), jnp.uint32))
        assert out.shape == (0,) and out.dtype == jnp.int32

    def test_support_count_zero_transactions(self):
        masks = jnp.asarray(pack_itemsets([(0, 1), (2,)], 32))
        out = ops.support_count(jnp.zeros((0, 1), jnp.uint32), masks)
        assert out.shape == (2,)
        np.testing.assert_array_equal(np.asarray(out), [0, 0])

    def test_support_count_prune_zero_sizes(self):
        masks = jnp.asarray(pack_itemsets([(0, 1), (2,)], 32))
        cnt, freq = ops.support_count_prune(jnp.zeros((0, 1), jnp.uint32), masks, 1)
        assert cnt.shape == (2,) and freq.shape == (2,)
        assert not np.asarray(freq).any()
        cnt0, freq0 = ops.support_count_prune(
            jnp.zeros((0, 1), jnp.uint32), jnp.zeros((0, 1), jnp.uint32), 1
        )
        assert cnt0.shape == (0,) and freq0.shape == (0,)

    def test_kmeans_assign_zero_points(self):
        rng = np.random.default_rng(1)
        c = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))
        a, d2 = ops.kmeans_assign(jnp.zeros((0, 8), jnp.float32), c)
        assert a.shape == (0,) and d2.shape == (0,)
        assert a.dtype == jnp.int32 and d2.dtype == jnp.float32

    def test_pallas_entries_zero_sizes(self):
        """The jitted kernel entry points themselves take the fast path."""
        a, d2 = kmeans_assign_pallas(
            jnp.zeros((0, 128), jnp.float32),
            jnp.full((128, 128), BIG, jnp.float32),
            interpret=True,
        )
        assert a.shape == (0,)
        out = support_count_pallas(
            jnp.zeros((2, 10), jnp.int32), jnp.zeros((2, 0), jnp.int32), interpret=True
        )
        assert out.shape == (0,)
        out = support_count_pallas(
            jnp.zeros((2, 0), jnp.int32), jnp.ones((2, 3), jnp.int32), interpret=True
        )
        np.testing.assert_array_equal(np.asarray(out), [0, 0, 0])


class TestSupportCountPrune:
    """The fused count+threshold kernel must equal count-then-threshold
    exactly — the conformance-adjacent gate for the Apriori level fusion."""

    @given(
        n=st.integers(1, 900),
        items=st.integers(1, 96),
        c=st.integers(1, 200),
        min_count=st.integers(0, 400),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=25, deadline=None)
    def test_equals_count_then_threshold(self, n, items, c, min_count, seed):
        rng = np.random.default_rng(seed)
        dense = rng.random((n, items)) < 0.3
        tx = jnp.asarray(pack_bool_matrix(dense))
        sets = [
            tuple(sorted(rng.choice(items, size=rng.integers(1, min(4, items) + 1), replace=False).tolist()))
            for _ in range(c)
        ]
        masks = jnp.asarray(pack_itemsets(sets, items))
        cnt, freq = ops.support_count_prune(tx, masks, min_count)
        want = np.asarray(ops.support_count(tx, masks))
        np.testing.assert_array_equal(np.asarray(cnt), want)
        np.testing.assert_array_equal(np.asarray(freq), want >= min_count)

    def test_threshold_is_traced_not_static(self):
        """Distinct thresholds must share one compilation (min_count is a
        traced operand, not a static arg that would recompile per level)."""
        rng = np.random.default_rng(2)
        tx = jnp.asarray(pack_bool_matrix(rng.random((200, 32)) < 0.4))
        masks = jnp.asarray(pack_itemsets([(0,), (1, 2), (3, 4, 5)], 32))
        base = np.asarray(ops.support_count(tx, masks))
        for mc in (0, 1, 50, 200, 10**6):
            _, freq = ops.support_count_prune(tx, masks, mc)
            np.testing.assert_array_equal(np.asarray(freq), base >= mc)

    def test_empty_mask_pad_correction_in_kernel(self):
        """The in-kernel pad correction must run BEFORE thresholding: an
        all-zero mask over a non-block-multiple N must report the true
        transaction count and threshold against it."""
        rng = np.random.default_rng(3)
        dense = rng.random((130, 32)) < 0.5
        tx_t = jnp.asarray(pack_bool_matrix(dense).astype(np.int64).astype(np.int32)).T
        mk_t = jnp.zeros((tx_t.shape[0], 2), jnp.int32)
        cnt, freq = support_count_prune_pallas(
            tx_t, mk_t, 131, block_n=128, block_c=128, interpret=True
        )
        np.testing.assert_array_equal(np.asarray(cnt), [130, 130])
        # 130 < 131: the padded (256-row) count would wrongly pass
        np.testing.assert_array_equal(np.asarray(freq), [False, False])
        _, freq2 = support_count_prune_pallas(
            tx_t, mk_t, 130, block_n=128, block_c=128, interpret=True
        )
        np.testing.assert_array_equal(np.asarray(freq2), [True, True])

    def test_prune_sites_per_site_thresholds(self):
        """The fused site-axis form applies each site's OWN threshold."""
        rng = np.random.default_rng(4)
        dense = rng.random((2, 90, 32)) < 0.4
        txs = jnp.asarray(np.stack([pack_bool_matrix(d) for d in dense]))
        sets = [(0, 1), (2,), (3, 4)]
        masks = jnp.asarray(np.stack([pack_itemsets(sets, 32)] * 2))
        cnt, freq = ops.support_count_prune_sites(txs, masks, jnp.asarray([5, 80]))
        for i, mc in enumerate((5, 80)):
            want = np.asarray(ops.support_count(txs[i], masks[i]))
            np.testing.assert_array_equal(np.asarray(cnt[i]), want)
            np.testing.assert_array_equal(np.asarray(freq[i]), want >= mc)


class TestSLSTMKernel:
    """The VMEM-resident-weights sLSTM kernel (§Perf, xlstm train cell)
    must match the sequential JAX reference bit-for-tolerance."""

    def _setup(self, seed, b, s, d, h):
        from repro.models import xlstm as X
        from repro.models.config import ModelConfig
        from repro.models.layers import init_from_specs

        cfg = ModelConfig(n_layers=1, d_model=d, n_heads=h, n_kv_heads=h,
                          head_dim=d // h, d_ff=0, vocab=64, dtype="float32")
        p = init_from_specs(jax.random.PRNGKey(seed), X.slstm_spec(cfg))
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(b, s, d)).astype(np.float32) * 0.5)
        return cfg, p, x

    @pytest.mark.parametrize("b,s,d,h,tc", [(2, 16, 32, 2, 4), (3, 24, 64, 4, 8), (1, 8, 16, 1, 8)])
    def test_matches_reference(self, b, s, d, h, tc):
        from repro.models import xlstm as X

        cfg, p, x = self._setup(0, b, s, d, h)
        y_ref, cache_ref = X.apply_slstm(cfg, p, x)
        wx = jnp.einsum("bsd,dhq->bshq", x, p["w"])
        pdim = d // h
        zero = jnp.zeros((b, h, pdim), jnp.float32)
        hids, (cT, nT, hT) = ops.slstm_scan(wx, p["r"], p["bias"], (zero, zero, zero), t_chunk=tc)
        from repro.models.layers import rms_norm

        y_k = rms_norm(hids.reshape(b, s, d), p["out_norm"]["scale"]) @ p["w_out"]
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(hT), np.asarray(cache_ref["hid"]), rtol=2e-4, atol=2e-4)

    def test_state_carries_across_chunks(self):
        cfg, p, x = self._setup(1, 2, 32, 32, 2)
        wx = jnp.einsum("bsd,dhq->bshq", x, p["w"])
        zero = jnp.zeros((2, 2, 16), jnp.float32)
        h_all, st_all = ops.slstm_scan(wx, p["r"], p["bias"], (zero, zero, zero), t_chunk=32)
        h_c, st_c = ops.slstm_scan(wx, p["r"], p["bias"], (zero, zero, zero), t_chunk=4)
        np.testing.assert_allclose(np.asarray(h_all), np.asarray(h_c), rtol=1e-5, atol=1e-5)
        for a, b_ in zip(st_all, st_c):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-5, atol=1e-5)


class TestFlashAttentionKernel:
    """Flash attention (VMEM-resident score blocks — §Roofline fix for the
    fleet-wide memory-dominated attention streams) vs the chunked oracle."""

    @staticmethod
    def _ref(q, k, v, causal, window, cap):
        from repro.models.attention import _grouped, chunked_attention

        b, sq, h, dh = q.shape
        kvh = k.shape[2]
        out = chunked_attention(
            _grouped(q, kvh), k, v,
            jnp.arange(sq, dtype=jnp.int32), jnp.arange(k.shape[1], dtype=jnp.int32),
            causal=causal, window=window, cap=cap, chunk=64,
        )
        return out.reshape(b, sq, h, dh)

    @given(
        b=st.integers(1, 3),
        sq=st.integers(1, 96),
        h_g=st.sampled_from([(1, 1), (2, 1), (4, 2), (4, 4)]),
        dh=st.sampled_from([16, 32, 64]),
        causal=st.booleans(),
        window=st.sampled_from([0, 16]),
        cap=st.sampled_from([0.0, 30.0]),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=20, deadline=None)
    def test_matches_oracle(self, b, sq, h_g, dh, causal, window, cap, seed):
        h, kvh = h_g
        rng = np.random.default_rng(seed)
        skv = sq if causal else ((sq + 15) // 16) * 16  # non-causal: divisible
        q = jnp.asarray(rng.normal(size=(b, sq, h, dh)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(b, skv, kvh, dh)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, skv, kvh, dh)).astype(np.float32))
        got = ops.flash_attention(q, k, v, causal=causal, window=window, cap=cap,
                                  block_q=16, block_k=16)
        want = self._ref(q, k, v, causal, window, cap)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-4, atol=3e-4)

    def test_bf16(self):
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(size=(2, 32, 4, 32))).astype(jnp.bfloat16)
        k = jnp.asarray(rng.normal(size=(2, 32, 2, 32))).astype(jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(2, 32, 2, 32))).astype(jnp.bfloat16)
        got = ops.flash_attention(q, k, v, block_q=16, block_k=16)
        want = self._ref(q, k, v, True, 0, 0.0)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=5e-2, atol=5e-2
        )
