"""Scalability in #sites (the grid dimension the paper cares about):
communication bytes and sync rounds vs s for both algorithms — clustering
comm grows O(s*k) (stats only) while data grows O(n); GFM rounds stay 2
at every scale while FDM stays k."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro.core.apriori import TransactionDB
from repro.core.fdm import fdm_mine
from repro.core.gfm import gfm_mine
from repro.core.vclustering import VClusterConfig, vcluster_pooled
from repro.data.synthetic import gaussian_mixture, ibm_transactions, split_sites, split_transactions


def run():
    # clustering: fixed global data, growing sites
    pts, _ = gaussian_mixture(3, 64_000, 6, n_components=8, spread=15.0, sigma=0.7)
    for s in (2, 4, 8, 16):
        xs = split_sites(pts, s, seed=0)
        cfg = VClusterConfig(k_local=12, kmeans_iters=15)
        t0 = time.perf_counter()
        res = vcluster_pooled(jax.random.PRNGKey(0), jnp.asarray(xs), cfg)
        jax.block_until_ready(res.labels)
        dt = time.perf_counter() - t0
        row(f"vcluster_sites_{s}", dt, f"comm_bytes={int(res.comm_bytes)};n_global={int(res.merged.n_global)}")

    # itemsets: fixed global db, growing sites
    dense = ibm_transactions(seed=9, n_tx=12_000, n_items=64, avg_tx_len=8, n_patterns=16)
    for s in (2, 4, 8, 16):
        sites = [TransactionDB.from_dense(x) for x in split_transactions(dense, s, seed=0)]
        t0 = time.perf_counter()
        g = gfm_mine(sites, 4, 0.06)
        t_g = time.perf_counter() - t0
        t0 = time.perf_counter()
        f = fdm_mine(sites, 4, 0.06)
        t_f = time.perf_counter() - t0
        assert g.frequent == f.frequent
        row(
            f"gfm_sites_{s}", t_g,
            f"rounds={g.comm.rounds};bytes={g.comm.bytes_sent};fdm_rounds={f.comm.rounds};fdm_bytes={f.comm.bytes_sent};fdm_s={t_f:.3f}",
        )


if __name__ == "__main__":
    run()
