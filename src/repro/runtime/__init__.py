"""Multi-device grid-mining runtime.

Bridges the repo's two halves: the paper-faithful mining algorithms
(``repro.core``) and the DAGMan-analog grid workflow model
(``repro.workflow``).  ``GridRuntime`` executes both applications
end-to-end through ``workflow.engine.Engine`` on a real JAX device mesh,
with measured kernel time calibrating the simulated grid clock.

Runtime-built engines default to the BATCHED execution backend (fused
vmapped fan-out dispatch, proven bit-identical to inline by the
conformance suite); ``backend="inline"`` restores the per-job host loop,
and ``MultiHostBackend`` distributes the same DAGs over a
``jax.distributed`` process mesh with wave-fused result shipping.
``ResultCache`` is the serving layer's versioned result cache
(``launch.serve``): keys carry the dataset version, so stale results are
unreachable by construction.
"""

from repro.runtime.backends import MultiHostBackend
from repro.runtime.cache import CacheStats, ResultCache, params_key
from repro.runtime.gridruntime import GridRuntime, RuntimeRun

__all__ = [
    "CacheStats",
    "GridRuntime",
    "MultiHostBackend",
    "ResultCache",
    "RuntimeRun",
    "params_key",
]
