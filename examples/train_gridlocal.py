"""Training driver: GridLocal (the paper's minimal-sync pattern) on a
small LM with checkpoint/restart.

Trains a reduced-config model for --steps steps on synthetic tokens with
N simulated grid sites, merging every H inner steps, checkpointing every
C steps, and (to demonstrate fault tolerance) killing and resuming the
run halfway.  Communication ledger printed at the end.

    PYTHONPATH=src python examples/train_gridlocal.py --steps 60
"""

import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import TokenStream
from repro.models import transformer as T
from repro.models.config import reduced
from repro.optim.adamw import AdamWConfig
from repro.optim.outer import OuterConfig, outer_init, outer_update
from repro.train.steps import make_train_step, materialize_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b", choices=C.ARCHS)
    ap.add_argument("--steps", type=int, default=48)
    ap.add_argument("--sites", type=int, default=2)
    ap.add_argument("--h-steps", type=int, default=8)
    ap.add_argument("--ckpt-every", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced(C.get(args.arch)).scaled(vocab=512)
    print(f"== GridLocal training: {cfg.name}, {T.param_count(cfg) / 1e6:.2f}M params, "
          f"{args.sites} sites, merge every {args.h_steps} ==")

    stream = TokenStream(vocab=cfg.vocab, global_batch=4 * args.sites, seq_len=64, seed=0,
                         frontend_len=cfg.frontend_len if cfg.frontend != "none" else 0,
                         d_model=cfg.d_model)
    opt_cfg = AdamWConfig(lr=3e-3, warmup=5, decay_steps=args.steps)
    inner_step = jax.jit(make_train_step(cfg, opt_cfg, loss_chunk=32))
    outer_cfg = OuterConfig(h_steps=args.h_steps, outer_lr=0.7, outer_momentum=0.9)

    ckdir = tempfile.mkdtemp()
    ck = Checkpointer(ckdir, keep=2, async_mode=True)

    # per-site replicas (the pod axis, simulated sequentially on CPU)
    sites = [materialize_state(cfg, jax.random.PRNGKey(0)) for _ in range(args.sites)]
    outer = outer_init(sites[0]["params"])
    pbytes = sum(leaf.size * leaf.dtype.itemsize for leaf in jax.tree.leaves(sites[0]["params"]))
    sync_bytes = 0

    def one_step(step):
        nonlocal sites, outer, sync_bytes
        batch = jax.tree.map(jnp.asarray, stream.batch_at(step))
        losses = []
        for s in range(args.sites):
            sub = jax.tree.map(lambda x: x[s::args.sites], batch)
            sites[s], m = inner_step(sites[s], sub)
            losses.append(float(m["loss"]))
        if (step + 1) % args.h_steps == 0:
            merged = jax.tree.map(
                lambda *xs: sum(x.astype(jnp.float32) for x in xs) / args.sites,
                *[st["params"] for st in sites],
            )
            new_p, outer = outer_update(outer_cfg, outer, merged)
            for st in sites:
                st["params"] = new_p
            sync_bytes += args.sites * pbytes
        return float(np.mean(losses))

    half = args.steps // 2
    for step in range(half):
        loss = one_step(step)
        if (step + 1) % args.ckpt_every == 0:
            ck.save(step + 1, {"sites": sites, "outer": outer})
        if step % 8 == 0:
            print(f"step {step:4d} loss {loss:.4f}")

    # ---- simulated crash + rescue restart ----
    ck.save(half, {"sites": sites, "outer": outer}, wait=True)
    print(f"-- simulated node failure at step {half}; restoring from {ckdir} --")
    like = {"sites": [materialize_state(cfg, jax.random.PRNGKey(1)) for _ in range(args.sites)],
            "outer": outer_init(sites[0]["params"])}
    restored = jax.tree.map(jnp.asarray, ck.restore(like))
    sites, outer = restored["sites"], restored["outer"]

    final_loss = None
    for step in range(half, args.steps):
        final_loss = one_step(step)
        if step % 8 == 0:
            print(f"step {step:4d} loss {final_loss:.4f}")

    dp_bytes = args.steps * args.sites * pbytes
    print(f"== done: final loss {final_loss:.4f} ==")
    print(f"GridLocal cross-site traffic: {sync_bytes / 1e6:.1f} MB "
          f"vs synchronous DP {dp_bytes / 1e6:.1f} MB  ({dp_bytes / max(sync_bytes, 1):.0f}x reduction)")


if __name__ == "__main__":
    main()
