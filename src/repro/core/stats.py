"""Sufficient statistics for variance-based distributed clustering.

The paper's key asymmetry: a site never ships data points, only the triple
(size N, center c, within-cluster SSE ``var``) per sub-cluster.  All global
decisions (merging, perturbation bookkeeping) are derivable from these.

Formulas (paper §3.1):

    N_new  = N_i + N_j
    c_new  = (N_i c_i + N_j c_j) / N_new
    var_new = var_i + var_j + s(i, j)
    s(i,j) = (N_i N_j) / (N_i + N_j) * d(c_i, c_j)^2

``var`` is the within-cluster *sum of squared distances* (SSE), which is
additive under the union formula above — this is what makes "logical
merging" possible with zero data movement.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SuffStats(NamedTuple):
    """Per-(sub)cluster sufficient statistics, vectorised over M slots.

    sizes:   (M,)   float32 — number of points (0 marks a dead/empty slot)
    centers: (M, D) float32 — centroid
    sse:     (M,)   float32 — within-cluster sum of squared distances ("var")
    """

    sizes: jax.Array
    centers: jax.Array
    sse: jax.Array

    @property
    def n_slots(self) -> int:
        return self.sizes.shape[0]

    @property
    def dim(self) -> int:
        return self.centers.shape[-1]


def stats_from_assignment(x: jax.Array, assign: jax.Array, k: int) -> SuffStats:
    """Compute per-cluster sufficient statistics from an assignment vector.

    x: (N, D); assign: (N,) int in [0, k).  Returns SuffStats with M = k.
    Empty clusters get size 0, center 0, sse 0.
    """
    n, d = x.shape
    one = jnp.ones((n,), dtype=jnp.float32)
    sizes = jax.ops.segment_sum(one, assign, num_segments=k)
    sums = jax.ops.segment_sum(x.astype(jnp.float32), assign, num_segments=k)
    safe = jnp.maximum(sizes, 1.0)
    centers = sums / safe[:, None]
    # SSE via E[|x|^2] - |c|^2 * N  (one pass, numerically fine in f32 for
    # the data scales used here; tests cross-check against direct form).
    sqsum = jax.ops.segment_sum(
        jnp.sum(x.astype(jnp.float32) ** 2, axis=-1), assign, num_segments=k
    )
    sse = sqsum - sizes * jnp.sum(centers**2, axis=-1)
    sse = jnp.maximum(sse, 0.0)  # clamp negative rounding residue
    return SuffStats(sizes=sizes, centers=centers, sse=sse)


def merge_cost(stats: SuffStats) -> jax.Array:
    """Pairwise variance increase s(i,j) for every slot pair.

    Returns (M, M) float32; s(i,j) = N_i N_j/(N_i+N_j) * ||c_i - c_j||^2.
    Dead slots (size 0) produce +inf rows/cols; diagonal is +inf.
    """
    sizes, centers = stats.sizes, stats.centers
    m = sizes.shape[0]
    d2 = pairwise_sq_dists(centers, centers)
    denom = sizes[:, None] + sizes[None, :]
    s = jnp.where(denom > 0, (sizes[:, None] * sizes[None, :]) / jnp.maximum(denom, 1e-30) * d2, jnp.inf)
    alive = sizes > 0
    mask = alive[:, None] & alive[None, :] & ~jnp.eye(m, dtype=bool)
    return jnp.where(mask, s, jnp.inf)


def merge_stats(stats: SuffStats, i: jax.Array, j: jax.Array) -> SuffStats:
    """Merge slot j into slot i (paper's update formulas); slot j dies."""
    ni, nj = stats.sizes[i], stats.sizes[j]
    ci, cj = stats.centers[i], stats.centers[j]
    n_new = ni + nj
    w = jnp.where(n_new > 0, 1.0 / jnp.maximum(n_new, 1e-30), 0.0)
    c_new = (ni * ci + nj * cj) * w
    s_ij = jnp.where(n_new > 0, ni * nj * w * jnp.sum((ci - cj) ** 2), 0.0)
    sse_new = stats.sse[i] + stats.sse[j] + s_ij
    sizes = stats.sizes.at[i].set(n_new).at[j].set(0.0)
    centers = stats.centers.at[i].set(c_new).at[j].set(0.0)
    sse = stats.sse.at[i].set(sse_new).at[j].set(0.0)
    return SuffStats(sizes=sizes, centers=centers, sse=sse)


def pairwise_sq_dists(a: jax.Array, b: jax.Array) -> jax.Array:
    """(Na, D), (Nb, D) -> (Na, Nb) squared euclidean distances.

    MXU-friendly form |a|^2 + |b|^2 - 2 a.b^T (same identity the Pallas
    kernel uses); clamped at 0 against rounding.
    """
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    a2 = jnp.sum(a**2, axis=-1)[:, None]
    b2 = jnp.sum(b**2, axis=-1)[None, :]
    d2 = a2 + b2 - 2.0 * (a @ b.T)
    return jnp.maximum(d2, 0.0)


def stack_site_stats(per_site: SuffStats) -> SuffStats:
    """Flatten per-site stats (s, k, ...) into a single (s*k, ...) slot array.

    Slot index encodes the paper's ``cluster_{i,number}`` unique id:
    slot = site * k + number.
    """
    s, k = per_site.sizes.shape
    return SuffStats(
        sizes=per_site.sizes.reshape(s * k),
        centers=per_site.centers.reshape(s * k, -1),
        sse=per_site.sse.reshape(s * k),
    )


def total_sse(stats: SuffStats) -> jax.Array:
    """Global clustering objective: sum of within-cluster SSE over live slots."""
    return jnp.sum(jnp.where(stats.sizes > 0, stats.sse, 0.0))


def stats_bytes(stats: SuffStats) -> int:
    """Communication payload of shipping these stats (paper's comm model).

    4 bytes/float: N + D (center) + SSE per slot.
    """
    m, d = stats.centers.shape
    return int(m * (1 + d + 1) * 4)
