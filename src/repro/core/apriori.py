"""Apriori substrate: packed-bitmap transaction DBs + candidate machinery.

Transactions are bitmaps over a fixed item universe, packed 32 items/word
(uint32).  Support counting — the compute hot-spot — is `AND + compare +
reduce` over (transactions x candidates) tiles and is served either by the
pure-jnp oracle here or by the Pallas TPU kernel in
``repro.kernels.support_count`` (selected via ``count_backend``).

Candidate *generation* (level-wise join + prune) is classic set algebra
with data-dependent sizes; it stays on host exactly as in the paper, where
the protocol is orchestrated at the grid-job level anyway.
"""

from __future__ import annotations

import functools
from collections.abc import Iterable, Sequence
from dataclasses import dataclass
from itertools import combinations

import jax
import jax.numpy as jnp
import numpy as np

Itemset = tuple[int, ...]  # always sorted


# ---------------------------------------------------------------------------
# Packed-bitmap DB
# ---------------------------------------------------------------------------


def n_words(n_items: int) -> int:
    return (n_items + 31) // 32


def pack_bool_matrix(dense: np.ndarray) -> np.ndarray:
    """(N, n_items) bool -> (N, W) uint32, bit i of word w = item 32*w+i."""
    n, m = dense.shape
    w = n_words(m)
    padded = np.zeros((n, w * 32), dtype=bool)
    padded[:, :m] = dense
    bits = padded.reshape(n, w, 32)
    weights = (1 << np.arange(32, dtype=np.uint64)).astype(np.uint64)
    words = (bits.astype(np.uint64) * weights[None, None, :]).sum(axis=-1)
    return words.astype(np.uint32)


def pack_itemsets(itemsets: Sequence[Itemset], n_items: int) -> np.ndarray:
    """List of itemsets -> (C, W) uint32 masks."""
    w = n_words(n_items)
    out = np.zeros((max(len(itemsets), 1), w), dtype=np.uint32)
    for c, its in enumerate(itemsets):
        for item in its:
            out[c, item // 32] |= np.uint32(1) << np.uint32(item % 32)
    return out


@dataclass(frozen=True)
class TransactionDB:
    """One site's transaction database."""

    packed: jax.Array  # (n_tx, W) uint32
    n_items: int
    n_tx: int

    @staticmethod
    def from_dense(dense: np.ndarray) -> "TransactionDB":
        return TransactionDB(
            packed=jnp.asarray(pack_bool_matrix(dense)),
            n_items=dense.shape[1],
            n_tx=dense.shape[0],
        )


# ---------------------------------------------------------------------------
# Support counting (jnp oracle; kernel behind the same signature)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=())
def _count_block(db: jax.Array, masks: jax.Array) -> jax.Array:
    """(N, W) uint32, (C, W) uint32 -> (C,) int32 supports."""
    hit = (db[:, None, :] & masks[None, :, :]) == masks[None, :, :]  # (N, C, W)
    return jnp.sum(jnp.all(hit, axis=-1), axis=0).astype(jnp.int32)


def count_supports(
    db: TransactionDB,
    itemsets: Sequence[Itemset],
    backend: str = "jnp",
    block_c: int = 512,
) -> np.ndarray:
    """Support counts for ``itemsets`` on one site's DB.  Returns (C,) int64."""
    if not itemsets:
        return np.zeros((0,), dtype=np.int64)
    masks_np = pack_itemsets(itemsets, db.n_items)
    if backend == "kernel":
        from repro.kernels import ops

        out = ops.support_count(db.packed, jnp.asarray(masks_np))
        return np.asarray(out, dtype=np.int64)
    outs = []
    for s in range(0, masks_np.shape[0], block_c):
        outs.append(np.asarray(_count_block(db.packed, jnp.asarray(masks_np[s : s + block_c]))))
    return np.concatenate(outs).astype(np.int64)


def count_supports_prune(
    db: TransactionDB,
    itemsets: Sequence[Itemset],
    min_count: int,
    backend: str = "jnp",
    block_c: int = 512,
) -> tuple[np.ndarray, np.ndarray]:
    """Counts AND the ``>= min_count`` frequent mask for one site's level
    in a single pass — ``(counts (C,) int64, frequent (C,) bool)`` with
    ``frequent == counts >= min_count`` exactly.  On the kernel backend
    the threshold is fused into the device pass
    (``ops.support_count_prune``), so the level loop's hygiene step stops
    being a host round-trip of the raw count vector; the jnp oracle
    thresholds on host behind the identical signature."""
    if not itemsets:
        return np.zeros((0,), dtype=np.int64), np.zeros((0,), dtype=bool)
    if backend == "kernel":
        from repro.kernels import ops

        masks_np = pack_itemsets(itemsets, db.n_items)
        cnt, freq = ops.support_count_prune(db.packed, jnp.asarray(masks_np), int(min_count))
        return np.asarray(cnt, dtype=np.int64), np.asarray(freq)
    sup = count_supports(db, itemsets, backend=backend, block_c=block_c)
    return sup, sup >= int(min_count)


def _cand_bucket(n: int, step: int = 64) -> int:
    """Round a candidate count up to a bucket so the fused counting jit
    compiles O(log) distinct shapes instead of one per level."""
    return max(step, ((n + step - 1) // step) * step)


@jax.jit
def _count_block_sites(dbs: jax.Array, masks: jax.Array) -> jax.Array:
    """(S, N, W) uint32, (S, C, W) uint32 -> (S, C) int32 — the fused
    site-axis form of ``_count_block``: one device dispatch for the
    whole fan-out."""
    return jax.vmap(_count_block)(dbs, masks)


def fused_count_sites(
    dbs: Sequence[TransactionDB],
    itemset_lists: Sequence[Sequence[Itemset]],
    backend: str = "jnp",
) -> list[np.ndarray]:
    """Count each site's OWN candidate list with ONE device dispatch
    across the site axis — the fused form of per-site ``count_supports``
    loops that the batched execution backend uses for the ``apriori_i``
    / ``recount_i`` / FDM count fan-outs.

    Sites are padded to a common shape: transactions to the max ``n_tx``
    (all-zero rows match no non-empty mask, so padded rows count zero
    support) and candidates to a bucketed max count (padded all-zero
    masks produce garbage counts that are sliced away per site before
    returning).  Returns one (C_i,) int64 array per site, exactly equal
    to ``count_supports(dbs[i], itemset_lists[i])``.

    Falls back to the per-site loop when the sites disagree on the item
    universe (no common mask width) — correctness first, fusion when
    legal.

    The "site" axis is purely positional: under cross-request batching
    (``GridRuntime.run_many``) the entries may come from DIFFERENT
    requests mining the same dataset, so nothing here may assume the
    lists share a threshold or a candidate pool — each position is
    counted against its own list only.
    """
    lists = [list(lst) for lst in itemset_lists]
    if len(dbs) != len(lists):
        raise ValueError(f"{len(dbs)} sites but {len(lists)} candidate lists")
    empty = np.zeros((0,), dtype=np.int64)
    live = [i for i, lst in enumerate(lists) if lst]
    out: list[np.ndarray] = [empty] * len(lists)
    if not live:
        return out
    widths = {n_words(dbs[i].n_items) for i in live}
    if len(widths) != 1:
        # heterogeneous item universes cannot share one mask layout
        for i in live:
            out[i] = count_supports(dbs[i], lists[i], backend=backend)
        return out
    w = widths.pop()
    n_max = max(dbs[i].n_tx for i in live)
    c_max = _cand_bucket(max(len(lists[i]) for i in live))
    tx_s = np.zeros((len(live), n_max, w), dtype=np.uint32)
    masks_s = np.zeros((len(live), c_max, w), dtype=np.uint32)
    for row, i in enumerate(live):
        tx_s[row, : dbs[i].n_tx] = np.asarray(dbs[i].packed)
        masks_s[row, : len(lists[i])] = pack_itemsets(lists[i], dbs[i].n_items)
    if backend == "kernel":
        from repro.kernels import ops

        counts = np.asarray(ops.support_count_sites(jnp.asarray(tx_s), jnp.asarray(masks_s)))
    else:
        counts = np.asarray(_count_block_sites(jnp.asarray(tx_s), jnp.asarray(masks_s)))
    for row, i in enumerate(live):
        out[i] = counts[row, : len(lists[i])].astype(np.int64)
    return out


def fused_prune_sites(
    dbs: Sequence[TransactionDB],
    itemset_lists: Sequence[Sequence[Itemset]],
    min_counts: Sequence[int],
    backend: str = "jnp",
) -> list[tuple[np.ndarray, np.ndarray]]:
    """The prune-fused form of :func:`fused_count_sites`: one device
    dispatch counts every site's own candidate list AND thresholds it
    against that site's ``min_counts[i]`` (a per-site traced operand, so
    heterogeneous thresholds ride the same launch).  Returns one
    ``(counts (C_i,) int64, frequent (C_i,) bool)`` pair per site, with
    ``counts`` exactly equal to ``fused_count_sites`` and ``frequent ==
    counts >= min_counts[i]``.  Same padding rules, heterogeneous-
    universe fallback, and positional-axis contract as the count-only
    form — per-position ``min_counts`` is what lets one launch serve
    members of different requests (different ``minsup``) under
    cross-request batching, since the threshold is a traced operand and
    never a compile-time constant."""
    lists = [list(lst) for lst in itemset_lists]
    if len(dbs) != len(lists):
        raise ValueError(f"{len(dbs)} sites but {len(lists)} candidate lists")
    if len(dbs) != len(min_counts):
        raise ValueError(f"{len(dbs)} sites but {len(min_counts)} thresholds")
    empty = (np.zeros((0,), dtype=np.int64), np.zeros((0,), dtype=bool))
    live = [i for i, lst in enumerate(lists) if lst]
    out: list[tuple[np.ndarray, np.ndarray]] = [empty] * len(lists)
    if not live:
        return out
    widths = {n_words(dbs[i].n_items) for i in live}
    if len(widths) != 1:
        for i in live:
            out[i] = count_supports_prune(dbs[i], lists[i], min_counts[i], backend=backend)
        return out
    w = widths.pop()
    n_max = max(dbs[i].n_tx for i in live)
    c_max = _cand_bucket(max(len(lists[i]) for i in live))
    tx_s = np.zeros((len(live), n_max, w), dtype=np.uint32)
    masks_s = np.zeros((len(live), c_max, w), dtype=np.uint32)
    mc = np.asarray([int(min_counts[i]) for i in live], dtype=np.int32)
    for row, i in enumerate(live):
        tx_s[row, : dbs[i].n_tx] = np.asarray(dbs[i].packed)
        masks_s[row, : len(lists[i])] = pack_itemsets(lists[i], dbs[i].n_items)
    if backend == "kernel":
        from repro.kernels import ops

        counts, freq = ops.support_count_prune_sites(
            jnp.asarray(tx_s), jnp.asarray(masks_s), jnp.asarray(mc)
        )
        counts, freq = np.asarray(counts), np.asarray(freq)
    else:
        counts = np.asarray(_count_block_sites(jnp.asarray(tx_s), jnp.asarray(masks_s)))
        freq = counts >= mc[:, None]
    for row, i in enumerate(live):
        c_i = len(lists[i])
        out[i] = (counts[row, :c_i].astype(np.int64), freq[row, :c_i])
    return out


def item_supports(db: TransactionDB) -> np.ndarray:
    """Singleton supports (L1 seed) via bit-unpack + column sum."""
    words = np.asarray(db.packed)  # (N, W)
    bits = ((words[:, :, None] >> np.arange(32, dtype=np.uint32)[None, None, :]) & 1).astype(np.int64)
    cols = bits.reshape(words.shape[0], -1)[:, : db.n_items]
    return cols.sum(axis=0)


# ---------------------------------------------------------------------------
# Candidate generation (host-side set algebra)
# ---------------------------------------------------------------------------


def apriori_join(prev_frequent: Iterable[Itemset]) -> list[Itemset]:
    """F(k-1) x F(k-1) prefix join + downward-closure prune."""
    prev = sorted(set(prev_frequent))
    prev_set = set(prev)
    if not prev:
        return []
    k_1 = len(prev[0])
    out = []
    for a_i in range(len(prev)):
        a = prev[a_i]
        for b_i in range(a_i + 1, len(prev)):
            b = prev[b_i]
            if a[:-1] != b[:-1]:
                break  # sorted ⇒ shared prefix block is contiguous
            cand = a + (b[-1],)
            # prune: every (k)-subset must be in prev_set
            if all(tuple(sub) in prev_set for sub in combinations(cand, k_1)):
                out.append(cand)
    return out


def subsets_of(itemset: Itemset) -> list[Itemset]:
    """Immediate (size-1 smaller) subsets."""
    return [tuple(s) for s in combinations(itemset, len(itemset) - 1)]


# ---------------------------------------------------------------------------
# Site-local Apriori (paper Alg 2 line 2: apriori_gen(X_i, k))
# ---------------------------------------------------------------------------


@dataclass
class LocalMineResult:
    """All itemsets COUNTED locally, with counts; `frequent[k]` lists the
    locally frequent ones per level.  Counts are cached so the global phase
    never re-counts something this site already measured."""

    counts: dict[Itemset, int]
    frequent: dict[int, list[Itemset]]
    count_calls: int  # device count invocations (for perf accounting)
    candidates_counted: int


def local_apriori(
    db: TransactionDB,
    k_max: int,
    min_count: int,
    backend: str = "jnp",
) -> LocalMineResult:
    """Level-wise Apriori with LOCAL pruning only (GFM phase 1)."""
    counts: dict[Itemset, int] = {}
    frequent: dict[int, list[Itemset]] = {}
    calls = 0
    n_cand = 0

    sup1 = item_supports(db)
    for item, c in enumerate(sup1):
        counts[(int(item),)] = int(c)
    frequent[1] = [(int(i),) for i in np.nonzero(sup1 >= min_count)[0]]
    calls += 1
    n_cand += db.n_items

    level = 1
    while level < k_max and frequent.get(level):
        cands = apriori_join(frequent[level])
        level += 1
        if not cands:
            frequent[level] = []
            break
        sup, freq = count_supports_prune(db, cands, min_count, backend=backend)
        calls += 1
        n_cand += len(cands)
        for its, c in zip(cands, sup):
            counts[its] = int(c)
        frequent[level] = [its for its, f in zip(cands, freq) if f]
    for lv in range(1, k_max + 1):
        frequent.setdefault(lv, [])
    return LocalMineResult(counts=counts, frequent=frequent, count_calls=calls, candidates_counted=n_cand)


def batched_local_apriori(
    dbs: Sequence[TransactionDB],
    k_max: int,
    min_counts: Sequence[int],
    backend: str = "jnp",
) -> list[LocalMineResult]:
    """Phase-1 local Apriori for ALL sites in lockstep: per level, every
    site generates its candidates on host, then ONE fused device
    dispatch (``fused_count_sites``) counts every site's candidates
    across the site axis.  Result-identical to per-site
    ``local_apriori`` calls — same candidates (generation depends only
    on each site's own frequents), same exact integer counts, same
    ``count_calls`` ledger (which counts the protocol's logical
    per-site count rounds, not device dispatches) — but the fan-out
    costs one kernel launch per level instead of one per site-level.

    ``min_counts`` is per position for the same reason it is in
    ``fused_prune_sites``: a cross-request fused wave mines the same
    shards under different thresholds, and sites exhaust (leave
    ``active``) independently — a position that stops generating
    candidates at level l must not drag its wave-mates down with it.
    """
    if len(dbs) != len(min_counts):
        raise ValueError(f"{len(dbs)} sites but {len(min_counts)} thresholds")
    res: list[LocalMineResult] = []
    for db, min_count in zip(dbs, min_counts):
        counts: dict[Itemset, int] = {}
        sup1 = item_supports(db)
        for item, c in enumerate(sup1):
            counts[(int(item),)] = int(c)
        res.append(
            LocalMineResult(
                counts=counts,
                frequent={1: [(int(i),) for i in np.nonzero(sup1 >= min_count)[0]]},
                count_calls=1,
                candidates_counted=db.n_items,
            )
        )
    level = 1
    active = set(range(len(dbs)))
    while level < k_max and active:
        cands_by: list[list[Itemset]] = [[] for _ in dbs]
        for i in list(active):
            if not res[i].frequent.get(level):
                active.discard(i)  # this site's search is exhausted
                continue
            cands_by[i] = apriori_join(res[i].frequent[level])
        level += 1
        sups = fused_prune_sites(dbs, cands_by, min_counts, backend=backend)
        for i in list(active):
            cands = cands_by[i]
            if not cands:
                res[i].frequent[level] = []
                active.discard(i)
                continue
            res[i].count_calls += 1
            res[i].candidates_counted += len(cands)
            cnt_i, freq_i = sups[i]
            for its, c in zip(cands, cnt_i):
                res[i].counts[its] = int(c)
            res[i].frequent[level] = [its for its, f in zip(cands, freq_i) if f]
    for lm in res:
        for lv in range(1, k_max + 1):
            lm.frequent.setdefault(lv, [])
    return res


# ---------------------------------------------------------------------------
# Delta (incremental) Apriori — the serving layer's hot repeated query
# ---------------------------------------------------------------------------


def concat_dbs(dbs: Sequence[TransactionDB]) -> TransactionDB:
    """Concatenate same-universe TransactionDBs along the transaction
    axis (the from-scratch view of an appended stream)."""
    if not dbs:
        raise ValueError("concat_dbs needs at least one TransactionDB")
    universes = {db.n_items for db in dbs}
    if len(universes) != 1:
        raise ValueError(f"cannot concat DBs over different item universes: {sorted(universes)}")
    return TransactionDB(
        packed=jnp.concatenate([db.packed for db in dbs], axis=0),
        n_items=dbs[0].n_items,
        n_tx=sum(db.n_tx for db in dbs),
    )


class DeltaApriori:
    """Incremental frequent-itemset state over an append-only transaction
    stream — the delta-maintenance entry point the continuous mining
    service (``launch.serve``) queries repeatedly.

    Support counts are ADDITIVE over transactions, which is the whole
    trick (the FUP family of incremental Apriori algorithms; the Apriori
    performance study of arXiv:1903.03008 motivates exactly this as the
    hot repeated query): every itemset this state has ever counted keeps
    an exact cumulative count, and :meth:`append` extends each of them
    with one support-count pass over the NEW batch only — O(|delta|)
    device work instead of O(|stream|).  A :meth:`query` then replays the
    level-wise Apriori loop, serving candidates from the cumulative cache
    for free and counting only candidates it has never seen — over the
    full concatenated stream, so their counts are exact too.

    Correctness contract (property-tested): ``query(k_max, min_count)``
    is BIT-IDENTICAL — same per-level frequent itemsets, same exact
    integer counts for every generated candidate — to
    ``local_apriori(concat_dbs(batches), k_max, min_count)`` run from
    scratch, for every append history and every threshold.  Candidate
    generation depends only on the (identical) frequents, and every
    served count equals the from-scratch count by additivity, so the
    equality holds by induction over levels.  Only the ``count_calls``
    ledger differs: it counts the DEVICE passes this instance actually
    ran, which is the saving being bought.

    ``version`` increments per append — the cache key the serving layer
    uses to guarantee a result is never served across a data change.
    """

    def __init__(self, n_items: int, backend: str = "jnp"):
        self.n_items = int(n_items)
        self.backend = backend
        self.version = 0  # bumped per append — the dataset_version key
        self._batches: list[TransactionDB] = []
        self._full: TransactionDB | None = None  # lazy concat of batches
        # cumulative exact counts over ALL appended transactions, for
        # every itemset ever counted (singletons always included)
        self._counts: dict[Itemset, int] = {(i,): 0 for i in range(self.n_items)}
        self.count_calls = 0  # lifetime device count passes (the ledger)

    @classmethod
    def from_db(cls, db: TransactionDB, backend: str = "jnp") -> "DeltaApriori":
        """Seed incremental state from an already-packed DB (one singleton
        pass, no dense round-trip) — how a grid site wraps its local shard
        so per-level candidate counts serve from the cumulative cache."""
        st = cls(db.n_items, backend=backend)
        sup1 = item_supports(db)
        st.count_calls += 1
        for item, c in enumerate(sup1):
            st._counts[(int(item),)] += int(c)
        st._batches.append(db)
        st._full = db
        st.version = 1
        return st

    @property
    def n_tx(self) -> int:
        return sum(db.n_tx for db in self._batches)

    def stream(self) -> TransactionDB:
        """The full appended stream as one DB (lazy concat, cached)."""
        if not self._batches:
            raise RuntimeError("DeltaApriori.stream before any append")
        if self._full is None:
            self._full = concat_dbs(self._batches)
        return self._full

    def uncached(self, itemsets: Iterable[Itemset]) -> list[Itemset]:
        """The subset of ``itemsets`` this state has never counted."""
        return [its for its in itemsets if its not in self._counts]

    def fold_exact(self, itemsets: Sequence[Itemset], counts) -> None:
        """Install exact full-stream counts computed EXTERNALLY (e.g. by a
        fused site-axis dispatch).  Caller contract: ``counts[i]`` is the
        support of ``itemsets[i]`` over the whole appended stream — the
        cumulative invariant extends to them as if counted here.  Ledgers
        one device pass when non-empty."""
        if not itemsets:
            return
        self.count_calls += 1
        for its, c in zip(itemsets, counts):
            self._counts[its] = int(c)

    def counts_for(self, itemsets: Sequence[Itemset]) -> dict[Itemset, int]:
        """Exact cumulative counts for arbitrary itemsets, counting only
        the never-seen ones (at most one device pass); cached itemsets are
        served for free — the local-pass entry point for workloads that
        bring their own candidate lists (count-distribution Apriori)."""
        self._count_new(self.uncached(itemsets))
        return {its: self._counts[its] for its in itemsets}

    def append(self, dense_batch: np.ndarray) -> int:
        """Fold one appended transaction batch into the cumulative counts
        (one singleton pass + one cached-itemset count pass over the new
        batch only) and bump ``version``.  Returns the new version."""
        if dense_batch.shape[1] != self.n_items:
            raise ValueError(
                f"batch has {dense_batch.shape[1]} items, state tracks {self.n_items}"
            )
        db = TransactionDB.from_dense(np.asarray(dense_batch, dtype=bool))
        sup1 = item_supports(db)
        self.count_calls += 1
        for item, c in enumerate(sup1):
            self._counts[(int(item),)] += int(c)
        cached = [its for its in self._counts if len(its) > 1]
        if cached:
            sup = count_supports(db, cached, backend=self.backend)
            self.count_calls += 1
            for its, c in zip(cached, sup):
                self._counts[its] += int(c)
        self._batches.append(db)
        self._full = None
        self.version += 1
        return self.version

    def _count_new(self, cands: list[Itemset]) -> None:
        """Count never-seen candidates over the full stream (exact, so the
        cumulative-cache invariant extends to them)."""
        if not cands:
            return
        if self._full is None:
            self._full = concat_dbs(self._batches)
        sup = count_supports(self._full, cands, backend=self.backend)
        self.count_calls += 1
        for its, c in zip(cands, sup):
            self._counts[its] = int(c)

    def query(self, k_max: int, min_count: int) -> LocalMineResult:
        """Level-wise Apriori over everything appended so far, serving
        counts from the cumulative cache.  Returns a ``LocalMineResult``
        bit-identical (counts + frequents) to a from-scratch
        ``local_apriori`` over the concatenated stream; its
        ``count_calls`` field reports the device passes THIS query cost
        (0 when every candidate was already cached)."""
        if not self._batches:
            raise RuntimeError("DeltaApriori.query before any append")
        calls0 = self.count_calls
        counts: dict[Itemset, int] = {}
        frequent: dict[int, list[Itemset]] = {}
        n_cand = self.n_items
        for i in range(self.n_items):
            counts[(i,)] = self._counts[(i,)]
        frequent[1] = [(i,) for i in range(self.n_items) if counts[(i,)] >= min_count]
        level = 1
        while level < k_max and frequent.get(level):
            cands = apriori_join(frequent[level])
            level += 1
            if not cands:
                frequent[level] = []
                break
            fresh = [its for its in cands if its not in self._counts]
            n_cand += len(cands)
            if fresh and len(fresh) == len(cands):
                # cold level (every candidate is new — the first query on
                # freshly appended data): one fused count+threshold pass
                # serves counts AND frequents, instead of a count pass
                # plus a host threshold sweep
                cnt, freq = count_supports_prune(
                    self.stream(), cands, min_count, backend=self.backend
                )
                self.count_calls += 1
                for its, c in zip(cands, cnt):
                    self._counts[its] = int(c)
                    counts[its] = int(c)
                frequent[level] = [its for its, f in zip(cands, freq) if f]
                continue
            self._count_new(fresh)
            for its in cands:
                counts[its] = self._counts[its]
            frequent[level] = [its for its in cands if counts[its] >= min_count]
        for lv in range(1, k_max + 1):
            frequent.setdefault(lv, [])
        return LocalMineResult(
            counts=counts,
            frequent=frequent,
            count_calls=self.count_calls - calls0,
            candidates_counted=n_cand,
        )


# ---------------------------------------------------------------------------
# Streaming top-k frequent itemsets (served via the delta path)
# ---------------------------------------------------------------------------


@dataclass
class TopKResult:
    """The ``top`` highest-support itemsets of sizes 1..k_max over the
    appended stream, with the support threshold the search settled at."""

    items: list[tuple[Itemset, int]]  # (itemset, exact count), best first
    threshold: int  # smallest min_count tried (all items have count >= it)
    k_max: int
    count_calls: int  # device passes THIS query cost (0 when fully cached)


def topk_itemsets(
    delta: DeltaApriori, k_max: int, top: int, floor: int = 1
) -> TopKResult:
    """Top-``top`` frequent itemsets by support over a DeltaApriori
    stream, without the caller naming a support threshold.

    Threshold search by halving: start at the stream length (only
    universally-supported itemsets qualify) and halve until at least
    ``top`` itemsets are frequent or the ``floor`` is reached.  Each
    probe is a ``DeltaApriori.query``, so repeated probes serve counts
    from the cumulative cache — on a warm state the whole search costs
    zero device passes, which is what makes this a *streaming* query:
    appends are O(|delta|), and the top-k refreshes cheaply after each.

    Deterministic: ties break by (higher count, smaller itemset,
    lexicographic items).  Exactness is inherited from the delta
    contract — every returned count equals the from-scratch count.
    """
    if top < 1:
        raise ValueError(f"top must be >= 1, got {top}")
    if floor < 1:
        raise ValueError(f"floor must be >= 1, got {floor}")
    calls0 = delta.count_calls
    t = max(int(delta.n_tx), floor)
    while True:
        res = delta.query(k_max, t)
        found = [
            (its, res.counts[its])
            for lv in sorted(res.frequent)
            for its in res.frequent[lv]
        ]
        if len(found) >= top or t <= floor:
            break
        t = max(floor, t // 2)
    found.sort(key=lambda ic: (-ic[1], len(ic[0]), ic[0]))
    return TopKResult(
        items=found[:top],
        threshold=t,
        k_max=k_max,
        count_calls=delta.count_calls - calls0,
    )


# ---------------------------------------------------------------------------
# Brute-force oracle (tests)
# ---------------------------------------------------------------------------


def bruteforce_frequent(
    dense_pooled: np.ndarray, k_max: int, min_count: int
) -> dict[Itemset, int]:
    """Exhaustive frequent itemsets of sizes 1..k_max over a pooled dense DB.

    Exponential — tests only.  Uses downward closure for pruning.
    """
    n, m = dense_pooled.shape
    cols = dense_pooled.astype(bool)
    out: dict[Itemset, int] = {}
    level: list[tuple[Itemset, np.ndarray]] = []
    for i in range(m):
        c = int(cols[:, i].sum())
        if c >= min_count:
            out[(i,)] = c
            level.append(((i,), cols[:, i]))
    for _ in range(2, k_max + 1):
        fset = {its for its, _ in level}
        nxt = []
        for cand in apriori_join([its for its, _ in level]):
            mask = np.ones(n, dtype=bool)
            for item in cand:
                mask &= cols[:, item]
            c = int(mask.sum())
            if c >= min_count:
                out[cand] = c
                nxt.append((cand, mask))
        level = nxt
        if not level:
            break
    return out
