"""GridLocal outer optimizer — the paper's single-aggregation pattern
applied to distributed training.

Each pod ("grid site") runs H inner AdamW steps with NO cross-pod
communication; every H steps the pods' parameter deltas are aggregated by
the paper's sufficient-statistics merge (weighted by examples processed —
uniform here, so a pmean over the `pod` axis) and an outer Nesterov-SGD
step is applied (DiLoCo-style).  Cross-pod (DCN) traffic drops by ~H×.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class OuterConfig(NamedTuple):
    h_steps: int = 16  # inner steps between outer syncs
    outer_lr: float = 0.7
    outer_momentum: float = 0.9
    # cross-pod delta compression for the merge ('none' | 'int8'):
    # per-leaf symmetric quantisation of (params - anchor) so the ONLY
    # cross-pod payload is int8 + one scale scalar per leaf (4x fewer
    # wire bytes than f32, 2x fewer than bf16) — gradient compression in
    # the paper's "ship sufficient statistics, not data" spirit.
    compress: str = "none"


def quantize_delta(delta, scale=None):
    """Symmetric per-leaf int8 quantisation.  Returns (q, scale)."""
    if scale is None:
        scale = jnp.maximum(jnp.max(jnp.abs(delta)), 1e-12)
    q = jnp.clip(jnp.round(delta / scale * 127.0), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_delta(q, scale):
    return q.astype(jnp.float32) * (scale / 127.0)


def outer_init(params):
    return {
        "anchor": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "momentum": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def outer_update(cfg: OuterConfig, outer_state, merged_params):
    """Nesterov outer step on the (already pod-averaged) parameters.

    delta = merged - anchor;  m = mu*m + delta
    anchor' = anchor + lr * (delta + mu*m)
    Returns (new_inner_params, new_outer_state) — inner params are reset to
    the new anchor (all pods identical again).
    """
    mu, lr = cfg.outer_momentum, cfg.outer_lr

    def upd(anchor, m, merged):
        delta = merged.astype(jnp.float32) - anchor
        m = mu * m + delta
        new_anchor = anchor + lr * (delta + mu * m)
        return new_anchor, m

    flat_a, tdef = jax.tree.flatten(outer_state["anchor"])
    flat_m = tdef.flatten_up_to(outer_state["momentum"])
    flat_p = tdef.flatten_up_to(merged_params)
    out = [upd(a, m, p) for a, m, p in zip(flat_a, flat_m, flat_p)]
    anchor = jax.tree.unflatten(tdef, [o[0] for o in out])
    mom = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_params = jax.tree.map(lambda a, p: a.astype(p.dtype), anchor, merged_params)
    return new_params, {"anchor": anchor, "momentum": mom}
