"""CI perf-regression gate: diff a fresh ``BENCH_sweep.json`` against the
committed ``BENCH_baseline.json`` and fail on regression.

What is compared, per sweep cell (app x n_sites x links x compute_scale x
schedule x placement):

  * machine-INDEPENDENT simulated components — ``prep_s``, ``submit_s``,
    ``transfer_s`` — byte-for-byte of the grid model, so they get a tight
    relative band (default 1%): any drift is a scheduler/model change,
    not noise.  Only fixed-placement cells qualify: adaptive policies
    choose sites from the host-calibrated job times, so their transfer
    ledger legitimately varies across hosts and is covered by the loose
    wall band instead;
  * ``wall_s`` and ``overhead_pct`` — these embed the calibrated device
    compute, which varies across hosts, so they get loose bands (default
    30% / 5 points; overhead_pct only at compute_scale x1, where compute
    is a sliver of the wall) that still catch order-of-magnitude
    regressions (losing submit pipelining, double-charged staging,
    barrier reintroduction);
  * the async<=staged invariant on every candidate comparison row — the
    event-driven scheduler must never lose to the stage-barrier one on
    identical replayed times;
  * the greedy_eta<=fixed placement invariant on every skewed-links
    candidate placement-comparison row — on the heterogeneous grid
    (degraded per-site links + per-site compute speeds), adaptive
    matchmaking must never lose to a-priori site pinning on identical
    replayed times;
  * the batched<=inline execution-backend gate on every candidate
    backend-comparison row with >= 8 sites — on fan-out-heavy cells the
    fused vmapped site-compute must not lose wall time to the per-job
    host loop (5% band: the walls share identical simulated components,
    so the delta is pure calibrated-compute difference plus host noise).

Regressions are one-sided: a candidate that got FASTER passes (with a
note suggesting a baseline refresh).  Cells present in the baseline but
missing from the candidate fail (coverage must not silently shrink).

Refresh the baseline intentionally with:

    PYTHONPATH=src python -m benchmarks.bench_sweep --smoke --out BENCH_baseline.json

    PYTHONPATH=src python -m benchmarks.compare_baseline \
        --baseline BENCH_baseline.json --candidate BENCH_sweep.json
"""

from __future__ import annotations

import argparse
import json
import sys

CELL_KEY = ("app", "n_sites", "links", "compute_scale", "schedule", "placement", "exec_backend")
STRICT_FIELDS = ("prep_s", "submit_s", "transfer_s")
# axis fields added over time default to the behavior older baselines ran
KEY_DEFAULTS = {"placement": "fixed", "exec_backend": "inline"}


def _key(cell: dict) -> tuple:
    # pre-placement baselines carry no "placement" field (those cells ran
    # the fixed a-priori sites); pre-backend baselines carry no
    # "exec_backend" (those ran the inline host loop)
    return tuple(cell.get(k, KEY_DEFAULTS[k]) if k in KEY_DEFAULTS else cell[k] for k in CELL_KEY)


def compare(
    baseline: dict,
    candidate: dict,
    tol_strict: float = 0.01,
    tol_wall: float = 0.30,
    tol_overhead_pts: float = 5.0,
) -> tuple[list[str], list[str]]:
    """Returns (failures, notes)."""
    failures: list[str] = []
    notes: list[str] = []
    base_cells = {_key(c): c for c in baseline.get("cells", [])}
    cand_cells = {_key(c): c for c in candidate.get("cells", [])}

    for key, base in sorted(base_cells.items()):
        tag = "/".join(str(k) for k in key)
        cand = cand_cells.get(key)
        if cand is None:
            failures.append(f"{tag}: cell missing from candidate sweep")
            continue
        # adaptive-placement cells: site choices (and with them the
        # transfer ledger and overhead split) depend on host-calibrated
        # job times — only the loose wall band applies there
        strict_fields = STRICT_FIELDS if base.get("placement", "fixed") == "fixed" else ()
        for fld in strict_fields:
            b, c = base[fld], cand[fld]
            if c > b * (1 + tol_strict) + 1e-9:
                failures.append(
                    f"{tag}: {fld} regressed {b:.3f}s -> {c:.3f}s "
                    f"(simulated component; tolerance {tol_strict:.0%})"
                )
            elif c < b * (1 - tol_strict) - 1e-9:
                notes.append(
                    f"{tag}: {fld} improved {b:.3f}s -> {c:.3f}s — refresh the baseline"
                )
        b, c = base["wall_s"], cand["wall_s"]
        if c > b * (1 + tol_wall):
            failures.append(f"{tag}: wall_s regressed {b:.2f}s -> {c:.2f}s (tolerance {tol_wall:.0%})")
        elif c < b * (1 - tol_wall):
            notes.append(f"{tag}: wall_s improved {b:.2f}s -> {c:.2f}s — refresh the baseline")
        # overhead_pct embeds calibrated compute in its denominator; the
        # what-if compute scales multiply the calibration noise, so the
        # band is only meaningful at x1 (the Table 3 cells, where compute
        # is a sliver of the simulated wall).  Scaled cells stay covered
        # by the strict simulated components and the wall band.
        if base.get("compute_scale", 1) == 1 and base.get("placement", "fixed") == "fixed":
            b, c = base["overhead_pct"], cand["overhead_pct"]
            if c > b + tol_overhead_pts:
                failures.append(
                    f"{tag}: overhead_pct regressed {b:.2f} -> {c:.2f} "
                    f"(tolerance {tol_overhead_pts} points)"
                )

    def comp_key(comp: dict) -> tuple:
        return (comp["app"], comp["n_sites"], comp["links"], comp["compute_scale"])

    cand_comps = {comp_key(c): c for c in candidate.get("comparisons", [])}
    # coverage must not silently shrink: every baseline comparison row must
    # exist in the candidate so the invariant is actually exercised
    for comp in baseline.get("comparisons", []):
        key = comp_key(comp)
        if key not in cand_comps:
            tag = f"{key[0]}/s{key[1]}/{key[2]}/x{key[3]}"
            failures.append(f"{tag}: comparison row missing from candidate sweep")
    for comp in cand_comps.values():
        s, a = comp["wall_staged_s"], comp["wall_async_s"]
        tag = f"{comp['app']}/s{comp['n_sites']}/{comp['links']}/x{comp['compute_scale']}"
        if a > s * 1.01 + 1e-9:
            failures.append(f"{tag}: invariant violated — async wall {a:.2f}s > staged {s:.2f}s")

    # placement matchmaking gate: on the skewed (heterogeneous) grid,
    # greedy_eta must never lose to fixed a-priori placement.  Coverage
    # first: every baseline placement-comparison row must survive.
    cand_pcomps = {comp_key(c): c for c in candidate.get("placement_comparisons", [])}
    for comp in baseline.get("placement_comparisons", []):
        key = comp_key(comp)
        if key not in cand_pcomps:
            tag = f"{key[0]}/s{key[1]}/{key[2]}/x{key[3]}"
            failures.append(f"{tag}: placement comparison row missing from candidate sweep")
    for comp in cand_pcomps.values():
        if comp["links"] != "skewed":
            continue  # homogeneous grids: adaptive ~ fixed, not gated
        f_, g = comp["wall_fixed_s"], comp["wall_greedy_eta_s"]
        tag = f"{comp['app']}/s{comp['n_sites']}/{comp['links']}/x{comp['compute_scale']}"
        # greedy's ETA is a heuristic over host-calibrated times, not a
        # by-construction bound like async<=staged — the band (5%) allows
        # estimator noise while still catching a policy that loses to
        # a-priori pinning on the heterogeneous grid
        if g > f_ * 1.05 + 1e-9:
            failures.append(
                f"{tag}: placement invariant violated — greedy_eta wall {g:.2f}s > fixed {f_:.2f}s"
            )

    # execution-backend gate: on fan-out-heavy cells (>= 8 sites) the
    # fused batched backend must not lose wall time to the inline host
    # loop.  Coverage first: baseline backend-comparison rows must
    # survive into the candidate.
    def bcomp_key(comp: dict) -> tuple:
        return (comp["app"], comp["n_sites"], comp["schedule"], comp["compute_scale"])

    cand_bcomps = {bcomp_key(c): c for c in candidate.get("backend_comparisons", [])}
    for comp in baseline.get("backend_comparisons", []):
        key = bcomp_key(comp)
        if key not in cand_bcomps:
            tag = f"{key[0]}/s{key[1]}/{key[2]}/x{key[3]}"
            failures.append(f"{tag}: backend comparison row missing from candidate sweep")
    for comp in cand_bcomps.values():
        if comp["n_sites"] < 8:
            continue  # small fan-outs: fusion gains are within host noise
        i, b = comp["wall_inline_s"], comp["wall_batched_s"]
        tag = f"{comp['app']}/s{comp['n_sites']}/{comp['schedule']}/x{comp['compute_scale']}"
        if b > i * 1.05 + 1e-9:
            failures.append(
                f"{tag}: backend invariant violated — batched wall {b:.2f}s > inline {i:.2f}s"
            )

    return failures, notes


def compare_kernels(
    baseline: dict,
    candidate: dict,
    tol_kernels: float = 1.0,
    tol_autotune: float = 0.25,
) -> tuple[list[str], list[str]]:
    """Kernel microbench gate: per-kernel median seconds vs the committed
    ``BENCH_kernels_baseline.json``.

    The band is deliberately GENEROUS (default 100%, i.e. fail only past
    2x the committed time): CI runners are shared and a microbench's
    absolute time swings with the host, but an accidentally-deoptimized
    kernel (lost jit cache, dtype promotion to f64, a fallback path) costs
    an order of magnitude and still trips it — which is the regression
    class end-to-end wall time hides behind scheduler noise.  Coverage is
    strict as everywhere else: a kernel present in the baseline must
    appear in the candidate.

    Autotune invariant: every CANDIDATE row carrying both
    ``seconds_tuned`` and ``seconds_default`` (the ``*_autotune`` rows
    ``bench_kernels --autotune`` emits) must satisfy ``tuned <= default``
    within ``tol_autotune`` — the autotuner keeps the default unless a
    candidate wins beyond its noise margin, so a tuned config that LOSES
    to the default by more than measurement noise means the search or
    the memo key broke.  Both sides are measured back-to-back in one
    process, so the band (default 25%) is host-noise only."""
    failures: list[str] = []
    notes: list[str] = []
    base = {c["name"]: c for c in baseline.get("kernels", [])}
    cand = {c["name"]: c for c in candidate.get("kernels", [])}
    for name, b in sorted(base.items()):
        c = cand.get(name)
        if c is None:
            failures.append(f"kernel {name}: missing from candidate run")
            continue
        bs, cs = float(b["seconds"]), float(c["seconds"])
        if cs > bs * (1 + tol_kernels) + 1e-9:
            failures.append(
                f"kernel {name}: regressed {bs * 1e6:.1f}us -> {cs * 1e6:.1f}us "
                f"(tolerance {tol_kernels:.0%})"
            )
        elif cs < bs * (1 - min(tol_kernels, 0.5)):
            notes.append(
                f"kernel {name}: improved {bs * 1e6:.1f}us -> {cs * 1e6:.1f}us "
                f"— refresh the kernels baseline"
            )
    for name, c in sorted(cand.items()):
        if "seconds_tuned" not in c or "seconds_default" not in c:
            continue
        t, d = float(c["seconds_tuned"]), float(c["seconds_default"])
        if t > d * (1 + tol_autotune) + 1e-4:
            failures.append(
                f"kernel {name}: tuned config LOST to default "
                f"({t * 1e6:.1f}us > {d * 1e6:.1f}us, tolerance {tol_autotune:.0%}) "
                f"— autotune search/memo is broken"
            )
    return failures, notes


# how to (re)produce each input file this gate consumes — used to turn a
# bare FileNotFoundError into an actionable message
REGEN = {
    "baseline": "PYTHONPATH=src python -m benchmarks.bench_sweep --smoke --out {path}",
    "candidate": "PYTHONPATH=src python -m benchmarks.bench_sweep --smoke --out {path}",
    "kernels baseline": "PYTHONPATH=src python -m benchmarks.bench_kernels --autotune --smoke --out {path}",
    "kernels candidate": "PYTHONPATH=src python -m benchmarks.bench_kernels --autotune --smoke --out {path}",
}


def _load(path: str, role: str) -> dict:
    """Load one JSON input, or exit with the file's name and the command
    that regenerates it (instead of a bare traceback)."""
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        cmd = REGEN[role].format(path=path)
        sys.exit(
            f"compare_baseline: {role} file {path!r} not found.\n"
            f"  Regenerate it with:\n    {cmd}\n"
            f"  (committed baselines are refreshed intentionally — see the module docstring)"
        )
    except json.JSONDecodeError as e:
        sys.exit(f"compare_baseline: {role} file {path!r} is not valid JSON ({e})")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--candidate", default="BENCH_sweep.json")
    ap.add_argument("--tol-strict", type=float, default=0.01)
    ap.add_argument("--tol-wall", type=float, default=0.30)
    ap.add_argument("--tol-overhead-pts", type=float, default=5.0)
    # optional kernels section: both paths given -> the microbench gate
    # runs alongside the sweep gate (one exit code for CI)
    ap.add_argument("--kernels-baseline", default=None)
    ap.add_argument("--kernels-candidate", default=None)
    ap.add_argument("--tol-kernels", type=float, default=1.0)
    ap.add_argument("--tol-autotune", type=float, default=0.25)
    args = ap.parse_args()

    baseline = _load(args.baseline, "baseline")
    candidate = _load(args.candidate, "candidate")

    failures, notes = compare(
        baseline,
        candidate,
        tol_strict=args.tol_strict,
        tol_wall=args.tol_wall,
        tol_overhead_pts=args.tol_overhead_pts,
    )
    n_kernels = 0
    if args.kernels_baseline and args.kernels_candidate:
        kb = _load(args.kernels_baseline, "kernels baseline")
        kc = _load(args.kernels_candidate, "kernels candidate")
        kfail, knotes = compare_kernels(
            kb, kc, tol_kernels=args.tol_kernels, tol_autotune=args.tol_autotune
        )
        failures.extend(kfail)
        notes.extend(knotes)
        n_kernels = len(kb.get("kernels", []))
    for n in notes:
        print(f"NOTE  {n}")
    for f_ in failures:
        print(f"FAIL  {f_}")
    n_cells = len(baseline.get("cells", []))
    scope = f"{n_cells} baseline cells" + (f" + {n_kernels} kernels" if n_kernels else "")
    if failures:
        print(f"# perf gate: {len(failures)} regression(s) across {scope}")
        return 1
    print(f"# perf gate: OK ({scope} within tolerance)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
