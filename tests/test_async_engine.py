"""Event-driven async scheduler: per-site queues, barrier removal, rescue
resume, retries, speculation determinism, the async<=staged invariant, and
the split critical-path accounting (compute vs transfer) in RunReport."""

import json

import pytest

from repro.workflow.dag import DAG, TimedResult
from repro.workflow.engine import Engine
from repro.workflow.faults import FaultInjector
from repro.workflow.overhead import (
    GridModel,
    JobSpec,
    estimate_dag,
    estimate_stages_from_specs,
)

ZERO = dict(prep_latency_s=0, submit_latency_s=0)


def sim(value=None):
    """A job fn whose measured compute is exactly 0 (TimedResult), so the
    simulated clock advances by sim_compute_s alone — deterministic."""
    return lambda *a: TimedResult(value, 0.0)


def zero_engine(**kw):
    return Engine(model=GridModel(**ZERO, **kw.pop("model_kw", {})), schedule="async", **kw)


def dag_from_specs(specs, times=None):
    """Replay a workflow topology with simulated compute — identical DAG,
    model and 'seed' (times) across schedule modes, zero timing noise."""
    from repro.workflow.sitejob import replay_dag

    return replay_dag(specs, times)


class TestAsyncExecution:
    def test_topological_execution_and_results(self):
        calls = []
        dag = DAG("diamond")
        dag.job("a", lambda: calls.append("a") or 1)
        dag.job("b", lambda a: calls.append("b") or a + 1, deps=["a"])
        dag.job("c", lambda a: calls.append("c") or a + 2, deps=["a"])
        dag.job("d", lambda b, c: calls.append("d") or b + c, deps=["b", "c"])
        results = {}
        rep = zero_engine().run(dag, results=results)
        assert calls[0] == "a" and calls[-1] == "d"
        assert results["d"] == 5
        assert rep.schedule == "async"
        assert rep.wall_s >= rep.critical_path_s

    def test_per_site_queue_serializes_contention(self):
        """3 jobs on one site with 1 worker slot run back-to-back; with 3
        slots they run concurrently."""

        def mk():
            dag = DAG()
            for i in range(3):
                dag.job(f"j{i}", sim(), site=2, sim_compute_s=1.0)
            return dag

        one = Engine(
            model=GridModel(**ZERO, workers_per_site=1), schedule="async"
        ).run(mk())
        three = Engine(
            model=GridModel(**ZERO, workers_per_site=3), schedule="async"
        ).run(mk())
        assert one.wall_s == pytest.approx(3.0)
        assert three.wall_s == pytest.approx(1.0)

    def test_no_stage_barrier_beats_staged(self):
        """A fast chain no longer waits for a slow sibling at each wave:
        staged pays max-per-wave, async pays the true critical path."""
        specs = [
            JobSpec("a0", (), 1.0, site=1),
            JobSpec("b0", (), 3.0, site=2),
            JobSpec("a1", ("a0",), 3.0, site=1),
            JobSpec("b1", ("b0",), 0.1, site=2),
        ]
        staged = Engine(model=GridModel(**ZERO)).run(dag_from_specs(specs))
        async_ = zero_engine().run(dag_from_specs(specs))
        assert staged.wall_s == pytest.approx(6.0)  # max(1,3) + max(3,0.1)
        assert async_.wall_s == pytest.approx(4.0)  # the a-chain
        assert async_.wall_s < staged.wall_s


class TestAsyncFaults:
    def test_retry_recovers(self):
        dag = DAG()
        dag.job("flaky", lambda: 42, retries=2)
        eng = zero_engine(faults=FaultInjector(fail={"flaky": 2}))
        results = {}
        rep = eng.run(dag, results=results)
        assert results["flaky"] == 42
        assert dag.jobs["flaky"].attempts == 3
        assert rep.retries == 2

    def test_retry_exhaustion_raises(self):
        dag = DAG()
        dag.job("doomed", lambda: 1, retries=1)
        eng = zero_engine(faults=FaultInjector(fail={"doomed": 5}))
        with pytest.raises(RuntimeError, match="exhausted"):
            eng.run(dag)

    def test_rescue_resume_mid_dag(self, tmp_path):
        """Crash mid-DAG: completed prefix is in the rescue file; the
        resumed run re-executes only the unfinished suffix."""
        rescue = tmp_path / "rescue.json"
        calls = []

        def mk():
            dag = DAG()
            dag.job("a", lambda: calls.append("a") or 1)
            dag.job("b", lambda a: calls.append("b") or a + 1, deps=["a"])
            dag.job("boom", lambda b: calls.append("boom") or b, deps=["b"], retries=0)
            dag.job("tail", lambda x: calls.append("tail") or x + 10, deps=["boom"])
            return dag

        eng = zero_engine(faults=FaultInjector(fail={"boom": 5}), rescue_path=rescue)
        with pytest.raises(RuntimeError, match="exhausted"):
            eng.run(mk())
        assert set(json.loads(rescue.read_text())) == {"a", "b"}
        assert calls == ["a", "b"]

        eng2 = zero_engine(rescue_path=rescue)
        results = {"a": 1, "b": 2}  # rescued values re-injected by the driver
        rep = eng2.run(mk(), results=results)
        assert calls == ["a", "b", "boom", "tail"], "prefix must not re-execute"
        assert results["tail"] == 12
        assert rep.wall_s >= 0.0


class TestAsyncSpeculation:
    def mk(self):
        dag = DAG()
        for i in range(3):
            dag.job(f"fast{i}", sim(), site=i, sim_compute_s=1.0)
        dag.job("straggler", sim(), site=3, sim_compute_s=10.0)
        return dag

    def test_speculative_copy_wins(self):
        rep = zero_engine(straggler_factor=3.0).run(self.mk())
        assert rep.speculative == 1
        # the duplicate finishes with the sample median, not 10 s
        assert rep.wall_s == pytest.approx(1.0)
        base = zero_engine().run(self.mk())
        assert base.wall_s == pytest.approx(10.0)

    def test_speculation_deterministic(self):
        """Pure simulated compute: two runs replay identically — same
        wall, same speculative count, same per-job times."""
        a = zero_engine(straggler_factor=3.0).run(self.mk())
        b = zero_engine(straggler_factor=3.0).run(self.mk())
        assert a.wall_s == b.wall_s
        assert a.speculative == b.speculative == 1
        assert a.job_times == b.job_times
        assert a.critical_compute_s == b.critical_compute_s
        assert a.critical_transfer_s == b.critical_transfer_s

    def test_early_straggler_detected_online(self):
        """A straggler that STARTS before enough peers have been observed
        must still be speculated once the evidence exists (detection is
        re-evaluated at every later start, and the superseded finish event
        must not stretch the wall)."""
        dag = DAG()
        dag.job("straggler", sim(), site=3, sim_compute_s=10.0)  # first!
        for i in range(3):
            dag.job(f"fast{i}", sim(), site=i, sim_compute_s=1.0)
        rep = zero_engine(straggler_factor=3.0).run(dag)
        assert rep.speculative == 1
        assert rep.wall_s == pytest.approx(1.0)

    def test_duplicate_pays_its_own_staging(self):
        """The speculative copy stages the input to its slot — it cannot
        'finish' before its input could physically arrive, and the
        critical-path compute credit never goes negative."""
        m = GridModel(**ZERO)
        dag = DAG()
        dag.job("heavy", sim(), site=1, input_bytes=10**8, sim_compute_s=100.0)
        for i in range(3):
            dag.job(f"fast{i}", sim(), site=2 + i, sim_compute_s=1.0)
        rep = Engine(model=m, schedule="async", straggler_factor=3.0).run(dag)
        assert rep.speculative == 1
        # the duplicate's win still includes a full input staging leg
        min_staging = min(
            m.transfer_s(0, s, 10**8) for s in range(5) if s != 1
        )
        assert rep.wall_s >= min_staging
        assert rep.critical_compute_s > 0
        assert 0.0 <= rep.overhead_pct() <= 100.0

    def test_deferred_speculation_fires_when_slot_frees(self):
        """Detection blocked by a full grid is retried at slot release: the
        straggler's duplicate launches as soon as capacity exists."""
        dag = DAG()
        dag.job("straggler", sim(), site=0, sim_compute_s=10.0)
        for i in range(3):
            dag.job(f"fast{i}", sim(), site=1, sim_compute_s=1.0)
        rep = Engine(
            model=GridModel(**ZERO, workers_per_site=1),
            schedule="async",
            straggler_factor=3.0,
        ).run(dag)
        assert rep.speculative == 1
        # fast jobs serialize on site 1 (finish 1,2,3); at t=3 the slot
        # frees, the duplicate runs the 1 s median -> done at 4, not 10
        assert rep.wall_s == pytest.approx(4.0)

    def test_no_speculation_when_grid_full(self):
        """The duplicate needs a second free slot; with every slot busy the
        straggler runs to completion."""
        dag = DAG()
        for i in range(4):
            dag.job(f"j{i}", sim(), site=0, sim_compute_s=1.0)
        dag.job("straggler", sim(), site=0, sim_compute_s=10.0)
        rep = Engine(
            model=GridModel(**ZERO, workers_per_site=1),
            schedule="async",
            straggler_factor=3.0,
        ).run(dag)
        assert rep.speculative == 0
        assert rep.wall_s == pytest.approx(14.0)


class TestAsyncLeqStagedInvariant:
    """async wall <= staged wall on identical DAG/model/seed — replayed
    with the applications' own smoke topologies and deterministic
    simulated compute, under both submit models."""

    def app_specs(self):
        import jax

        from repro.core.apriori import TransactionDB
        from repro.core.gfm import gfm_site_jobs
        from repro.core.vclustering import VClusterConfig, vcluster_site_jobs
        from repro.data.synthetic import (
            gaussian_mixture,
            ibm_transactions,
            split_sites,
            split_transactions,
        )
        from repro.workflow.sitejob import job_specs

        pts, _ = gaussian_mixture(0, 400, 2, 4, spread=12.0, sigma=0.5)
        xs = split_sites(pts, 4, seed=1)
        cfg = VClusterConfig(k_local=4, kmeans_iters=5)
        vjobs = vcluster_site_jobs(jax.random.PRNGKey(0), xs, cfg)

        dense = ibm_transactions(seed=2, n_tx=200, n_items=16, avg_tx_len=5, n_patterns=4)
        sites = [TransactionDB.from_dense(s) for s in split_transactions(dense, 4, seed=0)]
        gjobs = gfm_site_jobs(sites, 2, 0.1)
        return {"vclustering": job_specs(vjobs), "gfm": job_specs(gjobs)}

    @pytest.mark.parametrize("overlap", [False, True])
    def test_async_wall_leq_staged(self, overlap):
        for app, specs in self.app_specs().items():
            times = {sp.name: 0.05 * (i % 3 + 1) for i, sp in enumerate(specs)}
            walls = {}
            for schedule in ("staged", "async"):
                eng = Engine(model=GridModel(), overlap_prep=overlap, schedule=schedule)
                walls[schedule] = eng.run(dag_from_specs(specs, times)).wall_s
            assert walls["async"] <= walls["staged"] + 1e-9, (app, overlap, walls)


class TestCriticalPathAccounting:
    def test_transfer_separated_from_compute(self):
        """The regression this fixes: the critical path's staging used to be
        folded into a compute-named field, so overhead_pct undercounted
        transfer.  Now staging is overhead."""
        m = GridModel(**ZERO)
        nbytes = 10**7
        dag = DAG()
        dag.job("move", sim(), site=1, input_bytes=nbytes, sim_compute_s=2.0)
        rep = Engine(model=m).run(dag)  # staged
        tr = m.transfer_s(0, 1, nbytes)
        assert rep.critical_transfer_s == pytest.approx(tr)
        assert rep.critical_compute_s == pytest.approx(2.0)
        assert rep.max_stage_compute_s == pytest.approx(tr + 2.0)  # compat alias
        assert rep.wall_s == pytest.approx(tr + 2.0)
        assert rep.overhead_pct() == pytest.approx(100.0 * tr / (tr + 2.0))

    def test_async_accounting_matches_staged_on_chain(self):
        specs = [
            JobSpec("a", (), 1.0, input_bytes=10**6, site=1),
            JobSpec("b", ("a",), 2.0, output_bytes=10**6, site=2),
        ]
        staged = Engine(model=GridModel(**ZERO)).run(dag_from_specs(specs))
        async_ = zero_engine().run(dag_from_specs(specs))
        for rep in (staged, async_):
            assert rep.critical_compute_s == pytest.approx(3.0)
            assert rep.critical_transfer_s > 0
        assert async_.wall_s == pytest.approx(staged.wall_s)


class TestEstimateDag:
    M = GridModel()

    def test_chain_is_sum(self):
        specs = [
            JobSpec("a", (), 1.0),
            JobSpec("b", ("a",), 2.0),
            JobSpec("c", ("b",), 3.0),
        ]
        assert estimate_dag(specs, self.M) == pytest.approx(6.0)

    def test_fork_join_takes_longest_branch(self):
        specs = [
            JobSpec("a", (), 1.0),
            JobSpec("fast", ("a",), 1.0),
            JobSpec("slow", ("a",), 5.0),
            JobSpec("join", ("fast", "slow"), 1.0),
        ]
        assert estimate_dag(specs, self.M) == pytest.approx(7.0)

    def test_order_independent(self):
        specs = [
            JobSpec("join", ("x", "y"), 1.0),
            JobSpec("y", ("x",), 2.0),
            JobSpec("x", (), 1.0),
        ]
        assert estimate_dag(specs, self.M) == pytest.approx(4.0)

    def test_dag_bound_leq_staged_bound(self):
        """Per-job overlap can only tighten the stage-barrier estimate."""
        specs = [
            JobSpec("a0", (), 1.0, 10**6, 0, 1),
            JobSpec("b0", (), 3.0, 10**6, 0, 2),
            JobSpec("a1", ("a0",), 3.0, 0, 10**5, 1),
            JobSpec("b1", ("b0",), 0.5, 0, 10**5, 2),
        ]
        assert estimate_dag(specs, self.M) <= estimate_stages_from_specs(specs, self.M) + 1e-12

    def test_lan_links_faster_than_grid5000(self):
        specs = [JobSpec("a", (), 1.0, 10**7, 10**7, 2)]
        wan = estimate_dag(specs, GridModel(links="grid5000"))
        lan = estimate_dag(specs, GridModel(links="lan"))
        assert lan < wan

    def test_engine_wall_lower_bounded_by_estimate(self):
        """The analytical bound is a true lower bound on the async engine's
        simulated wall (which adds prep/submit/contention)."""
        specs = [
            JobSpec("a", (), 1.0, 10**6, 0, 1),
            JobSpec("b", ("a",), 2.0, 0, 10**5, 2),
        ]
        rep = Engine(model=GridModel(), schedule="async").run(dag_from_specs(specs))
        assert rep.wall_s >= estimate_dag(specs, GridModel()) - 1e-9
